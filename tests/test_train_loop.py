"""End-to-end training loop tests, including the GDT offload integration:
tier migrations must never change numerics, only placement."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core import GuidanceConfig
from repro.core.placement import memory_kind_of
from repro.data import SyntheticLM
from repro.models import build_model
from repro.optim import AdamW
from repro.train import StepConfig, Trainer, TrainerConfig

MB = 2**20


def tiny_model():
    cfg = get_smoke("llama3_2_1b")
    return build_model(dataclasses.replace(cfg, remat=False))


def batches(model, n_steps, batch=4, seq=64):
    src = SyntheticLM(model.cfg.vocab, seq, batch, seed=3)
    out = []
    for i in range(n_steps + 1):
        b = src.batch_np(i)
        out.append({k: jnp.asarray(v) for k, v in b.items()})
    return out


def test_loss_decreases():
    model = tiny_model()
    opt = AdamW(lr=1e-2, weight_decay=0.0)
    tr = Trainer(model, opt, TrainerConfig(steps=60, log_every=1, seed=0))
    res = tr.run(iter(batches(model, 60, batch=16)))
    losses = [m["loss"] for m in tr.metrics_log]
    assert min(losses[-5:]) < losses[0] * 0.95
    assert np.isfinite(losses).all()


def test_grad_accumulation_matches_full_batch():
    model = tiny_model()
    opt = AdamW(lr=1e-3, weight_decay=0.0, grad_clip=None)
    data = batches(model, 2, batch=8, seq=32)

    tr1 = Trainer(model, opt, TrainerConfig(steps=1, log_every=1),
                  rng=jax.random.PRNGKey(1))
    tr2 = Trainer(model, opt,
                  TrainerConfig(steps=1, log_every=1,
                                step=StepConfig(accum=4)),
                  rng=jax.random.PRNGKey(1))
    tr1.run(iter(data))
    tr2.run(iter(data))
    l1 = jax.tree.leaves(tr1.params)
    l2 = jax.tree.leaves(tr2.params)
    for a, b in zip(l1, l2):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-2)


def test_int8_compression_tracks_uncompressed():
    """The lossy gradient channel must not visibly derail optimization:
    the int8 run's loss trajectory stays within a few percent of the
    uncompressed run's."""
    model = tiny_model()
    opt = AdamW(lr=1e-2, weight_decay=0.0)
    data = batches(model, 30, batch=16)
    tr_plain = Trainer(model, opt, TrainerConfig(steps=30, log_every=1),
                       rng=jax.random.PRNGKey(2))
    tr_plain.run(iter(data))
    tr_int8 = Trainer(model, opt,
                      TrainerConfig(steps=30, log_every=1,
                                    step=StepConfig(compression="int8")),
                      rng=jax.random.PRNGKey(2))
    tr_int8.run(iter(data))
    lp = [m["loss"] for m in tr_plain.metrics_log]
    li = [m["loss"] for m in tr_int8.metrics_log]
    assert li[-1] < lp[-1] * 1.05
    assert li[-1] < li[0]          # and it is actually improving


from conftest import has_host_memory


@pytest.mark.skipif(not has_host_memory(),
                    reason="backend lacks pinned_host memory kind")
def test_gdt_offload_preserves_numerics_and_migrates():
    """Under a tight HBM budget the controller offloads cold groups (adam
    moments mostly); loss trajectory must match the non-tiered run exactly
    because migration never alters values."""
    model = tiny_model()
    opt = AdamW(lr=3e-3, weight_decay=0.0)
    data = batches(model, 25)

    tr_plain = Trainer(model, opt, TrainerConfig(steps=25, log_every=1),
                       rng=jax.random.PRNGKey(5))
    res_plain = tr_plain.run(iter(data))

    # Budget ~60% of total state -> something must live on the host tier.
    state_bytes = sum(
        a.size * a.dtype.itemsize
        for a in jax.tree.leaves(tr_plain.params))
    state_bytes += sum(
        a.size * a.dtype.itemsize
        for a in jax.tree.leaves(tr_plain.opt_state.m)) * 2
    gdt = GuidanceConfig(enabled=True, strategy="thermos",
                    fast_capacity_bytes=int(state_bytes * 0.6),
                    interval_steps=5, promotion_threshold=1024)
    tr_gdt = Trainer(model, opt,
                     TrainerConfig(steps=25, log_every=1, gdt=gdt),
                     rng=jax.random.PRNGKey(5))
    res_gdt = tr_gdt.run(iter(data))

    pl = [m["loss"] for m in tr_plain.metrics_log]
    gl = [m["loss"] for m in tr_gdt.metrics_log]
    np.testing.assert_allclose(pl, gl, rtol=1e-5, atol=1e-5)

    # Something actually lives on the slow tier and transfers happened.
    assert tr_gdt.placer.slow_bytes() > 0
    assert tr_gdt.placer.transfers_bytes > 0
    kinds = {memory_kind_of(e.array)
             for entries in tr_gdt.placer._store.values() for e in entries}
    assert "pinned_host" in kinds


def test_checkpoint_restart_in_trainer(tmp_path):
    model = tiny_model()
    opt = AdamW(lr=1e-3, weight_decay=0.0)
    data = batches(model, 12)
    tr = Trainer(model, opt,
                 TrainerConfig(steps=10, log_every=1, ckpt_every=5,
                               ckpt_dir=str(tmp_path), seed=0))
    tr.run(iter(data))
    tr2 = Trainer(model, opt, TrainerConfig(steps=1, log_every=1,
                                            ckpt_dir=str(tmp_path), seed=0))
    meta = tr2.restore_checkpoint()
    assert meta["step"] == 10
    for a, b in zip(jax.tree.leaves(tr.params), jax.tree.leaves(tr2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
