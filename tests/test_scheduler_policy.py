"""Scheduler-policy conformance suite (the `TierBackend` conformance
pattern applied to scheduling): EVERY registered policy must preserve
bitwise sampled streams under preemption-by-recompute and chunked-prefill
interleaving, finish leak-free, and never starve a request — policies may
reorder service, never change it.

Plus the policy-layer unit surface: registry errors, per-policy ordering
semantics on synthetic requests, Engine.cancel lifecycle, and the new
queue/admission-wait stats."""

import dataclasses
import types

import jax
import pytest

from repro.configs import get_smoke
from repro.models import build_model
from repro.serve import (
    LLM,
    Engine,
    Request,
    SamplingParams,
    ServeConfig,
    make_scheduler_policy,
)

POLICIES = ["fifo", "priority", "drr"]

N_REQ = 4
PROMPTS = [[(7 * i + j) % 50 + 1 for j in range(5 + 3 * i)]
           for i in range(N_REQ)]


def params_for(i):
    """Sampled (not greedy) params with varied scheduling metadata, so the
    bitwise comparison exercises the PRNG position-fold under every
    policy's reordering."""
    return SamplingParams(
        temperature=0.8, seed=100 + i, max_tokens=5,
        tenant="ab"[i % 2], priority=i % 3,
        deadline_steps=8 if i % 2 else None)


@pytest.fixture(scope="module")
def model_and_params():
    cfg = dataclasses.replace(get_smoke("llama3_2_1b"), remat=False)
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def reference_streams(model_and_params):
    """Unloaded reference: each request runs ALONE on a roomy engine —
    its stream depends only on (seed, positions), so this is what every
    policy/chunking/preemption combination must reproduce bitwise."""
    model, params = model_and_params
    eng = Engine(model, params,
                 ServeConfig(max_batch=2, page_size=4, hbm_pages=32,
                             host_pages=32))
    streams = {}
    for i in range(N_REQ):
        eng.add_request(i, PROMPTS[i], params=params_for(i))
        for _ in range(64):
            eng.step()
            if i in eng.finished:
                break
        streams[i] = list(eng.pop_finished(i).generated)
        assert len(streams[i]) == 5
    return streams


def drain(eng, max_steps=300):
    for _ in range(max_steps):
        eng.step()
        if not eng.requests and not eng.wait_queue:
            return
    raise AssertionError(
        f"engine did not drain: live={list(eng.requests)} "
        f"queue={list(eng.wait_queue)}")


# ----------------------------------------------------------- conformance
@pytest.mark.parametrize("chunk", [0, 3], ids=["eager", "interleaved"])
@pytest.mark.parametrize("policy", POLICIES)
def test_policy_preserves_streams_under_churn(model_and_params,
                                              reference_streams,
                                              policy, chunk):
    """Concurrent load + forced pause/resume churn + a pool small enough
    to preempt: whatever the policy reorders, every request's sampled
    stream must equal its unloaded solo run bitwise, and the engine must
    finish leak-free."""
    model, params = model_and_params
    cfg = ServeConfig(max_batch=2, page_size=4, hbm_pages=8, host_pages=8,
                      scheduler=policy, prefill_chunk_tokens=chunk)
    eng = Engine(model, params, cfg)
    for i in range(N_REQ):
        eng.add_request(i, PROMPTS[i], params=params_for(i))
    for step in range(300):
        # Deterministic churn: periodically park whichever live request
        # has the smallest id, so paused victims exist for preemption.
        live = sorted(r for r in eng.requests
                      if eng.requests[r].state == "active")
        if step % 5 == 1 and live:
            eng.pause(live[0])
        elif step % 5 == 3:
            for rid in list(eng.requests):
                eng.resume(rid)
        eng.step()
        if not eng.requests and not eng.wait_queue:
            break
    assert not eng.requests and not eng.wait_queue
    for i in range(N_REQ):
        got = list(eng.finished[i].generated)
        assert got == reference_streams[i], (
            f"policy={policy} chunk={chunk} req={i}: stream diverged")
    # Leak-free finish: no pages owned, both free lists whole again.
    assert not eng.pool.pages
    assert len(eng.pool.free_hbm) == cfg.hbm_pages - 1   # minus scratch
    assert len(eng.pool.free_host) == cfg.host_pages


@pytest.mark.parametrize("policy", POLICIES)
def test_no_starvation_under_long_prefill_load(model_and_params, policy):
    """The interleaving guarantee, under every policy: requests already
    DECODING keep producing tokens while a 40-token prompt drips through
    chunked prefill — the shorts finish before the long prompt's first
    token, and the long request still drains (nobody starves)."""
    model, params = model_and_params
    eng = Engine(model, params,
                 ServeConfig(max_batch=2, page_size=4, hbm_pages=32,
                             host_pages=32, max_pages_per_seq=16,
                             scheduler=policy, prefill_chunk_tokens=4))
    for i in (1, 2):
        eng.add_request(i, PROMPTS[i % N_REQ],
                        params=SamplingParams(max_tokens=6,
                                              priority=1, tenant="b"))
    while any(eng.requests[i].state == "prefilling" for i in (1, 2)):
        eng.step()                       # let the shorts reach decode
    long_prompt = [(3 * j) % 40 + 1 for j in range(40)]
    eng.add_request(0, long_prompt, params=SamplingParams(max_tokens=2))
    assert eng.requests[0].state == "prefilling"
    short_done_at = {}
    long_first_token = None
    for step in range(1, 200):
        out = eng.step()
        if 0 in out and long_first_token is None:
            long_first_token = step
        for i in (1, 2):
            if i in eng.finished and i not in short_done_at:
                short_done_at[i] = step
        if not eng.requests and not eng.wait_queue:
            break
    assert not eng.requests and not eng.wait_queue, "starved"
    assert long_first_token is not None
    assert set(short_done_at) == {1, 2}
    for i, at in short_done_at.items():
        assert at < long_first_token, (
            f"policy={policy}: short request {i} finished at step {at}, "
            f"after the 40-token prefill's first token ({long_first_token})"
            f" — interleaving failed to protect decode")


# ------------------------------------------------------------- registry
def test_unknown_policy_raises_naming_the_knob(model_and_params):
    model, params = model_and_params
    with pytest.raises(ValueError, match="ServeConfig.scheduler"):
        Engine(model, params, ServeConfig(scheduler="lifo"))


def test_fresh_policy_instance_per_engine():
    a, b = make_scheduler_policy("drr"), make_scheduler_policy("drr")
    assert a is not b
    a.deficit["t"] = 99.0
    assert "t" not in b.deficit


# -------------------------------------------------- ordering unit tests
def _req(rid, priority=0, tenant="default", deadline=None, queued=0,
         last_scheduled=0):
    return Request(
        request_id=rid, tokens=[1], max_new=1,
        params=SamplingParams(priority=priority, tenant=tenant,
                              deadline_steps=deadline),
        queued_step=queued, last_scheduled=last_scheduled)


def _fake_engine(reqs=(), chunk=0, max_batch=4):
    return types.SimpleNamespace(
        cfg=types.SimpleNamespace(prefill_chunk_tokens=chunk,
                                  max_batch=max_batch),
        requests={r.request_id: r for r in reqs})


def test_fifo_admission_is_queue_order():
    pol = make_scheduler_policy("fifo")
    reqs = [_req(3), _req(1), _req(2)]
    assert [r.request_id
            for r in pol.admission_order(reqs, _fake_engine(reqs))] \
        == [3, 1, 2]


def test_priority_orders_by_class_then_deadline():
    pol = make_scheduler_policy("priority")
    lo = _req(0, priority=0)
    hi_late = _req(1, priority=2, deadline=50, queued=0)
    hi_soon = _req(2, priority=2, deadline=5, queued=0)
    mid = _req(3, priority=1)
    order = pol.admission_order([lo, hi_late, hi_soon, mid],
                                _fake_engine([lo, hi_late, hi_soon, mid]))
    assert [r.request_id for r in order] == [2, 1, 3, 0]
    # Preemption inverts: the lowest class pays first.
    assert pol.preempt_paused([lo, hi_soon, mid], None).request_id == 0


def test_drr_served_tenant_yields_to_starved_tenant():
    pol = make_scheduler_policy("drr")
    a, b = _req(0, tenant="a"), _req(1, tenant="b")
    eng = _fake_engine([a, b])
    pol.on_step(eng)                       # both earn one quantum
    pol.on_tokens(a, pol.quantum * 2, eng)      # tenant a over-served
    order = pol.decode_order([a, b], eng)
    assert [r.request_id for r in order] == [1, 0]
    # Preemption charges the over-served (poorest-deficit) tenant.
    assert pol.preempt_paused([a, b], eng).request_id == 0
    # Idle tenants bank nothing across steps.
    eng.requests.pop(0)
    pol.on_step(eng)
    assert "a" not in pol.deficit


def test_drr_deficit_is_capped():
    pol = make_scheduler_policy("drr")
    r = _req(0, tenant="t")
    eng = _fake_engine([r])
    for _ in range(pol.cap_steps * 3):
        pol.on_step(eng)
    assert pol.deficit["t"] == pol.quantum * pol.cap_steps


# ------------------------------------------------------ cancel lifecycle
def test_cancel_lifecycle_and_stats(model_and_params):
    model, params = model_and_params
    llm = LLM(model, params,
              ServeConfig(max_batch=2, page_size=4, hbm_pages=32,
                          host_pages=32))
    # Active request with tokens already streamed: cancel ends the handle
    # with a final (token, "cancelled") delta and keeps the tokens.
    h = llm.submit([1, 2, 3], SamplingParams(max_tokens=50))
    llm.step()
    llm.step()
    llm.cancel(h.request_id)
    deltas = list(h)
    assert h.finish_reason == "cancelled"
    assert deltas[-1][1] == "cancelled"
    assert len(h.token_ids) == 2
    assert h.result().finish_reason == "cancelled"
    # A never-stepped (waiting-or-active, zero tokens) cancel emits the
    # tokenless final delta.
    h2 = llm.submit([4, 5], SamplingParams(max_tokens=50))
    llm.cancel(h2.request_id)
    assert list(h2) == [(None, "cancelled")]
    # Stats + lifecycle errors.  The LLM absorbed the finished result, so
    # a second cancel sees an id the cluster no longer tracks.
    s = llm.stats()
    assert s["finished_cancelled"] == 2
    with pytest.raises(ValueError, match="unknown id"):
        llm.cancel(h.request_id)
    with pytest.raises(ValueError, match="unknown id"):
        llm.cancel(999)
    # Cancel of a PAUSED page-holder frees its pages immediately.
    h3 = llm.submit([1, 2, 3, 4, 5], SamplingParams(max_tokens=50))
    llm.step()
    llm.pause(h3.request_id)
    assert llm.engine.pool.request_pages(h3.request_id)
    llm.cancel(h3.request_id)
    assert not llm.engine.pool.request_pages(h3.request_id)
    assert not llm.engine.pool.pages
    assert h3.result().finish_reason == "cancelled"


def test_queue_depth_and_admission_wait_stats(model_and_params):
    """queue_depth counts LIVE waiting requests; admission wait accrues in
    steps between enqueue and admission."""
    model, params = model_and_params
    eng = Engine(model, params,
                 ServeConfig(max_batch=1, page_size=2, hbm_pages=5,
                             host_pages=0, max_pages_per_seq=4))
    eng.add_request(0, [1, 2, 3, 4, 5], max_new=4)    # holds the pool
    eng.add_request(1, [1, 2, 3, 4, 5], max_new=4)    # must wait
    assert eng.requests[1].state == "waiting"
    assert eng.stats()["queue_depth"] == 1
    drain(eng)
    s = eng.stats()
    assert s["queue_depth"] == 0
    assert s["admissions"] >= 2
    assert s["admission_wait_steps"] > 0       # request 1 waited
    assert s["mean_admission_wait_steps"] == pytest.approx(
        s["admission_wait_steps"] / s["admissions"])
    # Engine-level cancel of an undrained finished result names the state.
    with pytest.raises(ValueError, match="already finished"):
        eng.cancel(0)
