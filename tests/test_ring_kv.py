"""Ring-buffer KV cache (sliding-window archs): decode past the window must
match the full-sequence windowed-attention forward exactly (§Perf climb #3)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import build_model


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_smoke("mixtral_8x7b"), remat=False)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_cache_is_ring_sized(setup):
    cfg, model, _ = setup
    cache = model.init_cache(2, 512)
    assert cache["kv"]["k"].shape[2] == cfg.window  # 64 in smoke


def test_ring_decode_matches_full_forward(setup):
    """Step-by-step ring decode vs prefill (full windowed attention) at
    positions beyond the window."""
    cfg, model, params = setup
    W = cfg.window
    S = W + 24                      # well past one ring wrap
    rng = np.random.default_rng(0)
    tokens = rng.integers(1, cfg.vocab, S).astype(np.int32)

    # Ring decode the whole sequence.
    cache = model.init_cache(1, S)
    decode = jax.jit(model.decode)
    ring_logits = {}
    for t in range(S):
        logits, cache = decode(params, cache,
                               jnp.asarray([[tokens[t]]]), jnp.int32(t))
        ring_logits[t] = np.asarray(logits[0], np.float32)

    # Full-sequence windowed forward at selected positions.
    prefill = jax.jit(model.prefill)
    for t in [W - 2, W, W + 5, S - 1]:
        batch = {"tokens": jnp.asarray(tokens[: t + 1][None])}
        cache0 = model.init_cache(1, t + 1)
        full_logits, _ = prefill(params, batch, cache0)
        full = np.asarray(full_logits[0], np.float32)
        # bf16 path noise between chunked-prefill and decode attention is
        # ~0.1 absolute on logits; the argmax must agree exactly.
        np.testing.assert_allclose(ring_logits[t], full, atol=0.15, rtol=0.05)
        assert ring_logits[t].argmax() == full.argmax(), t


def test_prefill_ring_then_decode_continues(setup):
    """Prefill a prompt longer than the window, then keep decoding on the
    ring; must equal pure step-by-step ring decode."""
    cfg, model, params = setup
    W = cfg.window
    S = W + 10
    rng = np.random.default_rng(1)
    tokens = rng.integers(1, cfg.vocab, S).astype(np.int32)

    # Path A: prefill the full prompt, then decode 4 more greedily.
    cache = model.init_cache(1, S)
    batch = {"tokens": jnp.asarray(tokens[None])}
    logits, cache = jax.jit(model.prefill)(params, batch, cache)
    decode = jax.jit(model.decode)
    a = []
    pos = S
    for _ in range(4):
        nxt = int(jnp.argmax(logits[0]))
        a.append(nxt)
        logits, cache = decode(params, cache, jnp.asarray([[nxt]]),
                               jnp.int32(pos))
        pos += 1

    # Path B: pure step-by-step decode of the same prompt.
    cache = model.init_cache(1, S)
    for t in range(S):
        logits_b, cache = decode(params, cache, jnp.asarray([[tokens[t]]]),
                                 jnp.int32(t))
    b = []
    pos = S
    for _ in range(4):
        nxt = int(jnp.argmax(logits_b[0]))
        b.append(nxt)
        logits_b, cache = decode(params, cache, jnp.asarray([[nxt]]),
                                 jnp.int32(pos))
        pos += 1
    assert a == b
