"""Front-door generation API tests: ``LLM.generate``/``submit`` blocking and
streaming semantics, greedy bitwise-equality with the contiguous-cache
reference on dense + MoE configs, sampled preemption-replay determinism
(the PR's extension of the bitwise-equality invariant from logits to
tokens), and finish reasons end-to-end through ``pop_finished``,
``stats()`` and ``serving_summary``."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.launch.analysis import serving_summary
from repro.models import build_model
from repro.serve import (
    DEFAULT_MAX_TOKENS,
    Engine,
    LLM,
    SamplingParams,
    ServeConfig,
)


@pytest.fixture(scope="module")
def model_and_params():
    cfg = dataclasses.replace(get_smoke("llama3_2_1b"), remat=False)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


@pytest.fixture(scope="module")
def moe_model_and_params():
    cfg = dataclasses.replace(get_smoke("granite_moe_3b_a800m"), remat=False)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def greedy_reference(model, params, prompt, n_new, cache_len=64):
    """Contiguous-cache greedy decode (the model's own serve path)."""
    cache = model.init_cache(1, cache_len)
    logits = None
    for t, tok in enumerate(prompt):
        logits, cache = jax.jit(model.decode)(
            params, cache, jnp.asarray([[tok]], jnp.int32), jnp.int32(t))
    out = []
    pos = len(prompt)
    for _ in range(n_new):
        nxt = int(jnp.argmax(logits[0]))
        out.append(nxt)
        logits, cache = jax.jit(model.decode)(
            params, cache, jnp.asarray([[nxt]], jnp.int32), jnp.int32(pos))
        pos += 1
    return out


def small_cfg(**kw):
    base = dict(max_batch=2, page_size=4, hbm_pages=32, host_pages=64,
                policy="gdt", interval_steps=4)
    base.update(kw)
    return ServeConfig(**base)


# ===================================================== greedy equivalence
@pytest.mark.parametrize("family", ["dense", "moe"])
def test_generate_greedy_bitwise_equals_reference(
        family, model_and_params, moe_model_and_params):
    """``LLM.generate`` at temperature=0 (the default) is bitwise-equal to
    the contiguous-cache greedy decode — the acceptance equality that makes
    the new front door a strict superset of the old engine."""
    model, params = (model_and_params if family == "dense"
                     else moe_model_and_params)
    prompt = [5, 17, 133, 42, 7, 99, 250, 3]
    ref = greedy_reference(model, params, prompt, 6)
    llm = LLM(model, params, small_cfg())
    out = llm.generate([prompt], SamplingParams(max_tokens=6))[0]
    assert out.token_ids == ref
    assert out.finish_reason == "length"
    assert out.prompt_token_ids == prompt


# =============================================== sampled replay invariants
def test_sampled_preemption_replay_identical_stream(model_and_params):
    """A seeded sampled request preempted mid-generation (all pages
    dropped, prompt+generated recomputed on resume) finishes with the
    IDENTICAL token stream as a never-preempted twin: the per-token PRNG
    folds the absolute stream position, so recompute never resamples
    history and continues exactly where it left off."""
    model, params = model_and_params
    prompt_a = [3, 1, 4, 1, 5, 9]
    prompt_b = [2, 7, 1, 8, 2, 8, 1, 8]
    sp = SamplingParams(temperature=0.9, top_k=50, top_p=0.95, seed=123,
                        max_tokens=4)

    twin = Engine(model, params,
                  ServeConfig(max_batch=1, page_size=2, hbm_pages=16,
                              host_pages=32))
    twin.add_request(0, prompt_a, params=sp)
    while 0 in twin.requests:
        twin.step()

    eng = Engine(model, params,
                 ServeConfig(max_batch=1, page_size=2, hbm_pages=7,
                             host_pages=1))
    eng.add_request(0, prompt_a, params=sp)
    eng.step()                                    # sample 1 token
    eng.pause(0)
    eng.add_request(1, prompt_b, max_new=2)       # forces full preemption
    assert eng.preemptions >= 1
    assert eng.requests[0].state == "preempted"
    while 1 in eng.requests:
        eng.step()
    eng.resume(0)                                 # re-prefill + continue
    while 0 in eng.requests:
        eng.step()
    assert eng.finished[0].generated == twin.finished[0].generated
    # And the stream is genuinely sampled, not greedy-by-accident.
    greedy = greedy_reference(model, params, prompt_a, 4)
    assert twin.finished[0].generated != greedy or sp.temperature == 0


def test_sampled_one_shot_prefill_equals_chunked(model_and_params):
    """The prefill-mode invariant extends from logits to sampled tokens: a
    temperature>0 request decodes the identical stream whether its prompt
    was ingested in one dispatch or stepped through decode."""
    model, params = model_and_params
    prompt = [5, 17, 133, 42, 7, 99, 250, 3, 11, 29]
    sp = SamplingParams(temperature=0.8, top_k=40, top_p=0.9, seed=7,
                        max_tokens=5)
    streams = {}
    for mode in ("one_shot", "chunked"):
        llm = LLM(model, params, small_cfg(prefill=mode))
        streams[mode] = llm.generate([prompt], sp)[0].token_ids
    assert streams["one_shot"] == streams["chunked"]


def test_default_seed_gives_independent_streams_per_request(
        model_and_params):
    """seed=None (the default) folds the request id: identical prompts
    submitted as different requests sample independent streams, while an
    explicit shared seed makes them bitwise-identical."""
    model, params = model_and_params
    prompt = [5, 17, 133, 42]
    llm = LLM(model, params, small_cfg(max_batch=4, hbm_pages=48))
    a, b = llm.generate([prompt, prompt],
                        SamplingParams(temperature=1.0, max_tokens=8))
    assert a.token_ids != b.token_ids, \
        "default-seeded twins must not collide streams"
    c, d = llm.generate([prompt, prompt],
                        SamplingParams(temperature=1.0, seed=5,
                                       max_tokens=8))
    assert c.token_ids == d.token_ids, \
        "an explicit shared seed must reproduce the stream"


def test_auto_seed_is_replayable_but_never_aliases_explicit_seeds(
        model_and_params):
    """Auto-derived seeds (seed=None) are a pure function of the request
    id — same id replays the same stream across engines — but live in a
    domain explicit seeds can't reach: request_id=5 with seed=None must
    NOT sample the same stream as an explicit seed=5."""
    model, params = model_and_params
    prompt = [5, 17, 133, 42]
    sp_auto = SamplingParams(temperature=1.0, max_tokens=6)

    def run_rid5(sp):
        llm = LLM(model, params, small_cfg())
        return llm.submit(prompt, sp, request_id=5).result().token_ids

    assert run_rid5(sp_auto) == run_rid5(sp_auto), \
        "auto seed must replay deterministically per request id"
    explicit = run_rid5(SamplingParams(temperature=1.0, seed=5,
                                       max_tokens=6))
    assert run_rid5(sp_auto) != explicit, \
        "auto seed domain must not alias explicit seed space"


def test_mixed_direct_and_llm_stepping_streams_exact_tokens(
        model_and_params):
    """Interleaving direct engine.step() with llm.step() must deliver the
    request's generated stream exactly once, in order — routing reconciles
    by cursor against req.generated, not by counting routed calls."""
    model, params = model_and_params
    llm = LLM(model, params, small_cfg())
    handle = llm.submit([5, 17, 133, 42], SamplingParams(max_tokens=4))
    llm.engine.step()                  # t1 generated behind llm's back
    llm.step()                         # t2 routed; t1 reconciled first
    while not handle.finished and llm.engine.requests:
        llm.step()
    deltas = list(handle)
    want = handle.token_ids
    assert len(want) == 4
    assert [t for t, _ in deltas] == want, "dup/dropped deltas"
    assert [r for _, r in deltas] == [None] * 3 + ["length"]


def test_mixed_greedy_sampled_batch_keeps_greedy_rows_bitwise(
        model_and_params):
    """A greedy request batched WITH a sampled one must decode bitwise the
    tokens it gets alone — the per-batch greedy/sampled dispatch split and
    the sampler's per-row short-circuit both protect it."""
    model, params = model_and_params
    prompt = [5, 17, 133, 42]
    llm = LLM(model, params, small_cfg(max_batch=4, hbm_pages=48))
    alone = llm.generate([prompt], SamplingParams(max_tokens=6))[0]
    outs = llm.generate(
        [prompt, [7, 99, 250, 3]],
        [SamplingParams(max_tokens=6),
         SamplingParams(temperature=1.0, max_tokens=6)])
    assert outs[0].token_ids == alone.token_ids


# ======================================================== finish reasons
def test_stop_token_finish_reason_end_to_end(model_and_params):
    """A stop-token hit reports ``finish_reason="stop"`` through every
    telemetry surface: the RequestOutput, ``pop_finished``, ``stats()``
    and ``analysis.serving_summary``."""
    model, params = model_and_params
    prompt = [5, 17, 133, 42]
    ref = greedy_reference(model, params, prompt, 6)
    stop_tok = ref[2]

    llm = LLM(model, params, small_cfg())
    out = llm.generate([prompt], SamplingParams(
        max_tokens=6, stop_token_ids=(stop_tok,)))[0]
    assert out.finish_reason == "stop"
    assert out.token_ids == ref[:3], "stop token is included, then stops"

    # Engine level: pop_finished carries the reason.
    eng = Engine(model, params, small_cfg())
    eng.add_request(0, prompt, params=SamplingParams(
        max_tokens=6, stop_token_ids=(stop_tok,)))
    while 0 in eng.requests:
        eng.step()
    req = eng.pop_finished(0)
    assert req.finish_reason == "stop"
    assert not req.truncated
    assert eng.stats()["finished_stop"] == 1
    assert eng.stats()["finished_length"] == 0
    summary = serving_summary(eng)
    assert summary["engine_finished_stop"] == 1.0
    assert summary["engine_finished_truncated"] == 0.0


def test_truncated_finish_reason(model_and_params):
    """A request alone against a pool it outgrows finishes with
    ``finish_reason="truncated"`` (and counts in stats)."""
    model, params = model_and_params
    eng = Engine(model, params,
                 ServeConfig(max_batch=1, page_size=2, hbm_pages=4,
                             host_pages=0))       # 3 usable HBM pages
    eng.add_request(0, [1, 2, 3, 4, 5], max_new=8)   # needs 6 pages to end
    for _ in range(20):
        eng.step()
        if 0 in eng.finished:
            break
    assert eng.finished[0].finish_reason == "truncated"
    assert eng.finished[0].truncated
    assert eng.stats()["finished_truncated"] == 1


def test_length_finish_reason_via_pop_finished_all(model_and_params):
    model, params = model_and_params
    eng = Engine(model, params, small_cfg())
    eng.add_request(0, [1, 2, 3], max_new=2)
    while eng.requests:
        eng.step()
    drained = eng.pop_finished()
    assert drained[0].finish_reason == "length"
    assert not eng.finished


# ============================================================== streaming
def test_streaming_handle_deltas(model_and_params):
    """The handle streams one ``(token, None)`` delta per generated token,
    with the finish reason attached to the final delta only — and matches
    the blocking path bitwise."""
    model, params = model_and_params
    prompt = [5, 17, 133, 42]
    n_new = 5
    ref = greedy_reference(model, params, prompt, n_new)
    llm = LLM(model, params, small_cfg())
    handle = llm.submit(prompt, SamplingParams(max_tokens=n_new))
    deltas = list(handle)
    assert [t for t, _ in deltas] == ref
    assert [r for _, r in deltas] == [None] * (n_new - 1) + ["length"]
    assert handle.finished and handle.finish_reason == "length"
    assert handle.token_ids == ref
    out = handle.result()                  # idempotent after exhaustion
    assert out.token_ids == ref and out.finish_reason == "length"


def test_streaming_interleaves_with_other_requests(model_and_params):
    """Iterating one handle drives the shared engine: a second in-flight
    request finishes on its own while the first is being consumed."""
    model, params = model_and_params
    llm = LLM(model, params, small_cfg())
    slow = llm.submit([5, 17, 133, 42], SamplingParams(max_tokens=8))
    fast = llm.submit([7, 99, 250], SamplingParams(max_tokens=2))
    list(slow)
    assert fast.finished and len(fast.token_ids) == 2


def test_streaming_paused_request_raises_instead_of_spinning(
        model_and_params):
    model, params = model_and_params
    llm = LLM(model, params, small_cfg())
    handle = llm.submit([5, 17, 133], SamplingParams(max_tokens=4))
    llm.pause(handle.request_id)
    with pytest.raises(RuntimeError, match="paused"):
        handle.next_delta()
    llm.resume(handle.request_id)
    assert handle.result().finish_reason == "length"


# ========================================================== generate API
def test_generate_batch_order_and_per_prompt_params(model_and_params):
    model, params = model_and_params
    prompts = [[5, 17, 133, 42], [7, 99, 250, 3], [11, 29, 31, 2]]
    plist = [SamplingParams(max_tokens=2),
             SamplingParams(max_tokens=4),
             SamplingParams(max_tokens=3)]
    llm = LLM(model, params, small_cfg(max_batch=4, hbm_pages=48))
    outs = llm.generate(prompts, plist)
    assert [o.prompt_token_ids for o in outs] == prompts
    assert [len(o.token_ids) for o in outs] == [2, 4, 3]
    assert all(o.finish_reason == "length" for o in outs)
    with pytest.raises(ValueError, match="SamplingParams"):
        llm.generate(prompts, plist[:2])


def test_generate_flat_prompt_and_default_budget(model_and_params):
    model, params = model_and_params
    llm = LLM(model, params, small_cfg())
    outs = llm.generate([5, 17, 133])      # single flat prompt
    assert len(outs) == 1
    assert len(outs[0].token_ids) == DEFAULT_MAX_TOKENS
    # numpy token ids (the benches' idiom) are one prompt too, not a batch.
    np_out = llm.generate(list(np.asarray([5, 17, 133], np.int64)),
                          SamplingParams(max_tokens=2))
    assert len(np_out) == 1 and np_out[0].prompt_token_ids == [5, 17, 133]


def test_finished_handles_leave_the_routing_table(model_and_params):
    """The API layer must not reintroduce the finished-request leak: a
    long-lived LLM holds one handle per LIVE request only."""
    model, params = model_and_params
    llm = LLM(model, params, small_cfg())
    for batch in range(3):
        llm.generate([[1 + batch, 2, 3], [4, 5, 6 + batch]],
                     SamplingParams(max_tokens=2))
        assert not llm._handles, "finished handles must be pruned"
    assert not llm.engine.finished, "generate() drains the engine"


def test_handle_raises_when_result_drained_behind_its_back(
        model_and_params):
    model, params = model_and_params
    llm = LLM(model, params, small_cfg())
    handle = llm.submit([5, 17, 133], SamplingParams(max_tokens=2))
    while llm.engine.requests:
        llm.engine.step()              # bypass llm.step bookkeeping
    llm.engine.pop_finished(handle.request_id)
    with pytest.raises(RuntimeError, match="pop_finished"):
        list(handle)


def test_direct_engine_stepping_still_streams_all_tokens(model_and_params):
    """Driving the engine directly (bypassing llm.step's routing) must not
    lose deltas: the finish reconciliation replays the authoritative
    generated stream onto the handle."""
    model, params = model_and_params
    llm = LLM(model, params, small_cfg())
    handle = llm.submit([5, 17, 133, 42], SamplingParams(max_tokens=3))
    while llm.engine.requests:
        llm.engine.step()
    deltas = list(handle)
    assert len(deltas) == 3
    assert [r for _, r in deltas] == [None, None, "length"]
    assert handle.token_ids == [t for t, _ in deltas]


def test_max_tokens_overrides_engine_max_new(model_and_params):
    model, params = model_and_params
    eng = Engine(model, params, small_cfg())
    eng.add_request(0, [1, 2, 3], max_new=9,
                    params=SamplingParams(max_tokens=2))
    while eng.requests:
        eng.step()
    assert len(eng.finished[0].generated) == 2
