"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.paged_attention import (
    paged_attention_pallas,
    paged_prefill_pallas,
)
from repro.kernels.ssd_scan import ssd_scan_pallas

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# --------------------------------------------------------- flash attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Sq,Sk,H,K,dh,causal,window",
    [
        (2, 128, 128, 4, 4, 64, True, None),      # MHA causal
        (1, 256, 256, 8, 2, 64, True, None),      # GQA
        (2, 128, 128, 4, 2, 128, True, 64),       # sliding window
        (1, 128, 256, 4, 4, 64, True, None),      # Sk > Sq (continuation)
        (2, 96, 96, 4, 4, 80, True, None),        # unaligned seq + dh
        (1, 128, 128, 4, 4, 64, False, None),     # bidirectional (encoder)
    ],
)
def test_flash_attention_sweep(B, Sq, Sk, H, K, dh, causal, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = rand(ks[0], (B, Sq, H, dh), dtype)
    k = rand(ks[1], (B, Sk, K, dh), dtype)
    v = rand(ks[2], (B, Sk, K, dh), dtype)
    got = flash_attention_pallas(q, k, v, causal, window, True)
    want = ref.mha_reference(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype])


def test_flash_attention_grad_matches_reference():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = rand(ks[0], (1, 128, 4, 64), jnp.float32)
    k = rand(ks[1], (1, 128, 2, 64), jnp.float32)
    v = rand(ks[2], (1, 128, 2, 64), jnp.float32)

    def loss_kernel(q, k, v):
        return flash_attention_pallas(q, k, v, True, None, True).sum()

    def loss_ref(q, k, v):
        return ref.mha_reference(q, k, v, causal=True).sum()

    g1 = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=2e-4)


# --------------------------------------------------------- paged attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,K,dh,N,P,MP,window",
    [
        (2, 8, 8, 64, 8, 8, 3, None),     # MHA
        (3, 8, 4, 64, 16, 8, 4, None),    # GQA
        (2, 4, 4, 128, 8, 16, 2, None),   # bigger pages
        (2, 8, 4, 64, 16, 8, 4, 7),       # sliding window
        (1, 8, 2, 96, 8, 8, 4, None),     # unaligned dh
    ],
)
def test_paged_attention_sweep(B, H, K, dh, N, P, MP, window, dtype):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, H, dh)), dtype)
    kp = jnp.asarray(rng.normal(size=(N, P, K, dh)), dtype)
    vp = jnp.asarray(rng.normal(size=(N, P, K, dh)), dtype)
    table = np.full((B, MP), -1, np.int32)
    lengths = np.zeros((B,), np.int32)
    slots = rng.permutation(N)
    si = 0
    for b in range(B):
        n_pages = int(rng.integers(1, MP + 1))
        lengths[b] = int(rng.integers((n_pages - 1) * P + 1, n_pages * P + 1))
        for pg in range(n_pages):
            table[b, pg] = slots[si]
            si += 1
    table = jnp.asarray(table)
    lengths = jnp.asarray(lengths)
    got = paged_attention_pallas(q, kp, vp, table, lengths, window=window,
                                 interpret=True)
    want = ref.paged_attention_reference(q, kp, vp, table, lengths,
                                         window=window)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype])


# ----------------------------------------------------- one-shot prefill
def _prefill_case(rng, S, N, P, K, dh, MP):
    q = jnp.asarray(rng.normal(size=(S, 4 * K, dh)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(N, P, K, dh)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(N, P, K, dh)), jnp.float32)
    table = np.full((MP,), -1, np.int32)
    slots = rng.permutation(N)
    for pg in range(-(-S // P)):
        table[pg] = slots[pg]
    return q, kp, vp, jnp.asarray(table)


@pytest.mark.parametrize("window", [None, 5])
def test_paged_prefill_sweep(window):
    """One sequence's S prompt rows with causal lengths 1..S (plus padded
    zero-length rows): Pallas vs the masked-einsum oracle."""
    rng = np.random.default_rng(3)
    S, N, P, K, dh, MP = 11, 8, 4, 2, 64, 4
    q, kp, vp, table = _prefill_case(rng, S, N, P, K, dh, MP)
    lengths = jnp.asarray(
        np.concatenate([np.arange(1, S + 1), np.zeros(5)]).astype(np.int32))
    qpad = jnp.concatenate([q, jnp.zeros((5,) + q.shape[1:], q.dtype)])
    got = paged_prefill_pallas(qpad, kp, vp, table, lengths, window=window,
                               interpret=True)
    want = ref.paged_prefill_reference(qpad, kp, vp, table, lengths,
                                       window=window)
    np.testing.assert_allclose(
        np.asarray(got[:S], np.float32), np.asarray(want[:S], np.float32),
        atol=TOL[jnp.float32], rtol=TOL[jnp.float32])
    assert np.all(np.isfinite(np.asarray(got))), \
        "padded zero-length rows must not emit NaNs"


def test_paged_prefill_padding_invariance():
    """Bucketed prompts: real rows of a padded call must be bitwise equal
    to the unpadded call — the property that lets the engine pad prompts
    to power-of-two buckets without perturbing ingestion."""
    rng = np.random.default_rng(4)
    S, N, P, K, dh, MP = 7, 8, 4, 2, 64, 4
    q, kp, vp, table = _prefill_case(rng, S, N, P, K, dh, MP)
    lengths = jnp.asarray(np.arange(1, S + 1, dtype=np.int32))
    exact = ref.paged_prefill_reference(q, kp, vp, table, lengths)
    qpad = jnp.concatenate([q, jnp.zeros((9,) + q.shape[1:], q.dtype)])
    lpad = jnp.concatenate([lengths, jnp.zeros((9,), jnp.int32)])
    padded = ref.paged_prefill_reference(qpad, kp, vp, table, lpad)
    assert np.array_equal(np.asarray(padded[:S]), np.asarray(exact))


# ----------------------------------------------------------------- SSD scan
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Q,H,P,N,bh",
    [
        (2, 64, 8, 32, 16, 4),
        (1, 128, 4, 64, 64, 4),
        (2, 128, 16, 64, 64, 8),
        (1, 64, 2, 64, 32, 2),
    ],
)
def test_ssd_scan_sweep(B, Q, H, P, N, bh, dtype):
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(B, Q, H, P)), dtype)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, size=(B, Q, H)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, size=(H,)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, Q, N)), dtype)
    Cm = jnp.asarray(rng.normal(size=(B, Q, N)), dtype)
    got = ssd_scan_pallas(x, dt, A, Bm, Cm, block_h=bh, interpret=True)
    want = ref.ssd_reference(x, dt, A, Bm, Cm)
    scale = float(jnp.abs(want).max()) + 1e-6
    np.testing.assert_allclose(
        np.asarray(got) / scale, np.asarray(want) / scale,
        atol=5 * TOL[dtype], rtol=5 * TOL[dtype])


def test_ssd_kernel_agrees_with_model_chunk():
    """The kernel computes exactly the intra-chunk term of models/ssm.py's
    chunked scan (single chunk, zero initial state)."""
    from repro.models.ssm import SSMConfig, ssd_chunked

    rng = np.random.default_rng(3)
    B, Q, H, P, N = 1, 128, 4, 32, 16
    cfg = SSMConfig(d_model=8, d_inner=H * P, head_dim=P, state_dim=N,
                    chunk=Q)
    x = jnp.asarray(rng.normal(size=(B, Q, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, size=(B, Q, H)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, size=(H,)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, Q, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(B, Q, N)), jnp.float32)
    y_model, _ = ssd_chunked(x, dt, A, Bm, Cm, cfg)
    y_kernel = ssd_scan_pallas(x, dt, A, Bm, Cm, interpret=True)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_model),
                               atol=1e-4, rtol=1e-4)


# ------------------------------------------------------------- ops dispatch
def test_ops_mode_dispatch():
    assert ops.current_mode() in ("reference", "pallas")
    ops.set_mode("interpret")
    try:
        q = jnp.ones((1, 128, 4, 64), jnp.float32)
        k = jnp.ones((1, 128, 4, 64), jnp.float32)
        out = ops.flash_attention(q, k, q)
        want = ref.mha_reference(q, k, q)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=1e-5)
    finally:
        ops.set_mode(None)
