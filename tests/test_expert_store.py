"""Guided expert-weight tiering: store semantics + engine bitwise parity.

The contract under test (DESIGN.md Sec. 15): serving MoE expert FFN
weights out of a bounded HBM cache is a *placement* change, never a
*results* change — streams and logits are bitwise-equal to the fully
resident path whenever each dispatch's working set fits, across eviction
churn, double-buffered prefetch, chunked prefill and preemption; a
working set that cannot fit raises a named error citing the knob.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import build_model
from repro.serve import (
    LLM,
    ExpertCacheMissError,
    ExpertStore,
    SamplingParams,
    ServeConfig,
)

# ================================================== store-level unit tests
L, E, D, F = 2, 4, 4, 4


def make_store(cache_slots, double_buffer=False):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    moe_params = {
        "w_gate": jax.random.normal(ks[0], (L, E, D, F), jnp.float32),
        "w_up": jax.random.normal(ks[1], (L, E, D, F), jnp.float32),
        "w_down": jax.random.normal(ks[2], (L, E, F, D), jnp.float32),
    }
    return moe_params, ExpertStore(moe_params, L, E, cache_slots,
                                   double_buffer=double_buffer)


def counts(*experts):
    c = np.zeros(E, dtype=np.int64)
    for e in experts:
        c[e] += 1
    return c


def test_init_rejects_zero_slots():
    with pytest.raises(ValueError, match="expert_cache_size"):
        make_store(0)


def test_dispatch_installs_and_maps_slots():
    params, st = make_store(4)
    slot_map = st.dispatch(0, counts(0, 2), step=1)
    assert st.is_resident(0, 0) and st.is_resident(0, 2)
    assert slot_map[1] == -1 and slot_map[3] == -1
    assert st.demand_fetches == 2
    # the cache rows hold bitwise copies of the host blocks
    wg = np.asarray(params["w_gate"])
    cache = np.asarray(st.w_gate_cache)
    assert np.array_equal(cache[slot_map[0]], wg[0, 0])
    assert np.array_equal(cache[slot_map[2]], wg[0, 2])


def test_lru_eviction_prefers_oldest():
    _, st = make_store(2)
    st.dispatch(0, counts(0, 1), step=1)
    st.dispatch(0, counts(1), step=2)          # refresh (0,1), (0,0) is LRU
    st.dispatch(1, counts(3), step=3)          # needs a slot: evict (0,0)
    assert not st.is_resident(0, 0)
    assert st.is_resident(0, 1) and st.is_resident(1, 3)
    assert st.evictions == 1


def test_working_set_overflow_raises_named_error():
    _, st = make_store(2)
    with pytest.raises(ExpertCacheMissError, match="expert_cache_size"):
        st.dispatch(0, counts(0, 1, 2), step=1)


def test_prefetch_hit_skips_demand_and_miss_falls_back():
    _, st = make_store(4, double_buffer=True)
    st.dispatch(0, counts(0, 1), step=1)
    assert st.prefetch(1, step=1, predicted=[0, 1]) == 2
    st.dispatch(1, counts(0, 2), step=1)
    assert st.prefetch_fetches == 2
    assert st.prefetch_hits == 1               # predicted 0, routed {0, 2}
    assert st.demand_fetches == 3              # (0,0) (0,1) + fallback (1,2)


def test_prefetch_never_evicts_pins_or_its_own_forecast():
    _, st = make_store(2, double_buffer=True)
    st.dispatch(0, counts(0, 1), step=1)       # both slots pinned
    assert st.prefetch(1, step=1, predicted=[2, 3]) == 0
    assert st.dropped_prefetches == 2
    assert st.is_resident(0, 0) and st.is_resident(0, 1)


def test_prefetch_disabled_in_sync_mode():
    _, st = make_store(4, double_buffer=False)
    st.dispatch(0, counts(0), step=1)
    assert st.prefetch(1, step=1, predicted=[0, 1]) == 0
    assert st.prefetch_fetches == 0


def test_drop_many_refuses_dispatching_blocks():
    _, st = make_store(4)
    st.dispatch(0, counts(0, 1), step=1)
    st.fetch_many([(1, 3)], step=1)            # controller promote
    dropped = st.drop_many([(0, 0), (1, 3)])
    assert dropped == [(1, 3)], \
        "a block named in its layer's last dispatch must never demote"
    assert st.is_resident(0, 0) and not st.is_resident(1, 3)


def test_fetch_many_uses_free_slots_only():
    _, st = make_store(2)
    st.dispatch(0, counts(0, 1), step=1)       # cache full
    done, refused = st.fetch_many([(1, 2)], step=2)
    assert done == [] and refused == [(1, 2)], \
        "controller promotion must never evict"


def test_demotion_is_metadata_only_and_refetch_is_bitwise():
    params, st = make_store(2)
    m0 = st.dispatch(0, counts(0), step=1)
    first = np.asarray(st.w_down_cache)[m0[0]].copy()
    st.dispatch(1, counts(1, 2), step=2)       # evicts (0,0)
    assert st.bytes_fetched == 3 * st.block_bytes
    m1 = st.dispatch(0, counts(0), step=3)     # refetch from host tier
    again = np.asarray(st.w_down_cache)[m1[0]]
    assert np.array_equal(first, again)
    assert np.array_equal(again, np.asarray(params["w_down"])[0, 0])


# ============================================== engine-level equivalence
@pytest.fixture(scope="module")
def moe_model():
    cfg = dataclasses.replace(get_smoke("granite_moe_3b_a800m"),
                              remat=False)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def make_llm(moe_model, **kw):
    model, params = moe_model
    return LLM(model, params, ServeConfig(
        max_batch=2, page_size=4, hbm_pages=32, host_pages=64,
        max_pages_per_seq=16, interval_steps=4, keep_logits=True, **kw))


PROMPTS = [[3, 1, 4, 1, 5, 9], [2, 7, 1, 8, 2, 8], [1, 6, 1, 8, 0, 3]]
PLIST = [SamplingParams(max_tokens=6),
         SamplingParams(max_tokens=6, temperature=0.8, top_k=4, seed=7),
         SamplingParams(max_tokens=6, temperature=1.1, top_p=0.9, seed=3)]


def drive(llm, prompts, params_list):
    """Drive generation by hand, capturing every step's logits per row."""
    handles = [llm.submit(p, sp) for p, sp in zip(prompts, params_list)]
    logits = {h.request_id: [] for h in handles}
    while any(not h.finished for h in handles):
        out = llm.step()
        for rid in out:
            if rid in llm.engine.last_logits:
                logits[rid].append(llm.engine.last_logits[rid].copy())
    return [h.result() for h in handles], logits


def assert_equal_runs(ref, got):
    (outs_a, logits_a), (outs_b, logits_b) = ref, got
    for a, b in zip(outs_a, outs_b):
        assert a.token_ids == b.token_ids
        assert a.finish_reason == b.finish_reason
    for rid in logits_a:
        assert len(logits_a[rid]) == len(logits_b[rid])
        for la, lb in zip(logits_a[rid], logits_b[rid]):
            assert np.array_equal(la, lb), "logits must be bitwise-equal"


@pytest.fixture(scope="module")
def resident_reference(moe_model):
    return drive(make_llm(moe_model), PROMPTS, PLIST)


@pytest.mark.parametrize("cache", [8, 12, 16])
def test_tiered_bitwise_equal_across_cache_sizes(moe_model,
                                                 resident_reference, cache):
    """The acceptance contract: greedy and sampled rows through the tiered
    path match the resident path bitwise, at cache sizes from all-fit (16)
    down to heavy eviction churn (8 of 16 blocks)."""
    llm = make_llm(moe_model, expert_offchip=True, expert_cache_size=cache)
    got = drive(llm, PROMPTS, PLIST)
    assert_equal_runs(resident_reference, got)
    st = llm.engine.expert_store
    if cache < 16:
        assert st.evictions > 0, "sweep must actually churn the cache"


def test_double_buffer_equals_sync(moe_model, resident_reference):
    """Prefetch is pure staging: a misprediction falls back to the demand
    fetch, so db on/off both equal the resident reference bitwise."""
    for db in (True, False):
        llm = make_llm(moe_model, expert_offchip=True, expert_cache_size=8,
                       expert_double_buffer=db)
        assert_equal_runs(resident_reference, drive(llm, PROMPTS, PLIST))


def test_chunked_prefill_tight_cache_bitwise_equal(moe_model,
                                                   resident_reference):
    """At the decode floor (4 slots) a one-shot prefill working set cannot
    fit, but chunked prefill bounds each dispatch — and still matches the
    resident path bitwise."""
    llm = make_llm(moe_model, expert_offchip=True, expert_cache_size=4,
                   prefill_chunk_tokens=2)
    assert_equal_runs(resident_reference, drive(llm, PROMPTS, PLIST))
    assert llm.engine.expert_store.evictions > 0


def test_preemption_replay_through_tiered_path(moe_model):
    """Preemption-by-recompute must replay the identical stream when the
    re-prefill and resumed decode run through the expert cache."""
    def run(preempt):
        llm = make_llm(moe_model, expert_offchip=True, expert_cache_size=8)
        llm.submit(PROMPTS[0], SamplingParams(max_tokens=1)).result()
        h = llm.submit(PROMPTS[1], SamplingParams(
            max_tokens=8, temperature=0.9, seed=11))
        for _ in range(3):
            llm.step()
        if preempt:
            llm.pause(h.request_id)
            assert llm.engine._preempt_one(), "victim must exist"
            assert llm.engine.requests[h.request_id].state == "preempted"
            llm.resume(h.request_id)
        out = h.result()
        return out.token_ids, llm.engine.stats()

    calm, _ = run(preempt=False)
    replayed, stats = run(preempt=True)
    assert replayed == calm, \
        "preempted request must resample the identical stream"
    assert stats["preemptions"] >= 1


def test_one_shot_overflow_raises_named_error(moe_model):
    """A one-shot prefill whose distinct routed experts exceed the cache
    must raise the named error, not dispatch against wrong weights."""
    llm = make_llm(moe_model, expert_offchip=True, expert_cache_size=4)
    with pytest.raises(ExpertCacheMissError, match="expert_cache_size"):
        llm.submit(list(range(1, 13)), SamplingParams(max_tokens=2))
        for _ in range(4):
            llm.step()


def test_init_validation_names_knobs(moe_model):
    with pytest.raises(ValueError, match="expert_cache_size"):
        make_llm(moe_model, expert_offchip=True, expert_cache_size=2)
    with pytest.raises(ValueError, match="expert_cache_size"):
        make_llm(moe_model, expert_offchip=True, expert_cache_size=-1)


def test_offchip_requires_moe():
    cfg = dataclasses.replace(get_smoke("llama3_2_1b"), remat=False)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="expert_offchip"):
        LLM(model, params, ServeConfig(
            max_batch=2, page_size=4, hbm_pages=16, host_pages=16,
            expert_offchip=True))


def test_serving_summary_reports_expert_counters(moe_model):
    llm = make_llm(moe_model, expert_offchip=True, expert_cache_size=8)
    drive(llm, PROMPTS, PLIST)
    stats = llm.engine.stats()
    assert stats["expert_cache_slots"] == 8
    assert stats["expert_demand_fetches"] > 0
    assert stats["expert_bytes_fetched"] > 0
