"""Tests for the loop-aware cost accounting (launch/analysis.py) — the
roofline's data source.  XLA's cost_analysis counts while bodies once; these
tests pin our corrected pipeline against analytic ground truth."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.analysis import collective_bytes, jaxpr_cost

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ------------------------------------------------------------- jaxpr costs
def test_dot_flops_exact():
    def f(x, w):
        return x @ w

    jx = jax.make_jaxpr(f)(jnp.ones((64, 128)), jnp.ones((128, 32)))
    cost = jaxpr_cost(jx)
    assert cost["flops"] == 2 * 64 * 128 * 32


def test_scan_multiplies_body_cost():
    def f(x, ws):
        def body(x, w):
            return x @ w, None

        x, _ = jax.lax.scan(body, x, ws)
        return x

    for L in (1, 4, 16):
        jx = jax.make_jaxpr(f)(jnp.ones((8, 16)), jnp.ones((L, 16, 16)))
        cost = jaxpr_cost(jx)
        assert cost["flops"] == L * 2 * 8 * 16 * 16, L


def test_nested_scan_multiplies():
    def f(x, ws):
        def outer(x, w_outer):
            def inner(x, _):
                return x @ w_outer, None

            x, _ = jax.lax.scan(inner, x, None, length=3)
            return x, None

        x, _ = jax.lax.scan(outer, x, ws)
        return x

    jx = jax.make_jaxpr(f)(jnp.ones((4, 8)), jnp.ones((5, 8, 8)))
    cost = jaxpr_cost(jx)
    assert cost["flops"] == 5 * 3 * 2 * 4 * 8 * 8


def test_grad_and_remat_counted():
    """Remat-inclusive backward ~ 4x forward for a matmul chain (fwd+recomp
    + 2 bwd dots)."""
    w = jnp.ones((32, 32))

    def f(x):
        @jax.checkpoint
        def blk(x):
            return jnp.tanh(x @ w)

        def body(x, _):
            return blk(x), None

        x, _ = jax.lax.scan(body, x, None, length=6)
        return x.sum()

    fwd = jaxpr_cost(jax.make_jaxpr(f)(jnp.ones((16, 32))))["flops"]
    bwd = jaxpr_cost(jax.make_jaxpr(jax.grad(f))(jnp.ones((16, 32))))["flops"]
    dot = 2 * 16 * 32 * 32 * 6
    assert abs(fwd - dot) / dot < 0.2
    # grad jaxpr = fwd dot + recompute/transpose dots: ~3-4x the fwd cost
    assert 2.8 <= bwd / fwd <= 4.8


def test_gather_counts_gathered_bytes_not_pool():
    pool = jnp.zeros((1024, 256))      # 1 MB pool

    def f(idx):
        return pool[idx]

    jx = jax.make_jaxpr(f)(jnp.zeros((4,), jnp.int32))
    cost = jaxpr_cost(jx)
    # 4 rows of 256 f32 = 4 KB; the 1 MB pool operand must not be charged.
    assert cost["bytes_dot"] < 64 * 1024


# ------------------------------------------------ HLO collective expansion
def _collect(devices, body):
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count={devices}")
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        import numpy as np
    """) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    return proc.stdout


def test_while_loop_collectives_expand_by_trip_count():
    out = _collect(8, """
        from repro.launch.analysis import collective_bytes
        from repro.launch.mesh import compat_make_mesh
        mesh = compat_make_mesh((8,), ("model",))
        L = 7
        def f(x, ws):
            def body(x, w):
                y = x @ w                     # contract sharded dim -> psum
                return y, None
            x, _ = jax.lax.scan(body, x, ws)
            return x
        xs = jax.ShapeDtypeStruct((16, 64), jnp.float32)
        ws = jax.ShapeDtypeStruct((L, 64, 64), jnp.float32)
        with mesh:
            c = jax.jit(
                f,
                in_shardings=(NamedSharding(mesh, P(None, "model")),
                              NamedSharding(mesh, P(None, "model", None))),
                out_shardings=NamedSharding(mesh, P(None, "model")),
            ).lower(xs, ws).compile()
        coll = collective_bytes(c.as_text())
        total = sum(v["bytes"] for v in coll.values())
        counts = sum(v["count"] for v in coll.values())
        print("BYTES", int(total), "COUNT", int(counts))
    """)
    bytes_, count = int(out.split()[1]), int(out.split()[3])
    # One collective per iteration, 7 iterations; each moves >= the partial
    # product (16x64 f32 = 4 KB result, possibly resharded pieces).
    assert count >= 7, out
    assert bytes_ >= 7 * 16 * 64 * 4 // 8, out


def test_direct_collectives_counted_once():
    out = _collect(8, """
        from repro.launch.analysis import collective_bytes
        from repro.launch.mesh import compat_make_mesh
        mesh = compat_make_mesh((8,), ("d",))
        def f(x):
            return jax.lax.with_sharding_constraint(
                x.sum(axis=0, keepdims=True) + x, NamedSharding(mesh, P()))
        xs = jax.ShapeDtypeStruct((64, 32), jnp.float32)
        with mesh:
            c = jax.jit(f, in_shardings=NamedSharding(mesh, P("d", None)),
                        out_shardings=NamedSharding(mesh, P())) \
                .lower(xs).compile()
        coll = collective_bytes(c.as_text())
        print("COUNT", int(sum(v["count"] for v in coll.values())))
    """)
    assert int(out.split()[1]) >= 1


@pytest.fixture(scope="module")
def dryrun_train_artifact(tmp_path_factory):
    """The dry-run artifact the 6ND validation reads.  A full dry-run drop
    (results/dryrun/...) is preferred when present; otherwise the artifact
    is regenerated trace-only into a tmpdir — jaxpr costs are mesh- and
    compile-independent, so a 1x1 mesh on the test host reproduces the
    pod256 numbers exactly and the assertions always run (no silent skip)."""
    real = os.path.join(REPO, "results", "dryrun", "pod256",
                        "llama3_2_1b__train_4k.json")
    if os.path.exists(real):
        return real
    from repro.launch.dryrun import run_cell
    from repro.launch.mesh import compat_make_mesh

    outdir = str(tmp_path_factory.mktemp("dryrun") / "pod256")
    mesh = compat_make_mesh((1, 1), ("data", "model"))
    rec = run_cell("llama3_2_1b", "train_4k", mesh, "pod256", outdir,
                   trace_only=True)
    assert rec["status"] == "ok", rec.get("error")
    return os.path.join(outdir, "llama3_2_1b__train_4k.json")


def test_flops_validation_against_6nd(dryrun_train_artifact):
    """The headline validation: full train step flops within 5% of the
    analytic remat-inclusive 8*N*D (also asserted in EXPERIMENTS.md)."""
    import json
    rec = json.load(open(dryrun_train_artifact))
    from repro.configs import get
    from repro.models import build_model
    from repro.models.common import count_params

    n = count_params(build_model(get("llama3_2_1b")).param_defs())
    analytic = 8 * n * 256 * 4096
    assert abs(rec["global_cost"]["flops"] - analytic) / analytic < 0.05
