"""Behavioural test of Algorithm 1's optional ReweightProfile step: with
decay, placement tracks shifting behaviour; with the paper's default
(no decay), accumulated history can pin a formerly-hot site forever."""

from repro.core import (
    ArenaBackend,
    ArenaManager,
    CLX,
    GuidanceConfig,
    GuidanceRuntime,
    SiteKind,
    SiteRegistry,
)

MB = 2**20


def run_phase_shift(decay: float):
    """Site A is hot for 30 intervals then goes cold while B becomes hot.
    Returns (A.fast_fraction, B.fast_fraction) at the end."""
    reg = SiteRegistry()
    mgr = ArenaManager(reg, promotion_threshold=1 * MB,
                       fast_capacity_bytes=50 * MB)
    a = reg.register(["phase_a"], SiteKind.OTHER)
    b = reg.register(["phase_b"], SiteKind.OTHER)
    arena_a = mgr.allocate(a, 40 * MB)      # first-touch: A fast
    arena_b = mgr.allocate(b, 40 * MB)      # spills mostly slow
    gdt = GuidanceRuntime(ArenaBackend(mgr, CLX), CLX,
                          GuidanceConfig(strategy="thermos",
                                         fast_capacity_bytes=50 * MB,
                                         interval_steps=1, decay=decay))
    for i in range(60):
        if i < 30:
            mgr.touch(a, 500_000)
            mgr.touch(b, 10)
        else:                               # phase shift
            mgr.touch(a, 10)
            mgr.touch(b, 500_000)
        gdt.on_step()
    return arena_a.fast_fraction, arena_b.fast_fraction


def test_decay_adapts_to_phase_shift():
    fa, fb = run_phase_shift(decay=0.5)
    assert fb > 0.9, "decayed profile must promote the newly-hot site"
    assert fa < 0.5, "and demote the stale one"


def test_no_decay_pins_stale_history():
    """Paper default (never reweight): 30 intervals of accumulated counts on
    A outweigh B's recent burst for a long time — B stays underplaced
    relative to the decayed run (the exact trade-off Sec. 4.2 describes)."""
    fa_d, fb_d = run_phase_shift(decay=0.5)
    fa_n, fb_n = run_phase_shift(decay=1.0)
    assert fb_n <= fb_d + 1e-9
    # With equal totals only at interval ~60, the no-decay run still favours
    # A at least as much as the decayed run.
    assert fa_n >= fa_d - 1e-9
