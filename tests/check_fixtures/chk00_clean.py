"""CHK00 clean fixture: a well-formed suppression (rule list + reason)
silences the finding it covers and raises no hygiene finding itself."""


def probe(fn):
    try:
        fn()
    # check: disable=EXC01 -- capability probe: any failure means absent
    except Exception:
        return None
