"""EXC01 fixture: broad handlers that swallow silently."""


def load(path):
    try:
        return open(path).read()
    except Exception:
        return None


def probe(fn):
    try:
        fn()
    except:  # noqa: E722
        pass
