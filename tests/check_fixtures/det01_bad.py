"""DET01 fixture: nondeterminism reaching traced code."""

import random
import time

import jax
import jax.numpy as jnp


@jax.jit
def noisy(x):
    jitter = random.random()
    t0 = time.time()
    return x * jitter + t0


def body(x):
    total = x
    for axis in {0, 1}:
        total = total + jnp.sum(x, axis=axis)
    return total


def run(x):
    return jax.jit(body)(x)
