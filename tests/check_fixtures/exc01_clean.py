"""EXC01 clean fixture: narrow types, or log-and-reraise."""

import logging

log = logging.getLogger(__name__)


def load(path):
    try:
        return open(path).read()
    except OSError:
        return None


def probe(fn):
    try:
        fn()
    except Exception:
        log.warning("probe failed")
        raise
