"""KRN01 fixture: index-map arity, OOB block index, unguarded store to a
revisited output block."""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def accum_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def revisited_unguarded(x):
    return pl.pallas_call(
        accum_kernel,
        grid=(4, 2),
        in_specs=[pl.BlockSpec((8, 8), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((8, 8), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((32, 8), jnp.float32),
    )(x)


def copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def oob_block(x):
    return pl.pallas_call(
        copy_kernel,
        grid=(4,),
        in_specs=[pl.BlockSpec((8, 8), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((8, 8), lambda i: (i, 5)),
        out_shape=jax.ShapeDtypeStruct((32, 8), jnp.float32),
    )(x)
