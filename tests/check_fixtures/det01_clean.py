"""DET01 clean fixture: jax.random inside jit is sanctioned; wall-clock
reads outside any traced entry point are host-side and fine."""

import time

import jax


@jax.jit
def scaled(x, key):
    noise = jax.random.normal(key, x.shape)
    return x + noise


def timed_host_step(x, key):
    t0 = time.time()
    y = scaled(x, key)
    return y, time.time() - t0
