"""KV01 fixture: leaked acquire, shared-page mutation, free on a held
request page."""


class LeakyCache:
    def __init__(self, pool):
        self.pool = pool
        self.refs = []

    def grab(self, rid, page_id):
        self.refs.append(self.pool.acquire(rid, page_id))


def mutate_shared(pool, rid, page_id):
    page = pool.acquire(rid, page_id, shared=True)
    page.tokens_used = 0
    return page


def free_held(pool, rid):
    for page in pool.request_pages(rid):
        pool.free(page.page_id)
