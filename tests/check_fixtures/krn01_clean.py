"""KRN01 clean fixture: the revisited output block is initialized under
pl.when and accumulated into (augmented stores are the sanctioned
revisit pattern)."""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def masked_kernel(x_ref, o_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += x_ref[...]


def grouped_accumulate(x):
    return pl.pallas_call(
        masked_kernel,
        grid=(4, 2),
        in_specs=[pl.BlockSpec((8, 8), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((8, 8), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((32, 8), jnp.float32),
    )(x)
