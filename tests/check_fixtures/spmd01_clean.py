"""SPMD01 clean fixture: collectives on the bound axis, rotation-idiom
ppermute perm."""

import jax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def body(x):
    n = jax.lax.psum(x, "data")
    return jax.lax.ppermute(
        n, "data", perm=[(j, (j + 1) % 4) for j in range(4)])


def run(mesh, x):
    return shard_map(body, mesh=mesh, in_specs=(P("data"),),
                     out_specs=P("data"))(x)
