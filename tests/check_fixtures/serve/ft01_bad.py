"""FT01 bad fixture: direct wall-clock reads in a serve/-scoped module.

Every timestamp here bypasses clock injection, so heartbeat timeouts and
failover decisions in a test replay would depend on real elapsed time."""
import time


class Watchdog:
    def __init__(self, timeout_s):
        self.timeout_s = timeout_s
        self.last_beat = time.monotonic()

    def beat(self):
        self.last_beat = time.time()

    def expired(self):
        return time.perf_counter() - self.last_beat > self.timeout_s
