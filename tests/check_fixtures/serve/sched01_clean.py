"""SCHED01 clean fixture: every draw flows from one explicitly seeded
generator, so the synthesized arrival sequence is a pure function of the
seed — the replay-determinism contract."""
import numpy as np


def synthesize_arrivals(n_steps, rate, seed=0):
    rng = np.random.default_rng(seed)
    return [int(rng.poisson(rate)) for _ in range(n_steps)]
