"""FT01 clean fixture: the clock arrives by injection.

The default parameter value is a *reference* to ``time.monotonic`` (never
a call), and every read goes through the injected parameter — tests can
substitute a step-counter clock and replay failure timelines exactly."""
import time


class Watchdog:
    def __init__(self, timeout_s, clock=time.monotonic):
        self.timeout_s = timeout_s
        self.clock = clock
        self.last_beat = clock()

    def beat(self):
        self.last_beat = self.clock()

    def expired(self):
        return self.clock() - self.last_beat > self.timeout_s
