"""SCHED01 bad fixture: unseeded / global-state randomness in a
serve/-scoped module.

Every draw here either reads OS entropy or mutates process-global RNG
state, so a replayed trace would generate different arrivals each run."""
import random

import numpy as np


def synthesize_arrivals(n_steps, rate):
    rng = np.random.default_rng()
    burst = np.random.poisson(rate)
    jitter = random.random()
    coin = random.Random()
    return rng, burst, jitter, coin.getrandbits(8 * n_steps)
