"""CHK00 fixture: malformed suppression directives (empty rule list, and
a missing mandatory reason)."""

X = 1  # check: disable=

# check: disable=DET01
Y = 2
