"""DET02 clean fixture: split/fold_in between draws, seed required."""

import jax


def two_draws(key, shape):
    k1, k2 = jax.random.split(key)
    a = jax.random.normal(k1, shape)
    b = jax.random.normal(k2, shape)
    return a + b


def per_position(key, n):
    return [jax.random.uniform(jax.random.fold_in(key, i))
            for i in range(n)]


def from_seed(seed):
    if seed is None:
        raise ValueError("an explicit seed is required")
    return jax.random.PRNGKey(seed)
