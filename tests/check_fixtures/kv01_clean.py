"""KV01 clean fixture: balanced acquire/release, copy-on-write before
mutating a shared page, ownership dropped via release_request."""


class BalancedCache:
    def __init__(self, pool):
        self.pool = pool

    def grab(self, rid, page_id):
        return self.pool.acquire(rid, page_id)

    def drop(self, rid):
        self.pool.release_request(rid)


def mutate_private(pool, rid, page_id):
    page = pool.acquire(rid, page_id, shared=True)
    page = pool.copy_page(rid, page)
    page.tokens_used = 0
    pool.release_request(rid)
    return page
