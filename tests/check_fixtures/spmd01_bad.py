"""SPMD01 fixture: a collective naming an axis the shard_map does not
bind, and a ppermute perm with duplicate sources."""

import jax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def body(x):
    return jax.lax.psum(x, "model")


def run(mesh, x):
    return shard_map(body, mesh=mesh, in_specs=(P("data"),),
                     out_specs=P("data"))(x)


def shifted(x):
    return jax.lax.ppermute(x, "data", perm=[(0, 1), (0, 2)])
