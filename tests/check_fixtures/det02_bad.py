"""DET02 fixture: key reuse and hardcoded fallback keys."""

import jax


def two_draws(key, shape):
    a = jax.random.normal(key, shape)
    b = jax.random.normal(key, shape)
    return a + b


def sample(key=jax.random.PRNGKey(0)):
    return jax.random.uniform(key)


def fallback(key):
    key = key if key is not None else jax.random.PRNGKey(0)
    return jax.random.uniform(key)


def redraw_in_loop(key, n):
    out = []
    for _ in range(n):
        out.append(jax.random.uniform(key))
    return out
