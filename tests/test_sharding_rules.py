"""Property tests for the logical-axis sharding rules — the layer every
pspec in the system flows through."""

import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings
from hypothesis import strategies as st
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import (
    DECODE_RULES,
    DEFAULT_RULES,
    FSDP_RULES,
    MOMENTS_RULES,
    SP_DECODE_RULES,
    abstract_mesh,
    logical_to_pspec,
)
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
# AbstractMesh carries axis names/sizes without devices — exactly what the
# rule resolver consumes, so property tests don't need fake devices.
MESH = abstract_mesh((2, 4), ("data", "model"))

LOGICAL = sorted(DEFAULT_RULES)
RULESETS = {
    "default": DEFAULT_RULES,
    "sp": SP_DECODE_RULES,
    "decode": DECODE_RULES,
    "moments": MOMENTS_RULES,
    "fsdp": FSDP_RULES,
}


dims = st.lists(
    st.tuples(st.sampled_from(LOGICAL + [None]), st.integers(1, 64)),
    min_size=1, max_size=5,
)


@settings(max_examples=200, deadline=None)
@given(dims=dims, ruleset=st.sampled_from(sorted(RULESETS)))
def test_pspec_invariants(dims, ruleset):
    names = [d[0] for d in dims]
    sizes = [d[1] for d in dims]
    spec = logical_to_pspec(names, sizes, MESH, RULESETS[ruleset])
    assert len(spec) <= len(dims)
    used = []
    for entry, size in zip(tuple(spec) + (None,) * (len(dims) - len(spec)),
                           sizes):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        total = 1
        for a in axes:
            assert a in MESH.axis_names          # only real mesh axes
            assert a not in used, "mesh axis reused across dims"
            used.append(a)
            total *= MESH.shape[a]
        assert size % total == 0, "non-divisible dim was sharded"


@settings(max_examples=100, deadline=None)
@given(dims=dims)
def test_none_names_never_shard(dims):
    names = [None for _ in dims]
    sizes = [d[1] for d in dims]
    spec = logical_to_pspec(names, sizes, MESH, DEFAULT_RULES)
    assert all(e is None for e in spec)


def test_gqa_fallback_behaviour():
    # kv_heads=8 on a 4-way model axis shards; on 8-way it would replicate.
    spec = logical_to_pspec(("kv_heads",), (8,), MESH, DEFAULT_RULES)
    assert spec == P("model")
    mesh8 = abstract_mesh((1, 8), ("data", "model"))
    spec = logical_to_pspec(("kv_heads",), (4,), mesh8, DEFAULT_RULES)
    assert spec == P(None)   # 4 % 8 != 0 -> replicate


def test_batch_spans_pod_and_data_on_multipod():
    """On the 3-axis mesh the batch dim uses both DP axes; requires 512
    fake devices, so run in a subprocess."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        import jax
        from repro.dist.sharding import DEFAULT_RULES, logical_to_pspec
        from repro.launch.mesh import make_production_mesh
        from jax.sharding import PartitionSpec as P

        mesh = make_production_mesh(multi_pod=True)
        spec = logical_to_pspec(("batch", "seq"), (256, 4096), mesh,
                                DEFAULT_RULES)
        assert spec == P(("pod", "data"), None), spec
        # and the single-pod mesh drops the pod axis transparently
        mesh1 = make_production_mesh(multi_pod=False)
        spec1 = logical_to_pspec(("batch", "seq"), (256, 4096), mesh1,
                                 DEFAULT_RULES)
        assert spec1 == P("data", None), spec1
        print("OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "OK" in proc.stdout
