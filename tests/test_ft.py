"""Unit tests for the ``ft`` package: heartbeat failure detection with an
injected clock, elastic membership, EWMA straggler scoring, and
``plan_rescale`` edge cases (n_alive=1, non-power-of-two survivors,
model-axis shrink, lead axes)."""

import pytest

from repro.ft import HeartbeatMonitor, plan_rescale


# -------------------------------------------------------- missed beats
def test_missed_beat_detection_with_injected_clock():
    t = [0.0]
    mon = HeartbeatMonitor(3, timeout_s=5.0, clock=lambda: t[0])
    t[0] = 3.0
    mon.beat(0)
    mon.beat(1)
    t[0] = 6.0                       # node 2 silent since t=0: 6 > 5
    assert mon.check_failures() == [2]
    assert mon.dead == {2}
    assert mon.alive == [0, 1]
    # Already-dead nodes are not re-reported, and their beats are ignored.
    assert mon.check_failures() == []
    mon.beat(2)
    t[0] = 100.0
    assert mon.check_failures() == [0, 1]


def test_beat_resets_the_timeout_window():
    t = [0.0]
    mon = HeartbeatMonitor(1, timeout_s=2.0, clock=lambda: t[0])
    for tick in range(1, 10):        # beat every 1s: never times out
        t[0] = float(tick)
        assert mon.check_failures() == []
        mon.beat(0)
    t[0] += 2.5                      # then go silent past the timeout
    assert mon.check_failures() == [0]


# --------------------------------------------------- elastic membership
def test_add_node_rejects_alive_duplicate_and_revives_dead():
    t = [0.0]
    mon = HeartbeatMonitor(2, timeout_s=1.0, clock=lambda: t[0])
    with pytest.raises(ValueError, match="already monitored and alive"):
        mon.add_node(1)
    t[0] = 5.0
    assert sorted(mon.check_failures()) == [0, 1]
    mon.add_node(0)                  # re-admitting a dead node revives it
    assert mon.alive == [0]
    assert mon.nodes[0].last_beat == 5.0   # beat clock restarts at now
    mon.add_node(7)                  # brand-new ids join alive
    assert 7 in mon.alive


def test_remove_node_forgets_and_tolerates_unknown_ids():
    t = [0.0]
    mon = HeartbeatMonitor(2, timeout_s=1.0, clock=lambda: t[0])
    t[0] = 5.0
    assert mon.check_failures() == [0, 1]
    mon.remove_node(0)
    assert 0 not in mon.nodes and 0 not in mon.dead
    mon.remove_node(99)              # unknown id: no-op, no raise
    # A removed node no longer appears in failure sweeps.
    t[0] = 50.0
    assert mon.check_failures() == []


# ------------------------------------------------------ EWMA stragglers
def test_ewma_blend_first_sample_seeds_then_blends():
    t = [0.0]
    mon = HeartbeatMonitor(1, clock=lambda: t[0], ewma=0.2)
    mon.beat(0, step_time_s=1.0)     # first sample seeds the EWMA
    assert mon.nodes[0].step_time_ewma == pytest.approx(1.0)
    mon.beat(0, step_time_s=2.0)     # then blends: 0.8*1.0 + 0.2*2.0
    assert mon.nodes[0].step_time_ewma == pytest.approx(1.2)
    mon.beat(0)                      # beat without timing leaves it alone
    assert mon.nodes[0].step_time_ewma == pytest.approx(1.2)


def test_stragglers_need_three_alive_samples():
    t = [0.0]
    mon = HeartbeatMonitor(4, clock=lambda: t[0], straggler_factor=1.8)
    mon.beat(0, step_time_s=1.0)
    mon.beat(1, step_time_s=3.0)     # 2 samples: never enough signal
    assert mon.stragglers() == []
    mon.beat(2, step_time_s=1.0)     # 3rd sample: median 1.0, 3.0 > 1.8x
    assert mon.stragglers() == [1]
    mon.dead.add(1)                  # dead nodes drop out of the pool
    assert mon.stragglers() == []    # back under three alive samples


def test_plan_replacement_consumes_spares_fifo():
    mon = HeartbeatMonitor(4, clock=lambda: 0.0)
    mon.add_spare(10)
    mon.add_spare(11)
    assert mon.plan_replacement([2, 3, 0]) == {2: 10, 3: 11, 0: None}
    assert mon.spares == []


# -------------------------------------------------------- plan_rescale
def test_plan_rescale_single_survivor():
    plan = plan_rescale(1, (2, 4))
    assert plan.new_shape == (1, 1)
    assert plan.new_device_count == 1
    # Global batch preserved: data axis 2 -> 1 doubles accumulation.
    assert plan.accum_factor == 2


def test_plan_rescale_non_power_of_two_survivors_keep_model_axis():
    plan = plan_rescale(6, (2, 4))   # one full model group fits in 6
    assert plan.new_shape == (1, 4)
    assert plan.accum_factor == 2


def test_plan_rescale_model_axis_shrink():
    plan = plan_rescale(3, (2, 4))   # <1 model group: model -> largest p2
    assert plan.new_shape == (1, 2)
    assert plan.accum_factor == 2


def test_plan_rescale_identity_when_nothing_lost():
    plan = plan_rescale(8, (2, 4))
    assert plan.new_shape == (2, 4)
    assert plan.accum_factor == 1


def test_plan_rescale_with_lead_axes():
    # (replica=2, data=2, model=4): lose half -> data axis absorbs it.
    plan = plan_rescale(8, (2, 2, 4), axis_names=("replica", "data", "model"))
    assert plan.new_shape == (2, 1, 4)
    assert plan.accum_factor == 2
    # Below one model group even the lead axes collapse.
    plan = plan_rescale(2, (2, 2, 4), axis_names=("replica", "data", "model"))
    assert plan.new_shape == (1, 1, 2)
    assert plan.accum_factor == 2
