"""Shared test helpers."""


def has_host_memory() -> bool:
    """True when the backend exposes the pinned_host memory kind (real
    two-tier placement); CPU jaxlibs without it skip the physical-move
    tests."""
    try:
        import jax

        kinds = [m.kind for m in jax.devices()[0].addressable_memories()]
        return "pinned_host" in kinds
    except (ImportError, AttributeError, RuntimeError, IndexError,
            NotImplementedError):
        return False
