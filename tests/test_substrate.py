"""Substrate tests: optimizer, data pipeline, checkpointing, fault tolerance,
gradient compression."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.ckpt import AsyncCheckpointer, available_steps, restore, save
from repro.data import SyntheticLM
from repro.dist.compress import (
    ErrorFeedback,
    compress_with_feedback,
    dequantize,
    quantize,
    quantize_roundtrip,
)
from repro.ft import HeartbeatMonitor, plan_rescale
from repro.optim import AdamW, cosine_schedule


# ------------------------------------------------------------------ optim
def test_adamw_minimizes_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(jnp.square(p["w"] - 1.0))

    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = opt.update(g, state, params)
    assert float(loss(params)) < 1e-2


def test_adamw_weight_decay_masks_1d():
    opt = AdamW(lr=0.0, weight_decay=0.5, grad_clip=None)
    params = {"w": jnp.ones((2, 2)), "scale": jnp.ones((2,))}
    state = opt.init(params)
    zeros = jax.tree.map(jnp.zeros_like, params)
    # lr=0 -> no update at all regardless of decay
    p2, _, _ = opt.update(zeros, state, params)
    assert jnp.allclose(p2["w"], params["w"])
    # with lr>0 and zero grads, 2D decays, 1D does not
    opt = AdamW(lr=0.1, weight_decay=0.5, grad_clip=None)
    p3, _, _ = opt.update(zeros, opt.init(params), params)
    assert float(jnp.abs(p3["w"]).max()) < 1.0
    assert jnp.allclose(p3["scale"], params["scale"])


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    vals = [float(lr(jnp.asarray(s))) for s in [0, 5, 10, 50, 100]]
    assert vals[0] == 0.0
    assert vals[1] == pytest.approx(5e-4)
    assert vals[2] == pytest.approx(1e-3)
    assert vals[3] < 1e-3
    assert vals[4] == pytest.approx(1e-4, rel=0.01)


# ------------------------------------------------------------------- data
def test_synthetic_lm_deterministic_and_shifted():
    src = SyntheticLM(vocab=100, seq_len=32, global_batch=4, seed=7)
    b1, b2 = src.batch_np(3), src.batch_np(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 32)
    # labels are next tokens
    row = src.batch_np(0)
    full = np.concatenate([row["tokens"][:, :1], row["labels"]], axis=1)
    np.testing.assert_array_equal(row["tokens"][:, 1:], full[:, 1:-0 or None][:, :31])
    assert (row["tokens"] > 0).all()


def test_synthetic_lm_different_steps_differ():
    src = SyntheticLM(vocab=1000, seq_len=64, global_batch=2, seed=7)
    assert not np.array_equal(src.batch_np(0)["tokens"],
                              src.batch_np(1)["tokens"])


# ------------------------------------------------------------------- ckpt
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": {"w": jnp.arange(6.0).reshape(2, 3)},
            "step": jnp.asarray(7, jnp.int32)}
    save(str(tmp_path), 3, tree, extra_meta={"note": "x"})
    loaded, meta = restore(str(tmp_path), target_tree=tree)
    assert meta["step"] == 3 and meta["note"] == "x"
    np.testing.assert_array_equal(np.asarray(loaded["a"]["w"]),
                                  np.asarray(tree["a"]["w"]))
    assert int(loaded["step"]) == 7


def test_checkpoint_picks_latest_and_ignores_torn(tmp_path):
    tree = {"w": jnp.ones((2,))}
    save(str(tmp_path), 1, tree)
    save(str(tmp_path), 5, jax.tree.map(lambda x: x * 5, tree))
    # torn save: tmp dir without manifest
    os.makedirs(tmp_path / "step_00000009.tmp")
    loaded, meta = restore(str(tmp_path), target_tree=tree)
    assert meta["step"] == 5
    assert float(loaded["w"][0]) == 5.0
    assert available_steps(str(tmp_path)) == [1, 5]


def test_async_checkpointer_gc(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    tree = {"w": jnp.zeros((4,))}
    for s in (1, 2, 3, 4):
        ck.save_async(s, tree)
    ck.wait()
    assert available_steps(str(tmp_path)) == [3, 4]


def test_checkpoint_restart_resumes_training(tmp_path):
    """restart from checkpoint reproduces the exact same next step."""
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.array([2.0])}
    state = opt.init(params)

    def g(p):
        return jax.grad(lambda p: jnp.sum(p["w"] ** 2))(p)

    params1, state1, _ = opt.update(g(params), state, params)
    save(str(tmp_path), 1, {"params": params1, "m": state1.m, "v": state1.v,
                            "opt_step": state1.step})
    loaded, _ = restore(str(tmp_path),
                        target_tree={"params": params1, "m": state1.m,
                                     "v": state1.v, "opt_step": state1.step})
    from repro.optim.adamw import AdamWState

    state_r = AdamWState(loaded["opt_step"], loaded["m"], loaded["v"])
    p_a, _, _ = opt.update(g(params1), state1, params1)
    p_b, _, _ = opt.update(g(loaded["params"]), state_r, loaded["params"])
    np.testing.assert_allclose(np.asarray(p_a["w"]), np.asarray(p_b["w"]),
                               rtol=1e-6)


# --------------------------------------------------------------------- ft
def test_heartbeat_failure_detection():
    t = [0.0]
    mon = HeartbeatMonitor(4, timeout_s=10.0, clock=lambda: t[0])
    for i in range(4):
        mon.beat(i, 1.0)
    t[0] = 5.0
    for i in (0, 1, 3):
        mon.beat(i, 1.0)
    assert mon.check_failures() == []
    t[0] = 16.0
    for i in (0, 1, 3):
        mon.beat(i, 1.0)
    assert mon.check_failures() == [2]
    assert mon.alive == [0, 1, 3]


def test_straggler_detection_and_replacement():
    t = [0.0]
    mon = HeartbeatMonitor(8, clock=lambda: t[0])
    mon.add_spare(100)
    for step in range(20):
        t[0] += 1
        for i in range(8):
            mon.beat(i, 1.0 if i != 5 else 3.0)
    assert mon.stragglers() == [5]
    plan = mon.plan_replacement([5])
    assert plan == {5: 100}
    assert mon.plan_replacement([6]) == {6: None}  # no spares left


def test_plan_rescale_shrinks_data_axis():
    plan = plan_rescale(240, (16, 16))
    assert plan.new_shape == (15, 16)
    plan = plan_rescale(255, (16, 16))
    assert plan.new_shape == (15, 16)
    plan = plan_rescale(8, (16, 16))   # less than one model group
    assert plan.new_shape[-1] == 8


# ----------------------------------------------------------- compression
@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(-100, 100, width=32), min_size=1, max_size=600))
def test_quantize_roundtrip_error_bound(vals):
    x = jnp.asarray(np.array(vals, np.float32))
    y = quantize_roundtrip(x)
    blocks = np.abs(np.asarray(x))
    # per-block max / 127 is the max quantization error within a block
    err = np.abs(np.asarray(y) - np.asarray(x))
    assert err.max() <= (blocks.max() / 127.0) + 1e-6


def test_error_feedback_reduces_bias():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1024,)).astype(np.float32) * 0.01)
    ef = ErrorFeedback.init(x)
    total_plain = jnp.zeros_like(x)
    total_ef = jnp.zeros_like(x)
    for _ in range(50):
        total_plain = total_plain + quantize_roundtrip(x)
        qx, ef = compress_with_feedback(x, ef)
        total_ef = total_ef + dequantize(qx)
    target = x * 50
    err_plain = float(jnp.abs(total_plain - target).mean())
    err_ef = float(jnp.abs(total_ef - target).mean())
    assert err_ef <= err_plain * 0.5 + 1e-7


def test_quantize_shapes_preserved():
    x = jnp.ones((3, 5, 7))
    assert quantize_roundtrip(x).shape == (3, 5, 7)
