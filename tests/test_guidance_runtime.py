"""Backend-conformance suite for the unified Algorithm-1 API.

Every ``TierBackend`` is driven by the same ``GuidanceRuntime`` loop, so each
backend is checked two ways on a fixed trace:

1. **Protocol conformance** — snapshot/telemetry/reweight/enforce invariants
   (unique arena ids, telemetry consistent with the profile, counters scaled
   by decay, capacity respected after enforcement).

2. **Decision parity with the pre-refactor loops** — the reference functions
   below are transliterations of the seed implementations this API replaced
   (the seed controller's ``maybe_migrate``, ``MemSimulator._online_decide``'s
   fragmentation arm, ``Engine._gdt_interval``).  They are pure reads of
   backend state, so at each interval the reference runs first and the
   runtime's recorded ``MigrationPlan`` must match it exactly.

Backends covered parametrically: ``ArenaBackend`` (trainer path),
``SimArenaBackend`` (simulator path, fragmented telemetry) and
``PagedKVBackend`` (serving path, page chunks) — plus the capacity fix at
the ``PagedKVBackend.enforce`` boundary.
"""


import pytest

from repro.core import (
    CLX,
    ArenaBackend,
    ArenaManager,
    FractionPlacer,
    GuidanceConfig,
    GuidanceRuntime,
    SiteKind,
    SiteRegistry,
    collapse_to_chunks,
    decide,
    explode_profile,
    parent_fractions,
    recommend,
)
from repro.core.profiler import ArenaProfile, IntervalProfile
from repro.core.runtime import MigrationPlan, MoveStats
from repro.mem.simulator import SimArenaBackend
from repro.mem import SimSite, SimWorkload

MB = 2**20


# ====================================================== reference loops
def profile_of(arenas: ArenaManager) -> IntervalProfile:
    """Pure snapshot of arena state (what OnlineProfiler reports)."""
    rows = [
        ArenaProfile(arena_id=a.arena_id, site_id=a.site.site_id,
                     label=a.site.label, accesses=a.accesses,
                     resident_bytes=a.resident_bytes,
                     fast_fraction=a.fast_fraction)
        for a in arenas
    ]
    return IntervalProfile(0, rows, arenas.private_pool_bytes, 0.0)


def reference_plain(arenas, hw, cap, strategy):
    """The seed controller's maybe_migrate: profile -> recommend -> decide."""
    profile = profile_of(arenas)
    recs = recommend(profile, cap, strategy)
    decision = decide(profile, recs, hw)
    return decision, dict(recs.fractions), {}


def reference_fragmented(arenas, telemetry, hw, cap, strategy, num_fragments):
    """Seed fragmentation arm (simulator ``_online_decide`` / engine
    ``_gdt_interval``): explode -> recommend -> decide -> collapse."""
    profile = profile_of(arenas)
    exploded, frags = explode_profile(profile, telemetry,
                                      num_fragments=num_fragments)
    recs = recommend(exploded, cap, strategy)
    decision = decide(exploded, recs, hw)
    placement = collapse_to_chunks(frags, recs.fractions)
    pf = parent_fractions(frags, placement)
    fractions = {
        a.arena_id: pf.get(a.arena_id,
                           recs.fractions.get(a.arena_id, 0.0))
        for a in arenas
    }
    return decision, fractions, placement


# ========================================================= harnesses
class Harness:
    """One backend + runtime + a fixed access trace + its reference loop."""

    def __init__(self, name, backend, runtime, touch, reference):
        self.name = name
        self.backend = backend
        self.runtime = runtime
        self.touch = touch          # touch(i): apply interval i's accesses
        self.reference = reference  # () -> (decision, fractions, placement)


def make_arena_harness():
    reg = SiteRegistry()
    cap = 50 * MB
    mgr = ArenaManager(reg, promotion_threshold=1 * MB,
                       fast_capacity_bytes=cap)
    hot = reg.register(["hot"], SiteKind.PARAM)
    cold = reg.register(["cold"], SiteKind.PARAM)
    mgr.allocate(cold, 40 * MB)     # first-touch: cold grabs the fast tier
    mgr.allocate(hot, 40 * MB)
    backend = ArenaBackend(mgr, CLX)
    runtime = GuidanceRuntime(
        backend, CLX, GuidanceConfig(strategy="thermos",
                                     fast_capacity_bytes=cap,
                                     interval_steps=1))

    def touch(i):
        mgr.touch(hot, 400_000)
        mgr.touch(cold, 10)

    def reference():
        return reference_plain(mgr, CLX, cap, "thermos")

    return Harness("arena", backend, runtime, touch, reference)


def make_sim_harness():
    sites = [
        SimSite("big_skewed", nbytes=60 * MB, read_GBps=8.0,
                hot_page_frac=0.3, hot_traffic_frac=0.9),
        SimSite("uniform", nbytes=30 * MB, read_GBps=2.0),
    ]
    wl = SimWorkload("conformance", sites, phases=8)
    reg = SiteRegistry()
    cap = 40 * MB
    mgr = ArenaManager(reg, fast_capacity_bytes=cap)
    core_sites = {s.name: reg.register([s.name], SiteKind.OTHER)
                  for s in sites}
    arena_of = {s.name: mgr.allocate(core_sites[s.name], s.nbytes)
                for s in sites}
    backend = SimArenaBackend(mgr, CLX, FractionPlacer(mgr), wl, arena_of,
                              fragmentation=True)
    runtime = GuidanceRuntime(
        backend, CLX, GuidanceConfig(strategy="thermos",
                                     fast_capacity_bytes=cap,
                                     interval_steps=1, num_fragments=2))

    def touch(i):
        # Phase shift: the skewed site dominates early, then the uniform
        # site becomes the hot set and must be promoted over it.
        if i < 3:
            mgr.touch(core_sites["big_skewed"], 900_000)
            mgr.touch(core_sites["uniform"], 120_000)
        else:
            mgr.touch(core_sites["big_skewed"], 90_000)
            mgr.touch(core_sites["uniform"], 5_000_000)

    def reference():
        # Rebuild the telemetry exactly as the backend will (pure read).
        profile = profile_of(mgr)
        by_arena = profile.by_arena()
        telem = {}
        for s in wl.sites:
            arena = arena_of[s.name]
            if s.hot_page_frac >= 1.0:
                continue
            row = by_arena[arena.arena_id]
            hot_b = int(s.nbytes * s.hot_page_frac)
            from repro.core import ChunkStats
            telem[arena.arena_id] = [
                ChunkStats(chunk_id=arena.arena_id * 2, nbytes=hot_b,
                           accesses=int(row.accesses * s.hot_traffic_frac),
                           age=0, fast=row.fast_fraction > 0.5),
                ChunkStats(chunk_id=arena.arena_id * 2 + 1,
                           nbytes=s.nbytes - hot_b,
                           accesses=int(row.accesses * (1 - s.hot_traffic_frac)),
                           age=1, fast=False),
            ]
        return reference_fragmented(mgr, telem, CLX, cap, "thermos", 2)

    return Harness("sim", backend, runtime, touch, reference)


def make_paged_harness():
    from repro.serve import PagedKVBackend
    from repro.serve.kvcache import PagedKVPool

    pool = PagedKVPool(n_layers=2, page_size=4, kv_heads=2, head_dim=8,
                       hbm_pages=6, host_pages=16)
    pool.free_hbm.pop(0)            # engine-style reserved scratch slot
    requests = {0: object(), 1: object()}
    for rid in (0, 1):
        for idx in range(2):
            pool.allocate(rid, idx, step=0)
    # One cold page starts on the host tier.
    pool.swap_out(pool.request_pages(1)[1].page_id)
    clock = {"step": 0}
    backend = PagedKVBackend(pool, requests, clock=lambda: clock["step"])
    cap = 5 * pool.page_bytes       # hbm_pages minus the scratch slot
    runtime = GuidanceRuntime(
        backend, CLX, GuidanceConfig(strategy="thermos",
                                     fast_capacity_bytes=cap,
                                     interval_steps=1, num_fragments=4,
                                     skip_empty_intervals=True),
        clock=lambda: clock["step"])

    def touch(i):
        clock["step"] = i + 1
        for p in pool.request_pages(0):
            p.accesses += 50        # request 0 is hot
        for p in pool.request_pages(1):
            p.accesses += 2

    def reference():
        # Transliteration of the seed Engine._gdt_interval (pure read).
        from repro.core import ChunkStats
        rows, telem = [], {}
        pb = pool.page_bytes
        for rid in requests:
            pages = pool.request_pages(rid)
            if not pages:
                continue
            fast_b = sum(1 for p in pages if p.hbm_slot is not None)
            rows.append(ArenaProfile(
                arena_id=rid, site_id=rid, label=f"req{rid}",
                accesses=sum(p.accesses for p in pages),
                resident_bytes=len(pages) * pb,
                fast_fraction=fast_b / len(pages)))
            telem[rid] = [
                ChunkStats(chunk_id=p.page_id, nbytes=pb,
                           accesses=p.accesses,
                           age=clock["step"] - p.birth_step,
                           fast=p.hbm_slot is not None)
                for p in pages]
        profile = IntervalProfile(clock["step"], rows, 0, 0.0)
        exploded, frags = explode_profile(profile, telem, num_fragments=4)
        recs = recommend(exploded, cap, "thermos")
        decision = decide(exploded, recs, CLX)
        placement = collapse_to_chunks(frags, recs.fractions)
        return decision, None, placement

    return Harness("paged", backend, runtime, touch, reference)


def make_expert_harness():
    import jax
    import jax.numpy as jnp
    from repro.serve import ExpertBackend, ExpertStore

    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    moe_params = {
        "w_gate": jax.random.normal(ks[0], (2, 4, 4, 4), jnp.float32),
        "w_up": jax.random.normal(ks[1], (2, 4, 4, 4), jnp.float32),
        "w_down": jax.random.normal(ks[2], (2, 4, 4, 4), jnp.float32),
    }
    store = ExpertStore(moe_params, 2, 4, 2, double_buffer=False)
    clock = {"step": 0}
    backend = ExpertBackend(store, clock=lambda: clock["step"])
    cap = store.cache_bytes
    runtime = GuidanceRuntime(
        backend, CLX, GuidanceConfig(strategy="thermos",
                                     fast_capacity_bytes=cap,
                                     interval_steps=1, num_fragments=4,
                                     skip_empty_intervals=True),
        clock=lambda: clock["step"])

    def touch(i):
        # Phase shift: layer 0's experts dominate the routed-token counts
        # early, then layer 1 becomes the hot population and its blocks
        # must be promoted over layer 0's.
        clock["step"] = i + 1
        hot = 0 if i < 3 else 1
        for e in range(store.n_experts):
            store.blocks[(hot, e)].accesses += 200.0 + 50.0 * e
            store.blocks[(1 - hot, e)].accesses += 1.0

    def reference():
        # Transliteration of ExpertBackend.snapshot + the engine interval
        # loop (pure read): layer arenas, per-block chunk telemetry.
        from repro.core import ChunkStats
        step = clock["step"]
        bb = store.block_bytes
        rows, telem = [], {}
        for l in range(store.n_layers):
            blocks = [store.blocks[(l, e)] for e in range(store.n_experts)]
            fast = sum(1 for b in blocks if b.slot is not None)
            rows.append(ArenaProfile(
                arena_id=l, site_id=l, label=f"moe_l{l}",
                accesses=sum(b.accesses for b in blocks),
                resident_bytes=len(blocks) * bb,
                fast_fraction=fast / len(blocks)))
            telem[l] = [
                ChunkStats(chunk_id=store.chunk_id(l, b.expert), nbytes=bb,
                           accesses=b.accesses, age=step - b.birth_step,
                           fast=b.slot is not None)
                for b in blocks]
        profile = IntervalProfile(step, rows, 0, 0.0)
        exploded, frags = explode_profile(profile, telem, num_fragments=4)
        recs = recommend(exploded, cap, "thermos")
        decision = decide(exploded, recs, CLX)
        placement = collapse_to_chunks(frags, recs.fractions)
        return decision, None, placement

    return Harness("expert", backend, runtime, touch, reference)


HARNESSES = {
    "arena": make_arena_harness,
    "sim": make_sim_harness,
    "paged": make_paged_harness,
    "expert": make_expert_harness,
}


@pytest.fixture(params=sorted(HARNESSES))
def harness(request):
    return HARNESSES[request.param]()


# ==================================================== protocol conformance
def test_snapshot_invariants(harness):
    harness.touch(0)
    profile = harness.backend.snapshot()
    ids = [r.arena_id for r in profile.rows]
    assert len(ids) == len(set(ids)), "duplicate arena ids"
    assert profile.rows, "fixed trace must produce a non-empty profile"
    for r in profile.rows:
        assert 0.0 <= r.fast_fraction <= 1.0
        assert r.resident_bytes >= 0
        assert r.accesses >= 0


def test_telemetry_consistent_with_profile(harness):
    harness.touch(0)
    profile = harness.backend.snapshot()
    telemetry = harness.backend.telemetry()
    by_arena = profile.by_arena()
    for arena_id, chunks in telemetry.items():
        assert arena_id in by_arena, "telemetry for unknown arena"
        assert sum(c.nbytes for c in chunks) == by_arena[arena_id].resident_bytes
        ids = [c.chunk_id for c in chunks]
        assert len(ids) == len(set(ids))


def test_reweight_scales_access_counters(harness):
    harness.touch(0)
    before = {r.arena_id: r.accesses
              for r in harness.backend.snapshot().rows}
    harness.backend.reweight(0.5)
    after = {r.arena_id: r.accesses
             for r in harness.backend.snapshot().rows}
    for arena_id, accs in before.items():
        assert after[arena_id] <= accs // 2 + len(after), \
            "reweight must decay every profiled counter"


# =============================================== parity with the seed loop
def test_decisions_match_pre_refactor_loop(harness):
    """Fixed trace, interval by interval: the runtime must reproduce the
    seed loop's ski-rental decision, target fractions and chunk placement."""
    migrated_any = False
    for i in range(8):
        harness.touch(i)
        want_decision, want_fractions, want_placement = harness.reference()
        event = harness.runtime.maybe_migrate()
        assert event is not None
        assert event.decision == want_decision, f"interval {i}"
        assert event.migrated == want_decision.migrate
        if want_placement:
            assert event.plan.chunk_placement == want_placement, f"interval {i}"
        if want_fractions is not None and event.migrated:
            for arena_id, frac in want_fractions.items():
                assert event.plan.fast_fraction(arena_id) == pytest.approx(frac)
        migrated_any = migrated_any or event.migrated
    assert migrated_any, "trace must exercise at least one migration"


def test_capacity_respected_after_enforcement(harness):
    cap = harness.runtime.config.fast_capacity_bytes
    for i in range(8):
        harness.touch(i)
        harness.runtime.maybe_migrate()
    fast = getattr(harness.backend, "fast_bytes", lambda: 0)()
    assert fast <= cap, f"{harness.name}: fast tier over budget"


def test_event_stream_is_structured(harness):
    for i in range(4):
        harness.touch(i)
        harness.runtime.maybe_migrate()
    events = harness.runtime.events
    assert len(harness.runtime.history) == 4
    assert all(e.kind == "interval" for e in harness.runtime.history)
    assert harness.runtime.total_bytes_migrated == sum(
        e.bytes_moved for e in harness.runtime.history)
    # The summary consumer digests the stream without touching backends.
    from repro.launch.analysis import guidance_summary

    summary = guidance_summary(events)
    assert summary["intervals"] == 4
    assert summary["migrations"] == harness.runtime.migration_count


# ===================================== serving capacity fix (API boundary)
def test_paged_enforce_refuses_overfull_promotions():
    """The seed engine silently dropped promotions when HBM was full,
    desynchronizing ``last_recs`` from reality.  ``PagedKVBackend.enforce``
    must refuse the excess, report it, and keep ``last_recs`` truthful."""
    from repro.serve import PagedKVBackend
    from repro.serve.kvcache import PagedKVPool

    pool = PagedKVPool(n_layers=1, page_size=2, kv_heads=1, head_dim=4,
                       hbm_pages=4, host_pages=8)
    pool.free_hbm.pop(0)            # reserved scratch slot
    requests = {0: object()}
    # Three pages on the host tier, all "recommended fast" (allocated first
    # and swapped straight out so the HBM slots stay free for the residents).
    hosted = []
    for i in range(3):
        p = pool.allocate(0, i, step=0)
        pool.swap_out(p.page_id)
        hosted.append(p)
    resident = [pool.allocate(0, 3 + i, step=0) for i in range(3)]
    assert pool.free_hbm == []      # HBM full: 3 resident + scratch

    backend = PagedKVBackend(pool, requests, clock=lambda: 1)
    placement = {p.page_id: True for p in resident + hosted}
    backend.last_recs = dict(placement)
    plan = MigrationPlan(
        profile=IntervalProfile(1, [], 0, 0.0),
        exploded=IntervalProfile(1, [], 0, 0.0),
        fragments=[], assignment=None, decision=None,
        fractions={}, chunk_placement=placement,
        capacity_bytes=3 * pool.page_bytes, strategy="thermos")
    stats = backend.enforce(plan)

    assert isinstance(stats, MoveStats)
    assert stats.bytes_promoted == 0, "no free slot -> no promotion"
    assert stats.dropped_promotions == 3
    # last_recs now reflects the placement that actually exists.
    for p in hosted:
        assert backend.last_recs[p.page_id] is False
        assert pool.pages[p.page_id].hbm_slot is None
    for p in resident:
        assert backend.last_recs[p.page_id] is True
        assert pool.pages[p.page_id].hbm_slot is not None
