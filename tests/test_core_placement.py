"""Tests for real-array tier enforcement via JAX memory kinds."""

import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    ArenaBackend,
    ArenaManager,
    CLX,
    GuidanceConfig,
    GuidanceRuntime,
    SiteKind,
    SiteRegistry,
)
from repro.core.placement import JaxArenaPlacer, memory_kind_of

MB = 2**20


def has_host_memory():
    try:
        kinds = [m.kind for m in jax.devices()[0].addressable_memories()]
        return "pinned_host" in kinds
    except (AttributeError, RuntimeError, IndexError, NotImplementedError):
        return False


pytestmark = pytest.mark.skipif(
    not has_host_memory(), reason="backend lacks pinned_host memory kind"
)


def build(cap_bytes, first_touch=False):
    reg = SiteRegistry()
    mgr = ArenaManager(
        reg,
        promotion_threshold=1024,
        fast_capacity_bytes=cap_bytes if first_touch else None,
    )
    placer = JaxArenaPlacer(mgr)
    gdt = GuidanceRuntime(
        ArenaBackend(mgr, CLX, placer=placer), CLX,
        GuidanceConfig(fast_capacity_bytes=cap_bytes, interval_steps=1),
    )
    return reg, mgr, placer, gdt


def test_bind_and_fetch_roundtrip():
    reg, mgr, placer, _ = build(1 << 30)
    s = reg.register(["w"], SiteKind.PARAM)
    x = jnp.arange(4096, dtype=jnp.float32)
    arena = mgr.allocate(s, x.size * 4)
    placer.bind(arena.arena_id, "w", x)
    got = placer.fetch_fast(arena.arena_id)["w"]
    assert (got == x).all()
    assert memory_kind_of(got) == "device"


def test_enforce_moves_memory_kind():
    """Cold data first-touches into HBM; the hot late-comer spills to host.
    Online guidance swaps their tiers once rental beats purchase."""
    reg, mgr, placer, gdt = build(cap_bytes=8192, first_touch=True)
    cold = reg.register(["cold"], SiteKind.PARAM)
    hot = reg.register(["hot"], SiteKind.PARAM)
    xc = jnp.ones((2048,), jnp.float32)   # 8 KB
    xh = jnp.ones((2048,), jnp.float32)   # 8 KB
    ac = mgr.allocate(cold, 8192)          # first -> all fast
    ah = mgr.allocate(hot, 8192)           # spills -> slow
    placer.bind(ac.arena_id, "w", xc)
    placer.bind(ah.arena_id, "w", xh)
    assert memory_kind_of(placer.get(ah.arena_id, "w")) == "pinned_host"
    # Drive accesses so 'hot' is recommended fast; capacity only fits one.
    for _ in range(6):
        mgr.touch(hot, 10_000_000)
        mgr.touch(cold, 1)
        gdt.on_step()
    kh = memory_kind_of(placer.get(ah.arena_id, "w"))
    kc = memory_kind_of(placer.get(ac.arena_id, "w"))
    assert kh == "device"
    assert kc == "pinned_host"
    # Values survive migration (fetch back to device kind to compare).
    back = placer.fetch_fast(ac.arena_id)["w"]
    assert (back == xc).all()


def test_fetch_fast_transfers_slow_entries():
    reg, mgr, placer, _ = build(1 << 30)
    s = reg.register(["x"], SiteKind.OPT_STATE)
    x = jnp.full((1024,), 3.0, jnp.float32)
    arena = mgr.allocate(s, 4096)
    placer.bind(arena.arena_id, "m", x)
    placer._apply(arena.arena_id, 0.0)  # demote everything
    assert memory_kind_of(placer.get(arena.arena_id, "m")) == "pinned_host"
    before = placer.transfers_bytes
    got = placer.fetch_fast(arena.arena_id)["m"]
    assert memory_kind_of(got) == "device"
    assert placer.transfers_bytes > before  # rental paid
    assert (got == 3.0).all()


def test_writeback_preserves_tier():
    reg, mgr, placer, _ = build(1 << 30)
    s = reg.register(["x"], SiteKind.OPT_STATE)
    arena = mgr.allocate(s, 4096)
    placer.bind(arena.arena_id, "m", jnp.zeros((1024,), jnp.float32))
    placer._apply(arena.arena_id, 0.0)
    new = jnp.full((1024,), 7.0, jnp.float32)
    placer.writeback(arena.arena_id, {"m": new})
    got = placer.get(arena.arena_id, "m")
    assert memory_kind_of(got) == "pinned_host"
    assert (jax.device_put(got) == 7.0).all()


def test_fractional_placement_array_granularity():
    reg, mgr, placer, _ = build(1 << 30)
    s = reg.register(["kv"], SiteKind.KV_CACHE)
    arena = mgr.allocate(s, 4 * 4096)
    for i in range(4):
        placer.bind(arena.arena_id, f"p{i}", jnp.zeros((1024,), jnp.float32))
    placer._apply(arena.arena_id, 0.5)
    kinds = [memory_kind_of(e.array) for e in placer.entries(arena.arena_id)]
    assert kinds == ["device", "device", "pinned_host", "pinned_host"]
    assert placer.fast_bytes() == 2 * 4096
    assert placer.slow_bytes() == 2 * 4096
