"""Ragged expert-parallel MoE dispatch on a multi-device CPU mesh.

Covers the ep-mode serving-correctness contract end to end: the ring
ragged all-to-all against both its dense-gather oracle and a pure-numpy
ground truth (empty send/recv shards included), layer-level parity of the
shard_map ep path against the meshless dropless path, chunking invariance
(batched prefill == chunked prefill == step-by-step decode) under the
mesh, empty-segment expert shards, the ep-axis config validation, and the
shard-locality guarantee of the per-row dropless argsort (a data-sharded
mesh compiles the tp dispatch with zero gather collectives).

Everything needing >1 device runs in a subprocess that sets
XLA_FLAGS=--xla_force_host_platform_device_count before importing jax
(same pattern as test_dist_collectives.py)."""

from test_dist_collectives import run_in_subprocess


def test_ring_ragged_all_to_all_matches_oracle_and_numpy():
    run_in_subprocess("""
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_test_mesh
        from repro.dist.collectives import (
            ring_ragged_all_to_all, ragged_all_to_all_reference,
            shard_map_compat)

        n = 8
        mesh = make_test_mesh(data=1, model=n)
        rng = np.random.default_rng(0)
        R, d = 24, 16
        # sizes[j, p] = rows shard j sends shard p; row sums stay <= R.
        sizes = rng.integers(0, R // n, (n, n)).astype(np.int32)
        sizes[2, :] = 0                      # a shard that sends nothing
        sizes[:, 5] = 0                      # a shard that receives nothing
        rows = rng.normal(size=(n, R, d)).astype(np.float32)
        recv_sizes = np.ascontiguousarray(sizes.T)
        out_rows = n * R

        def body(rows_blk, send_blk, recv_blk):
            a = ring_ragged_all_to_all(
                rows_blk[0], send_blk[0], recv_blk[0], "model",
                chunk_rows=R, out_rows=out_rows)
            b = ragged_all_to_all_reference(
                rows_blk[0], send_blk[0], recv_blk[0], "model",
                chunk_rows=R, out_rows=out_rows)
            return a[None], b[None]

        f = jax.jit(shard_map_compat(
            body, mesh,
            in_specs=(P("model"), P("model"), P("model")),
            out_specs=(P("model"), P("model"))))
        a, b = f(jnp.asarray(rows), jnp.asarray(sizes),
                 jnp.asarray(recv_sizes))
        a, b = np.asarray(a), np.asarray(b)

        for p in range(n):
            want = np.zeros((out_rows, d), np.float32)
            off = 0
            for j in range(n):
                o = int(sizes[j, :p].sum())
                cnt = int(sizes[j, p])
                want[off:off + cnt] = rows[j, o:o + cnt]
                off += cnt
            np.testing.assert_allclose(a[p], want, atol=0, rtol=0)
            np.testing.assert_allclose(b[p], want, atol=0, rtol=0)
        print("ragged a2a OK")
    """)


def test_ep_dropless_parity_and_chunking_invariance_on_mesh():
    """The shard_map ragged-ep path computes the same function as the
    meshless per-row dropless path, and under the mesh batched prefill,
    chunked prefill and step-by-step decode agree (ep serving no longer
    re-exposes the prefill/decode divergence the capacity pin caused)."""
    run_in_subprocess("""
        from repro.launch.mesh import make_test_mesh
        from repro.models.common import init_params
        from repro.models.moe import MoEConfig, moe, moe_decode, moe_defs

        cfg = MoEConfig(d_model=32, d_ff=48, n_experts=6, top_k=2,
                        parallelism="ep", ep_axis_size=4)
        assert cfg.dispatch == "dropless" and cfg.padded_experts == 8
        p = jax.tree.map(
            lambda a: a.astype(jnp.float32)
            if a.dtype == jnp.bfloat16 else a,
            init_params(moe_defs(cfg), jax.random.PRNGKey(0)))
        rng = np.random.default_rng(2)
        B, S = 2, 12
        x = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)), jnp.float32)

        y_local = np.asarray(moe(p, x, cfg))        # no mesh: fallback path

        mesh = make_test_mesh(data=2, model=4)
        run = jax.jit(lambda xx: moe(p, xx, cfg))
        dec = jax.jit(lambda xx: moe_decode(p, xx, cfg))
        with mesh:
            y_full = np.asarray(run(x))
            y_chunks = np.concatenate(
                [np.asarray(run(x[:, i:i + 4])) for i in range(0, S, 4)],
                axis=1)
            y_steps = np.concatenate(
                [np.asarray(dec(x[:, i:i + 1])) for i in range(S)], axis=1)
            # grads flow through the ragged all-to-alls (ppermute/scatter
            # transposes) the same as through the meshless path
            g_mesh = np.asarray(jax.jit(jax.grad(
                lambda xx: moe(p, xx, cfg).sum()))(x))
        g_local = np.asarray(jax.grad(lambda xx: moe(p, xx, cfg).sum())(x))

        np.testing.assert_allclose(y_full, y_local, atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(y_chunks, y_full, atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(y_steps, y_full, atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(g_mesh, g_local, atol=1e-4, rtol=1e-4)
        print("ep parity OK")
    """)


def test_ep_dropless_empty_expert_shards():
    """Shards whose experts attract zero tokens exchange empty ragged
    segments (size-0 all-to-all blocks) without corrupting neighbours."""
    run_in_subprocess("""
        from repro.launch.mesh import make_test_mesh
        from repro.models.common import init_params
        from repro.models.moe import MoEConfig, moe, moe_defs

        cfg = MoEConfig(d_model=32, d_ff=48, n_experts=6, top_k=2,
                        parallelism="ep", ep_axis_size=4)
        p = jax.tree.map(
            lambda a: a.astype(jnp.float32)
            if a.dtype == jnp.bfloat16 else a,
            init_params(moe_defs(cfg), jax.random.PRNGKey(1)))
        # Bias routing so only experts 0 and 1 are ever picked: shards
        # owning experts 2..7 receive nothing at all.
        router = np.array(p["router"])
        router[:, 2:] = -30.0
        p = {**p, "router": jnp.asarray(router)}

        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.normal(size=(2, 8, cfg.d_model)), jnp.float32)
        y_local = np.asarray(moe(p, x, cfg))
        mesh = make_test_mesh(data=2, model=4)
        with mesh:
            y_mesh = np.asarray(jax.jit(lambda xx: moe(p, xx, cfg))(x))
        np.testing.assert_allclose(y_mesh, y_local, atol=1e-5, rtol=1e-5)
        print("empty shards OK")
    """)


def test_ep_axis_mismatch_raises_under_mesh():
    """A pad target that doesn't divide over the live model axis fails
    loudly at trace time, not as a shape error deep in the all-to-all."""
    run_in_subprocess("""
        from repro.launch.mesh import make_test_mesh
        from repro.models.common import init_params
        from repro.models.moe import MoEConfig, moe, moe_defs

        cfg = MoEConfig(d_model=32, d_ff=48, n_experts=6, top_k=2,
                        parallelism="ep", ep_axis_size=2)   # padded to 6
        p = init_params(moe_defs(cfg), jax.random.PRNGKey(0))
        x = jnp.zeros((2, 4, cfg.d_model), jnp.float32)
        mesh = make_test_mesh(data=2, model=4)              # 6 % 4 != 0
        try:
            with mesh:
                jax.jit(lambda xx: moe(p, xx, cfg))(x)
        except ValueError as e:
            assert "ep mesh mismatch" in str(e), e
            print("validation OK")
        else:
            raise AssertionError("expected ep mesh mismatch ValueError")
    """)


def test_per_row_dispatch_compiles_shard_local_on_data_mesh():
    """The acceptance check for the per-row argsort: tp-dropless lowered on
    a purely data-sharded mesh all-gathers NO float data — activations and
    routing probs (the token stream) stay inside their batch shard, and no
    all-to-all appears at all.  The old flat B*S*k argsort gathered the
    whole token stream across data shards.  (Tiny int32 segment-offset
    cumsums inside the grouped-FFN oracle may still gather: they are
    d_model*dtype-times smaller than the activation gathers this test
    guards against.)"""
    run_in_subprocess("""
        import re
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_test_mesh
        from repro.models.common import init_params
        from repro.models.moe import MoEConfig, moe, moe_defs

        cfg = MoEConfig(d_model=32, d_ff=48, n_experts=6, top_k=2)
        p = jax.tree.map(
            lambda a: a.astype(jnp.float32)
            if a.dtype == jnp.bfloat16 else a,
            init_params(moe_defs(cfg), jax.random.PRNGKey(0)))
        mesh = make_test_mesh(data=8, model=1)
        x = jnp.zeros((8, 16, cfg.d_model), jnp.float32)
        with mesh:
            lowered = jax.jit(
                lambda pp, xx: moe(pp, xx, cfg),
                in_shardings=(
                    jax.tree.map(
                        lambda a: NamedSharding(mesh, P()), p),
                    NamedSharding(mesh, P("data", None, None))),
            ).lower(p, x)
            hlo = lowered.compile().as_text()
        assert "all-to-all" not in hlo
        float_gathers = [
            ln.strip() for ln in hlo.splitlines()
            if re.search(r"= (f32|bf16|f16)\\[[0-9,]*\\][^=]*all-gather",
                         ln)]
        assert not float_gathers, float_gathers
        print("shard-local OK")
    """)
