"""Serving engine tests: paged decode correctness (vs the contiguous-cache
model decode), one-shot vs chunked prefill equality, two-tier page
migration, partial-batch masking, and the guided-policy benefit."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import build_model
from repro.serve import Engine, ServeConfig


@pytest.fixture(scope="module")
def model_and_params():
    cfg = dataclasses.replace(get_smoke("llama3_2_1b"), remat=False)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


@pytest.fixture(scope="module")
def moe_model_and_params():
    cfg = dataclasses.replace(get_smoke("granite_moe_3b_a800m"), remat=False)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def greedy_reference(model, params, prompt, n_new, cache_len=64):
    """Contiguous-cache greedy decode (the model's own serve path)."""
    cache = model.init_cache(1, cache_len)
    toks = list(prompt)
    logits = None
    for t, tok in enumerate(toks):
        logits, cache = jax.jit(model.decode)(
            params, cache, jnp.asarray([[tok]], jnp.int32), jnp.int32(t))
    out = []
    pos = len(toks)
    for _ in range(n_new):
        nxt = int(jnp.argmax(logits[0]))
        out.append(nxt)
        logits, cache = jax.jit(model.decode)(
            params, cache, jnp.asarray([[nxt]], jnp.int32), jnp.int32(pos))
        pos += 1
    return out


def still_live(eng, rid):
    """Finished requests are pruned from ``engine.requests``."""
    return rid in eng.requests


def generated(eng, rid):
    req = eng.finished.get(rid) or eng.requests.get(rid)
    return req.generated


def request_pages_bits(eng, rid):
    """K/V page contents for one request, in logical page order."""
    out = []
    for p in eng.pool.request_pages(rid):
        assert p.hbm_slot is not None
        out.append(np.asarray(eng.pool.k_hbm[:, p.hbm_slot]))
        out.append(np.asarray(eng.pool.v_hbm[:, p.hbm_slot]))
    return out


def test_paged_decode_matches_contiguous(model_and_params):
    model, params = model_and_params
    prompt = [5, 17, 133, 42, 7, 99, 250, 3]
    n_new = 6
    ref = greedy_reference(model, params, prompt, n_new)

    eng = Engine(model, params,
                 ServeConfig(max_batch=2, page_size=4, hbm_pages=32,
                             host_pages=64, policy="gdt", interval_steps=4))
    eng.add_request(0, prompt, max_new=n_new)
    got = []
    while still_live(eng, 0):
        out = eng.step()
        if 0 in out:
            got.append(out[0])
    assert got == ref, f"paged {got} != contiguous {ref}"


@pytest.mark.parametrize("family", ["dense", "moe"])
def test_one_shot_prefill_bitwise_equals_chunked(
        family, model_and_params, moe_model_and_params):
    """The tentpole equality: a whole prompt ingested in ONE jitted dispatch
    must produce bitwise the same K/V pages and the same continuation as
    stepping the prompt through decode token by token (which in turn is the
    decode path itself) — on dense and MoE smoke configs."""
    model, params = (model_and_params if family == "dense"
                     else moe_model_and_params)
    prompt = [5, 17, 133, 42, 7, 99, 250, 3, 11, 29]
    n_new = 5

    def make(mode):
        eng = Engine(model, params,
                     ServeConfig(max_batch=2, page_size=4, hbm_pages=32,
                                 host_pages=64, policy="gdt",
                                 interval_steps=4, prefill=mode))
        eng.add_request(0, prompt, max_new=n_new)
        return eng

    one, chunked = make("one_shot"), make("chunked")
    # O(1) jitted dispatches for an S-token prompt, not S.
    assert one.prefill_dispatches == 1
    assert chunked.prefill_dispatches == len(prompt) - 1
    for a, b in zip(request_pages_bits(one, 0), request_pages_bits(chunked, 0)):
        assert np.array_equal(a, b), "prefill K/V pages differ bitwise"
    while still_live(one, 0):
        one.step()
    while still_live(chunked, 0):
        chunked.step()
    assert generated(one, 0) == generated(chunked, 0)


def test_partial_batch_logits_match_full_batch(model_and_params):
    """Inactive batch rows are explicitly masked: a partial batch (2 live
    requests in a 4-slot batch) must produce bitwise the same logits for
    those requests as a full batch that also carries two more."""
    model, params = model_and_params
    prompts = {0: [5, 17, 133, 42], 1: [7, 99, 250, 3],
               2: [11, 29, 31, 2], 3: [1, 2, 3, 4]}

    def make(rids):
        eng = Engine(model, params,
                     ServeConfig(max_batch=4, page_size=4, hbm_pages=48,
                                 host_pages=64, policy="gdt",
                                 keep_logits=True))
        for rid in rids:
            eng.add_request(rid, prompts[rid], max_new=4)
        return eng

    partial, full = make([0, 1]), make([0, 1, 2, 3])
    for _ in range(4):
        partial.step()
        full.step()
        for rid in (0, 1):
            if rid in partial.last_logits and rid in full.last_logits:
                assert np.array_equal(partial.last_logits[rid],
                                      full.last_logits[rid]), \
                    f"rid {rid}: partial-batch logits != full-batch"
    assert generated(partial, 0) == generated(full, 0)
    assert generated(partial, 1) == generated(full, 1)


def test_multiple_concurrent_requests(model_and_params):
    model, params = model_and_params
    eng = Engine(model, params,
                 ServeConfig(max_batch=4, page_size=4, hbm_pages=48,
                             host_pages=96))
    for rid in range(6):
        eng.add_request(rid, [1 + rid, 2 + rid, 3 + rid], max_new=5)
    for _ in range(30):
        eng.step()
        if not eng.requests:
            break
    assert not eng.requests, "finished requests must leave the engine"
    assert len(eng.finished) == 6
    assert all(len(r.generated) == 5 for r in eng.finished.values())


def test_pages_migrate_under_pressure(model_and_params):
    """More session state than HBM pages: pages must spill to the host tier
    and come back correctly when sessions resume."""
    model, params = model_and_params
    eng = Engine(model, params,
                 ServeConfig(max_batch=1, page_size=4, hbm_pages=10,
                             host_pages=64, policy="gdt", interval_steps=2))
    # 12-token prompts -> 3 pages per session; three paused sessions fill
    # all 9 usable HBM pages, so the active one must force evictions.
    prompt = [3, 1, 4, 1, 5, 9, 2, 6, 5, 3, 5, 8]
    ref = greedy_reference(model, params, prompt, 4)

    # Fill HBM with paused sessions.
    for rid in range(3):
        eng.add_request(rid, prompt, max_new=4)
        eng.pause(rid)
    # New active session forces evictions.
    eng.add_request(99, prompt, max_new=4)
    got99 = []
    while still_live(eng, 99):
        out = eng.step()
        if 99 in out:
            got99.append(out[99])
    assert got99 == ref
    assert eng.pool.swaps_out > 0, "nothing ever spilled"

    # Resume a paused session: its pages swap back in and it decodes the
    # exact same continuation.
    eng.resume(0)
    got0 = []
    while still_live(eng, 0):
        out = eng.step()
        if 0 in out:
            got0.append(out[0])
    assert got0 == ref
    assert eng.pool.swaps_in > 0


def test_reweight_keeps_float_counters_and_ordering(model_and_params):
    """ReweightProfile must not floor counters to int: at access_decay=0.5 a
    page with one access per interval would be zeroed, erasing exactly the
    recency ordering decay is meant to preserve."""
    model, params = model_and_params
    eng = Engine(model, params,
                 ServeConfig(max_batch=1, page_size=4, hbm_pages=16,
                             host_pages=32, policy="gdt"))
    eng.add_request(0, [1, 2, 3, 4, 5, 6, 7, 8], max_new=4)
    pages = eng.pool.request_pages(0)
    pages[0].accesses = 1.0      # cold-ish page
    pages[1].accesses = 3.0      # hot page
    backend = eng.kv_backend
    backend.reweight(0.5)
    backend.reweight(0.5)
    assert pages[0].accesses == pytest.approx(0.25)
    assert pages[1].accesses == pytest.approx(0.75)
    assert 0 < pages[0].accesses < pages[1].accesses, \
        "two decay intervals must preserve relative page ordering"


def run_session_workload(model, params, policy, seed=0):
    """Sessions pause/resume; hot sessions resume often.  Returns stats."""
    rng = np.random.default_rng(seed)
    eng = Engine(model, params,
                 ServeConfig(max_batch=2, page_size=4, hbm_pages=12,
                             host_pages=128, policy=policy,
                             interval_steps=4))
    prompt = [2, 7, 1, 8, 2, 8]
    # Two hot sessions, four cold ones.
    for rid in range(6):
        eng.add_request(rid, prompt, max_new=24)
        eng.pause(rid)
    hot = [0, 1]
    for round_ in range(12):
        # hot sessions resume every round, cold ones rarely
        for rid in hot:
            eng.resume(rid)
        if round_ % 5 == 4:
            eng.resume(2 + (round_ // 5) % 4)
        for _ in range(2):
            eng.step()
        for rid in list(eng.requests):
            if eng.requests[rid].state == "active":
                eng.pause(rid)
    return eng.stats()


def test_gdt_policy_beats_fifo_on_sessions(model_and_params):
    model, params = model_and_params
    s_gdt = run_session_workload(model, params, "gdt")
    s_fifo = run_session_workload(model, params, "fifo")
    # Guided placement keeps hot sessions' pages resident -> fewer swap-ins.
    assert s_gdt["swap_ins"] <= s_fifo["swap_ins"]
    assert s_gdt["bytes_moved"] <= s_fifo["bytes_moved"]


def test_controller_tick_order_is_pinned(moe_model_and_params):
    """``_tick_controllers`` runs every guidance controller once per step
    in a FIXED order — KV pages, shared prefixes, expert weights.  The
    order is part of the replay contract (it decides which controller
    sees the interval's free HBM first), so a reorder must fail here."""
    model, params = moe_model_and_params
    eng = Engine(model, params, ServeConfig(
        max_batch=2, page_size=4, hbm_pages=24, host_pages=64,
        policy="gdt", interval_steps=4, enable_prefix_cache=True,
        expert_offchip=True, expert_cache_size=8))
    ticked = []
    for name, rt in (("paged_kv", eng.runtime),
                     ("prefix", eng.prefix_runtime),
                     ("expert", eng.expert_runtime)):
        assert rt is not None, f"{name} controller must exist in this cfg"

        def record(orig=rt.on_step, name=name):
            ticked.append(name)
            return orig()

        rt.on_step = record
    eng.add_request(0, [3, 1, 4, 1, 5, 9], max_new=6)
    n_steps = 0
    while eng.requests:
        eng.step()
        n_steps += 1
    assert n_steps >= 3
    assert ticked == ["paged_kv", "prefix", "expert"] * n_steps
