"""Serving engine tests: paged decode correctness (vs the contiguous-cache
model decode), two-tier page migration, and the guided-policy benefit."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import build_model
from repro.serve import Engine, ServeConfig


@pytest.fixture(scope="module")
def model_and_params():
    cfg = dataclasses.replace(get_smoke("llama3_2_1b"), remat=False)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def greedy_reference(model, params, prompt, n_new, cache_len=64):
    """Contiguous-cache greedy decode (the model's own serve path)."""
    cache = model.init_cache(1, cache_len)
    toks = list(prompt)
    logits = None
    for t, tok in enumerate(toks):
        logits, cache = jax.jit(model.decode)(
            params, cache, jnp.asarray([[tok]], jnp.int32), jnp.int32(t))
    out = []
    pos = len(toks)
    for _ in range(n_new):
        nxt = int(jnp.argmax(logits[0]))
        out.append(nxt)
        logits, cache = jax.jit(model.decode)(
            params, cache, jnp.asarray([[nxt]], jnp.int32), jnp.int32(pos))
        pos += 1
    return out


def test_paged_decode_matches_contiguous(model_and_params):
    model, params = model_and_params
    prompt = [5, 17, 133, 42, 7, 99, 250, 3]
    n_new = 6
    ref = greedy_reference(model, params, prompt, n_new)

    eng = Engine(model, params,
                 ServeConfig(max_batch=2, page_size=4, hbm_pages=32,
                             host_pages=64, policy="gdt", interval_steps=4))
    eng.add_request(0, prompt, max_new=n_new)
    got = []
    while self_active(eng, 0):
        out = eng.step()
        if 0 in out:
            got.append(out[0])
    assert got == ref, f"paged {got} != contiguous {ref}"


def self_active(eng, rid):
    return eng.requests[rid].state == "active"


def test_multiple_concurrent_requests(model_and_params):
    model, params = model_and_params
    eng = Engine(model, params,
                 ServeConfig(max_batch=4, page_size=4, hbm_pages=48,
                             host_pages=96))
    for rid in range(6):
        eng.add_request(rid, [1 + rid, 2 + rid, 3 + rid], max_new=5)
    for _ in range(30):
        eng.step()
        if all(r.state == "finished" for r in eng.requests.values()):
            break
    assert all(r.state == "finished" for r in eng.requests.values())
    assert all(len(r.generated) == 5 for r in eng.requests.values())


def test_pages_migrate_under_pressure(model_and_params):
    """More session state than HBM pages: pages must spill to the host tier
    and come back correctly when sessions resume."""
    model, params = model_and_params
    eng = Engine(model, params,
                 ServeConfig(max_batch=1, page_size=4, hbm_pages=10,
                             host_pages=64, policy="gdt", interval_steps=2))
    # 12-token prompts -> 3 pages per session; three paused sessions fill
    # all 9 usable HBM pages, so the active one must force evictions.
    prompt = [3, 1, 4, 1, 5, 9, 2, 6, 5, 3, 5, 8]
    ref = greedy_reference(model, params, prompt, 4)

    # Fill HBM with paused sessions.
    for rid in range(3):
        eng.add_request(rid, prompt, max_new=4)
        eng.pause(rid)
    # New active session forces evictions.
    eng.add_request(99, prompt, max_new=4)
    got99 = []
    while self_active(eng, 99):
        out = eng.step()
        if 99 in out:
            got99.append(out[99])
    assert got99 == ref
    assert eng.pool.swaps_out > 0, "nothing ever spilled"

    # Resume a paused session: its pages swap back in and it decodes the
    # exact same continuation.
    eng.resume(0)
    got0 = []
    while self_active(eng, 0):
        out = eng.step()
        if 0 in out:
            got0.append(out[0])
    assert got0 == ref
    assert eng.pool.swaps_in > 0


def run_session_workload(model, params, policy, seed=0):
    """Sessions pause/resume; hot sessions resume often.  Returns stats."""
    rng = np.random.default_rng(seed)
    eng = Engine(model, params,
                 ServeConfig(max_batch=2, page_size=4, hbm_pages=12,
                             host_pages=128, policy=policy,
                             interval_steps=4))
    prompt = [2, 7, 1, 8, 2, 8]
    # Two hot sessions, four cold ones.
    for rid in range(6):
        eng.add_request(rid, prompt, max_new=24)
        eng.pause(rid)
    hot = [0, 1]
    for round_ in range(12):
        # hot sessions resume every round, cold ones rarely
        for rid in hot:
            eng.resume(rid)
        if round_ % 5 == 4:
            eng.resume(2 + (round_ // 5) % 4)
        for _ in range(2):
            eng.step()
        for rid in list(eng.requests):
            if eng.requests[rid].state == "active":
                eng.pause(rid)
    return eng.stats()


def test_gdt_policy_beats_fifo_on_sessions(model_and_params):
    model, params = model_and_params
    s_gdt = run_session_workload(model, params, "gdt")
    s_fifo = run_session_workload(model, params, "fifo")
    # Guided placement keeps hot sessions' pages resident -> fewer swap-ins.
    assert s_gdt["swap_ins"] <= s_fifo["swap_ins"]
    assert s_gdt["bytes_moved"] <= s_fifo["bytes_moved"]
