"""Elastic restore: a checkpoint written under one mesh restores onto a
different device count with correct values and shardings (the recovery path
after ft/ rescaling)."""

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(devices, body, tmpdir):
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count={devices}")
        CKPT = {str(tmpdir)!r}
        import jax, jax.numpy as jnp
        import numpy as np
    """) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return proc.stdout


def test_checkpoint_restores_onto_smaller_mesh(tmp_path):
    # Phase 1: init + save on an 8-device (2x4) mesh.
    run_py(8, """
        from repro.configs import get_smoke
        from repro.models import build_model
        from repro.models.common import param_shardings
        from repro.ckpt import save
        from repro.launch.mesh import make_test_mesh

        mesh = make_test_mesh(data=2, model=4)
        model = build_model(get_smoke("llama3_2_1b"))
        params = model.init(jax.random.PRNGKey(0))
        sh = param_shardings(model.param_defs(), mesh)
        params = jax.tree.map(lambda x, s: jax.device_put(x, s), params, sh)
        save(CKPT, 7, {"params": params})
        print("saved", sum(x.size for x in jax.tree.leaves(params)))
    """, tmp_path)

    # Phase 2: restore on a 4-device (2x2) mesh — the post-failure shape —
    # with shardings from the same logical rules, and verify values.
    out = run_py(4, """
        from repro.configs import get_smoke
        from repro.models import build_model
        from repro.models.common import abstract_params, param_shardings
        from repro.ckpt import restore
        from repro.launch.mesh import make_test_mesh
        from repro.ft import plan_rescale

        plan = plan_rescale(4, (2, 4))
        assert plan.new_shape == (1, 4), plan
        mesh = make_test_mesh(data=1, model=4)
        model = build_model(get_smoke("llama3_2_1b"))
        defs = model.param_defs()
        sh = param_shardings(defs, mesh)
        target = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), abstract_params(defs))
        loaded, meta = restore(CKPT, target_tree={"params": target},
                               shardings={"params": sh})
        assert meta["step"] == 7
        # Values equal a fresh deterministic init (crc32-keyed -> process
        # independent), proving byte-exact restore across meshes.
        fresh = model.init(jax.random.PRNGKey(0))
        for a, b in zip(jax.tree.leaves(loaded["params"]),
                        jax.tree.leaves(fresh)):
            np.testing.assert_array_equal(
                np.asarray(a, np.float32), np.asarray(b, np.float32))
        # And the restored arrays are actually sharded on the new mesh.
        leaf = loaded["params"]["layers"]["mlp"]["w_gate"]
        assert len(leaf.sharding.device_set) == 4
        print("restored ok on", len(jax.devices()), "devices")
    """, tmp_path)
    assert "restored ok on 4 devices" in out


def test_restored_state_trains_identically(tmp_path):
    """Same loss after restore+step on a different mesh as on the original
    single-device run (synchronous semantics preserved across rescale)."""
    out1 = run_py(1, """
        import dataclasses
        from repro.configs import get_smoke
        from repro.models import build_model
        from repro.optim import AdamW
        from repro.ckpt import save
        from repro.data import SyntheticLM
        from repro.train.step import make_train_step

        cfg = dataclasses.replace(get_smoke("llama3_2_1b"), remat=False)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        opt = AdamW(lr=1e-3, weight_decay=0.0)
        state = opt.init(params)
        step = jax.jit(make_train_step(model, opt))
        src = SyntheticLM(cfg.vocab, 32, 4, seed=9)
        b = {k: jnp.asarray(v) for k, v in src.batch_np(0).items()}
        params, state, m = step(params, state, b)
        save(CKPT, 1, {"params": params, "m": state.m, "v": state.v,
                       "opt_step": state.step})
        b2 = {k: jnp.asarray(v) for k, v in src.batch_np(1).items()}
        _, _, m2 = step(params, state, b2)
        print("LOSS", float(m2["loss"]))
    """, tmp_path)
    loss_ref = float(out1.split("LOSS")[1])

    out2 = run_py(4, """
        import dataclasses
        from repro.configs import get_smoke
        from repro.models import build_model
        from repro.models.common import abstract_params
        from repro.optim import AdamW
        from repro.optim.adamw import AdamWState
        from repro.ckpt import restore
        from repro.data import SyntheticLM
        from repro.train.step import make_train_step

        cfg = dataclasses.replace(get_smoke("llama3_2_1b"), remat=False)
        model = build_model(cfg)
        opt = AdamW(lr=1e-3, weight_decay=0.0)
        params0 = model.init(jax.random.PRNGKey(0))
        state0 = opt.init(params0)
        target = {"params": params0, "m": state0.m, "v": state0.v,
                  "opt_step": state0.step}
        loaded, _ = restore(CKPT, target_tree=target)
        state = AdamWState(loaded["opt_step"], loaded["m"], loaded["v"])
        step = jax.jit(make_train_step(model, opt))
        src = SyntheticLM(cfg.vocab, 32, 4, seed=9)
        b2 = {k: jnp.asarray(v) for k, v in src.batch_np(1).items()}
        _, _, m2 = step(loaded["params"], state, b2)
        print("LOSS", float(m2["loss"]))
    """, tmp_path)
    loss_new = float(out2.split("LOSS")[1])
    assert abs(loss_ref - loss_new) < 1e-4, (loss_ref, loss_new)
