"""Validation tests: the simulator + real core/ policies reproduce the
paper's headline claims (Secs. 1, 6).  These are the reproduction gates —
numbers land in the paper's reported bands, not just directionally."""

import math

import pytest

from repro.core import CLX
from repro.mem import MemorySimulator
from repro.mem.workloads import CORAL, SPEC, amg, lulesh, qmcpack, snap

DRAM = CLX.fast.capacity_bytes
CAPS = (0.1, 0.2, 0.3, 0.4, 0.5)


def run_medium(wlf, cap_frac, policies=("ft", "off", "on")):
    wl = wlf("medium")
    sim = MemorySimulator(CLX, wl)
    cap = int(wl.peak_rss * cap_frac)
    out = {}
    if "ft" in policies:
        out["ft"] = sim.run_first_touch(cap)
    if "off" in policies:
        out["off"] = sim.run_offline(cap)
    if "on" in policies:
        out["on"] = sim.run_online(cap)
    return wl, sim, out


# ------------------------------------------------------------------- Fig. 6
def test_guided_beats_first_touch_all_coral_all_caps():
    """Sec. 6.2: profile-guided tiering enables significant speedups compared
    to first touch for all four CORAL benchmarks."""
    for name, wlf in CORAL.items():
        for cap_frac in CAPS:
            _, _, r = run_medium(wlf, cap_frac)
            assert r["off"].speedup_over(r["ft"]) > 1.3, (name, cap_frac)
            assert r["on"].speedup_over(r["ft"]) > 1.3, (name, cap_frac)


def test_hpc_speedups_in_paper_band():
    """Sec. 1: HPC speedups range from 1.4x to (more than) 7x."""
    ratios = []
    for name, wlf in CORAL.items():
        for cap_frac in CAPS:
            _, _, r = run_medium(wlf, cap_frac)
            ratios.append(r["on"].speedup_over(r["ft"]))
    assert min(ratios) >= 1.4
    assert max(ratios) >= 6.0          # best cases ~7x
    assert max(ratios) < 12.0          # and not absurdly beyond the paper


def test_coral_geomean_bands():
    """Sec. 6.2: CORAL geomean speedups 2.1x-3.3x (offline) and 1.8x-2.5x
    (online) across capacity limits — we accept a slightly wider band."""
    for cap_frac in CAPS:
        off_r, on_r = [], []
        for name, wlf in CORAL.items():
            _, _, r = run_medium(wlf, cap_frac)
            off_r.append(r["off"].speedup_over(r["ft"]))
            on_r.append(r["on"].speedup_over(r["ft"]))
        geo_off = math.prod(off_r) ** (1 / len(off_r))
        geo_on = math.prod(on_r) ** (1 / len(on_r))
        assert 1.8 <= geo_off <= 8.0, (cap_frac, geo_off)
        assert 1.6 <= geo_on <= 7.0, (cap_frac, geo_on)
        assert geo_on <= geo_off * 1.05  # online lags offline on average


def test_online_close_to_offline_after_startup():
    """Sec. 6.2: online converges to a placement similar to offline; ignoring
    the startup phases, its per-phase wall time approaches offline's."""
    for name, wlf in (("lulesh", lulesh), ("qmcpack", qmcpack)):
        wl, sim, r = run_medium(wlf, 0.5)
        off, on = r["off"], r["on"]
        n = len(on.phase_records)
        tail_on = sum(p.wall_seconds for p in on.phase_records[n // 2:])
        tail_off = sum(p.wall_seconds for p in off.phase_records[n // 2:])
        assert tail_on <= tail_off * 1.35, name


# ------------------------------------------------------------------- Fig. 7
def test_migrations_concentrate_early():
    """Sec. 6.2/Fig. 7: the majority of data migration occurs during the
    early period."""
    wl = amg("medium")
    sim = MemorySimulator(CLX, wl)
    res = sim.run_online(int(wl.peak_rss * 0.5))
    n = len(res.phase_records)
    first_half = sum(p.bytes_migrated for p in res.phase_records[: n // 2])
    second_half = sum(p.bytes_migrated for p in res.phase_records[n // 2:])
    assert first_half > second_half
    assert first_half >= 0.6 * (first_half + second_half)


def test_bandwidth_rises_after_convergence():
    wl = lulesh("medium")
    sim = MemorySimulator(CLX, wl)
    res = sim.run_online(int(wl.peak_rss * 0.5))
    # Phase 0 runs under first-touch placement (plus pays the migration);
    # converged phases sustain much higher total bandwidth (Fig. 7 shape).
    early = res.phase_records[0].bandwidth_GBps
    late = res.phase_records[-1].bandwidth_GBps
    assert late > early * 1.5


# ------------------------------------------------------------------- Fig. 8
def test_large_memory_guided_vs_hw_cache():
    """Sec. 6.3: for LULESH/AMG/SNAP the guided approaches are similar or
    better than hardware caching; offline up to ~7.7x over first touch."""
    for wlf in (lulesh, amg, snap):
        wl = wlf("large")
        sim = MemorySimulator(CLX, wl)
        ft = sim.run_first_touch(DRAM)
        off = sim.run_offline(DRAM)
        on = sim.run_online(DRAM)
        hw = sim.run_hw_cache(DRAM)
        assert off.speedup_over(ft) > 1.8
        assert on.speedup_over(ft) > 1.3
        assert off.speedup_over(hw) >= 0.95   # similar or better
        assert on.speedup_over(hw) >= 0.75


def test_qmcpack_pathology_hw_cache_wins():
    """Sec. 6.3: for large QMCPACK, hardware caching beats site-granularity
    guidance (paper: 2.8x-7x) though guidance still beats first touch."""
    for size in ("large", "huge"):
        wl = qmcpack(size)
        sim = MemorySimulator(CLX, wl)
        ft = sim.run_first_touch(DRAM)
        on = sim.run_online(DRAM)
        hw = sim.run_hw_cache(DRAM)
        assert on.speedup_over(ft) > 1.2          # guided still beats FT
        ratio = hw.speedup_over(on)
        assert 2.0 <= ratio <= 7.5, ratio          # hw wins, paper band


def test_fragmentation_fixes_qmcpack():
    """Beyond paper (Sec. 7 future work): age-based site fragmentation closes
    the QMCPACK gap to hardware caching."""
    wl = qmcpack("large")
    sim = MemorySimulator(CLX, wl)
    on = sim.run_online(DRAM)
    onf = sim.run_online(DRAM, fragmentation=True)
    hw = sim.run_hw_cache(DRAM)
    assert onf.speedup_over(on) > 1.5
    assert onf.speedup_over(hw) > 0.9  # at least matches hw caching


# ---------------------------------------------------------------- SPEC set
def test_spec_modest_benefits_and_regressions():
    """Sec. 6.2: SPEC speedups are modest; some benchmarks see none and the
    online approach can slightly degrade a couple of them."""
    on_ratios = {}
    for name, wlf in SPEC.items():
        wl = wlf()
        sim = MemorySimulator(CLX, wl)
        cap = int(wl.peak_rss * 0.2)
        ft = sim.run_first_touch(cap)
        on = sim.run_online(cap)
        on_ratios[name] = on.speedup_over(ft)
    # Memory-bound ones benefit.
    assert on_ratios["pop2"] > 1.3          # paper: ~1.84x best case
    assert on_ratios["bwaves"] > 1.05
    assert on_ratios["roms"] > 1.05
    # Compute-bound ones see little or nothing (within noise / slight loss).
    for name in ("imagick", "nab", "wrf", "cactuBSSN"):
        assert on_ratios[name] < 1.10, (name, on_ratios[name])
    # Online overhead can slightly degrade the no-benefit cases.
    assert min(on_ratios[n] for n in ("imagick", "nab")) < 1.02
