"""Tests for serve/workload.py: deterministic trace synthesis, the
versioned JSON trace format, the modeled step-cost clock, and trace
replay — including the load-bearing property that replaying the same
trace against an interleaved-prefill engine reproduces the eager
engine's sampled streams bitwise (scheduling moves WHEN, never WHICH)."""

import dataclasses
import math

import jax
import pytest

from repro.configs import get_smoke
from repro.models import build_model
from repro.serve import (
    LLM,
    SLO,
    ServeConfig,
    StepCostModel,
    TenantSpec,
    Trace,
    TraceReplayer,
    WorkloadConfig,
    synthesize,
)

TENANTS = (
    TenantSpec(name="chat", arrival="poisson", rate=0.4,
               prompt_mix=((4, 2.0), (6, 1.0)),
               output_mix=((4, 1.0),), temperature=0.7),
    TenantSpec(name="batch", arrival="bursty", rate=0.3, burst_factor=4.0,
               burst_period=8, burst_duty=0.25, priority=1,
               prompt_mix=((10, 1.0),), output_mix=((3, 1.0),),
               deadline_steps=16),
)
CFG = WorkloadConfig(tenants=TENANTS, horizon_steps=16, vocab=64, seed=7)


# ------------------------------------------------------------- synthesis
def test_synthesize_is_a_pure_function_of_the_config():
    a, b = synthesize(CFG), synthesize(CFG)
    assert a.requests == b.requests
    assert len(a) > 0
    reseeded = synthesize(dataclasses.replace(CFG, seed=8))
    assert reseeded.requests != a.requests


def test_synthesized_requests_carry_tenant_metadata():
    trace = synthesize(CFG)
    by_tenant = {t.name: [r for r in trace.requests if r.tenant == t.name]
                 for t in TENANTS}
    assert all(by_tenant.values()), "both tenants must produce arrivals"
    for r in by_tenant["batch"]:
        assert r.priority == 1 and r.deadline_steps == 16
        assert len(r.prompt) == 10 and r.max_tokens == 3
    for r in trace.requests:
        assert r.seed == r.request_id % (2 ** 31)
        assert all(0 <= t < CFG.vocab for t in r.prompt)
    # Ordered by (arrival step, request id).
    keyed = [(r.arrival_step, r.request_id) for r in trace.requests]
    assert keyed == sorted(keyed)


def test_bursty_arrivals_land_only_in_the_on_phase():
    trace = synthesize(CFG)
    spec = TENANTS[1]
    on_window = spec.burst_period * spec.burst_duty
    for r in trace.requests:
        if r.tenant == "batch":
            assert (r.arrival_step % spec.burst_period) < on_window


def test_tenant_spec_validation():
    with pytest.raises(ValueError, match="arrival"):
        TenantSpec(name="x", arrival="uniform")
    with pytest.raises(ValueError, match="burst_duty"):
        TenantSpec(name="x", burst_duty=0.0)


# ----------------------------------------------------------- JSON format
def test_trace_json_roundtrip_and_version_gate():
    trace = synthesize(CFG)
    assert Trace.from_json(trace.to_json()) == trace
    with pytest.raises(ValueError, match="version"):
        Trace.from_json('{"version": 0, "requests": []}')


# ------------------------------------------------------------ cost model
def test_step_cost_model_is_linear():
    cost = StepCostModel(base_ms=1.0, prefill_ms_per_token=0.2,
                         decode_ms_per_token=0.5)
    assert cost.step_ms(0, 0) == pytest.approx(1.0)
    assert cost.step_ms(10, 4) == pytest.approx(1.0 + 2.0 + 2.0)


# ---------------------------------------------------------------- replay
@pytest.fixture(scope="module")
def model_and_params():
    cfg = dataclasses.replace(get_smoke("llama3_2_1b"), remat=False)
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def _replay(model_and_params, trace, **serve_kw):
    model, params = model_and_params
    llm = LLM(model, params,
              ServeConfig(max_batch=4, page_size=4, hbm_pages=64,
                          host_pages=64, **serve_kw))
    return TraceReplayer(llm, trace, slo=SLO(ttft_ms=1e9, tpot_ms=1e9)).run(
        max_steps=512)


def test_replay_metrics_complete_and_interleaving_is_bitwise(
        model_and_params):
    trace = synthesize(CFG)
    eager = _replay(model_and_params, trace)
    inter = _replay(model_and_params, trace, prefill_chunk_tokens=4,
                    scheduler="drr")
    # Interleaving + a different policy reorders service, never streams.
    assert eager.token_ids == inter.token_ids
    for rep in (eager, inter):
        assert set(rep.metrics) == {r.request_id for r in trace.requests}
        for m in rep.metrics.values():
            assert m.finish_step is not None
            assert m.finish_reason == "length"
            assert m.ttft_ms is not None and m.ttft_ms > 0
            assert m.ttft_steps is not None and m.ttft_steps >= 1
            if m.n_tokens > 1:
                assert m.max_tpot_ms >= m.mean_tpot_ms > 0
        assert rep.modeled_ms > 0 and rep.steps_run > 0
    # Summary reducers: per-tenant rows partition the overall row, and the
    # sky-high SLO counts every finished request as good.
    s_all = eager.summary(slo=SLO(ttft_ms=1e9, tpot_ms=1e9))
    s_chat = eager.summary(tenant="chat")
    s_batch = eager.summary(tenant="batch")
    assert s_chat["requests"] + s_batch["requests"] == s_all["requests"]
    assert s_all["finished"] == s_all["requests"]
    assert s_all["goodput_slo"] == pytest.approx(1.0)
    for key in ("p50_ttft_ms", "p99_ttft_ms", "p50_tpot_ms", "p99_tpot_ms"):
        assert not math.isnan(s_all[key])
        assert s_all[key] > 0
