"""Tests for Algorithm 1's ski-rental break-even rule."""

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import CLX, decide, get_purchase_cost, get_rental_cost
from repro.core.profiler import ArenaProfile, IntervalProfile
from repro.core.recommend import TierAssignment


def mkprof(rows):
    out = [
        ArenaProfile(
            arena_id=aid,
            site_id=aid,
            label=f"a{aid}",
            accesses=accs,
            resident_bytes=nbytes,
            fast_fraction=frac,
        )
        for aid, accs, nbytes, frac in rows
    ]
    return IntervalProfile(
        interval_index=0, rows=out, private_pool_bytes=0, collection_seconds=0.0
    )


def mkrecs(fracs, cap=1 << 40):
    return TierAssignment(
        capacity_bytes=cap, fractions=dict(fracs), raw=dict(fracs), strategy="test"
    )


def test_no_change_no_costs():
    prof = mkprof([(0, 100, 4096, 1.0), (1, 50, 8192, 0.0)])
    recs = mkrecs({0: 1.0, 1: 0.0})
    assert get_rental_cost(prof, recs, CLX) == 0.0
    assert get_purchase_cost(prof, recs, CLX) == 0.0
    assert not decide(prof, recs, CLX).migrate


def test_rental_cost_matches_paper_formula():
    # Arena 0: slow but recommended fast, 1000 accesses -> a = 1000.
    # Arena 1: fast but recommended slow, 200 accesses  -> b = 200.
    prof = mkprof([(0, 1000, 4096, 0.0), (1, 200, 4096, 1.0)])
    recs = mkrecs({0: 1.0, 1: 0.0})
    rental = get_rental_cost(prof, recs, CLX)
    assert rental == (1000 - 200) * CLX.extra_ns_per_slow_access  # (a-b)*300ns


def test_rental_zero_when_b_exceeds_a():
    prof = mkprof([(0, 100, 4096, 0.0), (1, 900, 4096, 1.0)])
    recs = mkrecs({0: 1.0, 1: 0.0})
    assert get_rental_cost(prof, recs, CLX) == 0.0


def test_purchase_cost_counts_both_directions():
    prof = mkprof([(0, 0, 8 * 4096, 0.0), (1, 0, 4 * 4096, 1.0)])
    recs = mkrecs({0: 1.0, 1: 0.0})
    purchase = get_purchase_cost(prof, recs, CLX)
    assert purchase == (8 + 4) * CLX.ns_per_page_moved  # 2us per 4KB page


def test_breakeven_migrates_only_past_purchase():
    nbytes = 100 * 4096  # 100 pages -> purchase = 100 * 2000ns = 200us
    purchase_accs = int(100 * CLX.ns_per_page_moved / CLX.extra_ns_per_slow_access)
    prof_low = mkprof([(0, purchase_accs, nbytes, 0.0)])
    prof_high = mkprof([(0, purchase_accs + 1, nbytes, 0.0)])
    recs = mkrecs({0: 1.0})
    assert not decide(prof_low, recs, CLX).migrate      # rental == purchase
    assert decide(prof_high, recs, CLX).migrate         # rental > purchase


def test_fractional_residency_scales_costs():
    prof = mkprof([(0, 1000, 100 * 4096, 0.5)])
    recs = mkrecs({0: 1.0})
    # Only half the accesses are currently slow.
    assert get_rental_cost(prof, recs, CLX) == 500 * CLX.extra_ns_per_slow_access
    # Only half the pages need to move.
    assert get_purchase_cost(prof, recs, CLX) == 50 * CLX.ns_per_page_moved


@settings(max_examples=150, deadline=None)
@given(
    rows=st.lists(
        st.tuples(
            st.integers(0, 10**6),                       # accesses
            st.integers(0, 1 << 24),                     # bytes
            st.floats(0, 1),                             # cur fraction
            st.floats(0, 1),                             # rec fraction
        ),
        min_size=1,
        max_size=20,
    )
)
def test_cost_nonnegativity_and_consistency(rows):
    prof = mkprof([(i, a, b, cf) for i, (a, b, cf, _) in enumerate(rows)])
    recs = mkrecs({i: rf for i, (_, _, _, rf) in enumerate(rows)})
    rental = get_rental_cost(prof, recs, CLX)
    purchase = get_purchase_cost(prof, recs, CLX)
    assert rental >= 0.0 and purchase >= 0.0
    d = decide(prof, recs, CLX)
    assert d.migrate == (rental > purchase and d.bytes_to_move > 0)


@settings(max_examples=100, deadline=None)
@given(
    increments=st.lists(st.integers(0, 5000), min_size=1, max_size=60),
    pages=st.integers(1, 2000),
)
def test_breakeven_competitive_ratio_sequential(increments, pages):
    """Run the decision interval-by-interval as Algorithm 1 does: accesses
    accumulate, the arena is bought (migrated) the first time cumulative
    rental exceeds purchase.  Online cost <= 2*OPT + one interval's rental
    (the discretization slack)."""
    nbytes = pages * CLX.page_bytes
    recs = mkrecs({0: 1.0})
    cum_accs = 0
    online_cost = 0.0
    bought = False
    purchase0 = None
    max_increment_cost = 0.0
    for inc in increments:
        if bought:
            break
        cum_accs += inc
        max_increment_cost = max(
            max_increment_cost, inc * CLX.extra_ns_per_slow_access
        )
        prof = mkprof([(0, cum_accs, nbytes, 0.0)])
        d = decide(prof, recs, CLX)
        purchase0 = d.purchase_cost_ns
        if d.migrate:
            online_cost = d.rental_cost_ns + d.purchase_cost_ns
            bought = True
    if not bought:
        online_cost = cum_accs * CLX.extra_ns_per_slow_access
    total_rental = cum_accs * CLX.extra_ns_per_slow_access
    opt = min(total_rental, purchase0 if purchase0 is not None else total_rental)
    assert online_cost <= 2.0 * opt + max_increment_cost + 1e-9
