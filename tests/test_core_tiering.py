"""Integration tests for the Algorithm-1 controller loop
(``GuidanceRuntime`` over an ``ArenaBackend``)."""

from repro.core import (
    ArenaBackend,
    ArenaManager,
    CLX,
    GuidanceConfig,
    GuidanceRuntime,
    SiteKind,
    SiteRegistry,
)

MB = 2**20


def build_runtime(cap_bytes, interval=1, strategy="thermos", first_touch=False):
    reg = SiteRegistry()
    mgr = ArenaManager(
        reg,
        promotion_threshold=1 * MB,
        fast_capacity_bytes=cap_bytes if first_touch else None,
    )
    gdt = GuidanceRuntime(
        ArenaBackend(mgr, CLX),
        CLX,
        GuidanceConfig(
            strategy=strategy, fast_capacity_bytes=cap_bytes, interval_steps=interval
        ),
    )
    return reg, mgr, gdt


def test_interval_gating():
    reg, mgr, gdt = build_runtime(100 * MB, interval=3)
    s = reg.register(["x"], SiteKind.PARAM)
    mgr.allocate(s, 10 * MB)
    assert gdt.on_step() is None
    assert gdt.on_step() is None
    rec = gdt.on_step()
    assert rec is not None and rec.interval_index == 0


def test_hot_arena_migrates_after_breakeven():
    """A hot arena wrongly placed on the slow tier accumulates rental cost and
    is eventually promoted — but not on the first interval."""
    reg, mgr, gdt = build_runtime(100 * MB, interval=1)
    hot = reg.register(["hot"], SiteKind.PARAM)
    arena = mgr.allocate(hot, 50 * MB)
    arena.fast_fraction = 0.0  # start on slow tier

    # Per-interval access increment chosen so break-even needs a few intervals:
    # purchase = pages(50MB) * 2us = 12800 * 2000ns = 25.6ms
    # rental per access = 300ns -> need > 85334 accesses cumulative.
    per_interval = 30_000
    migrated_at = None
    for i in range(6):
        mgr.touch(hot, per_interval)
        rec = gdt.on_step()
        if rec.migrated:
            migrated_at = i
            break
    assert migrated_at is not None, "hot arena never promoted"
    assert migrated_at >= 2, "promoted before rental exceeded purchase"
    assert arena.fast_fraction == 1.0
    assert gdt.side_table[arena.arena_id] == 1.0


def test_cold_arena_never_migrates():
    reg, mgr, gdt = build_runtime(100 * MB, interval=1)
    cold = reg.register(["cold"], SiteKind.PARAM)
    arena = mgr.allocate(cold, 50 * MB)
    arena.fast_fraction = 0.0
    for _ in range(10):
        mgr.touch(cold, 1)  # nearly idle
        rec = gdt.on_step()
        assert not rec.migrated
    assert arena.fast_fraction == 0.0


def test_capacity_pressure_demotes_coldest():
    """Cold arena first-touches into the fast tier; the hot late-comer spills
    to slow.  Once rental accumulates, the controller swaps them (demotions
    first, then promotions — Sec. 4.2 enforcement order)."""
    reg, mgr, gdt = build_runtime(50 * MB, interval=1, first_touch=True)
    hot = reg.register(["hot"], SiteKind.PARAM)
    cold = reg.register(["cold"], SiteKind.PARAM)
    a_cold = mgr.allocate(cold, 40 * MB)   # arrives first -> all fast
    a_hot = mgr.allocate(hot, 40 * MB)     # spills: only 10 MB fast
    assert a_cold.fast_fraction == 1.0
    assert abs(a_hot.fast_fraction - 0.25) < 1e-6
    for _ in range(10):
        mgr.touch(hot, 500_000)
        mgr.touch(cold, 10)
        gdt.on_step()
    assert a_hot.fast_fraction == 1.0
    assert a_cold.fast_fraction < 0.3
    # Physical capacity respected after the swap.
    assert mgr.fast_tier_bytes() <= 50 * MB


def test_first_touch_spill_accounting():
    reg, mgr, gdt = build_runtime(10 * MB, interval=1, first_touch=True)
    s1 = reg.register(["a"], SiteKind.PARAM)
    s2 = reg.register(["b"], SiteKind.PARAM)
    a1 = mgr.allocate(s1, 8 * MB)
    a2 = mgr.allocate(s2, 8 * MB)
    assert a1.fast_fraction == 1.0
    assert abs(a2.fast_fraction - 0.25) < 1e-6  # 2 of 8 MB fit
    assert mgr.fast_tier_bytes() == 10 * MB


def test_disabled_gdt_is_inert():
    reg = SiteRegistry()
    mgr = ArenaManager(reg)
    gdt = GuidanceRuntime(ArenaBackend(mgr, CLX), CLX,
                          GuidanceConfig(enabled=False, fast_capacity_bytes=1))
    s = reg.register(["x"])
    mgr.allocate(s, 100 * MB)
    for _ in range(20):
        assert gdt.on_step() is None
    assert gdt.history == []


def test_telemetry_accumulates():
    reg, mgr, gdt = build_runtime(100 * MB, interval=1)
    s = reg.register(["x"], SiteKind.PARAM)
    arena = mgr.allocate(s, 10 * MB)
    arena.fast_fraction = 0.0
    for _ in range(50):
        mgr.touch(s, 100_000)
        gdt.on_step()
    assert gdt.migration_count >= 1
    assert gdt.total_bytes_migrated >= 10 * MB
    assert len(gdt.history) == 50
    assert gdt.backend.profiler.mean_collection_seconds >= 0.0
