"""Cross-request radix prefix cache: pool refcount lifecycle (named free /
allocate errors), radix match/insert/reclaim semantics, copy-on-write, the
cached-vs-uncached bitwise-equality contract through ``LLM.generate``
(greedy and sampled, including across preemption-by-recompute), full-hit
prefill skipping, and guided tier placement of shared prefixes through
``PrefixBackend``."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core.runtime import MigrationPlan
from repro.models import build_model
from repro.serve import LLM, SamplingParams, ServeConfig
from repro.serve.kvcache import PagedKVPool
from repro.serve.prefix_cache import PrefixBackend, PrefixCache, block_hash


# ============================================================ pool fixtures
def small_pool(hbm=8, host=16):
    return PagedKVPool(n_layers=2, page_size=4, kv_heads=2, head_dim=8,
                       hbm_pages=hbm, host_pages=host, dtype=jnp.float32)


def full_pages(pool, rid, n, step=0):
    """Allocate ``n`` FULL pages (the only shareable kind) for ``rid``."""
    pages = [pool.allocate(rid, i, step) for i in range(n)]
    for p in pages:
        p.tokens_used = pool.page_size
    return pages


# ===================================================== satellite: free()
def test_free_unknown_id_raises_named_error():
    pool = small_pool()
    with pytest.raises(ValueError, match="unknown or already-freed"):
        pool.free(999)


def test_double_free_raises_named_error():
    pool = small_pool()
    page = pool.allocate(0, 0, step=0)
    pool.free(page.page_id)
    with pytest.raises(ValueError, match="refcount reaches zero"):
        pool.free(page.page_id)


def test_free_is_refcount_decrement():
    pool = small_pool()
    page = pool.allocate(0, 0, step=0)
    pool.acquire(page.page_id, shared=True)
    free_before = len(pool.free_hbm)
    pool.free(page.page_id)                 # cache ref survives
    assert page.page_id in pool.pages
    assert len(pool.free_hbm) == free_before
    pool.free(page.page_id)                 # last ref: slot returns
    assert page.page_id not in pool.pages
    assert len(pool.free_hbm) == free_before + 1


# ================================================= satellite: allocate()
def test_allocate_exhausted_names_knob():
    pool = small_pool(hbm=2)
    full_pages(pool, 0, 2)
    with pytest.raises(MemoryError, match="ServeConfig.hbm_pages"):
        pool.allocate(0, 2, step=0)


# ===================================================== refcount lifecycle
def test_release_request_returns_only_dead_pages():
    pool = small_pool()
    pages = full_pages(pool, 0, 3)
    pool.acquire(pages[0].page_id, shared=True)   # cache holds page 0
    dead = pool.release_request(0)
    assert dead == [pages[1].page_id, pages[2].page_id]
    assert pages[0].page_id in pool.pages
    assert pool.request_pages(0) == []


def test_attach_enforces_prefix_order():
    pool = small_pool()
    pages = full_pages(pool, 0, 2)
    with pytest.raises(ValueError, match="attach in order"):
        pool.attach(1, pages[1].page_id, step=0)   # index 1 before 0
    pool.attach(1, pages[0].page_id, step=0)
    pool.attach(1, pages[1].page_id, step=0)
    assert [p.page_id for p in pool.request_pages(1)] == \
        [p.page_id for p in pages]
    assert pool.holders(pages[0].page_id) == [0, 1]


def test_copy_page_gives_private_bitwise_copy():
    pool = small_pool()
    rng = np.random.default_rng(0)
    pool.k_hbm = jnp.asarray(rng.normal(size=pool.k_hbm.shape), jnp.float32)
    pool.v_hbm = jnp.asarray(rng.normal(size=pool.v_hbm.shape), jnp.float32)
    (src,) = full_pages(pool, 0, 1)
    pool.attach(1, src.page_id, step=0)
    before_k = np.asarray(pool.k_hbm[:, src.hbm_slot])
    new = pool.copy_page(src.page_id, 1, step=1)
    assert new.page_id != src.page_id
    assert src.refcount == 1                     # writer's ref moved over
    assert pool.request_pages(1) == [new]
    assert pool.request_pages(0) == [src]
    assert np.array_equal(np.asarray(pool.k_hbm[:, new.hbm_slot]), before_k)


# ========================================================== radix cache
def test_block_hash_commits_to_left_context():
    a = block_hash(b"", (1, 2, 3, 4))
    b = block_hash(a, (5, 6, 7, 8))
    c = block_hash(block_hash(b"", (9, 2, 3, 4)), (5, 6, 7, 8))
    assert a != b and b != c                    # same block, different chain


def test_match_insert_roundtrip_and_min_pages_gate():
    pool = small_pool()
    cache = PrefixCache(pool, page_size=4, min_pages=2)
    tokens = list(range(1, 13))                 # 3 full pages
    pages = full_pages(pool, 0, 3)
    # Below the gate: a 1-page prefix must not enter.
    assert cache.insert(tokens[:4], pages[:1], limit=4, step=0) == 0
    assert len(cache) == 0
    assert cache.insert(tokens, pages, limit=12, step=0) == 3
    assert len(cache) == 3
    chain = cache.match(tokens + [99], step=1)
    assert [n.page_id for n in chain] == [p.page_id for p in pages]
    assert cache.match(tokens[:8], step=1) and cache.hit_pages == 5
    # Diverging block: no match past the shared prefix.
    assert [n.depth for n in cache.match([7] * 12, step=2)] == []
    assert cache.hit_rate == pytest.approx(2 / 3)


def test_reclaim_drops_coldest_leaf_and_cascades():
    pool = small_pool()
    cache = PrefixCache(pool, page_size=4)
    tokens = list(range(1, 13))
    pages = full_pages(pool, 0, 3)
    cache.insert(tokens, pages, limit=12, step=0)
    pool.release_request(0)                     # cache-only references now
    # A live holder pins its chain: attach a request to the first page.
    pool.attach(1, pages[0].page_id, step=1)
    # Only the childless leaf (depth 2) is evictable; reclaiming 3 pages
    # cascades leaf-by-leaf but must stop at the pinned root.
    assert {n.depth for n in cache.evictable()} == {2}
    assert cache.reclaim(3) == 2
    assert len(cache) == 1 and cache.evicted_pages == 2
    assert pages[0].page_id in pool.pages
    assert cache.reclaim(1) == 0                # pinned by request 1


# ============================================== engine-level equivalence
@pytest.fixture(scope="module")
def model_and_params():
    cfg = dataclasses.replace(get_smoke("llama3_2_1b"), remat=False)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def make_llm(model_and_params, **kw):
    model, params = model_and_params
    return LLM(model, params, ServeConfig(
        max_batch=4, page_size=4, hbm_pages=32, host_pages=64,
        max_pages_per_seq=16, interval_steps=4, keep_logits=True, **kw))


SHARED = [3, 1, 4, 1, 5, 9, 2, 6, 5, 3, 5, 8]    # 3 full pages


def drive(llm, prompts, params_list):
    """Drive generation by hand, capturing every step's logits per row."""
    handles = [llm.submit(p, sp) for p, sp in zip(prompts, params_list)]
    logits = {h.request_id: [] for h in handles}
    while any(not h.finished for h in handles):
        out = llm.step()
        for rid in out:
            if rid in llm.engine.last_logits:
                logits[rid].append(llm.engine.last_logits[rid].copy())
    return [h.result() for h in handles], logits


def test_cached_vs_uncached_bitwise_equal(model_and_params):
    """The acceptance contract: identical prompts through ``LLM.generate``
    with and without the prefix cache produce bitwise-equal logits and
    sampled token streams — greedy and temperature>0 rows both."""
    prompts = [SHARED + [20 + i] for i in range(3)] + [SHARED[:9]]
    plist = [SamplingParams(max_tokens=5),
             SamplingParams(max_tokens=5, temperature=0.8, top_k=40,
                            seed=7),
             SamplingParams(max_tokens=5, temperature=1.1, top_p=0.9),
             SamplingParams(max_tokens=5)]
    outs_off, logits_off = drive(make_llm(model_and_params), prompts, plist)
    llm = make_llm(model_and_params, enable_prefix_cache=True)
    outs_on, logits_on = drive(llm, prompts, plist)
    for a, b in zip(outs_off, outs_on):
        assert a.token_ids == b.token_ids
        assert a.finish_reason == b.finish_reason
    for rid in logits_off:
        assert len(logits_off[rid]) == len(logits_on[rid])
        for la, lb in zip(logits_off[rid], logits_on[rid]):
            assert np.array_equal(la, lb), "logits must be bitwise-equal"
    stats = llm.engine.stats()
    assert stats["prefix_hit_requests"] >= 2
    assert stats["saved_prefill_tokens"] >= 24


def test_full_hit_skips_prefill_dispatch(model_and_params):
    """A repeat of a prompt whose whole ingested span is cached must not
    dispatch prefill at all."""
    llm = make_llm(model_and_params, enable_prefix_cache=True)
    prompt = SHARED + [42]                       # n_ingest = 12 = 3 pages
    sp = SamplingParams(max_tokens=3)
    first = llm.submit(prompt, sp).result()
    d0 = llm.engine.prefill_dispatches
    second = llm.submit(prompt, sp).result()
    assert llm.engine.prefill_dispatches == d0, \
        "full prefix hit must skip the prefill dispatch"
    assert second.token_ids == first.token_ids   # same rid-independent path?
    stats = llm.engine.stats()
    assert stats["prefix_hit_requests"] >= 1
    assert stats["saved_prefill_tokens"] >= 12


def test_preemption_replay_through_cache_hit(model_and_params):
    """Preemption-by-recompute must replay identically when the re-prefill
    is served (partly) from the prefix cache."""
    def run(preempt):
        llm = make_llm(model_and_params, enable_prefix_cache=True)
        llm.submit(SHARED + [77], SamplingParams(max_tokens=1)).result()
        h = llm.submit(SHARED + [88],
                       SamplingParams(max_tokens=8, temperature=0.9,
                                      seed=11))
        for _ in range(3):
            llm.step()
        if preempt:
            llm.pause(h.request_id)
            assert llm.engine._preempt_one(), "victim must exist"
            assert llm.engine.requests[h.request_id].state == "preempted"
            llm.resume(h.request_id)
        out = h.result()
        return out.token_ids, llm.engine.stats()

    calm, _ = run(preempt=False)
    replayed, stats = run(preempt=True)
    assert replayed == calm, \
        "preempted request must resample the identical stream via the cache"
    assert stats["preemptions"] >= 1
    assert stats["prefix_hit_requests"] >= 2     # admit + re-admit both hit


def test_chunked_prefill_equals_one_shot_through_cache(model_and_params):
    """The chunked oracle must agree with one-shot when both run their
    suffix behind the same cache hit."""
    outs = {}
    for mode in ("one_shot", "chunked"):
        llm = make_llm(model_and_params, enable_prefix_cache=True,
                       prefill=mode)
        llm.submit(SHARED + [50], SamplingParams(max_tokens=1)).result()
        outs[mode] = llm.submit(
            SHARED + [51, 52, 53],
            SamplingParams(max_tokens=4)).result().token_ids
        assert llm.engine.stats()["prefix_hit_requests"] >= 1
    assert outs["one_shot"] == outs["chunked"]


# ====================================================== guided placement
def make_plan(placement):
    return MigrationPlan(
        profile=None, exploded=None, fragments=[], assignment=None,
        decision=None, fractions={}, chunk_placement=placement,
        capacity_bytes=0, strategy="thermos")


def seeded_cache():
    pool = small_pool(hbm=6, host=8)
    cache = PrefixCache(pool, page_size=4)
    tokens = list(range(1, 13))
    pages = full_pages(pool, 0, 3)
    cache.insert(tokens, pages, limit=12, step=0)
    pool.release_request(0)
    return pool, cache, tokens, pages


def test_prefix_backend_enforce_demotes_and_promotes():
    pool, cache, tokens, pages = seeded_cache()
    backend = PrefixBackend(cache, clock=lambda: 0)
    ids = [p.page_id for p in pages]
    backend.enforce(make_plan({pid: False for pid in ids}))
    assert all(pool.pages[pid].hbm_slot is None for pid in ids)
    stats = backend.enforce(make_plan({pid: True for pid in ids}))
    assert all(pool.pages[pid].hbm_slot is not None for pid in ids)
    assert stats.bytes_promoted == 3 * pool.page_bytes
    # Hits on the promoted chain keep flowing into the access profile.
    cache.match(tokens, step=1)
    snap = backend.snapshot()
    assert len(snap.rows) == 1
    assert snap.rows[0].accesses == pytest.approx(3.0)


def test_prefix_backend_never_demotes_referenced_pages():
    pool, cache, tokens, pages = seeded_cache()
    backend = PrefixBackend(cache, clock=lambda: 0)
    chain = cache.match(tokens, step=1)
    for node in chain[:2]:                       # a live request holds 0, 1
        pool.attach(5, node.page_id, step=1)
    backend.enforce(make_plan({p.page_id: False for p in pages}))
    assert pool.pages[pages[0].page_id].hbm_slot is not None
    assert pool.pages[pages[1].page_id].hbm_slot is not None
    assert pool.pages[pages[2].page_id].hbm_slot is None


def test_prefix_runtime_drives_interval_loop(model_and_params):
    """End-to-end: under the guided policy the SECOND controller (shared
    prefixes as tier objects) emits interval events and its plans reach
    ``engine.last_recs``."""
    llm = make_llm(model_and_params, enable_prefix_cache=True)
    eng = llm.engine
    assert eng.prefix_runtime is not None
    sp = SamplingParams(max_tokens=6)
    llm.generate([SHARED + [60 + i] for i in range(3)], sp)
    intervals = [e for e in eng.prefix_runtime.events
                 if getattr(e, "kind", None) == "interval"]
    assert intervals, "prefix controller must run at the decision interval"
    cached = set(eng.prefix_cache.by_page)
    assert cached & set(eng.last_recs), \
        "prefix placements must reach the merged eviction view"
