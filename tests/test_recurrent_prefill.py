"""Prefill/decode continuation for recurrent families: prefilling a prompt
and then decoding must match pure step-by-step decode exactly (hybrid SSM
states, shared-attn KV, mLSTM/sLSTM states all carried correctly)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import build_model

# f32 isolates the state-carrying logic from bf16 parallel-vs-sequential
# rounding noise (the chunked and stepwise forms order reductions
# differently; numerics equivalence in f32 is the correctness statement).
def f32_cfg(arch):
    return dataclasses.replace(get_smoke(arch), remat=False,
                               dtype=jnp.float32)


def f32_params(model, key):
    """ArrayDef defaults keep params bf16; upcast so the equivalence test is
    exact (activations inherit the embed dtype)."""
    return jax.tree.map(
        lambda a: a.astype(jnp.float32) if a.dtype == jnp.bfloat16 else a,
        model.init(key))


@pytest.mark.parametrize("arch", ["zamba2_7b", "xlstm_350m"])
def test_prefill_then_decode_matches_stepwise(arch):
    cfg = f32_cfg(arch)
    model = build_model(cfg)
    params = f32_params(model, jax.random.PRNGKey(0))
    S, n_new, cache_len = 8, 4, 16
    rng = np.random.default_rng(0)
    tokens = rng.integers(1, cfg.vocab, S).astype(np.int32)
    decode = jax.jit(model.decode)

    # Path A: prefill, then greedy decode.
    cache = model.init_cache(1, cache_len)
    logits, cache = jax.jit(model.prefill)(
        params, {"tokens": jnp.asarray(tokens[None])}, cache)
    a = []
    pos = S
    for _ in range(n_new):
        nxt = int(jnp.argmax(logits[0]))
        a.append(nxt)
        logits, cache = decode(params, cache, jnp.asarray([[nxt]]),
                               jnp.int32(pos))
        pos += 1

    # Path B: pure step-by-step decode.
    cache = model.init_cache(1, cache_len)
    for t in range(S):
        logits_b, cache = decode(params, cache, jnp.asarray([[tokens[t]]]),
                                 jnp.int32(t))
    b = []
    pos = S
    for _ in range(n_new):
        nxt = int(jnp.argmax(logits_b[0]))
        b.append(nxt)
        logits_b, cache = decode(params, cache, jnp.asarray([[nxt]]),
                                 jnp.int32(pos))
        pos += 1
    assert a == b, (arch, a, b)


@pytest.mark.parametrize("arch", ["zamba2_7b", "xlstm_350m"])
def test_prefill_logits_match_stepwise_logits(arch):
    """The prefill's final-position logits themselves agree with stepwise
    decode at the same position (tight tolerance: same math, chunked vs
    sequential)."""
    cfg = f32_cfg(arch)
    model = build_model(cfg)
    params = f32_params(model, jax.random.PRNGKey(1))
    S = 8
    rng = np.random.default_rng(2)
    tokens = rng.integers(1, cfg.vocab, S).astype(np.int32)
    cache = model.init_cache(1, 16)
    lp, _ = jax.jit(model.prefill)(
        params, {"tokens": jnp.asarray(tokens[None])}, cache)
    cache = model.init_cache(1, 16)
    decode = jax.jit(model.decode)
    for t in range(S):
        ld, cache = decode(params, cache, jnp.asarray([[tokens[t]]]),
                           jnp.int32(t))
    np.testing.assert_allclose(np.asarray(lp, np.float32),
                               np.asarray(ld, np.float32),
                               atol=2e-3, rtol=2e-3)
    assert int(jnp.argmax(lp[0])) == int(jnp.argmax(ld[0]))
