"""Sampler correctness: the in-dispatch batched Gumbel/top-k/top-p kernel
(`ops.sample_tokens`) against the independent numpy oracle
(`ref.sample_tokens_reference`) across temperature/top_k/top_p/seed sweeps,
bitwise greedy equivalence at temperature=0, filter-membership invariants,
and the fold_in(seed, position) determinism the replay contract rests on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.ref import sample_tokens_reference
from repro.serve import SamplingParams

B, V = 8, 211


def call_kernel(logits, seeds, pos, temp, top_k, top_p):
    out = jax.jit(ops.sample_tokens)(
        jnp.asarray(logits), jnp.asarray(seeds), jnp.asarray(pos),
        jnp.asarray(temp), jnp.asarray(top_k), jnp.asarray(top_p))
    return np.asarray(out)


def make_rows(rng, seed_base):
    logits = (rng.standard_normal((B, V)) * 3).astype(np.float32)
    seeds = (np.arange(B) + seed_base * 100).astype(np.int32)
    pos = rng.integers(0, 200, B).astype(np.int32)
    return logits, seeds, pos


@pytest.mark.parametrize("seed_base", [0, 1, 2])
@pytest.mark.parametrize("temp", [0.0, 0.3, 0.7, 1.0, 1.3])
@pytest.mark.parametrize("top_k", [0, 1, 5, 50, 500])
@pytest.mark.parametrize("top_p", [1.0, 0.9, 0.5, 0.1])
def test_kernel_matches_numpy_oracle(seed_base, temp, top_k, top_p):
    rng = np.random.default_rng(seed_base)
    logits, seeds, pos = make_rows(rng, seed_base)
    t = np.full(B, temp, np.float32)
    k = np.full(B, top_k, np.int32)
    p = np.full(B, top_p, np.float32)
    got = call_kernel(logits, seeds, pos, t, k, p)
    want = sample_tokens_reference(logits, seeds, pos, t, k, p)
    assert np.array_equal(got, want), \
        f"kernel != oracle at temp={temp} top_k={top_k} top_p={top_p}"


def test_temperature_zero_is_bitwise_argmax():
    """The greedy short-circuit: temperature=0 rows must take the plain
    ``argmax(logits)`` path regardless of the other knobs — this is the
    equality that makes sampled serving a superset of the greedy engine."""
    rng = np.random.default_rng(7)
    logits, seeds, pos = make_rows(rng, 7)
    for top_k, top_p in [(0, 1.0), (3, 0.5), (1, 0.1)]:
        got = call_kernel(logits, seeds, pos,
                          np.zeros(B, np.float32),
                          np.full(B, top_k, np.int32),
                          np.full(B, top_p, np.float32))
        assert np.array_equal(got, np.argmax(logits, axis=-1))


def test_top_k_one_is_argmax_at_any_temperature():
    rng = np.random.default_rng(11)
    logits, seeds, pos = make_rows(rng, 11)
    got = call_kernel(logits, seeds, pos,
                      np.full(B, 1.7, np.float32),
                      np.ones(B, np.int32),
                      np.ones(B, np.float32))
    assert np.array_equal(got, np.argmax(logits, axis=-1))


def test_filters_bound_the_support():
    """Sampled tokens must come from the filtered support: within the
    top-k ranks and inside the nucleus (smallest prefix covering top_p)."""
    rng = np.random.default_rng(3)
    top_k, top_p, temp = 7, 0.8, 1.1
    for trial in range(20):
        logits, seeds, pos = make_rows(rng, trial)
        got = call_kernel(logits, seeds, pos + trial,
                          np.full(B, temp, np.float32),
                          np.full(B, top_k, np.int32),
                          np.full(B, top_p, np.float32))
        for i in range(B):
            scaled = logits[i].astype(np.float64) / temp
            order = np.argsort(-scaled, kind="stable")
            rank = int(np.where(order == got[i])[0][0])
            assert rank < top_k, "token outside the top-k ranks"
            probs = np.exp(scaled - scaled.max())
            probs /= probs.sum()
            cum_before = probs[order][:rank].sum()
            assert cum_before < top_p + 1e-6, "token outside the nucleus"


def test_per_row_knobs_are_independent():
    """Rows carry independent SamplingParams: a greedy row batched next to
    sampled rows must stay bitwise-greedy (the engine mixes requests with
    different params in one dispatch)."""
    rng = np.random.default_rng(5)
    logits, seeds, pos = make_rows(rng, 5)
    t = np.array([0.0, 1.0] * (B // 2), np.float32)
    got = call_kernel(logits, seeds, pos, t,
                      np.zeros(B, np.int32), np.ones(B, np.float32))
    want_greedy = np.argmax(logits, axis=-1)
    for i in range(0, B, 2):
        assert got[i] == want_greedy[i]


def test_fold_in_determinism_and_position_sensitivity():
    """Same (seed, position) -> same token (replay); different positions
    -> an actually random stream (not a constant)."""
    rng = np.random.default_rng(9)
    logits = np.zeros((B, V), np.float32)       # uniform: pure noise argmax
    seeds = np.full(B, 42, np.int32)
    t = np.ones(B, np.float32)
    k = np.zeros(B, np.int32)
    p = np.ones(B, np.float32)
    same_pos = np.full(B, 17, np.int32)
    a = call_kernel(logits, seeds, same_pos, t, k, p)
    b = call_kernel(logits, seeds, same_pos, t, k, p)
    assert np.array_equal(a, b), "replay at identical (seed, pos) differs"
    assert len(set(a.tolist())) == 1, "identical keys must sample alike"
    diff_pos = np.arange(B, dtype=np.int32)
    c = call_kernel(logits, seeds, diff_pos, t, k, p)
    assert len(set(c.tolist())) > 1, "positions must decorrelate the noise"


def test_sampling_params_validation():
    with pytest.raises(ValueError, match="temperature"):
        SamplingParams(temperature=-0.1)
    with pytest.raises(ValueError, match="top_k"):
        SamplingParams(top_k=-1)
    with pytest.raises(ValueError, match="top_p"):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError, match="top_p"):
        SamplingParams(top_p=1.5)
    with pytest.raises(ValueError, match="max_tokens"):
        SamplingParams(max_tokens=0)
    # Seeds ride the dispatch as int32: out-of-range seeds must raise, not
    # silently wrap onto another request's stream.
    with pytest.raises(ValueError, match="seed"):
        SamplingParams(seed=2**31)
    with pytest.raises(ValueError, match="seed"):
        SamplingParams(seed=-1)
    SamplingParams(seed=2**31 - 1)
    sp = SamplingParams(stop_token_ids=[3, np.int64(5)])
    assert sp.stop_token_ids == (3, 5)
    assert SamplingParams().greedy
    assert not SamplingParams(temperature=0.5).greedy
