"""Continuous-batching scheduler tests: admission control + wait queue,
overflow validation, finished-request lifecycle cleanup, preemption by
recompute, and capacity starvation that must never crash the engine."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import build_model
from repro.serve import Engine, ServeConfig


@pytest.fixture(scope="module")
def model_and_params():
    cfg = dataclasses.replace(get_smoke("llama3_2_1b"), remat=False)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def run_to_completion(eng, max_steps=200):
    for _ in range(max_steps):
        eng.step()
        if not eng.requests and not eng.wait_queue:
            return
    raise AssertionError(
        f"engine did not drain: live={list(eng.requests)}, "
        f"queue={list(eng.wait_queue)}")


# ------------------------------------------------------------- validation
def test_overlong_request_rejected_naming_the_knob(model_and_params):
    """A request one token past ``max_pages_per_seq * page_size`` used to
    die later with a raw numpy IndexError inside the jitted-step table
    build; it must be rejected at add_request with the knob named."""
    model, params = model_and_params
    eng = Engine(model, params,
                 ServeConfig(max_batch=1, page_size=4, hbm_pages=16,
                             host_pages=32, max_pages_per_seq=2))
    cap = 2 * 4                                    # 8 KV tokens
    ok_prompt = list(range(1, cap + 1))            # 8 tokens, 7 written
    eng.add_request(0, ok_prompt, max_new=1)       # 7+1 == cap: admissible
    run_to_completion(eng)
    assert eng.finished[0].generated, "boundary-sized request must decode"

    with pytest.raises(ValueError, match="max_pages_per_seq"):
        eng.add_request(1, ok_prompt + [99], max_new=1)   # one token over
    # Generation budget counts too: same prompt, one more new token.
    with pytest.raises(ValueError, match="max_pages_per_seq"):
        eng.add_request(2, ok_prompt, max_new=2)
    assert 1 not in eng.requests and 2 not in eng.requests


def test_prompt_bigger_than_hbm_rejected(model_and_params):
    model, params = model_and_params
    eng = Engine(model, params,
                 ServeConfig(max_batch=1, page_size=4, hbm_pages=4,
                             host_pages=32))
    with pytest.raises(ValueError, match="hbm_pages"):
        eng.add_request(0, list(range(1, 40)), max_new=1)


# ---------------------------------------------------------- leak plugging
def test_finished_requests_leave_the_engine(model_and_params):
    """Three request generations: ``engine.requests``, the controller's
    snapshot rows and ``last_recs`` must stay bounded instead of
    accumulating dead requests and stale page ids forever."""
    model, params = model_and_params
    eng = Engine(model, params,
                 ServeConfig(max_batch=2, page_size=4, hbm_pages=16,
                             host_pages=32, policy="gdt", interval_steps=2))
    for gen in range(3):
        rids = [10 * gen + i for i in range(2)]
        for rid in rids:
            eng.add_request(rid, [1 + rid, 2, 3, 4, 5], max_new=4)
        run_to_completion(eng)
        assert len(eng.requests) == 0
        assert len(eng.pool.pages) == 0, "pages must be freed on finish"
        live_pages = set(eng.pool.pages)
        assert set(eng.last_recs) <= live_pages, \
            "last_recs holds stale page ids of finished requests"
        profile = eng.kv_backend.snapshot()
        assert len(profile.rows) == 0, \
            "snapshot must not iterate dead requests"
    assert len(eng.finished) == 6
    assert all(len(r.generated) == 4 for r in eng.finished.values())
    # Results drain on demand, so a long-lived engine holds nothing.
    drained = eng.pop_finished()
    assert len(drained) == 6 and not eng.finished


# -------------------------------------------------------------- admission
def test_wait_queue_admits_as_capacity_frees(model_and_params):
    """More concurrent work than the pool can hold: excess requests queue
    (no MemoryError), then admit FIFO as finishers free pages."""
    model, params = model_and_params
    eng = Engine(model, params,
                 ServeConfig(max_batch=2, page_size=2, hbm_pages=7,
                             host_pages=2))       # 8 logical pages total
    prompt = [3, 1, 4, 1, 5]                      # 2 prompt pages
    for rid in range(4):
        eng.add_request(rid, prompt, max_new=3)   # grows to 4 pages
    assert eng.stats()["waiting_requests"] > 0, \
        "pool cannot hold 4 requests at once; someone must queue"
    run_to_completion(eng)
    assert len(eng.finished) == 4
    assert all(len(r.generated) == 3 and not r.truncated
               for r in eng.finished.values())
    # All four decoded the same prompt greedily: identical continuations.
    gens = [eng.finished[r].generated for r in range(4)]
    assert all(g == gens[0] for g in gens)


def test_starved_batch_never_crashes(model_and_params):
    """Active requests whose combined pages exceed usable HBM: the
    scheduler must serialize them (starving some steps) rather than raise
    the old MemoryError('no evictable page')."""
    model, params = model_and_params
    eng = Engine(model, params,
                 ServeConfig(max_batch=2, page_size=2, hbm_pages=5,
                             host_pages=16))      # 4 usable HBM pages
    prompt = [3, 1, 4, 1, 5]                      # 3 pages by end of decode
    eng.add_request(0, prompt, max_new=2)
    eng.add_request(1, prompt, max_new=2)
    run_to_completion(eng)
    assert eng.stats()["starved_steps"] > 0, \
        "both requests cannot be batched; one must wait per step"
    assert [len(eng.finished[r].generated) for r in (0, 1)] == [2, 2]
    assert eng.finished[0].generated == eng.finished[1].generated


# -------------------------------------------------------------- preemption
def test_preempted_request_resumes_exactly(model_and_params):
    """Preemption by recompute: a paused request loses all pages to an
    incoming prompt, and on resume re-prefills prompt+generated — producing
    bitwise the same continuation as a never-preempted twin (the one-shot
    prefill == decode guarantee doing real work)."""
    model, params = model_and_params
    prompt_a = [3, 1, 4, 1, 5, 9]
    prompt_b = [2, 7, 1, 8, 2, 8, 1, 8]
    twin = Engine(model, params,
                  ServeConfig(max_batch=1, page_size=2, hbm_pages=16,
                              host_pages=32))
    twin.add_request(0, prompt_a, max_new=3)
    while 0 in twin.requests:
        twin.step()

    eng = Engine(model, params,
                 ServeConfig(max_batch=1, page_size=2, hbm_pages=7,
                             host_pages=1))       # 7 logical pages total
    eng.add_request(0, prompt_a, max_new=3)       # 3 pages after prefill
    eng.step()                                    # generate 1 token
    eng.pause(0)
    # B needs 4 prompt pages; only 7-3=4-ish logical free minus A's pages:
    # admission must preempt A wholesale to fit.
    eng.add_request(1, prompt_b, max_new=2)
    assert eng.preemptions >= 1, "paused request should have been preempted"
    assert eng.requests[0].state == "preempted"
    assert not eng.pool.request_pages(0), "preempted pages must be freed"
    while 1 in eng.requests:
        eng.step()
    eng.resume(0)                                 # re-enqueue + re-prefill
    while 0 in eng.requests:
        eng.step()
    assert eng.finished[0].generated == twin.finished[0].generated
    assert eng.finished[1].generated  # B ran too


def test_full_pool_slot_swap_never_crashes(model_and_params):
    """Both free lists empty, scheduled request's pages all on the slow
    tier: residency is a pure slot exchange.  An evict-then-swap-in order
    would need free host slots that don't exist; the atomic batched
    exchange must handle it."""
    model, params = model_and_params
    eng = Engine(model, params,
                 ServeConfig(max_batch=1, page_size=2, hbm_pages=3,
                             host_pages=2))      # 2 usable HBM + 2 host
    eng.add_request(0, [1, 2, 3, 4], max_new=1)  # 2 pages, fills HBM
    eng.add_request(1, [5, 6, 7, 8], max_new=1)  # admission evicts A fully
    assert len(eng.pool.free_hbm) == 0 and len(eng.pool.free_host) == 0, \
        "scenario must start with both free lists empty"
    run_to_completion(eng)
    assert sorted(eng.finished) == [0, 1]
    assert all(len(r.generated) == 1 and not r.truncated
               for r in eng.finished.values())


# ---------------------------------------------------- lifecycle contract
# Every edge of waiting -> active <-> paused -> finished (plus the
# preempted detour).  Transitions outside the documented contract raise a
# named ValueError instead of silently corrupting the wait queue.
def test_pause_resume_on_unknown_or_finished_raises(model_and_params):
    model, params = model_and_params
    eng = Engine(model, params,
                 ServeConfig(max_batch=1, page_size=4, hbm_pages=16,
                             host_pages=32))
    with pytest.raises(ValueError, match="unknown"):
        eng.pause(123)
    with pytest.raises(ValueError, match="unknown"):
        eng.resume(123)
    eng.add_request(0, [1, 2, 3], max_new=1)
    while 0 in eng.requests:
        eng.step()
    with pytest.raises(ValueError, match="finished"):
        eng.resume(0)    # finished: must not silently resurrect
    with pytest.raises(ValueError, match="finished"):
        eng.pause(0)
    assert 0 in eng.finished and 0 not in eng.requests


def test_pause_of_waiting_or_preempted_raises(model_and_params):
    """Pausing a request that holds no schedulable position must raise —
    the old silent no-op left callers believing the session was parked."""
    model, params = model_and_params
    eng = Engine(model, params,
                 ServeConfig(max_batch=2, page_size=2, hbm_pages=7,
                             host_pages=2))       # 8 logical pages total
    prompt = [3, 1, 4, 1, 5]
    for rid in range(4):
        eng.add_request(rid, prompt, max_new=3)
    waiting = [rid for rid in range(4)
               if eng.requests[rid].state == "waiting"]
    assert waiting, "pool cannot hold 4 requests; someone must wait"
    with pytest.raises(ValueError, match="waiting"):
        eng.pause(waiting[0])
    assert eng.requests[waiting[0]].state == "waiting"
    run_to_completion(eng)
    assert len(eng.finished) == 4, "failed pause must not wedge the queue"


def test_active_paused_edges_and_idempotence(model_and_params):
    model, params = model_and_params
    eng = Engine(model, params,
                 ServeConfig(max_batch=1, page_size=4, hbm_pages=16,
                             host_pages=32))
    eng.add_request(0, [1, 2, 3], max_new=4)
    assert eng.requests[0].state == "active"      # admitted immediately
    eng.resume(0)                                 # active -> no-op
    assert eng.requests[0].state == "active"
    eng.pause(0)                                  # active -> paused
    assert eng.requests[0].state == "paused"
    eng.pause(0)                                  # paused -> no-op
    assert eng.requests[0].state == "paused"
    assert eng.step() == {}, "paused request must not decode"
    eng.resume(0)                                 # paused -> active
    assert eng.requests[0].state == "active"
    run_to_completion(eng)
    assert eng.finished[0].finish_reason == "length"


def test_preempted_resume_requeues_and_waiting_resume_is_noop(
        model_and_params):
    """The preempted detour: resume moves preempted -> waiting exactly
    once; a second resume while still waiting is a no-op (no duplicate
    wait-queue entry to double-admit)."""
    model, params = model_and_params
    eng = Engine(model, params,
                 ServeConfig(max_batch=1, page_size=2, hbm_pages=7,
                             host_pages=1))
    eng.add_request(0, [3, 1, 4, 1, 5, 9], max_new=3)
    eng.step()
    eng.pause(0)
    eng.add_request(1, [2, 7, 1, 8, 2, 8, 1, 8], max_new=2)
    assert eng.requests[0].state == "preempted"
    with pytest.raises(ValueError, match="preempted"):
        eng.pause(0)                              # preempted can't pause
    eng.resume(0)                                 # preempted -> waiting
    state = eng.requests[0].state
    assert state in ("waiting", "active")         # may admit immediately
    queued = list(eng.wait_queue).count(0)
    eng.resume(0)                                 # second resume: no-op
    assert list(eng.wait_queue).count(0) == queued, \
        "double resume must not duplicate the wait-queue entry"
    run_to_completion(eng)
    assert sorted(eng.finished) == [0, 1]
