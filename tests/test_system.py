"""End-to-end behaviour tests for the paper's system: the full pipeline
(profile -> recommend -> ski-rental -> migrate) across its three hosts —
the calibrated simulator, the training loop, and the serving engine —
plus the launcher failure drill."""

import dataclasses
import subprocess
import sys
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core import CLX, GuidanceConfig
from repro.data import SyntheticLM
from repro.mem import MemorySimulator
from repro.mem.workloads import lulesh
from repro.models import build_model
from repro.optim import AdamW
from repro.train import Trainer, TrainerConfig

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_paper_pipeline_end_to_end_on_simulator():
    """The headline claim, end to end: online guidance beats first touch on
    a memory-bound workload and converges near the offline oracle."""
    wl = lulesh("medium")
    sim = MemorySimulator(CLX, wl)
    cap = int(wl.peak_rss * 0.3)
    ft = sim.run_first_touch(cap)
    online = sim.run_online(cap)
    offline = sim.run_offline(cap)
    assert online.speedup_over(ft) > 2.0
    assert online.throughput > 0.6 * offline.throughput
    assert online.bytes_migrated > 0


from conftest import has_host_memory


@pytest.mark.skipif(not has_host_memory(),
                    reason="backend lacks pinned_host memory kind")
def test_training_with_guidance_is_lossless_and_offloads():
    cfg = dataclasses.replace(get_smoke("llama3_2_1b"), remat=False)
    model = build_model(cfg)
    opt = AdamW(lr=3e-3, weight_decay=0.0)
    src = SyntheticLM(cfg.vocab, 64, 4, seed=1)
    data = [{k: jnp.asarray(v) for k, v in src.batch_np(i).items()}
            for i in range(16)]
    from repro.models.common import count_params, tree_bytes

    defs = model.param_defs()
    state_bytes = tree_bytes(defs) + 2 * 4 * count_params(defs)  # + f32 m,v
    runs = {}
    for name, gdt in (
        ("plain", None),
        ("guided", GuidanceConfig(enabled=True,
                             fast_capacity_bytes=int(state_bytes * 0.6),
                             interval_steps=4, promotion_threshold=1024)),
    ):
        tr = Trainer(model, opt, TrainerConfig(steps=15, log_every=1,
                                               gdt=gdt),
                     rng=jax.random.PRNGKey(7))
        tr.run(iter(data))
        runs[name] = ([m["loss"] for m in tr.metrics_log], tr)
    np.testing.assert_allclose(runs["plain"][0], runs["guided"][0],
                               rtol=1e-5)
    assert runs["guided"][1].placer.slow_bytes() > 0


def test_launcher_failure_drill(tmp_path):
    """Injected failure + checkpoint restart through the real CLI."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "llama3_2_1b",
         "--smoke", "--steps", "8", "--batch", "2", "--seq", "32",
         "--ckpt-dir", str(tmp_path), "--ckpt-every", "3",
         "--simulate-failure", "5"],
        env=env, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "restarting from checkpoint" in proc.stdout


def test_dryrun_cell_via_cli(tmp_path):
    """One full AOT cell through the real dry-run entry point (512 devices)."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "llama3_2_1b",
         "--shape", "decode_32k", "--mesh", "single",
         "--outdir", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "all cells compiled" in proc.stdout
    import json
    rec = json.load(open(tmp_path / "pod256" /
                         "llama3_2_1b__decode_32k.json"))
    assert rec["status"] == "ok"
    assert rec["devices"] == 256
    assert rec["global_cost"]["flops"] > 0
