"""Tests for the MemBrain recommendation engines (Sec. 3.2.1)."""

import itertools

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import IntervalProfile, hotset, knapsack, recommend, thermos
from repro.core.profiler import ArenaProfile


def mkprof(rows):
    """rows: list of (arena_id, accesses, nbytes[, fast_fraction])."""
    out = []
    for r in rows:
        aid, accs, nbytes = r[0], r[1], r[2]
        frac = r[3] if len(r) > 3 else 1.0
        out.append(
            ArenaProfile(
                arena_id=aid,
                site_id=aid,
                label=f"a{aid}",
                accesses=accs,
                resident_bytes=nbytes,
                fast_fraction=frac,
            )
        )
    return IntervalProfile(
        interval_index=0, rows=out, private_pool_bytes=0, collection_seconds=0.0
    )


profiles = st.lists(
    st.tuples(st.integers(0, 10_000), st.integers(0, 1 << 22)),
    min_size=1,
    max_size=25,
).map(lambda rows: mkprof([(i, a, b) for i, (a, b) in enumerate(rows)]))


# ------------------------------------------------------------------ invariants
@settings(max_examples=150, deadline=None)
@given(prof=profiles, cap=st.integers(0, 1 << 23), strat=st.sampled_from(
    ["knapsack", "hotset", "thermos"]))
def test_clipped_assignment_respects_capacity(prof, cap, strat):
    recs = recommend(prof, cap, strat)
    fast = recs.fast_bytes(prof.rows)
    assert fast <= cap
    for frac in recs.fractions.values():
        assert 0.0 <= frac <= 1.0


@settings(max_examples=100, deadline=None)
@given(prof=profiles, cap=st.integers(1, 1 << 23))
def test_hotset_overprescribes_at_most_one_site(prof, cap):
    recs = hotset(prof, cap)
    raw_bytes = sum(
        r.resident_bytes for r in prof.rows if recs.raw.get(r.arena_id, 0) > 0
    )
    largest = max((r.resident_bytes for r in prof.rows), default=0)
    # Hotset stops after the first crossing -> overshoot < largest site.
    assert raw_bytes <= cap + largest


def test_knapsack_optimal_small():
    """DP matches brute force on small instances."""
    rows = [(0, 60, 10), (1, 100, 20), (2, 120, 30)]
    cap = 50
    prof = mkprof(rows)
    recs = knapsack(prof, cap)
    # Brute force.
    best_val, best_set = -1, set()
    for mask in itertools.product([0, 1], repeat=3):
        w = sum(rows[i][2] for i in range(3) if mask[i])
        v = sum(rows[i][1] for i in range(3) if mask[i])
        if w <= cap and v > best_val:
            best_val, best_set = v, {i for i in range(3) if mask[i]}
    got = {aid for aid, f in recs.raw.items() if f > 0}
    got_val = sum(rows[i][1] for i in got)
    assert got_val == best_val == 220  # {1, 2}
    assert got == best_set


def test_knapsack_excludes_huge_hot_site():
    """Knapsack's documented weakness: a site bigger than capacity is dropped
    entirely even if it is the hottest (Sec. 3.2.1)."""
    prof = mkprof([(0, 10_000, 100), (1, 10, 30)])
    recs = knapsack(prof, 50)
    assert recs.raw.get(0, 0.0) == 0.0
    assert recs.raw.get(1, 0.0) == 1.0


def test_hotset_selects_by_density_until_cap():
    prof = mkprof([(0, 100, 10), (1, 90, 10), (2, 1, 10), (3, 80, 10)])
    recs = hotset(prof, 25)
    # density order: 0, 1, 3, 2. 10+10 <= 25, adding 3 crosses (30 > 25) and
    # is included; then loop stops.
    assert set(recs.raw) == {0, 1, 3}


def test_thermos_admits_huge_hot_site_partially():
    """The hotset/knapsack fix: a huge site hotter than what it displaces gets
    in (and keeps a portion after clipping)."""
    prof = mkprof([(0, 50, 40), (1, 10_000, 100)])  # site 1: huge and very hot
    recs = thermos(prof, 50)
    assert recs.raw.get(1, 0.0) == 1.0      # admitted despite crossing the cap
    # After clipping, site 1 keeps a portion; total fits.
    assert recs.fast_bytes(prof.rows) <= 50
    assert recs.fractions.get(1, 0.0) > 0.0


def test_thermos_rejects_cold_crowding():
    """A lukewarm big site must NOT displace hotter resident data."""
    prof = mkprof([(0, 1000, 40), (1, 30, 100)])  # site 1 cold-ish and big
    recs = thermos(prof, 50)
    assert recs.raw.get(0, 0.0) == 1.0
    assert recs.raw.get(1, 0.0) == 0.0      # rejected: would displace hotter bytes
    # Clipped: site 0 fully fast.
    assert recs.fractions.get(0, 0.0) == 1.0


def test_thermos_skips_then_fills_small_colder_sites():
    prof = mkprof([(0, 100, 30), (1, 20, 40), (2, 5, 10)])
    # cap 45: site0 (density 3.33) fits. site1 (density .5) crossing, displaced
    # value high -> rejected. site2 (density .5) fits free space (15) -> in.
    recs = thermos(prof, 45)
    assert recs.raw.get(0) == 1.0
    assert recs.raw.get(1) is None
    assert recs.raw.get(2) == 1.0


@settings(max_examples=100, deadline=None)
@given(prof=profiles, cap=st.integers(0, 1 << 23))
def test_zero_capacity_means_nothing_fast(prof, cap):
    recs = recommend(prof, 0, "thermos")
    assert recs.fast_bytes(prof.rows) == 0


@settings(max_examples=100, deadline=None)
@given(prof=profiles, strat=st.sampled_from(["knapsack", "hotset", "thermos"]))
def test_infinite_capacity_takes_everything_hot(prof, strat):
    cap = sum(r.resident_bytes for r in prof.rows) + 1
    recs = recommend(prof, cap, strat)
    for r in prof.rows:
        if r.resident_bytes > 0 and r.accesses > 0:
            assert recs.fractions.get(r.arena_id, 0.0) == 1.0


@settings(max_examples=80, deadline=None)
@given(prof=profiles, cap=st.integers(1, 1 << 23))
def test_hotset_selection_is_density_prefix(prof, cap):
    """Hotset selects a prefix of the density-sorted order."""
    from repro.core.recommend import _sorted_by_density

    recs = hotset(prof, cap)
    order = [r.arena_id for r in _sorted_by_density(
        [r for r in prof.rows if r.resident_bytes > 0])]
    selected = {aid for aid, f in recs.raw.items() if f > 0}
    assert selected == set(order[: len(selected)])
