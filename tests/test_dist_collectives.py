"""Multi-device tests for hand-scheduled collectives and sharding rules.

These need >1 device, so they run in a subprocess that sets
XLA_FLAGS=--xla_force_host_platform_device_count before importing jax
(the main test process must keep seeing 1 device)."""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_in_subprocess(body: str, devices: int = 8) -> str:
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count={devices} "
            + os.environ.get("XLA_FLAGS", ""))
        import jax
        import jax.numpy as jnp
        import numpy as np
        assert jax.device_count() == {devices}, jax.device_count()
    """) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    return proc.stdout


def test_ring_allgather_matmul_matches_reference():
    run_in_subprocess("""
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_test_mesh
        from repro.dist.collectives import (
            shard_map, ring_allgather_matmul, allgather_matmul_reference,
            ring_matmul_reducescatter, matmul_reducescatter_reference)

        mesh = make_test_mesh(data=1, model=8)
        x = jax.random.normal(jax.random.PRNGKey(0), (64, 32), jnp.float32)
        w1 = jax.random.normal(jax.random.PRNGKey(1), (32, 48), jnp.float32)
        w2 = jax.random.normal(jax.random.PRNGKey(2), (48, 32), jnp.float32)

        def both(x_shard, w_col):
            a = ring_allgather_matmul(x_shard, w_col, "model")
            b = allgather_matmul_reference(x_shard, w_col, "model")
            return a, b

        f = jax.jit(shard_map(
            both, mesh=mesh,
            in_specs=(P("model", None), P(None, "model")),
            out_specs=(P(None, "model"), P(None, "model"))))
        a, b = f(x, w1)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(a), np.asarray(x @ w1),
                                   rtol=1e-4, atol=1e-4)

        def both2(h, w_row):
            a = ring_matmul_reducescatter(h, w_row, "model")
            b = matmul_reducescatter_reference(h, w_row, "model")
            return a, b

        h = jax.random.normal(jax.random.PRNGKey(3), (64, 48), jnp.float32)
        g = jax.jit(shard_map(
            both2, mesh=mesh,
            in_specs=(P(None, "model"), P("model", None)),
            out_specs=(P("model", None), P("model", None))))
        a2, b2 = g(h, w2)
        np.testing.assert_allclose(np.asarray(a2), np.asarray(b2),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(a2), np.asarray(h @ w2),
                                   rtol=1e-4, atol=1e-4)
        print("collectives OK")
    """)


def test_overlapped_mlp_end_to_end():
    run_in_subprocess("""
        from repro.launch.mesh import make_test_mesh
        from repro.dist.collectives import make_overlapped_mlp

        mesh = make_test_mesh(data=1, model=8)
        x = jax.random.normal(jax.random.PRNGKey(0), (64, 32))
        w1 = jax.random.normal(jax.random.PRNGKey(1), (32, 64))
        w2 = jax.random.normal(jax.random.PRNGKey(2), (64, 32))
        fused = make_overlapped_mlp(mesh, overlap=True)(x, w1, w2)
        plain = make_overlapped_mlp(mesh, overlap=False)(x, w1, w2)
        want = jax.nn.relu(x @ w1) @ w2
        np.testing.assert_allclose(np.asarray(fused), np.asarray(plain),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(fused), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)
        print("overlapped MLP OK")
    """)


def test_overlap_replaces_allgather_with_permutes():
    """The fused path's HLO has collective-permutes instead of all-gathers —
    the structural evidence of overlap."""
    run_in_subprocess("""
        from repro.launch.mesh import make_test_mesh
        from repro.dist.collectives import make_overlapped_mlp

        mesh = make_test_mesh(data=1, model=8)
        x = jax.random.normal(jax.random.PRNGKey(0), (64, 32))
        w1 = jax.random.normal(jax.random.PRNGKey(1), (32, 64))
        w2 = jax.random.normal(jax.random.PRNGKey(2), (64, 32))
        fused_hlo = make_overlapped_mlp(mesh, overlap=True).lower(
            x, w1, w2).compile().as_text()
        plain_hlo = make_overlapped_mlp(mesh, overlap=False).lower(
            x, w1, w2).compile().as_text()
        assert "collective-permute" in fused_hlo
        assert "all-gather" in plain_hlo
        print("HLO structure OK")
    """)


from conftest import has_host_memory


@pytest.mark.skipif(not has_host_memory(),
                    reason="backend lacks pinned_host memory kind")
def test_gdt_placement_on_sharded_params():
    """Tier migration composes with mesh sharding: a sharded array keeps its
    PartitionSpec across a host-tier roundtrip."""
    run_in_subprocess("""
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_test_mesh

        mesh = make_test_mesh(data=2, model=4)
        sh = NamedSharding(mesh, P("data", "model"))
        x = jax.device_put(jnp.arange(64.0).reshape(8, 8), sh)
        host = jax.device_put(x, sh.with_memory_kind("pinned_host"))
        assert host.sharding.memory_kind == "pinned_host"
        assert host.sharding.spec == sh.spec
        back = jax.device_put(host, sh.with_memory_kind("device"))
        np.testing.assert_array_equal(np.asarray(back), np.asarray(x))
        print("sharded tier roundtrip OK")
    """)
