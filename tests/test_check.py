"""Tests for the ``repro.check`` static contract linter.

Each rule has a bad + clean fixture pair under ``tests/check_fixtures/``;
the bad ones assert exact rule ids and line numbers (they are the rule's
specification), the golden JSON pins the full report format, and the
self-run test is the PR gate: the linter must hold zero findings over
the repo's own tree.
"""

import json
import os
import subprocess
import sys

import pytest

from repro.check import all_rules, iter_py_files, run_check
from repro.check.registry import Module
from repro.check.report import render_json

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXTURES = os.path.join(ROOT, "tests", "check_fixtures")


def fixture(name):
    return os.path.join(FIXTURES, name)


def found(path, rule_ids=None):
    return [(f.rule, f.line) for f in run_check([path], rule_ids=rule_ids)]


# (rule id, fixture stem, expected finding lines in the bad fixture)
RULE_CASES = [
    ("CHK00", "chk00", [4, 6]),
    ("DET01", "det01", [12, 13, 19]),
    ("DET02", "det02", [8, 12, 17, 24]),
    ("EXC01", "exc01", [7, 14]),
    ("FT01", os.path.join("serve", "ft01"), [11, 14, 17]),
    ("KRN01", "krn01", [10, 17, 32]),
    ("KV01", "kv01", [11, 16, 22]),
    ("SCHED01", os.path.join("serve", "sched01"), [12, 13, 14, 15]),
    ("SPMD01", "spmd01", [10, 19]),
]


def test_every_registered_rule_has_a_fixture_case():
    assert sorted(all_rules()) == sorted(r for r, _, _ in RULE_CASES)
    assert len(all_rules()) >= 6


@pytest.mark.parametrize("rule,stem,lines", RULE_CASES,
                         ids=[r for r, _, _ in RULE_CASES])
def test_bad_fixture_findings(rule, stem, lines):
    got = found(fixture(f"{stem}_bad.py"))
    assert got == [(rule, ln) for ln in lines]


@pytest.mark.parametrize("rule,stem,lines", RULE_CASES,
                         ids=[r for r, _, _ in RULE_CASES])
def test_clean_fixture_is_clean(rule, stem, lines):
    assert found(fixture(f"{stem}_clean.py")) == []


def test_golden_json(monkeypatch):
    monkeypatch.chdir(ROOT)
    findings = run_check(["tests/check_fixtures"])
    report = json.loads(render_json(
        findings, {rid: r.title for rid, r in all_rules().items()}))
    with open(fixture("golden.json"), encoding="utf-8") as f:
        golden = json.load(f)
    assert report == golden


def test_self_run_is_clean(monkeypatch):
    """The PR gate: the linter holds zero findings over the repo tree."""
    monkeypatch.chdir(ROOT)
    findings = run_check(["src", "tests", "benchmarks", "examples"])
    assert findings == [], "\n".join(
        f"{f.location()}: {f.rule} {f.message}" for f in findings)


def test_fixtures_dir_excluded_from_traversal(monkeypatch):
    monkeypatch.chdir(ROOT)
    walked = iter_py_files(["tests"])
    assert not any("check_fixtures" in p for p in walked)
    # ...but explicit paths always win over the exclude list.
    explicit = iter_py_files([fixture("exc01_bad.py")])
    assert explicit == [fixture("exc01_bad.py")]


def test_rule_filter_and_unknown_rule():
    assert found(fixture("det01_bad.py"), rule_ids=["EXC01"]) == []
    with pytest.raises(ValueError, match="NOPE"):
        run_check([fixture("det01_bad.py")], rule_ids=["NOPE"])


def test_unparsable_file_reports_chk00(tmp_path):
    bad = tmp_path / "broken.py"
    bad.write_text("def f(:\n")
    got = run_check([str(bad)])
    assert [(f.rule, f.path) for f in got] == [("CHK00", str(bad))]
    assert "does not parse" in got[0].message


def test_suppression_silences_only_named_rule():
    src = (
        "def probe(fn):\n"
        "    try:\n"
        "        fn()\n"
        "    # check: disable=KV01 -- wrong rule on purpose\n"
        "    except Exception:\n"
        "        return None\n"
    )
    m = Module.load("inline.py", src)
    exc01 = all_rules()["EXC01"]
    findings = [f for f in exc01.check(m) if not m.suppressed(f)]
    assert [(f.rule, f.line) for f in findings] == [("EXC01", 5)]


def test_cli_exit_code_and_json(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = tmp_path / "report.json"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.check", fixture("exc01_bad.py"),
         "--format", "json", "--output", str(out)],
        capture_output=True, text=True, env=env, cwd=ROOT)
    assert proc.returncode == 2          # exit code == finding count
    report = json.loads(out.read_text())
    assert report["count"] == 2
    assert {f["rule"] for f in report["findings"]} == {"EXC01"}
    assert set(report["rules"]) == set(all_rules())

    proc = subprocess.run(
        [sys.executable, "-m", "repro.check", "--list-rules"],
        capture_output=True, text=True, env=env, cwd=ROOT)
    assert proc.returncode == 0
    for rid in all_rules():
        assert rid in proc.stdout
