"""Per-architecture smoke tests: reduced config, one real forward/train step
plus one decode step on CPU, asserting output shapes and finiteness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_smoke
from repro.models import SMOKE_SHAPES, build_model


def make_batch(model, shape, key):
    cfg = model.cfg
    B, S = shape.global_batch, shape.seq_len
    ks = jax.random.split(key, 3)
    batch = {}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(ks[0], (B, S, cfg.d_model),
                                            cfg.dtype)
        batch["tokens"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab)
        batch["labels"] = jax.random.randint(ks[2], (B, S), 0, cfg.vocab)
    elif cfg.family == "vlm":
        P = cfg.frontend_tokens
        batch["patches"] = jax.random.normal(ks[0], (B, P, cfg.d_model),
                                             cfg.dtype)
        batch["tokens"] = jax.random.randint(ks[1], (B, S - P), 0, cfg.vocab)
        batch["labels"] = jax.random.randint(ks[2], (B, S - P), 0, cfg.vocab)
    else:
        batch["tokens"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab)
        batch["labels"] = jax.random.randint(ks[2], (B, S), 0, cfg.vocab)
    return batch


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_grad_step(arch, rng):
    cfg = get_smoke(arch)
    model = build_model(cfg)
    params = model.init(rng)
    shape = SMOKE_SHAPES["train_4k"]
    batch = make_batch(model, shape, rng)

    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    assert float(loss) > 0.1
    # At least 99% of grad leaves finite and at least one nonzero.
    leaves = jax.tree.leaves(grads)
    assert leaves
    finite = [bool(jnp.isfinite(g).all()) for g in leaves]
    assert all(finite), f"{arch}: non-finite grads"
    assert any(float(jnp.abs(g).max()) > 0 for g in leaves)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch, rng):
    cfg = get_smoke(arch)
    model = build_model(cfg)
    params = model.init(rng)
    shape = SMOKE_SHAPES["decode_32k"]
    B, S = shape.global_batch, shape.seq_len
    cache = model.init_cache(B, S)
    tokens = jax.random.randint(rng, (B, 1), 0, cfg.vocab)

    decode = jax.jit(model.decode)
    logits, cache = decode(params, cache, tokens, jnp.int32(0))
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"
    # A second step at pos 1 must also be finite and differ from step 0.
    logits2, cache = decode(params, cache, tokens, jnp.int32(1))
    assert bool(jnp.isfinite(logits2).all())


@pytest.mark.parametrize("arch", ["granite_8b", "mixtral_8x7b",
                                  "seamless_m4t_medium"])
def test_prefill_matches_decode(arch, rng):
    """Prefill then decode continues consistently: decoding token t with a
    prefilled cache gives the same logits as pure step-by-step decode."""
    cfg = get_smoke(arch)
    model = build_model(cfg)
    k_init, k_frames, k_tokens = jax.random.split(rng, 3)
    params = model.init(k_init)
    B, S = 2, 8
    if cfg.family == "encdec":
        batch = {
            "frames": jax.random.normal(
                k_frames, (B, S, cfg.d_model), cfg.dtype),
            "tokens": jax.random.randint(k_tokens, (B, S), 0, cfg.vocab),
        }
    else:
        batch = {"tokens": jax.random.randint(k_tokens, (B, S), 0, cfg.vocab)}
    cache0 = model.init_cache(B, 16)
    # adapt cache seq to prompt for prefill outputs
    logits_p, cache_p = jax.jit(model.prefill)(params, batch, cache0)
    assert logits_p.shape == (B, cfg.vocab)
    assert bool(jnp.isfinite(logits_p).all())

    # Step-by-step decode over the same prompt.
    cache = model.init_cache(B, 16)
    logits_d = None
    for t in range(S):
        tok = batch["tokens"][:, t:t + 1]
        logits_d, cache = jax.jit(model.decode)(params, cache, tok,
                                                jnp.int32(t))
    if cfg.family == "encdec":
        # cross-attention memory differs (prefill computes it); skip equality
        return
    np.testing.assert_allclose(
        np.asarray(logits_p, np.float32), np.asarray(logits_d, np.float32),
        rtol=2e-2, atol=2e-2,
    )


def test_exact_published_configs_match_assignment():
    """The full configs carry the exact published numbers from the brief."""
    from repro.configs import get

    spec = {
        "seamless_m4t_medium": (12, 1024, 16, 16, 4096, 256206),
        "zamba2_7b": (81, 3584, 32, 32, 14336, 32000),
        "minitron_4b": (32, 3072, 24, 8, 9216, 256000),
        "granite_8b": (36, 4096, 32, 8, 14336, 49152),
        "stablelm_3b": (32, 2560, 32, 32, 6912, 50304),
        "llama3_2_1b": (16, 2048, 32, 8, 8192, 128256),
        "mixtral_8x7b": (32, 4096, 32, 8, 14336, 32000),
        "granite_moe_3b_a800m": (32, 1536, 24, 8, 512, 49155),
        "phi3_vision_4_2b": (32, 3072, 32, 32, 8192, 32064),
        "xlstm_350m": (24, 1024, 4, 4, 0, 50304),
    }
    for arch, (L, d, H, K, f, V) in spec.items():
        c = get(arch)
        assert (c.n_layers, c.d_model, c.n_heads, c.kv_heads, c.d_ff,
                c.vocab) == (L, d, H, K, f, V), arch
    from repro.configs import get as _g
    assert _g("zamba2_7b").ssm_state == 64
    assert _g("mixtral_8x7b").n_experts == 8 and _g("mixtral_8x7b").top_k == 2
    assert (_g("granite_moe_3b_a800m").n_experts == 40
            and _g("granite_moe_3b_a800m").top_k == 8)
