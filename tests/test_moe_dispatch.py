"""Dropless MoE dispatch: chunking-invariance parity + grouped-GEMM kernel.

The serving-correctness contract (tests/test_ring_kv.py depends on it):
token->expert assignment and combined outputs must not depend on how the
token stream is chunked — batched prefill, chunked prefill and step-by-step
decode compute the same function.  Parity is checked at the layer level
across tp/ep parallelism, top_k in {1, 2}, and padded-expert (ep) configs;
the Pallas grouped-expert GEMM is swept against the jnp oracle on
randomized ragged group sizes including empty groups.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.moe_gemm import make_group_metadata, moe_grouped_ffn_pallas
from repro.models.common import init_params
from repro.models.moe import (
    MoEConfig,
    _capacity,
    _moe_dropless,
    _padded_capacity,
    moe,
    moe_decode,
    moe_defs,
    route_tokens,
)

F32 = jnp.float32


def make_cfg(top_k=2, parallelism="tp", n_experts=6, **kw):
    return MoEConfig(d_model=32, d_ff=48, n_experts=n_experts, top_k=top_k,
                     parallelism=parallelism, ep_axis_size=4, **kw)


def f32_params(cfg, seed=0):
    return jax.tree.map(
        lambda a: a.astype(F32) if a.dtype == jnp.bfloat16 else a,
        init_params(moe_defs(cfg), jax.random.PRNGKey(seed)))


# ============================================================ routing parity
@pytest.mark.parametrize("parallelism", ["tp", "ep"])
@pytest.mark.parametrize("top_k", [1, 2])
def test_routing_assignment_chunking_invariant(parallelism, top_k):
    """route_tokens is per-token: any chunking of the stream yields the
    bitwise-identical token->expert assignment."""
    cfg = make_cfg(top_k=top_k, parallelism=parallelism)
    p = f32_params(cfg)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(40, cfg.d_model)), F32)

    gates_full, eids_full = route_tokens(p["router"], x, cfg)
    for chunk in (1, 5, 8):
        parts = [route_tokens(p["router"], x[i:i + chunk], cfg)
                 for i in range(0, x.shape[0], chunk)]
        gates = jnp.concatenate([g for g, _ in parts])
        eids = jnp.concatenate([e for _, e in parts])
        np.testing.assert_array_equal(np.asarray(eids),
                                      np.asarray(eids_full))
        np.testing.assert_allclose(np.asarray(gates),
                                   np.asarray(gates_full), rtol=1e-6)
    # ep pads 6 experts up to 8 with dead experts the router must never pick.
    assert int(eids_full.max()) < cfg.n_experts


@pytest.mark.parametrize("top_k", [1, 2])
def test_padded_ep_routing_matches_unpadded(top_k):
    """Dead padding experts (ep: 6 -> 8) preserve routing semantics exactly:
    slicing the padded router/experts back to n_experts gives a tp config
    with the identical assignment."""
    ep = make_cfg(top_k=top_k, parallelism="ep")
    assert ep.padded_experts == 8
    p_ep = f32_params(ep)
    tp = make_cfg(top_k=top_k, parallelism="tp")
    p_tp = {
        "router": p_ep["router"][:, : ep.n_experts],
        "w_gate": p_ep["w_gate"][: ep.n_experts],
        "w_up": p_ep["w_up"][: ep.n_experts],
        "w_down": p_ep["w_down"][: ep.n_experts],
    }
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(32, ep.d_model)), F32)
    g_ep, e_ep = route_tokens(p_ep["router"], x, ep)
    g_tp, e_tp = route_tokens(p_tp["router"], x, tp)
    np.testing.assert_array_equal(np.asarray(e_ep), np.asarray(e_tp))
    np.testing.assert_allclose(np.asarray(g_ep), np.asarray(g_tp), rtol=1e-6)


# ======================================================== layer-level parity
@pytest.mark.parametrize("parallelism", ["tp", "ep"])
@pytest.mark.parametrize("top_k", [1, 2])
def test_dropless_outputs_chunking_invariant(parallelism, top_k):
    """Batched prefill == chunked prefill == step-by-step decode, as arrays
    (f32 isolates the invariance claim from bf16 rounding noise).  For the
    ep (padded-expert) config the dropless path is forced via the dispatch
    override — parity is a property of the dispatch algorithm, not of the
    sharding mode."""
    cfg = make_cfg(top_k=top_k, parallelism=parallelism)
    p = f32_params(cfg)
    rng = np.random.default_rng(2)
    S = 24
    x = jnp.asarray(rng.normal(size=(1, S, cfg.d_model)), F32)

    y_full = moe(p, x, cfg, dispatch="dropless")
    for chunk in (4, 7):
        y_chunks = jnp.concatenate(
            [moe(p, x[:, i:i + chunk], cfg, dispatch="dropless")
             for i in range(0, S, chunk)], axis=1)
        np.testing.assert_allclose(np.asarray(y_chunks), np.asarray(y_full),
                                   atol=1e-5, rtol=1e-5)
    y_steps = jnp.concatenate(
        [moe_decode(p, x[:, i:i + 1], cfg, dispatch="dropless")
         for i in range(S)], axis=1)
    np.testing.assert_allclose(np.asarray(y_steps), np.asarray(y_full),
                               atol=1e-5, rtol=1e-5)


def test_single_token_capacity_equals_dropless():
    """At S=1 the capacity path cannot drop (top-k picks are distinct
    experts), so both dispatch modes agree — the decode-side anchor that
    made the pre-fix prefill divergence a pure prefill bug."""
    cfg = make_cfg(top_k=2)
    p = f32_params(cfg)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(3, 1, cfg.d_model)), F32)
    y_cap = moe(p, x, cfg, dispatch="capacity")
    y_drop = moe(p, x, cfg, dispatch="dropless")
    np.testing.assert_allclose(np.asarray(y_cap), np.asarray(y_drop),
                               atol=1e-5, rtol=1e-5)


def test_capacity_floor_honors_capacity_factor():
    """The old max(8, ...) floor silently overrode capacity_factor at small
    S; the true capacity is now exact (floored only at top_k) and padding
    is buffer layout, not a drop-rule change."""
    cfg = make_cfg(top_k=2, n_experts=8, parallelism="tp")
    assert _capacity(4, cfg) == 2          # ceil(4*2/8) = 1 -> top_k floor
    assert _capacity(16, cfg) == 4         # ceil(16*2/8) = 4, not 8
    assert _capacity(1, cfg) == cfg.top_k
    cfg2 = make_cfg(top_k=2, n_experts=8, capacity_factor=2.0)
    assert _capacity(16, cfg2) == 8
    assert _padded_capacity(2) == 8        # layout: multiple of 8
    assert _padded_capacity(9) == 16


def test_capacity_budgets_over_live_experts_not_padding():
    """Regression: ep padding experts are routing-dead, so capacity divides
    by the live expert count.  Dividing by padded_experts silently cut
    every live expert's slots to ~n/padded of the capacity_factor promise
    (6->8 experts lost 25%; granite's 40->48 lost 17%)."""
    cfg = make_cfg(top_k=2, n_experts=6, parallelism="ep")   # padded to 8
    assert cfg.padded_experts == 8
    assert _capacity(8, cfg) == 3          # ceil(8*2/6), NOT ceil(8*2/8)=2
    big = MoEConfig(d_model=8, d_ff=8, n_experts=40, top_k=1,
                    parallelism="ep", ep_axis_size=16)       # padded to 48
    assert big.padded_experts == 48
    assert _capacity(96, big) == 3         # ceil(96/40), NOT ceil(96/48)=2
    # tp (no padding) is unchanged.
    assert _capacity(16, make_cfg(top_k=2, n_experts=8)) == 4


def test_ep_no_longer_pins_capacity_and_validates_axis():
    """ep defaults to dropless like every other config, and the config's
    pad target is validated against the mesh's model-axis size at call
    sites instead of being silently trusted."""
    cfg = make_cfg(parallelism="ep")
    assert cfg.dispatch == "dropless"
    assert cfg.padded_experts == 8                     # 6 -> 8 (axis 4)
    cfg.validate_ep_axis(4)                            # 8 % 4 == 0: fine
    cfg.validate_ep_axis(2)
    with pytest.raises(ValueError, match="ep mesh mismatch"):
        cfg.validate_ep_axis(3)
    with pytest.raises(ValueError, match="ep mesh mismatch"):
        # pad target 2 -> 6 padded experts, indivisible over a 4-way axis
        dataclasses.replace(cfg, ep_axis_size=2).validate_ep_axis(4)
    # tp configs never validate (no padding, no expert sharding).
    make_cfg(parallelism="tp").validate_ep_axis(7)


@pytest.mark.parametrize("parallelism", ["tp", "ep"])
def test_dropless_layouts_agree(parallelism):
    """The flat (E-group) and per-row (B*E-group) segment layouts compute
    the identical function — the layout is a locality/grid trade chosen
    from the ambient mesh, never a semantic one."""
    cfg = make_cfg(top_k=2, parallelism=parallelism)
    p = f32_params(cfg)
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.normal(size=(3, 8, cfg.d_model)), F32)
    y_flat = _moe_dropless(p, x, cfg, per_row=False)
    y_row = _moe_dropless(p, x, cfg, per_row=True)
    np.testing.assert_allclose(np.asarray(y_row), np.asarray(y_flat),
                               atol=1e-6, rtol=1e-6)


@pytest.mark.parametrize("parallelism", ["tp", "ep"])
def test_dropless_matches_per_token_oracle(parallelism):
    """The per-row sorted dispatch computes exactly sum_k gate_k *
    SwiGLU_{e_k}(x_t) per token — checked against a direct per-token loop,
    so the sort/scatter plumbing (and the B*E-group GEMM layout) cannot
    silently permute or drop a contribution."""
    cfg = make_cfg(top_k=2, parallelism=parallelism)
    p = f32_params(cfg)
    rng = np.random.default_rng(5)
    B, S = 2, 9
    x = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)), F32)
    got = np.asarray(moe(p, x, cfg, dispatch="dropless"))

    gates, experts = route_tokens(
        p["router"], x.reshape(B * S, cfg.d_model), cfg)
    gates, experts = np.asarray(gates), np.asarray(experts)
    wg, wu, wd = (np.asarray(p[n]) for n in ("w_gate", "w_up", "w_down"))
    xt = np.asarray(x).reshape(B * S, cfg.d_model)

    def silu(a):
        return a / (1.0 + np.exp(-a))

    want = np.zeros_like(xt)
    for t in range(B * S):
        for j in range(cfg.top_k):
            e = int(experts[t, j])
            h = silu(xt[t] @ wg[e]) * (xt[t] @ wu[e])
            want[t] += gates[t, j] * (h @ wd[e])
    np.testing.assert_allclose(got.reshape(B * S, -1), want,
                               atol=1e-4, rtol=1e-4)


def test_dropless_is_differentiable():
    cfg = make_cfg(top_k=2)
    p = f32_params(cfg)
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(2, 8, cfg.d_model)), F32)
    grads = jax.grad(lambda p: moe(p, x, cfg).sum())(p)
    for leaf in jax.tree.leaves(grads):
        assert bool(jnp.isfinite(leaf).all())


# ===================================================== grouped GEMM kernel
TOL = {jnp.float32: 1e-5, jnp.bfloat16: 1e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_grouped_gemm_matches_reference(dtype, seed):
    """Pallas (interpret) vs oracle on randomized ragged group sizes,
    empty groups forced, ragged tile-straddling boundaries included."""
    rng = np.random.default_rng(seed)
    E, d, f = int(rng.integers(2, 9)), 64, 96
    sizes = rng.integers(0, 50, E)
    sizes[rng.integers(0, E)] = 0
    T = max(int(sizes.sum()), 1)
    if sizes.sum() == 0:
        sizes[0] = T
    x = jnp.asarray(rng.normal(size=(T, d)), dtype)
    wg = jnp.asarray(rng.normal(size=(E, d, f)) * 0.1, dtype)
    wu = jnp.asarray(rng.normal(size=(E, d, f)) * 0.1, dtype)
    wd = jnp.asarray(rng.normal(size=(E, f, d)) * 0.1, dtype)
    gs = jnp.asarray(sizes, jnp.int32)
    got = moe_grouped_ffn_pallas(x, wg, wu, wd, gs, block_t=32,
                                 block_f=64, interpret=True)
    want = ref.moe_grouped_ffn_reference(x, wg, wu, wd, gs)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype])


def test_grouped_gemm_grad_matches_reference():
    """The kernel's custom VJP (reference-recompute backward, float0
    cotangent for the integer group_sizes) against jax.grad of the oracle."""
    rng = np.random.default_rng(7)
    E, d, f = 4, 32, 48
    sizes = np.array([5, 0, 9, 2])
    T = int(sizes.sum())
    x = jnp.asarray(rng.normal(size=(T, d)), F32)
    wg = jnp.asarray(rng.normal(size=(E, d, f)) * 0.1, F32)
    wu = jnp.asarray(rng.normal(size=(E, d, f)) * 0.1, F32)
    wd = jnp.asarray(rng.normal(size=(E, f, d)) * 0.1, F32)
    gs = jnp.asarray(sizes, jnp.int32)
    g_kernel = jax.grad(
        lambda *a: moe_grouped_ffn_pallas(*a, gs, block_t=16, block_f=32,
                                          interpret=True).sum(),
        argnums=(0, 1, 2, 3))(x, wg, wu, wd)
    g_ref = jax.grad(
        lambda *a: ref.moe_grouped_ffn_reference(*a, gs).sum(),
        argnums=(0, 1, 2, 3))(x, wg, wu, wd)
    for a, b in zip(g_kernel, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("seed", [0, 1])
def test_grouped_gemm_group_experts_mapping(seed):
    """G > E groups with a group->expert weight map (the per-batch-row and
    ragged-ep layouts): Pallas (interpret) and the jnp oracle both honor
    the mapping, checked against a direct numpy per-segment computation."""
    rng = np.random.default_rng(10 + seed)
    E, G, d, f = 3, 8, 32, 48
    sizes = rng.integers(0, 20, G)
    sizes[rng.integers(0, G)] = 0
    T = max(int(sizes.sum()), 1)
    if sizes.sum() == 0:
        sizes[0] = T
    gexp = rng.integers(0, E, G).astype(np.int32)
    x = jnp.asarray(rng.normal(size=(T, d)), jnp.float32)
    wg = jnp.asarray(rng.normal(size=(E, d, f)) * 0.1, jnp.float32)
    wu = jnp.asarray(rng.normal(size=(E, d, f)) * 0.1, jnp.float32)
    wd = jnp.asarray(rng.normal(size=(E, f, d)) * 0.1, jnp.float32)
    gs = jnp.asarray(sizes, jnp.int32)
    ge = jnp.asarray(gexp)

    got_pal = moe_grouped_ffn_pallas(x, wg, wu, wd, gs, ge, block_t=16,
                                     block_f=32, interpret=True)
    got_ref = ref.moe_grouped_ffn_reference(x, wg, wu, wd, gs, ge)

    def silu(a):
        return a / (1.0 + np.exp(-a))

    xn = np.asarray(x)
    want = np.zeros((T, d), np.float32)
    row = 0
    for g in range(G):
        e = int(gexp[g])
        for _ in range(int(sizes[g])):
            h = silu(xn[row] @ np.asarray(wg)[e]) * (xn[row]
                                                    @ np.asarray(wu)[e])
            want[row] = h @ np.asarray(wd)[e]
            row += 1
    np.testing.assert_allclose(np.asarray(got_ref), want, atol=1e-5,
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(got_pal), want, atol=1e-5,
                               rtol=1e-5)


@pytest.mark.parametrize("seed", range(6))
def test_group_metadata_covers_every_row_once(seed):
    """Property check of the logical-tile schedule: every row is claimed by
    its own expert's segment (never another's), every real row is covered,
    and padded schedule entries only replay rows already owned."""
    rng = np.random.default_rng(seed)
    E = int(rng.integers(1, 9))
    bt = int(rng.choice([8, 16, 32]))
    sizes = rng.integers(0, 5 * bt, E)
    T = int(sizes.sum())
    if T == 0:
        sizes[0] = 3
        T = 3
    rows = -(-T // bt) * bt
    gids, mids, offs = jax.jit(
        make_group_metadata, static_argnums=(1, 2))(
            jnp.asarray(sizes, jnp.int32), rows, bt)
    gids, mids, offs = map(np.asarray, (gids, mids, offs))
    assert len(gids) == rows // bt + E - 1
    covered = np.zeros(T, bool)
    prev_tile = 0
    for g, mt in zip(gids, mids):
        assert mt >= prev_tile          # out tiles revisit, never rewind
        prev_tile = mt
        lo = max(offs[g], mt * bt)
        hi = min(offs[g + 1], (mt + 1) * bt)
        covered[lo:hi] = True
    assert covered.all()


# ===================================== cache-slot indirection (expert tiers)
def test_apply_dropless_flat_slot_layouts_bitwise_equal():
    """``apply_dropless_flat`` with ``expert_slots`` rides the grouped
    GEMM's ``group_experts`` remap: dense weights with no slot map, the
    identity map over dense weights, and a permuted bounded cache holding
    the routed experts must all produce the BITWISE-identical output —
    the invariant the serving expert cache's parity rests on."""
    from repro.models.moe import apply_dropless_flat

    cfg = make_cfg(top_k=2, parallelism="tp")
    p = f32_params(cfg)
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.normal(size=(2, 6, cfg.d_model)), F32)
    gates, experts = route_tokens(
        p["router"], x.reshape(12, cfg.d_model), cfg)
    gates = gates.reshape(2, 6, cfg.top_k)
    experts = experts.reshape(2, 6, cfg.top_k)
    wg, wu, wd = p["w_gate"], p["w_up"], p["w_down"]
    E = cfg.n_experts

    dense = apply_dropless_flat(gates, experts, x, wg, wu, wd, cfg)
    ident = apply_dropless_flat(gates, experts, x, wg, wu, wd, cfg,
                                expert_slots=jnp.arange(E, dtype=jnp.int32))
    assert np.array_equal(np.asarray(dense), np.asarray(ident))

    # Bounded cache: a permutation of the routed experts into cache rows,
    # unrouted experts absent (slot -1), plus a junk row whose weights
    # must never be *selected*.  Junk stays FINITE: the dispatch's one-hot
    # select zeroes unselected rows with an exact 0-multiply, which is
    # bitwise-safe for any finite value — that is why the serving cache
    # zero-initializes its slots and demotes metadata-only.  A wrongly
    # selected junk row would swing the output by ~1e7 and fail loudly.
    routed = sorted({int(e) for e in np.asarray(experts).reshape(-1)})
    perm = list(reversed(range(len(routed))))
    slots = np.full(E, -1, dtype=np.int32)
    C = len(routed) + 1
    cache_g = np.full((C,) + wg.shape[1:], 3.14e7, np.float32)
    cache_u = np.full((C,) + wu.shape[1:], 3.14e7, np.float32)
    cache_d = np.full((C,) + wd.shape[1:], 3.14e7, np.float32)
    for e, s in zip(routed, perm):
        slots[e] = s
        cache_g[s] = np.asarray(wg)[e]
        cache_u[s] = np.asarray(wu)[e]
        cache_d[s] = np.asarray(wd)[e]
    cached = apply_dropless_flat(
        gates, experts, x, jnp.asarray(cache_g), jnp.asarray(cache_u),
        jnp.asarray(cache_d), cfg, expert_slots=jnp.asarray(slots))
    assert np.array_equal(np.asarray(dense), np.asarray(cached)), \
        "cache-slot indirection must be bitwise-invisible"
