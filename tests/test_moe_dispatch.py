"""Dropless MoE dispatch: chunking-invariance parity + grouped-GEMM kernel.

The serving-correctness contract (tests/test_ring_kv.py depends on it):
token->expert assignment and combined outputs must not depend on how the
token stream is chunked — batched prefill, chunked prefill and step-by-step
decode compute the same function.  Parity is checked at the layer level
across tp/ep parallelism, top_k in {1, 2}, and padded-expert (ep) configs;
the Pallas grouped-expert GEMM is swept against the jnp oracle on
randomized ragged group sizes including empty groups.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.moe_gemm import make_group_metadata, moe_grouped_ffn_pallas
from repro.models.common import init_params
from repro.models.moe import (
    MoEConfig,
    _capacity,
    _padded_capacity,
    moe,
    moe_decode,
    moe_defs,
    route_tokens,
)

F32 = jnp.float32


def make_cfg(top_k=2, parallelism="tp", n_experts=6, **kw):
    return MoEConfig(d_model=32, d_ff=48, n_experts=n_experts, top_k=top_k,
                     parallelism=parallelism, ep_axis_size=4, **kw)


def f32_params(cfg, seed=0):
    return jax.tree.map(
        lambda a: a.astype(F32) if a.dtype == jnp.bfloat16 else a,
        init_params(moe_defs(cfg), jax.random.PRNGKey(seed)))


# ============================================================ routing parity
@pytest.mark.parametrize("parallelism", ["tp", "ep"])
@pytest.mark.parametrize("top_k", [1, 2])
def test_routing_assignment_chunking_invariant(parallelism, top_k):
    """route_tokens is per-token: any chunking of the stream yields the
    bitwise-identical token->expert assignment."""
    cfg = make_cfg(top_k=top_k, parallelism=parallelism)
    p = f32_params(cfg)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(40, cfg.d_model)), F32)

    gates_full, eids_full = route_tokens(p["router"], x, cfg)
    for chunk in (1, 5, 8):
        parts = [route_tokens(p["router"], x[i:i + chunk], cfg)
                 for i in range(0, x.shape[0], chunk)]
        gates = jnp.concatenate([g for g, _ in parts])
        eids = jnp.concatenate([e for _, e in parts])
        np.testing.assert_array_equal(np.asarray(eids),
                                      np.asarray(eids_full))
        np.testing.assert_allclose(np.asarray(gates),
                                   np.asarray(gates_full), rtol=1e-6)
    # ep pads 6 experts up to 8 with dead experts the router must never pick.
    assert int(eids_full.max()) < cfg.n_experts


@pytest.mark.parametrize("top_k", [1, 2])
def test_padded_ep_routing_matches_unpadded(top_k):
    """Dead padding experts (ep: 6 -> 8) preserve routing semantics exactly:
    slicing the padded router/experts back to n_experts gives a tp config
    with the identical assignment."""
    ep = make_cfg(top_k=top_k, parallelism="ep")
    assert ep.padded_experts == 8
    p_ep = f32_params(ep)
    tp = make_cfg(top_k=top_k, parallelism="tp")
    p_tp = {
        "router": p_ep["router"][:, : ep.n_experts],
        "w_gate": p_ep["w_gate"][: ep.n_experts],
        "w_up": p_ep["w_up"][: ep.n_experts],
        "w_down": p_ep["w_down"][: ep.n_experts],
    }
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(32, ep.d_model)), F32)
    g_ep, e_ep = route_tokens(p_ep["router"], x, ep)
    g_tp, e_tp = route_tokens(p_tp["router"], x, tp)
    np.testing.assert_array_equal(np.asarray(e_ep), np.asarray(e_tp))
    np.testing.assert_allclose(np.asarray(g_ep), np.asarray(g_tp), rtol=1e-6)


# ======================================================== layer-level parity
@pytest.mark.parametrize("parallelism", ["tp", "ep"])
@pytest.mark.parametrize("top_k", [1, 2])
def test_dropless_outputs_chunking_invariant(parallelism, top_k):
    """Batched prefill == chunked prefill == step-by-step decode, as arrays
    (f32 isolates the invariance claim from bf16 rounding noise).  For the
    ep (padded-expert) config the dropless path is forced via the dispatch
    override — parity is a property of the dispatch algorithm, not of the
    sharding mode."""
    cfg = make_cfg(top_k=top_k, parallelism=parallelism)
    p = f32_params(cfg)
    rng = np.random.default_rng(2)
    S = 24
    x = jnp.asarray(rng.normal(size=(1, S, cfg.d_model)), F32)

    y_full = moe(p, x, cfg, dispatch="dropless")
    for chunk in (4, 7):
        y_chunks = jnp.concatenate(
            [moe(p, x[:, i:i + chunk], cfg, dispatch="dropless")
             for i in range(0, S, chunk)], axis=1)
        np.testing.assert_allclose(np.asarray(y_chunks), np.asarray(y_full),
                                   atol=1e-5, rtol=1e-5)
    y_steps = jnp.concatenate(
        [moe_decode(p, x[:, i:i + 1], cfg, dispatch="dropless")
         for i in range(S)], axis=1)
    np.testing.assert_allclose(np.asarray(y_steps), np.asarray(y_full),
                               atol=1e-5, rtol=1e-5)


def test_single_token_capacity_equals_dropless():
    """At S=1 the capacity path cannot drop (top-k picks are distinct
    experts), so both dispatch modes agree — the decode-side anchor that
    made the pre-fix prefill divergence a pure prefill bug."""
    cfg = make_cfg(top_k=2)
    p = f32_params(cfg)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(3, 1, cfg.d_model)), F32)
    y_cap = moe(p, x, cfg, dispatch="capacity")
    y_drop = moe(p, x, cfg, dispatch="dropless")
    np.testing.assert_allclose(np.asarray(y_cap), np.asarray(y_drop),
                               atol=1e-5, rtol=1e-5)


def test_capacity_floor_honors_capacity_factor():
    """The old max(8, ...) floor silently overrode capacity_factor at small
    S; the true capacity is now exact (floored only at top_k) and padding
    is buffer layout, not a drop-rule change."""
    cfg = make_cfg(top_k=2, n_experts=8, parallelism="tp")
    assert _capacity(4, cfg) == 2          # ceil(4*2/8) = 1 -> top_k floor
    assert _capacity(16, cfg) == 4         # ceil(16*2/8) = 4, not 8
    assert _capacity(1, cfg) == cfg.top_k
    cfg2 = make_cfg(top_k=2, n_experts=8, capacity_factor=2.0)
    assert _capacity(16, cfg2) == 8
    assert _padded_capacity(2) == 8        # layout: multiple of 8
    assert _padded_capacity(9) == 16


def test_ep_config_pins_capacity_dispatch():
    cfg = make_cfg(parallelism="ep")
    assert cfg.effective_dispatch == "capacity"
    assert make_cfg(parallelism="tp").effective_dispatch == "dropless"


def test_dropless_is_differentiable():
    cfg = make_cfg(top_k=2)
    p = f32_params(cfg)
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(2, 8, cfg.d_model)), F32)
    grads = jax.grad(lambda p: moe(p, x, cfg).sum())(p)
    for leaf in jax.tree.leaves(grads):
        assert bool(jnp.isfinite(leaf).all())


# ===================================================== grouped GEMM kernel
TOL = {jnp.float32: 1e-5, jnp.bfloat16: 1e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_grouped_gemm_matches_reference(dtype, seed):
    """Pallas (interpret) vs oracle on randomized ragged group sizes,
    empty groups forced, ragged tile-straddling boundaries included."""
    rng = np.random.default_rng(seed)
    E, d, f = int(rng.integers(2, 9)), 64, 96
    sizes = rng.integers(0, 50, E)
    sizes[rng.integers(0, E)] = 0
    T = max(int(sizes.sum()), 1)
    if sizes.sum() == 0:
        sizes[0] = T
    x = jnp.asarray(rng.normal(size=(T, d)), dtype)
    wg = jnp.asarray(rng.normal(size=(E, d, f)) * 0.1, dtype)
    wu = jnp.asarray(rng.normal(size=(E, d, f)) * 0.1, dtype)
    wd = jnp.asarray(rng.normal(size=(E, f, d)) * 0.1, dtype)
    gs = jnp.asarray(sizes, jnp.int32)
    got = moe_grouped_ffn_pallas(x, wg, wu, wd, gs, block_t=32,
                                 block_f=64, interpret=True)
    want = ref.moe_grouped_ffn_reference(x, wg, wu, wd, gs)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype])


def test_grouped_gemm_grad_matches_reference():
    """The kernel's custom VJP (reference-recompute backward, float0
    cotangent for the integer group_sizes) against jax.grad of the oracle."""
    rng = np.random.default_rng(7)
    E, d, f = 4, 32, 48
    sizes = np.array([5, 0, 9, 2])
    T = int(sizes.sum())
    x = jnp.asarray(rng.normal(size=(T, d)), F32)
    wg = jnp.asarray(rng.normal(size=(E, d, f)) * 0.1, F32)
    wu = jnp.asarray(rng.normal(size=(E, d, f)) * 0.1, F32)
    wd = jnp.asarray(rng.normal(size=(E, f, d)) * 0.1, F32)
    gs = jnp.asarray(sizes, jnp.int32)
    g_kernel = jax.grad(
        lambda *a: moe_grouped_ffn_pallas(*a, gs, block_t=16, block_f=32,
                                          interpret=True).sum(),
        argnums=(0, 1, 2, 3))(x, wg, wu, wd)
    g_ref = jax.grad(
        lambda *a: ref.moe_grouped_ffn_reference(*a, gs).sum(),
        argnums=(0, 1, 2, 3))(x, wg, wu, wd)
    for a, b in zip(g_kernel, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("seed", range(6))
def test_group_metadata_covers_every_row_once(seed):
    """Property check of the logical-tile schedule: every row is claimed by
    its own expert's segment (never another's), every real row is covered,
    and padded schedule entries only replay rows already owned."""
    rng = np.random.default_rng(seed)
    E = int(rng.integers(1, 9))
    bt = int(rng.choice([8, 16, 32]))
    sizes = rng.integers(0, 5 * bt, E)
    T = int(sizes.sum())
    if T == 0:
        sizes[0] = 3
        T = 3
    rows = -(-T // bt) * bt
    gids, mids, offs = jax.jit(
        make_group_metadata, static_argnums=(1, 2))(
            jnp.asarray(sizes, jnp.int32), rows, bt)
    gids, mids, offs = map(np.asarray, (gids, mids, offs))
    assert len(gids) == rows // bt + E - 1
    covered = np.zeros(T, bool)
    prev_tile = 0
    for g, mt in zip(gids, mids):
        assert mt >= prev_tile          # out tiles revisit, never rewind
        prev_tile = mt
        lo = max(offs[g], mt * bt)
        hi = min(offs[g + 1], (mt + 1) * bt)
        covered[lo:hi] = True
    assert covered.all()
