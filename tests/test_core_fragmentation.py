"""Tests for beyond-paper age-quantile site fragmentation (Sec. 6.3/7 fix)."""

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    ChunkStats,
    collapse_to_chunks,
    explode_profile,
    fragment_by_age,
    parent_fractions,
    recommend,
)
from repro.core.profiler import ArenaProfile, IntervalProfile


def mkrow(aid, accs, nbytes, frac=1.0):
    return ArenaProfile(
        arena_id=aid, site_id=aid, label=f"a{aid}", accesses=accs,
        resident_bytes=nbytes, fast_fraction=frac,
    )


def test_fragment_by_age_partitions_chunks():
    chunks = [ChunkStats(chunk_id=i, nbytes=10, accesses=i, age=i) for i in range(10)]
    frags = fragment_by_age(0, chunks, 4)
    assert len(frags) == 4
    seen = sorted(c.chunk_id for f in frags for c in f.chunks)
    assert seen == list(range(10))
    # Age ordering: fragment j's max age <= fragment j+1's min age.
    for a, b in zip(frags, frags[1:]):
        assert max(c.age for c in a.chunks) <= min(c.age for c in b.chunks)


def test_explode_preserves_bytes_and_accesses():
    prof = IntervalProfile(
        interval_index=0,
        rows=[mkrow(0, 1000, 100), mkrow(1, 5, 50)],
        private_pool_bytes=7,
        collection_seconds=0.0,
    )
    chunks = [ChunkStats(chunk_id=i, nbytes=10, accesses=100, age=i) for i in range(10)]
    exploded, frags = explode_profile(prof, {0: chunks}, num_fragments=2)
    assert exploded.total_bytes == prof.total_bytes
    assert exploded.total_accesses == prof.total_accesses
    assert len(exploded.rows) == 3  # 2 fragments + untouched arena 1
    assert exploded.private_pool_bytes == 7


def test_qmcpack_pathology_fixed_by_fragmentation():
    """One dominant site (60% of data), half its pages cold: without
    fragmentation thermos pins the whole site fast (crowding out other hot
    sites); with fragmentation the cold half is left on the slow tier."""
    # Dominant site: 600 bytes, hot pages carry all its accesses.
    hot_chunks = [ChunkStats(chunk_id=i, nbytes=30, accesses=500, age=0) for i in range(10)]
    cold_chunks = [ChunkStats(chunk_id=100 + i, nbytes=30, accesses=1, age=9) for i in range(10)]
    dominant = mkrow(0, sum(c.accesses for c in hot_chunks + cold_chunks), 600)
    other_hot = mkrow(1, 2000, 300)  # smaller, genuinely hot site
    prof = IntervalProfile(0, [dominant, other_hot], 0, 0.0)
    cap = 640

    # Without fragmentation: dominant (density ~8.3) beats other_hot (6.7);
    # dominant takes 600 of 640, other_hot keeps only 40/300 fast.
    recs_plain = recommend(prof, cap, "thermos")
    assert recs_plain.fractions.get(0, 0) == 1.0
    assert recs_plain.fractions.get(1, 0) < 0.5

    # With fragmentation by age: the cold half of the dominant site loses.
    exploded, frags = explode_profile(prof, {0: hot_chunks + cold_chunks}, 2)
    recs_frag = recommend(exploded, cap, "thermos")
    placement = collapse_to_chunks(frags, recs_frag.fractions)
    assert all(placement[c.chunk_id] for c in hot_chunks)
    assert not any(placement[c.chunk_id] for c in cold_chunks)
    assert recs_frag.fractions.get(1, 0) == 1.0  # other hot site fully fast
    pf = parent_fractions(frags, placement)
    assert abs(pf[0] - 0.5) < 1e-6


@settings(max_examples=60, deadline=None)
@given(
    sizes=st.lists(st.integers(1, 1000), min_size=1, max_size=30),
    k=st.integers(1, 6),
)
def test_fragmentation_byte_conservation(sizes, k):
    chunks = [
        ChunkStats(chunk_id=i, nbytes=s, accesses=s * 2, age=i % 5)
        for i, s in enumerate(sizes)
    ]
    frags = fragment_by_age(7, chunks, k)
    assert sum(f.nbytes for f in frags) == sum(sizes)
    assert sum(len(f.chunks) for f in frags) == len(sizes)
    # Collapse with full placement keeps everything fast.
    placement = collapse_to_chunks(frags, {f.fragment_id: 1.0 for f in frags})
    assert all(placement.values())
    assert parent_fractions(frags, placement)[7] == 1.0
