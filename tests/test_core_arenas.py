"""Unit + property tests for the hybrid arena allocation scheme (Sec. 4.1.1)."""

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import ArenaManager, SiteKind, SiteRegistry

MB = 2**20


def make_mgr(threshold=4 * MB):
    reg = SiteRegistry()
    return reg, ArenaManager(reg, promotion_threshold=threshold)


def test_small_sites_stay_in_private_pool():
    reg, mgr = make_mgr()
    s = reg.register(["layer0", "attn", "wq"], SiteKind.PARAM)
    assert mgr.allocate(s, 1 * MB) is None
    assert mgr.allocate(s, 2 * MB) is None
    assert mgr.private_pool_bytes == 3 * MB
    assert mgr.arenas() == []


def test_promotion_after_threshold():
    reg, mgr = make_mgr()
    s = reg.register(["layer0", "mlp", "w1"], SiteKind.PARAM)
    assert mgr.allocate(s, 3 * MB) is None
    arena = mgr.allocate(s, 2 * MB)  # cumulative 5 MB > 4 MB threshold
    assert arena is not None
    # Already-pooled prefix stays in the pool; new data goes to the arena.
    assert mgr.private_pool_bytes == 3 * MB
    assert arena.resident_bytes == 2 * MB
    # Subsequent allocations land in the shared arena.
    assert mgr.allocate(s, 1 * MB) is arena
    assert arena.resident_bytes == 3 * MB


def test_large_allocation_promotes_immediately():
    reg, mgr = make_mgr()
    s = reg.register(["big"], SiteKind.KV_CACHE)
    arena = mgr.allocate(s, 100 * MB)
    assert arena is not None and arena.resident_bytes == 100 * MB
    assert mgr.private_pool_bytes == 0


def test_private_pool_not_profiled():
    reg, mgr = make_mgr()
    small = reg.register(["small"], SiteKind.PARAM)
    big = reg.register(["big"], SiteKind.PARAM)
    mgr.allocate(small, MB)
    mgr.allocate(big, 10 * MB)
    mgr.touch(small, 100)
    mgr.touch(big, 7)
    arenas = mgr.arenas()
    assert len(arenas) == 1
    assert arenas[0].site is big
    assert arenas[0].accesses == 7


def test_release_accounting():
    reg, mgr = make_mgr()
    s = reg.register(["x"], SiteKind.BUFFER)
    a = mgr.allocate(s, 10 * MB)
    mgr.release(s, 4 * MB)
    assert a.resident_bytes == 6 * MB
    mgr.release(s, 100 * MB)  # clamped
    assert a.resident_bytes == 0


def test_site_registry_context_depth():
    reg = SiteRegistry(context_depth=3)
    a = reg.register(["root", "enc", "layer0", "attn", "wq"])
    b = reg.register(["other", "dec", "layer0", "attn", "wq"])
    assert a is b  # last 3 components identical -> same site (paper's cloning bound)
    c = reg.register(["layer1", "attn", "wq"])
    assert c is not a
    # Same path, different kind -> different site.
    d = reg.register(["layer0", "attn", "wq"], SiteKind.OPT_STATE)
    assert d is not a


@settings(max_examples=100, deadline=None)
@given(
    allocs=st.lists(
        st.tuples(st.integers(0, 4), st.integers(0, 8 * MB)), min_size=1, max_size=40
    ),
    threshold=st.integers(1, 8 * MB),
)
def test_byte_conservation_property(allocs, threshold):
    """Every allocated byte is either in the private pool or a shared arena."""
    reg = SiteRegistry()
    mgr = ArenaManager(reg, promotion_threshold=threshold)
    sites = [reg.register([f"s{i}"]) for i in range(5)]
    total = 0
    for idx, nbytes in allocs:
        mgr.allocate(sites[idx], nbytes)
        total += nbytes
    assert mgr.private_pool_bytes + mgr.shared_bytes == total
    # All-fast default: fast tier bytes == all bytes.
    assert mgr.fast_tier_bytes() == total


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(1, 16 * MB), min_size=1, max_size=30))
def test_promotion_threshold_property(sizes):
    """A site gets a shared arena iff its cumulative bytes exceed the threshold."""
    threshold = 4 * MB
    reg, mgr = make_mgr(threshold)
    s = reg.register(["p"])
    cum = 0
    for nbytes in sizes:
        cum += nbytes
        arena = mgr.allocate(s, nbytes)
        assert (arena is not None) == (cum > threshold)
