"""Batched tier-migration parity: a whole ``MigrationPlan`` direction is one
gather + one staged transfer + one scatter per pool array.  The batched path
must produce identical pool contents and counters to the per-page path, with
a constant number of host<->device transfers per direction — and a migration
storm must leave decode bitwise unchanged."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core.runtime import MigrationPlan
from repro.models import build_model
from repro.serve import Engine, PagedKVBackend, ServeConfig
from repro.serve.kvcache import PagedKVPool


def make_pool(seed=0):
    """Pool with 6 allocated pages (2 requests), recognizable K/V contents,
    and two pages pre-spilled to the host tier."""
    pool = PagedKVPool(n_layers=2, page_size=4, kv_heads=2, head_dim=8,
                       hbm_pages=8, host_pages=16, dtype=jnp.float32)
    rng = np.random.default_rng(seed)
    pool.k_hbm = jnp.asarray(rng.normal(size=pool.k_hbm.shape), jnp.float32)
    pool.v_hbm = jnp.asarray(rng.normal(size=pool.v_hbm.shape), jnp.float32)
    for rid in (0, 1):
        for idx in range(3):
            pool.allocate(rid, idx, step=0)
    # Spill one page of each request so the plan has promotions to do.
    pool.swap_out(pool.request_pages(0)[2].page_id)
    pool.swap_out(pool.request_pages(1)[0].page_id)
    return pool


def page_state(pool):
    return sorted((pid, p.hbm_slot, p.host_slot)
                  for pid, p in pool.pages.items())


def pool_bits(pool):
    return tuple(np.asarray(a).tobytes()
                 for a in (pool.k_hbm, pool.v_hbm, pool.k_host, pool.v_host))


def make_plan(placement):
    """MigrationPlan stub: ``enforce`` only reads ``chunk_placement``."""
    return MigrationPlan(
        profile=None, exploded=None, fragments=[], assignment=None,
        decision=None, fractions={}, chunk_placement=placement,
        capacity_bytes=0, strategy="thermos")


def test_batched_enforce_matches_per_page_path():
    placement = None
    results = {}
    for path in ("batched", "per_page"):
        pool = make_pool()
        backend = PagedKVBackend(pool, {0: object(), 1: object()},
                                 clock=lambda: 0)
        if placement is None:
            # Demote the two hot pages of request 0 still in HBM; promote
            # both spilled pages.  Same dict order for both paths.
            r0, r1 = pool.request_pages(0), pool.request_pages(1)
            placement = {r0[0].page_id: False, r0[1].page_id: False,
                         r0[2].page_id: True, r1[0].page_id: True}
        t0 = pool.transfer_events
        if path == "batched":
            stats = backend.enforce(make_plan(placement))
            assert stats.bytes_demoted == 2 * pool.page_bytes
            assert stats.bytes_promoted == 2 * pool.page_bytes
            assert stats.dropped_promotions == 0
            # Constant transfers per direction: K+V for demote, K+V for
            # promote — not 2 per page.
            assert pool.transfer_events - t0 == 4
        else:
            for pid, fast in placement.items():
                if not fast:
                    pool.swap_out(pid)
            for pid, fast in placement.items():
                if fast:
                    pool.swap_in(pid)
            assert pool.transfer_events - t0 == 2 * len(placement)
        results[path] = (page_state(pool), pool_bits(pool),
                         pool.swaps_in, pool.swaps_out, pool.bytes_moved)
    assert results["batched"] == results["per_page"], \
        "batched migration must be observationally identical to per-page"


def test_batched_roundtrip_preserves_contents():
    """N pages out and back in one batch each way: contents bit-identical,
    counters exact, 2 transfers per direction."""
    pool = make_pool(seed=7)
    resident = [p.page_id for p in pool.pages.values()
                if p.hbm_slot is not None]
    before = {pid: np.asarray(pool.k_hbm[:, pool.pages[pid].hbm_slot])
              for pid in resident}
    t0, s_in, s_out = pool.transfer_events, pool.swaps_in, pool.swaps_out
    pool.swap_out_many(resident)
    assert all(pool.pages[pid].hbm_slot is None for pid in resident)
    pool.swap_in_many(resident)
    assert pool.transfer_events - t0 == 4
    assert pool.swaps_out - s_out == len(resident)
    assert pool.swaps_in - s_in == len(resident)
    for pid in resident:
        after = np.asarray(pool.k_hbm[:, pool.pages[pid].hbm_slot])
        assert np.array_equal(before[pid], after)


def test_batched_migration_empty_lists_are_noops():
    """Empty id lists must not touch pools, counters or transfer probes —
    the prefix backend routinely enforces plans with nothing to move."""
    pool = make_pool()
    before = (page_state(pool), pool_bits(pool), pool.swaps_in,
              pool.swaps_out, pool.bytes_moved, pool.transfer_events,
              list(pool.free_hbm), list(pool.free_host))
    pool.swap_out_many([])
    pool.swap_in_many([])
    pool.exchange([], [])
    after = (page_state(pool), pool_bits(pool), pool.swaps_in,
             pool.swaps_out, pool.bytes_moved, pool.transfer_events,
             list(pool.free_hbm), list(pool.free_host))
    assert before == after


def test_batched_migration_duplicate_ids_move_once():
    """Duplicate ids in one batch must behave exactly like the deduplicated
    batch (a duplicate that moved twice would corrupt the free lists)."""
    results = {}
    for dup in (False, True):
        pool = make_pool(seed=3)
        r0 = pool.request_pages(0)
        out_ids = [r0[0].page_id, r0[1].page_id]
        in_ids = [r0[2].page_id]
        if dup:
            out_ids = out_ids + out_ids[:1] * 3
            in_ids = in_ids * 2
        pool.exchange(out_ids, in_ids)
        pool.swap_in_many(out_ids + out_ids)      # duplicates again
        pool.swap_out_many(in_ids + in_ids)
        results[dup] = (page_state(pool), pool_bits(pool), pool.swaps_in,
                        pool.swaps_out, pool.bytes_moved,
                        sorted(pool.free_hbm), sorted(pool.free_host))
    assert results[True] == results[False], \
        "duplicate ids must migrate once, identically to the deduped batch"


def test_batched_migration_refcounted_pages_parity():
    """Pages shared by multiple requests (refcount > 1) migrate exactly
    like single-owner pages: one physical move, every holder's page list
    sees the same slot, batched == per-page."""
    results = {}
    for path in ("batched", "per_page"):
        pool = make_pool(seed=11)
        # Request 2 shares request 0's leading resident pages.
        shared = [p for p in pool.request_pages(0) if p.hbm_slot is not None]
        for p in shared:
            pool.attach(2, p.page_id, step=1)
        ids = [p.page_id for p in shared]
        if path == "batched":
            pool.swap_out_many(ids)
            pool.swap_in_many(ids)
        else:
            for pid in ids:
                pool.swap_out(pid)
            for pid in ids:
                pool.swap_in(pid)
        assert all(p.refcount == 2 for p in shared)
        assert [p.page_id for p in pool.request_pages(2)] == ids
        results[path] = (page_state(pool), pool_bits(pool), pool.swaps_in,
                         pool.swaps_out, pool.bytes_moved)
    assert results["batched"] == results["per_page"]


def test_migration_storm_leaves_decode_unchanged():
    """Engine-level: forcing whole-pool round-trip migrations between steps
    must not change a single generated token."""
    cfg = dataclasses.replace(get_smoke("llama3_2_1b"), remat=False)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = [5, 17, 133, 42, 7, 99, 250, 3]

    def run(storm):
        eng = Engine(model, params,
                     ServeConfig(max_batch=1, page_size=4, hbm_pages=16,
                                 host_pages=32, policy="gdt"))
        eng.add_request(0, prompt, max_new=6)
        while 0 in eng.requests:
            if storm:
                ids = [p.page_id for p in eng.pool.request_pages(0)]
                eng.pool.swap_out_many(ids)
                eng.pool.swap_in_many(ids)
            eng.step()
        return eng.finished[0].generated, eng.pool.swaps_out

    calm, _ = run(storm=False)
    stormy, swaps = run(storm=True)
    assert swaps > 0
    assert stormy == calm, "migration storm changed decode output"
