"""Elastic multi-replica serving tests: router dispatch, replica
lifecycle (fail/drain/remove/restart), live KV migration (cold by
recompute, warm by page export/import), and the loud-loss contract
(``ReplicaLostError``).

The determinism spine: a resumed stream after a mid-decode replica kill
must be bitwise-equal to the unkilled run — on the cold path because
preemption-by-recompute replays (prompt, seed, position) exactly, on the
warm path because ``export_pages``/``import_pages`` move the literal KV
bytes.  One reference run (no failures, one replica) anchors every
migration test.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.launch.analysis import serving_summary
from repro.models import build_model
from repro.serve import (
    LLM,
    ReplicaLostError,
    Router,
    SamplingParams,
    ServeConfig,
)
from repro.serve.kvcache import PagedKVPool


@pytest.fixture(scope="module")
def model_and_params():
    cfg = dataclasses.replace(get_smoke("llama3_2_1b"), remat=False)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


SC = ServeConfig(max_batch=2, page_size=4, hbm_pages=16, host_pages=32)
MAX_NEW = 8
N_REQ = 6


def prompts_for(cfg, n=N_REQ, seed=0, length=6):
    rng = np.random.default_rng(seed)
    return [[int(t) for t in rng.integers(1, cfg.vocab, length)]
            for _ in range(n)]


def sampled(i, max_tokens=MAX_NEW):
    """Seeded non-greedy sampling: the strongest bitwise bar — a migrated
    request must resample identically, which only holds if seed AND
    absolute stream position survive the move."""
    return SamplingParams(temperature=0.8, top_k=40, top_p=0.9,
                          seed=100 + i, max_tokens=max_tokens)


def run_to_completion(llm, handles):
    steps = 0
    while any(not h.finished for h in handles):
        llm.step()
        steps += 1
        assert steps < 400, "cluster failed to converge (dropped request?)"
    return {h.request_id: (list(h.token_ids), h.finish_reason)
            for h in handles}


@pytest.fixture(scope="module")
def reference_streams(model_and_params):
    """The unkilled single-replica run every migration test compares to."""
    cfg, model, params = model_and_params
    llm = LLM(model, params, SC)
    handles = [llm.submit(p, sampled(i), request_id=i)
               for i, p in enumerate(prompts_for(cfg))]
    return run_to_completion(llm, handles)


# ------------------------------------------------- pool export / import
def make_pool(seed=0):
    pool = PagedKVPool(n_layers=2, page_size=4, kv_heads=2, head_dim=8,
                       hbm_pages=8, host_pages=16, dtype=jnp.float32)
    rng = np.random.default_rng(seed)
    pool.k_hbm = jnp.asarray(rng.normal(size=pool.k_hbm.shape), jnp.float32)
    pool.v_hbm = jnp.asarray(rng.normal(size=pool.v_hbm.shape), jnp.float32)
    for idx in range(3):
        page = pool.allocate(0, idx, step=0)
        page.tokens_used = 4 if idx < 2 else 2
        page.accesses = float(10 - idx)
    pool.swap_out(pool.request_pages(0)[2].page_id)   # one page on host
    return pool


def page_bytes(pool, page):
    src_k = pool.k_hbm if page.hbm_slot is not None else pool.k_host
    src_v = pool.v_hbm if page.hbm_slot is not None else pool.v_host
    slot = page.hbm_slot if page.hbm_slot is not None else page.host_slot
    return (np.asarray(src_k[:, slot]).tobytes(),
            np.asarray(src_v[:, slot]).tobytes())


def test_export_import_roundtrip_bitwise_and_tier_preserving():
    src = make_pool()
    pages = src.request_pages(0)
    want = [page_bytes(src, p) for p in pages]
    export = src.export_pages([p.page_id for p in pages])
    assert src.exported_pages == 3
    assert export.fast == [True, True, False]      # source tiers recorded

    dst = PagedKVPool(n_layers=2, page_size=4, kv_heads=2, head_dim=8,
                      hbm_pages=8, host_pages=16, dtype=jnp.float32)
    landed = dst.import_pages(export, request_id=5, step=3)
    assert dst.imported_pages == 3
    assert [p.index_in_seq for p in landed] == [0, 1, 2]
    assert [p.tokens_used for p in landed] == [4, 4, 2]
    assert [p.accesses for p in landed] == [10.0, 9.0, 8.0]
    # Tier placement survives when the destination has room.
    assert [p.hbm_slot is not None for p in landed] == [True, True, False]
    assert [page_bytes(dst, p) for p in landed] == want
    assert [p.page_id for p in dst.request_pages(5)] == \
        [p.page_id for p in landed]


def test_export_unknown_page_and_geometry_mismatch_raise():
    src = make_pool()
    with pytest.raises(ValueError, match="999"):
        src.export_pages([999])
    export = src.export_pages([p.page_id for p in src.request_pages(0)])
    other = PagedKVPool(n_layers=2, page_size=8, kv_heads=2, head_dim=8,
                        hbm_pages=8, host_pages=16, dtype=jnp.float32)
    with pytest.raises(ValueError, match="share one model/page geometry"):
        other.import_pages(export, request_id=1, step=0)


def test_import_into_full_pool_raises_memoryerror_for_cold_fallback():
    src = make_pool()
    export = src.export_pages([p.page_id for p in src.request_pages(0)])
    tiny = PagedKVPool(n_layers=2, page_size=4, kv_heads=2, head_dim=8,
                       hbm_pages=1, host_pages=1, dtype=jnp.float32)
    with pytest.raises(MemoryError, match="cold-migrate instead"):
        tiny.import_pages(export, request_id=1, step=0)
    assert tiny.pages == {} and tiny.imported_pages == 0


def test_select_from_keeps_only_trailing_blocks():
    src = make_pool()
    export = src.export_pages([p.page_id for p in src.request_pages(0)])
    tail = export.select_from(2)
    assert len(tail) == 1 and tail.index_in_seq == [2]
    assert tail.k.shape[1] == 1


# ------------------------------------------------------------- dispatch
def test_least_loaded_dispatch_round_robins_fresh_cluster(model_and_params):
    cfg, model, params = model_and_params
    llm = LLM(model, params, SC, replicas=3)
    for i, p in enumerate(prompts_for(cfg)):
        llm.submit(p, SamplingParams(max_tokens=MAX_NEW), request_id=i)
    owners = [llm.cluster.owner[i].replica_id for i in range(N_REQ)]
    assert owners == [0, 1, 2, 0, 1, 2]     # pages-in-use ties broken by id

    # Pinning overrides the balance; pinning to a non-alive replica raises.
    llm2 = LLM(model, params, SC, replicas=2)
    llm2.submit(prompts_for(cfg)[0], SamplingParams(max_tokens=2),
                request_id=0, replica_id=1)
    assert llm2.cluster.owner[0].replica_id == 1
    llm2.cluster.fail(0)
    with pytest.raises(ValueError, match="failed"):
        llm2.submit(prompts_for(cfg)[1], SamplingParams(max_tokens=2),
                    request_id=1, replica_id=0)


def test_n3_cluster_matches_single_replica_bitwise(model_and_params,
                                                   reference_streams):
    cfg, model, params = model_and_params
    llm = LLM(model, params, SC, replicas=3)
    handles = [llm.submit(p, sampled(i), request_id=i)
               for i, p in enumerate(prompts_for(cfg))]
    assert run_to_completion(llm, handles) == reference_streams


# ------------------------------------------------------ cold migration
def test_replica_crash_cold_migrates_bitwise(model_and_params,
                                             reference_streams):
    cfg, model, params = model_and_params
    llm = LLM(model, params, SC, replicas=3, heartbeat_timeout=2.0)
    handles = [llm.submit(p, sampled(i), request_id=i)
               for i, p in enumerate(prompts_for(cfg))]
    for _ in range(2):
        llm.step()
    victim = llm.cluster.replicas[0].replica_id
    orphaned = sorted(rid for rid, rep in llm.cluster.owner.items()
                      if rep.replica_id == victim)
    llm.cluster.fail(victim)
    assert run_to_completion(llm, handles) == reference_streams
    assert llm.cluster.failovers == 1
    assert llm.cluster.migrations_cold == len(orphaned)
    assert llm.cluster.requests_lost == 0
    # The failed member is gone; its requests decode on survivors.
    assert [r.replica_id for r in llm.cluster.replicas] == [1, 2]


def test_finished_before_crash_result_survives_via_ticket(model_and_params):
    cfg, model, params = model_and_params
    llm = LLM(model, params, SC, replicas=2, heartbeat_timeout=2.0)
    llm.submit(prompts_for(cfg)[0], SamplingParams(max_tokens=3),
               request_id=9)
    steps = 0
    while 9 not in llm.cluster.finished:
        llm.cluster.step()        # step the router directly: no handle drain
        steps += 1
        assert steps < 100
    llm.cluster.fail(llm.cluster.owner[9].replica_id)
    for _ in range(4):
        llm.cluster.step()        # detection + recovery
    req = llm.cluster.pop_finished(9)   # orphaned result, served anyway
    assert req.finish_reason == "length" and len(req.generated) == 3


# ------------------------------------------------------ warm migration
def test_drain_warm_migrates_pages_bitwise(model_and_params,
                                           reference_streams):
    cfg, model, params = model_and_params
    llm = LLM(model, params, SC, replicas=3)
    handles = [llm.submit(p, sampled(i), request_id=i)
               for i, p in enumerate(prompts_for(cfg))]
    for _ in range(3):
        llm.step()
    victim = llm.cluster.replicas[0].replica_id
    n_owned = sum(1 for rep in llm.cluster.owner.values()
                  if rep.replica_id == victim)
    assert llm.cluster.drain(victim) == n_owned
    assert llm.cluster.migrations_warm == n_owned   # pages fit: all warm
    assert llm.cluster.migrations_cold == 0
    llm.cluster.remove_replica(victim)
    assert run_to_completion(llm, handles) == reference_streams
    assert llm.stats()["imported_pages"] > 0
    assert llm.cluster.requests_lost == 0


def test_drain_with_shared_prefix_pages_stays_bitwise(model_and_params):
    cfg, model, params = model_and_params
    sc = dataclasses.replace(SC, enable_prefix_cache=True,
                             min_prefix_pages=1)
    rng = np.random.default_rng(1)
    shared = [int(t) for t in rng.integers(1, cfg.vocab, 9)]
    p1 = shared + [int(t) for t in rng.integers(1, cfg.vocab, 3)]
    p2 = shared + [int(t) for t in rng.integers(1, cfg.vocab, 3)]

    def run(kill):
        llm = LLM(model, params, sc, replicas=2)
        rep0 = llm.cluster.replicas[0].replica_id
        h1 = llm.submit(p1, SamplingParams(max_tokens=6), request_id=0,
                        replica_id=rep0)
        run_to_completion(llm, [h1])    # seeds the prefix cache on rep0
        h2 = llm.submit(p2, SamplingParams(max_tokens=6), request_id=1,
                        replica_id=rep0)
        for _ in range(2):
            llm.step()
        # The in-flight request really holds shared prefix-cache pages.
        eng = llm.cluster.owner[1].engine
        assert any(p.shared for p in eng.pool.request_pages(1))
        if kill:
            llm.cluster.drain(rep0)
            llm.cluster.remove_replica(rep0)
            assert llm.cluster.migrations_warm == 1
        return run_to_completion(llm, [h2])

    assert run(kill=True) == run(kill=False)


def test_warm_import_that_cannot_fit_falls_back_cold(model_and_params,
                                                     reference_streams):
    cfg, model, params = model_and_params
    # The survivor's pool is big enough to DECODE one request at a time
    # (preemption handles the rest) but too small to absorb the drained
    # replica's pages wholesale on top of its own — so per-request warm
    # imports can raise MemoryError and fall back to cold recompute.
    tiny = dataclasses.replace(SC, hbm_pages=5, host_pages=2, max_batch=1)
    llm = LLM(model, params, tiny, replicas=2)
    handles = [llm.submit(p, sampled(i), request_id=i)
               for i, p in enumerate(prompts_for(cfg))]
    for _ in range(3):
        llm.step()
    victim = llm.cluster.replicas[0].replica_id
    llm.cluster.drain(victim)
    llm.cluster.remove_replica(victim)
    got = run_to_completion(llm, handles)
    assert llm.cluster.requests_lost == 0
    assert llm.cluster.migrations_cold >= 1     # at least one didn't fit
    # Streams still bitwise-equal: page_size/seeds match the reference run.
    assert got == reference_streams


# ------------------------------------------------------ rolling restart
def test_rolling_restart_under_load_zero_drops_bitwise(model_and_params,
                                                       reference_streams):
    cfg, model, params = model_and_params
    llm = LLM(model, params, SC, replicas=3)
    all_prompts = prompts_for(cfg)
    handles = [llm.submit(p, sampled(i), request_id=i)
               for i, p in enumerate(all_prompts[:4])]
    for _ in range(2):
        llm.step()
    original = [r.replica_id for r in llm.cluster.replicas]
    for i, rep_id in enumerate(original):
        llm.cluster.restart_replica(rep_id)
        # Submissions keep landing while the restart sweeps the cluster.
        rid = 4 + i
        if rid < N_REQ:
            handles.append(llm.submit(
                all_prompts[rid], sampled(rid), request_id=rid))
        llm.step()
    assert run_to_completion(llm, handles) == reference_streams
    assert llm.cluster.restarts == 3
    assert llm.cluster.requests_lost == 0
    # Every original member was replaced by a fresh id.
    now = [r.replica_id for r in llm.cluster.replicas]
    assert not set(now) & set(original) and len(now) == 3


# ----------------------------------------------------------- loud loss
def test_remove_without_migration_raises_replica_lost(model_and_params):
    cfg, model, params = model_and_params
    llm = LLM(model, params, SC, replicas=2)
    h = llm.submit(prompts_for(cfg)[0], SamplingParams(max_tokens=20),
                   request_id=7)
    llm.step()
    llm.cluster.remove_replica(llm.cluster.owner[7].replica_id,
                               migrate=False)
    with pytest.raises(ReplicaLostError, match="removed without migration"):
        for _ in h:
            pass
    assert llm.cluster.requests_lost == 1
    with pytest.raises(ReplicaLostError):
        llm.pause(7)


def test_crash_with_no_survivor_raises_replica_lost(model_and_params):
    cfg, model, params = model_and_params
    llm = LLM(model, params, SC, replicas=1, heartbeat_timeout=2.0)
    h = llm.submit(prompts_for(cfg)[0], SamplingParams(max_tokens=20),
                   request_id=3)
    llm.step()
    llm.cluster.fail(llm.cluster.replicas[0].replica_id)
    with pytest.raises(ReplicaLostError, match="no alive replica"):
        for _ in h:
            pass
    assert llm.cluster.requests_lost == 1


def test_drain_requires_another_alive_replica(model_and_params):
    cfg, model, params = model_and_params
    llm = LLM(model, params, SC, replicas=1)
    llm.submit(prompts_for(cfg)[0], SamplingParams(max_tokens=4),
               request_id=0)
    with pytest.raises(ValueError, match="no other alive"):
        llm.cluster.drain(0)
    assert llm.cluster.replicas[0].state == "alive"   # rolled back


# ------------------------------------------------- router transparency
def test_single_replica_delegates_engine_attributes(model_and_params):
    cfg, model, params = model_and_params
    llm = LLM(model, params, SC, replicas=1)
    assert isinstance(llm.engine, Router)
    assert llm.engine.pool is llm.cluster.replicas[0].engine.pool
    assert llm.engine.cfg.page_size == SC.page_size

    multi = LLM(model, params, SC, replicas=2)
    with pytest.raises(AttributeError, match="2 reachable replicas"):
        multi.engine.pool


def test_cluster_serving_summary_aggregates_and_nests(model_and_params):
    cfg, model, params = model_and_params
    llm = LLM(model, params, SC, replicas=2)
    handles = [llm.submit(p, SamplingParams(max_tokens=4), request_id=i)
               for i, p in enumerate(prompts_for(cfg, n=4))]
    run_to_completion(llm, handles)
    s = serving_summary(llm.cluster)
    assert s["cluster_replicas"] == 2
    assert set(s["replicas"]) == {"replica0", "replica1"}
    per = s["replicas"]
    assert s["engine_steps"] == sum(r["engine_steps"] for r in per.values())
    assert s["engine_finished_length"] == 4
    # At N=1 the summary is flat — same shape the pre-cluster tooling read.
    solo = LLM(model, params, SC, replicas=1)
    hs = [solo.submit(prompts_for(cfg)[0], SamplingParams(max_tokens=2),
                      request_id=0)]
    run_to_completion(solo, hs)
    flat = serving_summary(solo.cluster)
    assert "replicas" not in flat and flat["cluster_replicas"] == 1
