"""Checkpoint/restart with atomic manifests, async save, and elastic restore.

Layout:  <dir>/step_<N>/manifest.json + one .npy per leaf (path-hashed
names).  Writes go to ``step_<N>.tmp`` and are renamed into place only after
the manifest is fsync'd — a torn save can never be mistaken for a valid
checkpoint, and restart picks the newest valid step.

``restore(..., shardings=...)`` re-places leaves onto an arbitrary mesh
(elastic restore after rescale: the checkpoint is mesh-agnostic because
leaves are stored unsharded per host; on a real multi-host pod each host
would store its addressable shards — the manifest format carries the spec
string for that extension).

``AsyncCheckpointer`` runs saves on a background thread; ``wait()`` joins
before the next save or at shutdown (save-after-save never interleaves).
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

PyTree = Any


def _leaf_name(path_str: str) -> str:
    return hashlib.sha1(path_str.encode()).hexdigest()[:16] + ".npy"


def _load_leaf(base: str, entry: Dict) -> np.ndarray:
    arr = np.load(os.path.join(base, entry["file"]))
    if arr.dtype.kind == "V":  # ml_dtypes (bfloat16 etc.) round-trip as void
        import ml_dtypes

        arr = arr.view(np.dtype(getattr(ml_dtypes, entry["dtype"])))
    return arr


def _flatten(tree: PyTree) -> List[Tuple[str, Any]]:
    leaves, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in leaves:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out.append((name, leaf))
    return out


def save(directory: str, step: int, tree: PyTree,
         extra_meta: Optional[Dict] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest = {"step": step, "leaves": [], "meta": extra_meta or {}}
    for name, leaf in _flatten(tree):
        arr = np.asarray(jax.device_get(leaf))
        fname = _leaf_name(name)
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append(
            {"path": name, "file": fname, "shape": list(arr.shape),
             "dtype": str(arr.dtype)})
    mpath = os.path.join(tmp, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def available_steps(directory: str) -> List[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            man = os.path.join(directory, name, "manifest.json")
            if os.path.exists(man):
                out.append(int(name.split("_")[1]))
    return sorted(out)


def restore(directory: str, step: Optional[int] = None,
            target_tree: Optional[PyTree] = None,
            shardings: Optional[PyTree] = None) -> Tuple[PyTree, Dict]:
    steps = available_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no checkpoints in {directory}")
    step = step if step is not None else steps[-1]
    base = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(base, "manifest.json")) as f:
        manifest = json.load(f)
    by_path = {e["path"]: e for e in manifest["leaves"]}

    if target_tree is None:
        # Rebuild a nested dict from the stored paths.
        tree: Dict = {}
        for e in manifest["leaves"]:
            parts = e["path"].split("/")
            cur = tree
            for p in parts[:-1]:
                cur = cur.setdefault(p, {})
            cur[parts[-1]] = _load_leaf(base, e)
        loaded = _undict(tree)
    else:
        names = _flatten(target_tree)
        leaves = []
        for name, ref in names:
            e = by_path[name]
            leaves.append(_load_leaf(base, e))
        loaded = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(target_tree), leaves)
    if shardings is not None:
        loaded = jax.tree.map(
            lambda x, s: jax.device_put(x, s), loaded, shardings)
    else:
        loaded = jax.tree.map(jax.device_put, loaded)
    return loaded, {"step": manifest["step"], **manifest.get("meta", {})}


def _undict(tree):
    """Convert string-int dict levels (from list indices) back to lists is
    unnecessary for our dict-of-dicts params; keep dicts as-is but convert
    scalar arrays."""
    if isinstance(tree, dict):
        return {k: _undict(v) for k, v in tree.items()}
    if isinstance(tree, np.ndarray) and tree.shape == ():
        return tree[()]
    return tree


class AsyncCheckpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.saved: List[int] = []

    def save_async(self, step: int, tree: PyTree,
                   extra_meta: Optional[Dict] = None):
        self.wait()
        # Snapshot to host synchronously (cheap vs. step time), write async.
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)

        def _work():
            save(self.directory, step, host_tree, extra_meta)
            self.saved.append(step)
            self._gc()

        self._thread = threading.Thread(target=_work, daemon=True)
        self._thread.start()

    def _gc(self):
        steps = available_steps(self.directory)
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
