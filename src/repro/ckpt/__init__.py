from .checkpoint import AsyncCheckpointer, available_steps, restore, save

__all__ = ["AsyncCheckpointer", "available_steps", "restore", "save"]
