"""SLO-aware scheduling policies for the serving engine.

PR 4's continuous-batching scheduler was three hard-wired decisions inside
``Engine``: FIFO admission from the wait queue, preempt the
least-recently-scheduled paused holder, pack decode batches oldest-first.
Production traffic is bursty, multi-tenant, and SLO-bound — which policy
wins depends on the workload, so the decisions live here behind one
interface and the engine consumes whichever ``ServeConfig.scheduler``
names.

A ``SchedulerPolicy`` owns four decisions:

* **admission order** — which waiting request the engine tries to admit
  next (the head of the returned order; admission never skips past a
  request that does not fit, so every policy keeps the no-starvation
  property of bounded head-of-line blocking rather than reordering around
  a stuck request forever).
* **decode order** — how active requests pack into the per-step decode
  batch under the HBM/logical dual budget.
* **preemption victims** — which paused (``preempt_paused``) or running
  (``preempt_active``) page-holder loses its pages when capacity runs out.
  Preemption is always BY RECOMPUTE (lossless: one-shot prefill == decode
  bitwise and sampling folds absolute stream positions), so policies are
  free to preempt aggressively — the stream never changes, only when its
  tokens arrive.
* **per-step budget split** — ``step_budget`` returns how many prompt
  tokens may prefill this step alongside the decode batch
  (chunked-prefill interleaving: a long prefill is split into
  budget-sized chunks co-scheduled with decode steps so a 32k-token
  prompt cannot starve in-flight decodes).  ``prefill_tokens == 0`` means
  eager whole-suffix prefill at admission — bitwise the pre-policy
  engine.

Determinism: policies see only engine state (step counters, request
metadata) and must be pure functions of it — no wall clock (rule FT01),
no unseeded PRNG (rule SCHED01).  Every ordering below carries a total
deterministic tie-break (ultimately ``request_id``), so a replayed trace
schedules identically.

Scheduling metadata rides on ``SamplingParams`` (``priority``,
``tenant``, ``deadline_steps``) and therefore inside ``RequestTicket`` —
a migrated request keeps its class and deadline across replicas.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Sequence

# Engine/Request are only type hints here; importing them would cycle
# (engine.py imports this module), so signatures use duck typing.


@dataclasses.dataclass(frozen=True)
class StepBudget:
    """How one engine step splits its work.

    prefill_tokens: prompt tokens that may ingest this step across all
      requests in ``prefilling`` state (chunked-prefill interleaving).
      ``0`` disables interleaving: admission ingests the whole suffix in
      one eager dispatch (the pre-policy engine, bitwise).
    decode_requests: rows the decode batch may pack (<= ``max_batch``).
    """

    prefill_tokens: int
    decode_requests: int


class SchedulerPolicy:
    """Base class: subclasses set ``name`` and implement the orderings.

    All ordering methods receive non-empty lists of live ``Request``
    objects and the engine, and must return a NEW list/choice without
    mutating engine state (bookkeeping belongs in ``on_step`` /
    ``on_tokens``)."""

    name = "base"

    # ------------------------------------------------------------ orders
    def admission_order(self, waiting: Sequence, engine) -> List:
        """Waiting requests, most-admittable first.  The engine only ever
        admits the HEAD of this order (no skip-ahead past a request that
        does not fit)."""
        raise NotImplementedError

    def prefill_order(self, prefilling: Sequence, engine) -> List:
        """Requests in ``prefilling`` state, in the order the per-step
        prefill token budget is offered to them.  Defaults to the
        admission order — the request a policy wanted in first also
        ingests first."""
        return self.admission_order(prefilling, engine)

    def decode_order(self, active: Sequence, engine) -> List:
        """Active requests, in batch-packing preference order."""
        raise NotImplementedError

    # ------------------------------------------------------- preemption
    def preempt_paused(self, candidates: Sequence, engine):
        """Pick the paused page-holder that loses its pages (preempt by
        recompute)."""
        raise NotImplementedError

    def preempt_active(self, candidates: Sequence, engine):
        """Pick the running/prefilling page-holder that is pushed back to
        the wait queue when logical pages are exhausted."""
        raise NotImplementedError

    # ----------------------------------------------------------- budget
    def step_budget(self, engine) -> StepBudget:
        return StepBudget(
            prefill_tokens=max(int(engine.cfg.prefill_chunk_tokens), 0),
            decode_requests=engine.cfg.max_batch)

    # ------------------------------------------------------ bookkeeping
    def on_step(self, engine) -> None:
        """Called once at the top of every engine step."""

    def on_tokens(self, req, n: int, engine) -> None:
        """Called when ``req`` consumed ``n`` tokens of service (prefill
        chunk tokens and decode tokens both count)."""


class FifoPolicy(SchedulerPolicy):
    """The pre-policy engine, bit for bit.

    Admission follows wait-queue order (head-of-line blocking included),
    decode packs oldest-``last_scheduled`` first, paused preemption takes
    the least-recently-scheduled holder, and active reclaim takes the
    youngest — exactly the decisions PR 4 hard-wired, so an engine
    running ``scheduler="fifo"`` with ``prefill_chunk_tokens=0``
    schedules identically to every pre-policy trace."""

    name = "fifo"

    def admission_order(self, waiting, engine):
        return list(waiting)                    # wait-queue order

    def decode_order(self, active, engine):
        return sorted(active, key=lambda r: (r.last_scheduled,
                                             r.request_id))

    def preempt_paused(self, candidates, engine):
        return min(candidates, key=lambda r: (r.last_scheduled,
                                              r.request_id))

    def preempt_active(self, candidates, engine):
        return sorted(candidates, key=lambda r: (r.last_scheduled,
                                                 r.request_id))[-1]


class PriorityPolicy(SchedulerPolicy):
    """Strict priority classes with earliest-deadline-first inside a class.

    ``SamplingParams.priority`` (higher = sooner) picks the class;
    within a class, requests with an SLO deadline
    (``queued_step + deadline_steps``, in engine steps) order
    earliest-absolute-deadline first and deadline-free requests fall back
    to FIFO.  Preemption inverts the order: the lowest class pays first,
    and within it the FIFO rule applies (oldest paused / youngest
    active), so a high-priority arrival preempts exactly the work the
    admission order values least."""

    name = "priority"

    @staticmethod
    def _deadline(r) -> float:
        d = getattr(r.params, "deadline_steps", None)
        return float(r.queued_step + d) if d is not None else float("inf")

    def admission_order(self, waiting, engine):
        return sorted(waiting, key=lambda r: (
            -r.params.priority, self._deadline(r), r.queued_step,
            r.request_id))

    def decode_order(self, active, engine):
        return sorted(active, key=lambda r: (
            -r.params.priority, self._deadline(r), r.last_scheduled,
            r.request_id))

    def preempt_paused(self, candidates, engine):
        return sorted(candidates, key=lambda r: (
            r.params.priority, -self._deadline(r), -r.last_scheduled,
            -r.request_id))[0]

    def preempt_active(self, candidates, engine):
        return sorted(candidates, key=lambda r: (
            -r.params.priority, self._deadline(r), r.last_scheduled,
            r.request_id))[-1]


class DrrPolicy(SchedulerPolicy):
    """Deficit round robin across tenants (``SamplingParams.tenant``).

    Every step, each tenant with live work earns ``quantum`` tokens of
    deficit (capped at ``cap_steps`` steps' worth so an idle-then-bursty
    tenant cannot bank unbounded credit); serving a tenant — prefill
    chunk tokens and decode tokens alike — spends it.  All orderings run
    richest-deficit-first, so a tenant that received less than its share
    catches up regardless of how many requests a noisy neighbour
    submitted; within a tenant the FIFO rules apply.  Preemption charges
    the POOREST tenant (the one most over its share)."""

    name = "drr"

    def __init__(self, quantum: int = 32, cap_steps: int = 8):
        self.quantum = quantum
        self.cap_steps = cap_steps
        self.deficit: Dict[str, float] = {}

    @staticmethod
    def _tenant(r) -> str:
        return getattr(r.params, "tenant", "default")

    def on_step(self, engine) -> None:
        live = {self._tenant(r) for r in engine.requests.values()}
        cap = float(self.quantum * self.cap_steps)
        for t in sorted(live):
            self.deficit[t] = min(self.deficit.get(t, 0.0) + self.quantum,
                                  cap)
        for t in [t for t in self.deficit if t not in live]:
            del self.deficit[t]          # idle tenants bank nothing

    def on_tokens(self, req, n: int, engine) -> None:
        t = self._tenant(req)
        self.deficit[t] = self.deficit.get(t, 0.0) - n

    def _key(self, r):
        return (-self.deficit.get(self._tenant(r), 0.0), self._tenant(r),
                r.last_scheduled, r.request_id)

    def admission_order(self, waiting, engine):
        return sorted(waiting, key=lambda r: (
            -self.deficit.get(self._tenant(r), 0.0), self._tenant(r),
            r.queued_step, r.request_id))

    def decode_order(self, active, engine):
        return sorted(active, key=self._key)

    def preempt_paused(self, candidates, engine):
        # Poorest tenant pays; within it, the FIFO oldest-paused rule.
        return sorted(candidates, key=lambda r: (
            self.deficit.get(self._tenant(r), 0.0), self._tenant(r),
            r.last_scheduled, r.request_id))[0]

    def preempt_active(self, candidates, engine):
        return sorted(candidates, key=self._key)[-1]


SCHEDULER_POLICIES: Dict[str, Callable[[], SchedulerPolicy]] = {}


def register_scheduler_policy(name: str):
    """Register a policy factory under ``ServeConfig.scheduler`` name."""
    def deco(factory: Callable[[], SchedulerPolicy]):
        SCHEDULER_POLICIES[name] = factory
        return factory
    return deco


register_scheduler_policy("fifo")(FifoPolicy)
register_scheduler_policy("priority")(PriorityPolicy)
register_scheduler_policy("drr")(DrrPolicy)


def make_scheduler_policy(name: str) -> SchedulerPolicy:
    """A FRESH policy instance per engine (DRR carries per-tenant state —
    sharing one instance across engines would bleed deficits between
    replicas)."""
    try:
        factory = SCHEDULER_POLICIES[name]
    except KeyError:
        raise ValueError(
            f"unknown scheduler policy {name!r} "
            f"(ServeConfig.scheduler): registered policies are "
            f"{sorted(SCHEDULER_POLICIES)}") from None
    return factory()
