"""Paged KV cache with two memory tiers — the serving-side realization of
the paper's arenas.

Layout: one global pool of pages per tier; a page holds ``page_size`` tokens
of K and V for *all* layers: (L, page_size, K, dh).  Pages migrate between
the HBM pool (memory kind "device") and the host pool ("pinned_host") as
whole blocks — they are the ``ChunkStats`` chunks the fragmentation engine
reasons about, and each *request* is an allocation site whose arena is its
page list.

Attention computes only against the HBM pool; a page on the host tier must
be swapped in before its sequence can decode (the swap is the rental the
ski-rental controller weighs).  The engine keeps exact per-page access
counts — on every decode step the access set is known statically (all pages
of the scheduled sequences, or the window's pages under SWA).

Migrations are batched: ``swap_in_many`` / ``swap_out_many`` realize a whole
direction of a ``MigrationPlan`` as one gather + one staged transfer + one
scatter per pool array, so enforcing an N-page plan costs a constant number
of host<->device transfers (``transfer_events`` is the probe) while the
per-page swap/byte counters stay exact.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

HOST_KIND = "pinned_host"
DEVICE_KIND = "device"


@dataclasses.dataclass
class Page:
    page_id: int                 # global logical id
    request_id: int
    index_in_seq: int            # page number within the sequence
    birth_step: int
    hbm_slot: Optional[int]      # slot in HBM pool, None if on host
    host_slot: Optional[int]
    # Float, not int: ReweightProfile decays counters every interval, and
    # int-flooring ``1 * 0.5`` to zero would erase exactly the recency
    # signal decay is meant to preserve.
    accesses: float = 0.0
    tokens_used: int = 0


class PagedKVPool:
    """Two-tier physical page pools + logical page bookkeeping."""

    def __init__(self, n_layers: int, page_size: int, kv_heads: int,
                 head_dim: int, hbm_pages: int, host_pages: int,
                 dtype=jnp.bfloat16):
        self.shape = (page_size, kv_heads, head_dim)
        self.page_size = page_size
        self.n_layers = n_layers
        pool = lambda n: jnp.zeros((n_layers, n) + self.shape, dtype)
        dev = jax.devices()[0]
        kinds = []
        try:
            kinds = [m.kind for m in dev.addressable_memories()]
        except Exception:
            pass
        self._dev_sharding = jax.sharding.SingleDeviceSharding(
            dev, memory_kind=DEVICE_KIND if DEVICE_KIND in kinds else None)
        self._host_sharding = (
            jax.sharding.SingleDeviceSharding(dev, memory_kind=HOST_KIND)
            if HOST_KIND in kinds else self._dev_sharding)
        self.k_hbm = jax.device_put(pool(hbm_pages), self._dev_sharding)
        self.v_hbm = jax.device_put(pool(hbm_pages), self._dev_sharding)
        self.k_host = jax.device_put(pool(host_pages), self._host_sharding)
        self.v_host = jax.device_put(pool(host_pages), self._host_sharding)

        self.free_hbm: List[int] = list(range(hbm_pages))
        self.free_host: List[int] = list(range(host_pages))
        self.pages: Dict[int, Page] = {}
        self._next_id = 0
        self.swaps_in = 0
        self.swaps_out = 0
        self.bytes_moved = 0
        # Host<->device transfer probe: one event per staged pool-array
        # transfer (K and V count separately).  A batched N-page migration
        # costs a constant number of events per direction; the per-page
        # path costs 2 per page.  The migration-parity test asserts on it.
        self.transfer_events = 0

    # ------------------------------------------------------------ alloc
    @property
    def page_bytes(self) -> int:
        n = self.n_layers
        for s in self.shape:
            n *= s
        return 2 * n * self.k_hbm.dtype.itemsize  # K and V

    def allocate(self, request_id: int, index_in_seq: int,
                 step: int) -> Page:
        if not self.free_hbm:
            raise MemoryError("HBM pool exhausted; evict first")
        slot = self.free_hbm.pop()
        page = Page(page_id=self._next_id, request_id=request_id,
                    index_in_seq=index_in_seq, birth_step=step,
                    hbm_slot=slot, host_slot=None)
        self._next_id += 1
        self.pages[page.page_id] = page
        return page

    def free(self, page_id: int):
        page = self.pages.pop(page_id)
        if page.hbm_slot is not None:
            self.free_hbm.append(page.hbm_slot)
        if page.host_slot is not None:
            self.free_host.append(page.host_slot)

    # ------------------------------------------------------- migrations
    def _gather(self, src_k, src_v, src_idx):
        """Stage M pages out of a tier as numpy: ONE gather + device_get
        per pool array, regardless of M.

        Memory-kind metadata does not survive eager slicing on the CPU
        backend (the slice stays physically host-resident while reporting
        "device"), so the cross-tier copy stages through numpy and lands
        with an explicit device_put onto the destination tier's sharding
        (``_scatter``).  On TPU this path is a jitted DMA with in/out
        memory kinds instead.
        """
        if not src_idx:
            return None
        si = jnp.asarray(src_idx, jnp.int32)
        return (np.asarray(jax.device_get(jnp.take(src_k, si, axis=1))),
                np.asarray(jax.device_get(jnp.take(src_v, si, axis=1))))

    def _scatter(self, dst_k, dst_v, dst_idx, staged, dst_sharding):
        """Land staged pages on a tier: ONE device_put + scatter per pool
        array, regardless of M."""
        di = jnp.asarray(dst_idx, jnp.int32)
        ksrc = jax.device_put(staged[0], dst_sharding)
        vsrc = jax.device_put(staged[1], dst_sharding)
        dst_k = dst_k.at[:, di].set(ksrc)
        dst_v = dst_v.at[:, di].set(vsrc)
        self.transfer_events += 2            # one per pool array (K, V)
        return dst_k, dst_v

    def _move_pages(self, src_k, src_v, src_idx, dst_k, dst_v, dst_idx,
                    dst_sharding):
        """One-directional batched move: gather-stage then scatter."""
        staged = self._gather(src_k, src_v, src_idx)
        return self._scatter(dst_k, dst_v, dst_idx, staged, dst_sharding)

    def swap_out_many(self, page_ids: Sequence[int]):
        """HBM -> host, one batched transfer for the whole id list.
        Already-slow and unknown ids are skipped; counters stay per-page
        exact (one swap + page_bytes per page actually moved)."""
        ids = [pid for pid in page_ids
               if pid in self.pages and self.pages[pid].hbm_slot is not None]
        if not ids:
            return
        if len(self.free_host) < len(ids):
            raise MemoryError("host pool exhausted")
        src = [self.pages[pid].hbm_slot for pid in ids]
        dst = [self.free_host.pop() for _ in ids]
        self.k_host, self.v_host = self._move_pages(
            self.k_hbm, self.v_hbm, src,
            self.k_host, self.v_host, dst, self._host_sharding)
        for pid, si, di in zip(ids, src, dst):
            page = self.pages[pid]
            self.free_hbm.append(si)
            page.hbm_slot, page.host_slot = None, di
        self.swaps_out += len(ids)
        self.bytes_moved += self.page_bytes * len(ids)

    def swap_in_many(self, page_ids: Sequence[int]):
        """host -> HBM, one batched transfer for the whole id list."""
        ids = [pid for pid in page_ids
               if pid in self.pages and self.pages[pid].hbm_slot is None]
        if not ids:
            return
        if len(self.free_hbm) < len(ids):
            raise MemoryError("HBM pool exhausted; evict first")
        src = [self.pages[pid].host_slot for pid in ids]
        dst = [self.free_hbm.pop() for _ in ids]
        self.k_hbm, self.v_hbm = self._move_pages(
            self.k_host, self.v_host, src,
            self.k_hbm, self.v_hbm, dst, self._dev_sharding)
        for pid, si, di in zip(ids, src, dst):
            page = self.pages[pid]
            self.free_host.append(si)
            page.host_slot, page.hbm_slot = None, di
        self.swaps_in += len(ids)
        self.bytes_moved += self.page_bytes * len(ids)

    def exchange(self, out_ids: Sequence[int], in_ids: Sequence[int]):
        """Atomic bidirectional migration: demote ``out_ids`` and promote
        ``in_ids`` in one batched operation.

        Both directions are STAGED before any slot is freed, so the
        exchange succeeds even when both free lists are empty (a pure slot
        swap) — the case where evict-then-swap-in would deadlock on
        ``free_host``.  Feasibility: len(out) <= len(in) + free_host and
        len(in) <= len(out) + free_hbm (the scheduler's logical-page budget
        guarantees both).  Still one gather + one staged transfer + one
        scatter per pool array per direction.
        """
        outs = [pid for pid in out_ids
                if pid in self.pages and self.pages[pid].hbm_slot is not None]
        ins = [pid for pid in in_ids
               if pid in self.pages and self.pages[pid].hbm_slot is None]
        if not outs and not ins:
            return
        if len(outs) > len(ins) + len(self.free_host):
            raise MemoryError("host pool exhausted")
        if len(ins) > len(outs) + len(self.free_hbm):
            raise MemoryError("HBM pool exhausted; evict first")
        out_src = [self.pages[pid].hbm_slot for pid in outs]
        in_src = [self.pages[pid].host_slot for pid in ins]
        # Stage BOTH directions before any scatter: a destination slot may
        # be a just-freed source slot of the opposite direction.
        out_stage = self._gather(self.k_hbm, self.v_hbm, out_src)
        in_stage = self._gather(self.k_host, self.v_host, in_src)
        self.free_hbm.extend(out_src)
        self.free_host.extend(in_src)
        in_dst = [self.free_hbm.pop() for _ in ins]
        out_dst = [self.free_host.pop() for _ in outs]
        if outs:
            self.k_host, self.v_host = self._scatter(
                self.k_host, self.v_host, out_dst, out_stage,
                self._host_sharding)
        if ins:
            self.k_hbm, self.v_hbm = self._scatter(
                self.k_hbm, self.v_hbm, in_dst, in_stage,
                self._dev_sharding)
        for pid, di in zip(outs, out_dst):
            page = self.pages[pid]
            page.hbm_slot, page.host_slot = None, di
        for pid, di in zip(ins, in_dst):
            page = self.pages[pid]
            page.host_slot, page.hbm_slot = None, di
        self.swaps_out += len(outs)
        self.swaps_in += len(ins)
        self.bytes_moved += self.page_bytes * (len(outs) + len(ins))

    def swap_out(self, page_id: int):
        """HBM -> host (single page; the batched path with M=1)."""
        self.swap_out_many([page_id])

    def swap_in(self, page_id: int):
        """host -> HBM (single page; the batched path with M=1)."""
        self.swap_in_many([page_id])

    # --------------------------------------------------------- queries
    def resident(self, page_id: int) -> bool:
        return self.pages[page_id].hbm_slot is not None

    def hbm_used(self) -> int:
        return sum(1 for p in self.pages.values() if p.hbm_slot is not None)

    def request_pages(self, request_id: int) -> List[Page]:
        return sorted(
            (p for p in self.pages.values() if p.request_id == request_id),
            key=lambda p: p.index_in_seq)
