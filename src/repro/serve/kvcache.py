"""Paged KV cache with two memory tiers — the serving-side realization of
the paper's arenas.

Layout: one global pool of pages per tier; a page holds ``page_size`` tokens
of K and V for *all* layers: (L, page_size, K, dh).  Pages migrate between
the HBM pool (memory kind "device") and the host pool ("pinned_host") as
whole blocks — they are the ``ChunkStats`` chunks the fragmentation engine
reasons about, and each *request* is an allocation site whose arena is its
page list.

Attention computes only against the HBM pool; a page on the host tier must
be swapped in before its sequence can decode (the swap is the rental the
ski-rental controller weighs).  The engine keeps exact per-page access
counts — on every decode step the access set is known statically (all pages
of the scheduled sequences, or the window's pages under SWA).

Migrations are batched: ``swap_in_many`` / ``swap_out_many`` realize a whole
direction of a ``MigrationPlan`` as one gather + one staged transfer + one
scatter per pool array, so enforcing an N-page plan costs a constant number
of host<->device transfers (``transfer_events`` is the probe) while the
per-page swap/byte counters stay exact.

Pages are REFCOUNTED, not single-owner: the cross-request prefix cache
(serve/prefix_cache.py) shares one physical page between every request whose
prompt starts with the same token blocks, plus one reference held by the
cache itself.  ``Page.request_id`` is provenance only (the allocator);
authoritative request->pages association lives in the pool's per-request
sequence table (``request_pages``/``attach``/``release_request``), and
``free`` is a refcount decrement that releases physical slots only at zero.
Shared pages are immutable (copy-on-write: ``copy_page`` gives a writer a
private copy) — sharing is full-page granular, so the serving engine never
writes into a page with refcount > 1 on the normal path.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

HOST_KIND = "pinned_host"
DEVICE_KIND = "device"


@dataclasses.dataclass
class PageExport:
    """KV pages staged out of one pool for import into another — the warm
    half of live request migration.  Data is host-side numpy (one batched
    gather per source tier), bitwise-exact: the importing pool lands the
    same bytes it would have computed itself.  ``fast`` preserves the
    source pool's tier placement so the guidance state (where Algorithm 1
    put each page) survives the membership change when the destination has
    room."""

    page_ids: List[int]
    index_in_seq: List[int]
    tokens_used: List[int]
    accesses: List[float]
    fast: List[bool]              # source-tier residency (True = HBM)
    k: np.ndarray                 # (L, n, P, K, dh)
    v: np.ndarray
    n_layers: int
    shape: Tuple[int, ...]        # (page_size, kv_heads, head_dim)

    def __len__(self) -> int:
        return len(self.page_ids)

    def select_from(self, first_index: int) -> "PageExport":
        """Sub-export of the pages at ``index_in_seq >= first_index`` —
        what remains to import after the destination's prefix cache already
        covered the leading blocks."""
        rows = [i for i, idx in enumerate(self.index_in_seq)
                if idx >= first_index]
        return PageExport(
            page_ids=[self.page_ids[i] for i in rows],
            index_in_seq=[self.index_in_seq[i] for i in rows],
            tokens_used=[self.tokens_used[i] for i in rows],
            accesses=[self.accesses[i] for i in rows],
            fast=[self.fast[i] for i in rows],
            k=self.k[:, rows], v=self.v[:, rows],
            n_layers=self.n_layers, shape=self.shape)


@dataclasses.dataclass
class Page:
    page_id: int                 # global logical id
    request_id: int              # ALLOCATOR provenance, not ownership: the
    #                              pool's sequence table is authoritative
    index_in_seq: int            # page number within the sequence
    birth_step: int
    hbm_slot: Optional[int]      # slot in HBM pool, None if on host
    host_slot: Optional[int]
    # Float, not int: ReweightProfile decays counters every interval, and
    # int-flooring ``1 * 0.5`` to zero would erase exactly the recency
    # signal decay is meant to preserve.
    accesses: float = 0.0
    tokens_used: int = 0
    # Lifecycle: one reference per attached request plus one for the prefix
    # cache when the page is a shared-prefix block.  ``free`` decrements;
    # physical slots release only at zero.
    refcount: int = 1
    # True once the prefix cache holds a reference — such pages are profiled
    # and tier-placed by the PrefixBackend, not the per-request KV backend.
    shared: bool = False
    # Step of the last attach/access — the eviction fallback clock for pages
    # whose only holder is the cache (no live request to LRU against).
    last_used: int = 0


class PagedKVPool:
    """Two-tier physical page pools + logical page bookkeeping."""

    def __init__(self, n_layers: int, page_size: int, kv_heads: int,
                 head_dim: int, hbm_pages: int, host_pages: int,
                 dtype=jnp.bfloat16):
        self.shape = (page_size, kv_heads, head_dim)
        self.page_size = page_size
        self.n_layers = n_layers
        pool = lambda n: jnp.zeros((n_layers, n) + self.shape, dtype)
        dev = jax.devices()[0]
        kinds = []
        # Capability probe: jaxlibs without memory-kind support either
        # lack the method or refuse it at runtime; both mean "one tier".
        try:
            kinds = [m.kind for m in dev.addressable_memories()]
        except (AttributeError, RuntimeError, NotImplementedError):
            pass
        self._dev_sharding = jax.sharding.SingleDeviceSharding(
            dev, memory_kind=DEVICE_KIND if DEVICE_KIND in kinds else None)
        self._host_sharding = (
            jax.sharding.SingleDeviceSharding(dev, memory_kind=HOST_KIND)
            if HOST_KIND in kinds else self._dev_sharding)
        self.k_hbm = jax.device_put(pool(hbm_pages), self._dev_sharding)
        self.v_hbm = jax.device_put(pool(hbm_pages), self._dev_sharding)
        self.k_host = jax.device_put(pool(host_pages), self._host_sharding)
        self.v_host = jax.device_put(pool(host_pages), self._host_sharding)

        self.hbm_pages = hbm_pages
        self.host_pages = host_pages
        self.free_hbm: List[int] = list(range(hbm_pages))
        self.free_host: List[int] = list(range(host_pages))
        self.pages: Dict[int, Page] = {}
        # request_id -> ordered page list (the authoritative association;
        # a shared page appears in every attached request's list).
        self._seq: Dict[int, List[Page]] = {}
        self._next_id = 0
        self.swaps_in = 0
        self.swaps_out = 0
        self.bytes_moved = 0
        # Host<->device transfer probe: one event per staged pool-array
        # transfer (K and V count separately).  A batched N-page migration
        # costs a constant number of events per direction; the per-page
        # path costs 2 per page.  The migration-parity test asserts on it.
        self.transfer_events = 0
        # Cross-pool live-migration counters — separate from the swap
        # counters: an export/import is replica handoff, not tier traffic.
        self.exported_pages = 0
        self.imported_pages = 0

    # ------------------------------------------------------------ alloc
    @property
    def page_bytes(self) -> int:
        n = self.n_layers
        for s in self.shape:
            n *= s
        return 2 * n * self.k_hbm.dtype.itemsize  # K and V

    def allocate(self, request_id: int, index_in_seq: int,
                 step: int) -> Page:
        if not self.free_hbm:
            raise MemoryError(
                f"HBM pool exhausted: all {self.hbm_pages} pages "
                f"(ServeConfig.hbm_pages) hold live or cached KV; evict or "
                f"free pages first, or raise ServeConfig.hbm_pages")
        slot = self.free_hbm.pop()
        page = Page(page_id=self._next_id, request_id=request_id,
                    index_in_seq=index_in_seq, birth_step=step,
                    hbm_slot=slot, host_slot=None, last_used=step)
        self._next_id += 1
        self.pages[page.page_id] = page
        self._seq.setdefault(request_id, []).append(page)
        return page

    def free(self, page_id: int):
        """Drop ONE reference; physical slots release only at refcount zero.
        Unknown or already-freed ids raise a named error — a double free
        under sharing would hand the same physical slot to two sequences."""
        page = self.pages.get(page_id)
        if page is None:
            raise ValueError(
                f"cannot free page {page_id}: unknown or already-freed id "
                f"(a page dies when its refcount reaches zero — freeing it "
                f"again, or freeing an id this pool never allocated, is a "
                f"lifecycle bug in the caller)")
        page.refcount -= 1
        if page.refcount > 0:
            return
        self.pages.pop(page_id)
        if page.hbm_slot is not None:
            self.free_hbm.append(page.hbm_slot)
        if page.host_slot is not None:
            self.free_host.append(page.host_slot)

    # ----------------------------------------------------------- sharing
    def acquire(self, page_id: int, shared: bool = False) -> Page:
        """Add one bare reference (the prefix cache's hold on a block).
        ``shared=True`` marks the page as cache-governed for profiling."""
        page = self.pages[page_id]
        page.refcount += 1
        if shared:
            page.shared = True
        return page

    def attach(self, request_id: int, page_id: int, step: int) -> Page:
        """Reference an existing (shared) page from ``request_id``'s
        sequence.  Pages attach in index order — prefix sharing is only
        legal over a sequence's leading full pages."""
        page = self.pages[page_id]
        seq = self._seq.setdefault(request_id, [])
        if len(seq) != page.index_in_seq:
            raise ValueError(
                f"cannot attach page {page_id} (index_in_seq="
                f"{page.index_in_seq}) to request {request_id} holding "
                f"{len(seq)} pages: prefix pages attach in order")
        page.refcount += 1
        page.last_used = step
        seq.append(page)
        return page

    def release_request(self, request_id: int) -> List[int]:
        """Drop every reference ``request_id`` holds.  Returns the ids of
        pages that actually died (shared pages survive on the cache's
        reference)."""
        freed: List[int] = []
        for page in self._seq.pop(request_id, []):
            self.free(page.page_id)
            if page.page_id not in self.pages:
                freed.append(page.page_id)
        return freed

    def holders(self, page_id: int) -> List[int]:
        """Request ids currently referencing a page (provenance-free)."""
        return [rid for rid, seq in self._seq.items()
                if any(p.page_id == page_id for p in seq)]

    def copy_page(self, page_id: int, request_id: int, step: int) -> Page:
        """Copy-on-write: give ``request_id`` a private HBM copy of a shared
        page, swapping it into the request's sequence in place.  The source
        must be HBM-resident (writers only ever target resident pages)."""
        src = self.pages[page_id]
        if src.hbm_slot is None:
            raise ValueError(
                f"cannot copy-on-write page {page_id}: not HBM-resident "
                f"(swap it in first)")
        if not self.free_hbm:
            raise MemoryError(
                f"HBM pool exhausted: all {self.hbm_pages} pages "
                f"(ServeConfig.hbm_pages) hold live or cached KV; evict or "
                f"free pages first, or raise ServeConfig.hbm_pages")
        seq = self._seq.get(request_id, [])
        at = next((i for i, p in enumerate(seq) if p.page_id == page_id),
                  None)
        if at is None:
            raise ValueError(
                f"cannot copy-on-write page {page_id}: request "
                f"{request_id} does not reference it")
        slot = self.free_hbm.pop()
        new = Page(page_id=self._next_id, request_id=request_id,
                   index_in_seq=src.index_in_seq, birth_step=step,
                   hbm_slot=slot, host_slot=None, accesses=src.accesses,
                   tokens_used=src.tokens_used, last_used=step)
        self._next_id += 1
        self.pages[new.page_id] = new
        self.k_hbm = self.k_hbm.at[:, slot].set(self.k_hbm[:, src.hbm_slot])
        self.v_hbm = self.v_hbm.at[:, slot].set(self.v_hbm[:, src.hbm_slot])
        seq[at] = new
        self.free(page_id)               # drop the request's old reference
        return new

    # ------------------------------------------------------- migrations
    def _gather(self, src_k, src_v, src_idx):
        """Stage M pages out of a tier as numpy: ONE gather + device_get
        per pool array, regardless of M.

        Memory-kind metadata does not survive eager slicing on the CPU
        backend (the slice stays physically host-resident while reporting
        "device"), so the cross-tier copy stages through numpy and lands
        with an explicit device_put onto the destination tier's sharding
        (``_scatter``).  On TPU this path is a jitted DMA with in/out
        memory kinds instead.
        """
        if not src_idx:
            return None
        si = jnp.asarray(src_idx, jnp.int32)
        return (np.asarray(jax.device_get(jnp.take(src_k, si, axis=1))),
                np.asarray(jax.device_get(jnp.take(src_v, si, axis=1))))

    def _scatter(self, dst_k, dst_v, dst_idx, staged, dst_sharding):
        """Land staged pages on a tier: ONE device_put + scatter per pool
        array, regardless of M."""
        di = jnp.asarray(dst_idx, jnp.int32)
        ksrc = jax.device_put(staged[0], dst_sharding)
        vsrc = jax.device_put(staged[1], dst_sharding)
        dst_k = dst_k.at[:, di].set(ksrc)
        dst_v = dst_v.at[:, di].set(vsrc)
        self.transfer_events += 2            # one per pool array (K, V)
        return dst_k, dst_v

    def _move_pages(self, src_k, src_v, src_idx, dst_k, dst_v, dst_idx,
                    dst_sharding):
        """One-directional batched move: gather-stage then scatter."""
        staged = self._gather(src_k, src_v, src_idx)
        return self._scatter(dst_k, dst_v, dst_idx, staged, dst_sharding)

    def swap_out_many(self, page_ids: Sequence[int]):
        """HBM -> host, one batched transfer for the whole id list.
        Already-slow, unknown and duplicate ids are skipped; counters stay
        per-page exact (one swap + page_bytes per page actually moved)."""
        ids = [pid for pid in dict.fromkeys(page_ids)
               if pid in self.pages and self.pages[pid].hbm_slot is not None]
        if not ids:
            return
        if len(self.free_host) < len(ids):
            raise MemoryError("host pool exhausted")
        src = [self.pages[pid].hbm_slot for pid in ids]
        dst = [self.free_host.pop() for _ in ids]
        self.k_host, self.v_host = self._move_pages(
            self.k_hbm, self.v_hbm, src,
            self.k_host, self.v_host, dst, self._host_sharding)
        for pid, si, di in zip(ids, src, dst):
            page = self.pages[pid]
            self.free_hbm.append(si)
            page.hbm_slot, page.host_slot = None, di
        self.swaps_out += len(ids)
        self.bytes_moved += self.page_bytes * len(ids)

    def swap_in_many(self, page_ids: Sequence[int]):
        """host -> HBM, one batched transfer for the whole id list (unknown,
        already-fast and duplicate ids are skipped)."""
        ids = [pid for pid in dict.fromkeys(page_ids)
               if pid in self.pages and self.pages[pid].hbm_slot is None]
        if not ids:
            return
        if len(self.free_hbm) < len(ids):
            raise MemoryError(
                f"HBM pool exhausted: {len(ids)} pages to swap in but only "
                f"{len(self.free_hbm)} of {self.hbm_pages} slots "
                f"(ServeConfig.hbm_pages) are free; evict first or raise "
                f"ServeConfig.hbm_pages")
        src = [self.pages[pid].host_slot for pid in ids]
        dst = [self.free_hbm.pop() for _ in ids]
        self.k_hbm, self.v_hbm = self._move_pages(
            self.k_host, self.v_host, src,
            self.k_hbm, self.v_hbm, dst, self._dev_sharding)
        for pid, si, di in zip(ids, src, dst):
            page = self.pages[pid]
            self.free_host.append(si)
            page.host_slot, page.hbm_slot = None, di
        self.swaps_in += len(ids)
        self.bytes_moved += self.page_bytes * len(ids)

    def exchange(self, out_ids: Sequence[int], in_ids: Sequence[int]):
        """Atomic bidirectional migration: demote ``out_ids`` and promote
        ``in_ids`` in one batched operation.

        Both directions are STAGED before any slot is freed, so the
        exchange succeeds even when both free lists are empty (a pure slot
        swap) — the case where evict-then-swap-in would deadlock on
        ``free_host``.  Feasibility: len(out) <= len(in) + free_host and
        len(in) <= len(out) + free_hbm (the scheduler's logical-page budget
        guarantees both).  Still one gather + one staged transfer + one
        scatter per pool array per direction.
        """
        outs = [pid for pid in dict.fromkeys(out_ids)
                if pid in self.pages and self.pages[pid].hbm_slot is not None]
        ins = [pid for pid in dict.fromkeys(in_ids)
               if pid in self.pages and self.pages[pid].hbm_slot is None]
        if not outs and not ins:
            return
        if len(outs) > len(ins) + len(self.free_host):
            raise MemoryError(
                f"host pool exhausted: {len(outs)} demotions need more than "
                f"the {len(self.free_host)} free of {self.host_pages} host "
                f"slots (ServeConfig.host_pages) plus {len(ins)} freed by "
                f"promotions; raise ServeConfig.host_pages")
        if len(ins) > len(outs) + len(self.free_hbm):
            raise MemoryError(
                f"HBM pool exhausted: {len(ins)} promotions need more than "
                f"the {len(self.free_hbm)} free of {self.hbm_pages} HBM "
                f"slots (ServeConfig.hbm_pages) plus {len(outs)} freed by "
                f"demotions; evict first or raise ServeConfig.hbm_pages")
        out_src = [self.pages[pid].hbm_slot for pid in outs]
        in_src = [self.pages[pid].host_slot for pid in ins]
        # Stage BOTH directions before any scatter: a destination slot may
        # be a just-freed source slot of the opposite direction.
        out_stage = self._gather(self.k_hbm, self.v_hbm, out_src)
        in_stage = self._gather(self.k_host, self.v_host, in_src)
        self.free_hbm.extend(out_src)
        self.free_host.extend(in_src)
        in_dst = [self.free_hbm.pop() for _ in ins]
        out_dst = [self.free_host.pop() for _ in outs]
        if outs:
            self.k_host, self.v_host = self._scatter(
                self.k_host, self.v_host, out_dst, out_stage,
                self._host_sharding)
        if ins:
            self.k_hbm, self.v_hbm = self._scatter(
                self.k_hbm, self.v_hbm, in_dst, in_stage,
                self._dev_sharding)
        for pid, di in zip(outs, out_dst):
            page = self.pages[pid]
            page.hbm_slot, page.host_slot = None, di
        for pid, di in zip(ins, in_dst):
            page = self.pages[pid]
            page.host_slot, page.hbm_slot = None, di
        self.swaps_out += len(outs)
        self.swaps_in += len(ins)
        self.bytes_moved += self.page_bytes * (len(outs) + len(ins))

    # ------------------------------------------------- cross-pool handoff
    def export_pages(self, page_ids: Sequence[int]) -> PageExport:
        """Stage pages out of this pool for import into another (warm live
        migration).  One batched gather per source tier regardless of page
        count; the source pool is left untouched — the exporter releases
        its references separately once the handoff lands."""
        ids = list(dict.fromkeys(page_ids))
        missing = [pid for pid in ids if pid not in self.pages]
        if missing:
            raise ValueError(
                f"cannot export pages {missing}: unknown or freed ids")
        pages = [self.pages[pid] for pid in ids]
        n = len(pages)
        k = np.zeros((self.n_layers, n) + self.shape, self.k_hbm.dtype)
        v = np.zeros_like(k)
        fast_rows = [i for i, p in enumerate(pages) if p.hbm_slot is not None]
        slow_rows = [i for i, p in enumerate(pages) if p.hbm_slot is None]
        if fast_rows:
            staged = self._gather(self.k_hbm, self.v_hbm,
                                  [pages[i].hbm_slot for i in fast_rows])
            k[:, fast_rows], v[:, fast_rows] = staged
        if slow_rows:
            staged = self._gather(self.k_host, self.v_host,
                                  [pages[i].host_slot for i in slow_rows])
            k[:, slow_rows], v[:, slow_rows] = staged
        self.exported_pages += n
        return PageExport(
            page_ids=[p.page_id for p in pages],
            index_in_seq=[p.index_in_seq for p in pages],
            tokens_used=[p.tokens_used for p in pages],
            accesses=[p.accesses for p in pages],
            fast=[p.hbm_slot is not None for p in pages],
            k=k, v=v, n_layers=self.n_layers, shape=self.shape)

    def import_pages(self, export: PageExport, request_id: int,
                     step: int) -> List[Page]:
        """Land an export into THIS pool as fresh private pages attached to
        ``request_id``.  Each page keeps its source tier when the matching
        free list has room (the exporter's guidance placement survives the
        handoff), overflows to the other tier otherwise, and the whole
        import raises ``MemoryError`` — before moving any data — when the
        pools combined cannot hold it (callers fall back to cold
        recompute).  One batched scatter per destination tier."""
        if (export.n_layers, tuple(export.shape)) != (self.n_layers,
                                                      tuple(self.shape)):
            raise ValueError(
                f"cannot import pages shaped {export.n_layers}x"
                f"{tuple(export.shape)} into a pool shaped "
                f"{self.n_layers}x{tuple(self.shape)}: replica engines "
                f"must share one model/page geometry")
        n = len(export)
        if n == 0:
            return []
        if n > len(self.free_hbm) + len(self.free_host):
            raise MemoryError(
                f"cannot import {n} pages: only {len(self.free_hbm)} free "
                f"HBM + {len(self.free_host)} free host slots on the "
                f"destination pool (hbm_pages={self.hbm_pages}, "
                f"host_pages={self.host_pages}); cold-migrate instead")
        room_fast, room_slow = len(self.free_hbm), len(self.free_host)
        fast_rows: List[int] = []
        slow_rows: List[int] = []
        for i in range(n):
            to_fast = export.fast[i] if (room_fast and room_slow) \
                else room_fast > 0
            if to_fast:
                fast_rows.append(i)
                room_fast -= 1
            else:
                slow_rows.append(i)
                room_slow -= 1
        new_pages: List[Optional[Page]] = [None] * n
        for rows, free, is_fast in ((fast_rows, self.free_hbm, True),
                                    (slow_rows, self.free_host, False)):
            if not rows:
                continue
            slots = [free.pop() for _ in rows]
            staged = (export.k[:, rows], export.v[:, rows])
            if is_fast:
                self.k_hbm, self.v_hbm = self._scatter(
                    self.k_hbm, self.v_hbm, slots, staged,
                    self._dev_sharding)
            else:
                self.k_host, self.v_host = self._scatter(
                    self.k_host, self.v_host, slots, staged,
                    self._host_sharding)
            for i, slot in zip(rows, slots):
                page = Page(
                    page_id=self._next_id, request_id=request_id,
                    index_in_seq=export.index_in_seq[i], birth_step=step,
                    hbm_slot=slot if is_fast else None,
                    host_slot=None if is_fast else slot,
                    accesses=export.accesses[i],
                    tokens_used=export.tokens_used[i], last_used=step)
                self._next_id += 1
                self.pages[page.page_id] = page
                new_pages[i] = page
        seq = self._seq.setdefault(request_id, [])
        seq.extend(p for p in new_pages if p is not None)
        self.imported_pages += n
        return [p for p in new_pages if p is not None]

    def swap_out(self, page_id: int):
        """HBM -> host (single page; the batched path with M=1)."""
        self.swap_out_many([page_id])

    def swap_in(self, page_id: int):
        """host -> HBM (single page; the batched path with M=1)."""
        self.swap_in_many([page_id])

    # --------------------------------------------------------- queries
    def resident(self, page_id: int) -> bool:
        return self.pages[page_id].hbm_slot is not None

    def hbm_used(self) -> int:
        return sum(1 for p in self.pages.values() if p.hbm_slot is not None)

    def request_pages(self, request_id: int) -> List[Page]:
        """The request's ordered page list (shared prefix pages included) —
        read from the sequence table, NOT by scanning ``Page.request_id``:
        a shared page's allocator may be long finished."""
        return sorted(self._seq.get(request_id, ()),
                      key=lambda p: p.index_in_seq)
