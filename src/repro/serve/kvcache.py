"""Paged KV cache with two memory tiers — the serving-side realization of
the paper's arenas.

Layout: one global pool of pages per tier; a page holds ``page_size`` tokens
of K and V for *all* layers: (L, page_size, K, dh).  Pages migrate between
the HBM pool (memory kind "device") and the host pool ("pinned_host") as
whole blocks — they are the ``ChunkStats`` chunks the fragmentation engine
reasons about, and each *request* is an allocation site whose arena is its
page list.

Attention computes only against the HBM pool; a page on the host tier must
be swapped in before its sequence can decode (the swap is the rental the
ski-rental controller weighs).  The engine keeps exact per-page access
counts — on every decode step the access set is known statically (all pages
of the scheduled sequences, or the window's pages under SWA).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

HOST_KIND = "pinned_host"
DEVICE_KIND = "device"


@dataclasses.dataclass
class Page:
    page_id: int                 # global logical id
    request_id: int
    index_in_seq: int            # page number within the sequence
    birth_step: int
    hbm_slot: Optional[int]      # slot in HBM pool, None if on host
    host_slot: Optional[int]
    accesses: int = 0
    tokens_used: int = 0


class PagedKVPool:
    """Two-tier physical page pools + logical page bookkeeping."""

    def __init__(self, n_layers: int, page_size: int, kv_heads: int,
                 head_dim: int, hbm_pages: int, host_pages: int,
                 dtype=jnp.bfloat16):
        self.shape = (page_size, kv_heads, head_dim)
        self.page_size = page_size
        self.n_layers = n_layers
        pool = lambda n: jnp.zeros((n_layers, n) + self.shape, dtype)
        dev = jax.devices()[0]
        kinds = []
        try:
            kinds = [m.kind for m in dev.addressable_memories()]
        except Exception:
            pass
        self._dev_sharding = jax.sharding.SingleDeviceSharding(
            dev, memory_kind=DEVICE_KIND if DEVICE_KIND in kinds else None)
        self._host_sharding = (
            jax.sharding.SingleDeviceSharding(dev, memory_kind=HOST_KIND)
            if HOST_KIND in kinds else self._dev_sharding)
        self.k_hbm = jax.device_put(pool(hbm_pages), self._dev_sharding)
        self.v_hbm = jax.device_put(pool(hbm_pages), self._dev_sharding)
        self.k_host = jax.device_put(pool(host_pages), self._host_sharding)
        self.v_host = jax.device_put(pool(host_pages), self._host_sharding)

        self.free_hbm: List[int] = list(range(hbm_pages))
        self.free_host: List[int] = list(range(host_pages))
        self.pages: Dict[int, Page] = {}
        self._next_id = 0
        self.swaps_in = 0
        self.swaps_out = 0
        self.bytes_moved = 0

    # ------------------------------------------------------------ alloc
    @property
    def page_bytes(self) -> int:
        n = self.n_layers
        for s in self.shape:
            n *= s
        return 2 * n * self.k_hbm.dtype.itemsize  # K and V

    def allocate(self, request_id: int, index_in_seq: int,
                 step: int) -> Page:
        if not self.free_hbm:
            raise MemoryError("HBM pool exhausted; evict first")
        slot = self.free_hbm.pop()
        page = Page(page_id=self._next_id, request_id=request_id,
                    index_in_seq=index_in_seq, birth_step=step,
                    hbm_slot=slot, host_slot=None)
        self._next_id += 1
        self.pages[page.page_id] = page
        return page

    def free(self, page_id: int):
        page = self.pages.pop(page_id)
        if page.hbm_slot is not None:
            self.free_hbm.append(page.hbm_slot)
        if page.host_slot is not None:
            self.free_host.append(page.host_slot)

    # ------------------------------------------------------- migrations
    def _copy_page(self, src_k, src_v, si, dst_k, dst_v, di, dst_sharding):
        # Memory-kind metadata does not survive eager slicing on the CPU
        # backend (the slice stays physically host-resident while reporting
        # "device"), so the cross-tier copy stages through numpy and lands
        # with an explicit device_put onto the destination tier's sharding.
        # On TPU this path is a jitted DMA with in/out memory kinds instead.
        import numpy as np

        ksrc = np.asarray(jax.device_get(
            jax.lax.dynamic_slice_in_dim(src_k, si, 1, axis=1)))
        vsrc = np.asarray(jax.device_get(
            jax.lax.dynamic_slice_in_dim(src_v, si, 1, axis=1)))
        ksrc = jax.device_put(ksrc, dst_sharding)
        vsrc = jax.device_put(vsrc, dst_sharding)
        dst_k = jax.lax.dynamic_update_slice_in_dim(dst_k, ksrc, di, axis=1)
        dst_v = jax.lax.dynamic_update_slice_in_dim(dst_v, vsrc, di, axis=1)
        return dst_k, dst_v

    def swap_out(self, page_id: int):
        """HBM -> host."""
        page = self.pages[page_id]
        if page.hbm_slot is None:
            return
        if not self.free_host:
            raise MemoryError("host pool exhausted")
        di = self.free_host.pop()
        self.k_host, self.v_host = self._copy_page(
            self.k_hbm, self.v_hbm, page.hbm_slot,
            self.k_host, self.v_host, di, self._host_sharding)
        self.free_hbm.append(page.hbm_slot)
        page.hbm_slot, page.host_slot = None, di
        self.swaps_out += 1
        self.bytes_moved += self.page_bytes

    def swap_in(self, page_id: int):
        """host -> HBM."""
        page = self.pages[page_id]
        if page.hbm_slot is not None:
            return
        if not self.free_hbm:
            raise MemoryError("HBM pool exhausted; evict first")
        di = self.free_hbm.pop()
        self.k_hbm, self.v_hbm = self._copy_page(
            self.k_host, self.v_host, page.host_slot,
            self.k_hbm, self.v_hbm, di, self._dev_sharding)
        self.free_host.append(page.host_slot)
        page.host_slot, page.hbm_slot = None, di
        self.swaps_in += 1
        self.bytes_moved += self.page_bytes

    # --------------------------------------------------------- queries
    def resident(self, page_id: int) -> bool:
        return self.pages[page_id].hbm_slot is not None

    def hbm_used(self) -> int:
        return sum(1 for p in self.pages.values() if p.hbm_slot is not None)

    def request_pages(self, request_id: int) -> List[Page]:
        return sorted(
            (p for p in self.pages.values() if p.request_id == request_id),
            key=lambda p: p.index_in_seq)
