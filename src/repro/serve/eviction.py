"""First-class eviction policies for the paged KV pool.

Between guidance intervals the engine sometimes needs a free HBM slot *now*
(a paused session resumes, a new page is allocated).  Which resident page
loses its slot is a policy decision, previously inlined in the engine;
policies are now objects in a registry so serving benchmarks — and future
policies — select them by name.

``guided`` consults the latest enforced placement from the
``GuidanceRuntime`` (pages the last plan wanted fast never lose to pages it
wanted slow), tie-breaking by least-recently-scheduled request.  ``lru`` and
``fifo`` are the unguided baselines.
"""

from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Type

from .kvcache import Page


class EvictionPolicy:
    """Picks the page that loses its HBM slot.  Stateless by default."""

    name = "base"

    def pick(self, candidates: List[Page], engine) -> Optional[int]:
        raise NotImplementedError

    def pick_many(self, candidates: List[Page], engine,
                  n: int) -> List[int]:
        """Pick up to ``n`` victims (ranked by repeated ``pick``); the
        engine swaps them out in ONE batched migration rather than one
        transfer per victim.  Policies with a cheaper bulk ranking may
        override."""
        pool = list(candidates)
        victims: List[int] = []
        while len(victims) < n and pool:
            vid = self.pick(pool, engine)
            if vid is None:
                break
            victims.append(vid)
            pool = [p for p in pool if p.page_id != vid]
        return victims


class LRUEviction(EvictionPolicy):
    """Evict the page of the least-recently-scheduled request.

    Refcount-aware: a page's recency is the MOST recent of its holders'
    last-scheduled steps (a shared prefix page is as hot as its hottest
    request), and a page whose only holder is the prefix cache falls back
    to the page's own ``last_used`` clock (its last hit/attach)."""

    name = "lru"

    def pick(self, candidates: List[Page], engine) -> Optional[int]:
        if not candidates:
            return None

        def recency(p: Page) -> int:
            stamps = [engine.requests[rid].last_scheduled
                      for rid in engine.pool.holders(p.page_id)
                      if rid in engine.requests]
            return max(stamps) if stamps else p.last_used

        return min(candidates, key=recency).page_id


class FIFOEviction(EvictionPolicy):
    """Evict the oldest page by birth step."""

    name = "fifo"

    def pick(self, candidates: List[Page], engine) -> Optional[int]:
        if not candidates:
            return None
        return min(candidates, key=lambda p: p.birth_step).page_id


class GuidedEviction(LRUEviction):
    """Prefer pages the last recommendation placed on the slow tier; fall
    back to LRU among equals (and entirely, before the first interval)."""

    name = "gdt"

    def pick(self, candidates: List[Page], engine) -> Optional[int]:
        recs: Dict[int, bool] = getattr(engine, "last_recs", {}) or {}
        if recs:
            cold = [p for p in candidates if not recs.get(p.page_id, False)]
            if cold:
                candidates = cold
        return super().pick(candidates, engine)


EVICTION_POLICIES: Dict[str, Type[EvictionPolicy]] = {}


def register_eviction_policy(cls: Type[EvictionPolicy]) -> Type[EvictionPolicy]:
    EVICTION_POLICIES[cls.name] = cls
    return cls


for _cls in (LRUEviction, FIFOEviction, GuidedEviction):
    register_eviction_policy(_cls)


def make_eviction_policy(name: str) -> EvictionPolicy:
    try:
        return EVICTION_POLICIES[name]()
    except KeyError:
        raise ValueError(
            f"unknown eviction policy {name!r}; "
            f"expected one of {sorted(EVICTION_POLICIES)}") from None
