"""Cross-request radix prefix cache with guided tier placement.

Millions of users share system prompts and few-shot prefixes, yet a paged
engine that keys KV pages by request pays full prefill for every arrival.
This module makes the shared prefix a first-class object:

* **Radix tree over full-page token blocks.**  A node is one FULL page of
  tokens (``page_size`` of them) holding a refcounted page in
  ``PagedKVPool``; the path from a root to a node spells the token prefix it
  caches.  Keys are chain hashes — ``h_i = blake2b(h_{i-1} || block_i)`` —
  so a block's identity commits to its entire left context, and children are
  bucketed by their literal token block (exact match, no collisions decide
  placement).  Only full pages are cached: sharing is page-granular, which
  is exactly what makes shared pages immutable (a request's first private
  write always lands on a fresh page past the covered prefix, so the
  copy-on-write rule ``refcount > 1 == read-only`` is never hit on the
  normal path).

* **Determinism.**  K/V for a token depend only on the token sequence and
  absolute positions — not on batching, chunking, or sampling — and PR 4
  proved one-shot prefill == chunked prefill == decode bitwise.  A cached
  block is therefore bitwise-equal to what a fresh prefill of the same
  tokens would write, and a suffix-only prefill over a matched prefix
  replays the uncached computation exactly.

* **Guided placement (the paper's loop, applied to the KV cache itself).**
  ``PrefixBackend`` exposes the cache to ``GuidanceRuntime``: arena = one
  root subtree (one distinct leading block ~ one system prompt), chunk =
  one cached page, and the access profile is the per-interval HIT count —
  observed previous usage, no separate profiling run.  Ski-rental decides
  promote/demote and enforcement routes through the pool's batched
  ``exchange``, so hot shared prefixes get pinned in HBM while cold ones
  demote to host (or are reclaimed entirely under logical-page pressure).
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

from ..core.fragmentation import ChunkStats
from ..core.profiler import ArenaProfile, IntervalProfile
from ..core.runtime import MigrationPlan, MoveStats
from .kvcache import Page, PagedKVPool

Block = Tuple[int, ...]


def block_hash(parent_key: bytes, tokens: Sequence[int]) -> bytes:
    """Chain hash of one full-page token block: commits to the whole prefix
    through ``parent_key``, so equal keys mean equal token chains."""
    h = hashlib.blake2b(parent_key, digest_size=16)
    h.update(("|".join(str(int(t)) for t in tokens)).encode())
    return h.digest()


@dataclasses.dataclass(eq=False)       # identity semantics: tree nodes are
class PrefixNode:                      # unique objects, and dict-keyable
    """One cached full-page block: a radix-tree edge + its physical page."""

    key: bytes                    # chain hash of the prefix through here
    tokens: Block                 # this page's token block (len == page_size)
    page_id: int
    depth: int                    # == Page.index_in_seq
    parent: Optional["PrefixNode"]
    birth_step: int
    children: Dict[Block, "PrefixNode"] = dataclasses.field(
        default_factory=dict)
    # Per-interval hit counts are the access profile guidance consumes
    # (float: ReweightProfile decays them, same rationale as Page.accesses).
    hits: float = 0.0
    last_hit_step: int = 0

    def root(self) -> "PrefixNode":
        node = self
        while node.parent is not None:
            node = node.parent
        return node


class PrefixCache:
    """Radix tree mapping prompt prefixes to refcounted ``PagedKVPool``
    pages.  The cache holds ONE reference per cached page (taken at
    ``insert``, dropped at ``release``/``reclaim``); attached requests hold
    their own references through the pool's sequence table."""

    def __init__(self, pool: PagedKVPool, page_size: int,
                 min_pages: int = 1):
        if min_pages < 1:
            raise ValueError(
                f"min_prefix_pages must be >= 1, got {min_pages}")
        self.pool = pool
        self.page_size = page_size
        self.min_pages = min_pages
        self.roots: Dict[Block, PrefixNode] = {}
        self.by_page: Dict[int, PrefixNode] = {}
        # ------------------------------------------------------ counters
        self.lookups = 0          # match() calls (one per prefill)
        self.hit_requests = 0     # lookups that matched >= 1 page
        self.hit_pages = 0        # total pages served from the cache
        self.inserted_pages = 0   # pages adopted into the tree
        self.evicted_pages = 0    # pages reclaimed out of the tree

    def __len__(self) -> int:
        return len(self.by_page)

    def nodes(self) -> List[PrefixNode]:
        return list(self.by_page.values())

    @property
    def hit_rate(self) -> float:
        return self.hit_requests / self.lookups if self.lookups else 0.0

    # ------------------------------------------------------------- match
    def match(self, tokens: Sequence[int], step: int,
              count: bool = True) -> List[PrefixNode]:
        """Longest chain of cached full-page blocks covering a prefix of
        ``tokens``.  Charges one hit per matched node (the access profile)
        and one access on the physical page (the eviction clock).
        ``count=False`` walks the chain without charging anything — the
        re-attach path of live request migration, which is replica
        plumbing, not application access, and must not skew the guidance
        profile or the hit-rate telemetry."""
        if count:
            self.lookups += 1
        P = self.page_size
        chain: List[PrefixNode] = []
        level = self.roots
        for i in range(len(tokens) // P):
            node = level.get(tuple(int(t) for t in tokens[i * P:(i + 1) * P]))
            if node is None:
                break
            chain.append(node)
            level = node.children
        if not count:
            return chain
        for node in chain:
            node.hits += 1.0
            node.last_hit_step = step
            page = self.pool.pages[node.page_id]
            page.accesses += 1.0
            page.last_used = step
        if chain:
            self.hit_requests += 1
            self.hit_pages += len(chain)
        return chain

    # ------------------------------------------------------------ insert
    def insert(self, tokens: Sequence[int], pages: Sequence[Page],
               limit: int, step: int,
               chain: Sequence[PrefixNode] = ()) -> int:
        """Adopt a request's full-page prefix blocks into the tree.

        ``pages`` is the request's ordered page list (``chain`` pages first,
        then the private suffix pages); blocks beyond ``limit`` tokens —
        the caller's shareability horizon, e.g. the prompt length — stay
        private.  The cache takes one pool reference per adopted page, so
        cached blocks survive the inserting request.  Returns the number of
        pages adopted."""
        P = self.page_size
        n_full = min(limit, len(tokens)) // P
        if n_full < self.min_pages:
            return 0
        parent = chain[-1] if chain else None
        level = parent.children if parent is not None else self.roots
        added = 0
        for i in range(len(chain), n_full):
            block = tuple(int(t) for t in tokens[i * P:(i + 1) * P])
            node = level.get(block)
            if node is None:
                page = pages[i]
                assert page.tokens_used == P, \
                    "only FULL pages are shareable (partial pages mutate)"
                node = PrefixNode(
                    key=block_hash(parent.key if parent else b"", block),
                    tokens=block, page_id=page.page_id, depth=i,
                    parent=parent, birth_step=step, last_hit_step=step)
                level[block] = node
                self.by_page[page.page_id] = node
                self.pool.acquire(page.page_id, shared=True)
                added += 1
            parent, level = node, node.children
        self.inserted_pages += added
        return added

    # ----------------------------------------------------------- reclaim
    def evictable(self) -> List[PrefixNode]:
        """Leaves only the cache still references (refcount == 1): dropping
        one cannot orphan a descendant or a live request's prefix."""
        return [n for n in self.by_page.values()
                if not n.children
                and self.pool.pages[n.page_id].refcount == 1]

    def release(self, node: PrefixNode) -> None:
        """Drop one evictable leaf: detach from the tree and return the
        cache's pool reference (freeing the physical page)."""
        if node.children:
            raise ValueError(
                f"cannot release prefix node for page {node.page_id}: "
                f"it has {len(node.children)} cached children")
        siblings = (node.parent.children if node.parent is not None
                    else self.roots)
        siblings.pop(node.tokens)
        self.by_page.pop(node.page_id)
        self.pool.free(node.page_id)
        self.evicted_pages += 1

    def reclaim(self, n_pages: int) -> int:
        """Free up to ``n_pages`` logical pages by dropping the coldest
        evictable leaves (LRU by last hit, deepest first among equals —
        releasing a leaf can expose its parent for the next round).  Called
        by the engine under logical-page pressure, BEFORE preempting live
        requests."""
        freed = 0
        while freed < n_pages:
            cands = self.evictable()
            if not cands:
                break
            victim = min(cands, key=lambda n: (n.last_hit_step, -n.depth,
                                               n.page_id))
            self.release(victim)
            freed += 1
        return freed


class PrefixBackend:
    """``TierBackend`` making shared prefixes first-class tier objects.

    Arena = one root subtree of the radix tree (one distinct leading block —
    typically one system prompt); chunk = one cached page.  ``snapshot``'s
    access column is the per-interval hit count, ``reweight`` decays it, and
    ``enforce`` realizes the plan as ONE atomic batched ``exchange`` —
    demotions of cache-only pages fund promotions of planned-hot ones, and
    promotions past the free HBM slots are refused and reflected back into
    ``last_recs`` (same contract as ``PagedKVBackend``).  Pages a live
    request currently references never demote (they would swap straight
    back on the next scheduled step)."""

    name = "prefix"

    def __init__(self, cache: PrefixCache, clock: Callable[[], int]):
        self.cache = cache
        self.pool = cache.pool
        self.clock = clock
        self.last_recs: Dict[int, bool] = {}   # page_id -> recommended fast
        self._telemetry: Dict[int, List[ChunkStats]] = {}

    def _subtrees(self) -> Dict[PrefixNode, List[PrefixNode]]:
        out: Dict[PrefixNode, List[PrefixNode]] = {}
        for root in self.cache.roots.values():
            nodes, stack = [], [root]
            while stack:
                node = stack.pop()
                nodes.append(node)
                stack.extend(node.children.values())
            out[root] = nodes
        return out

    # ------------------------------------------------------------ protocol
    def snapshot(self) -> IntervalProfile:
        rows: List[ArenaProfile] = []
        telemetry: Dict[int, List[ChunkStats]] = {}
        page_bytes = self.pool.page_bytes
        step = self.clock()
        for root, nodes in self._subtrees().items():
            arena_id = root.page_id      # stable and unique per subtree
            fast = sum(1 for n in nodes
                       if self.pool.pages[n.page_id].hbm_slot is not None)
            rows.append(ArenaProfile(
                arena_id=arena_id, site_id=arena_id,
                label=f"prefix{root.page_id}",
                accesses=sum(n.hits for n in nodes),
                resident_bytes=len(nodes) * page_bytes,
                fast_fraction=fast / len(nodes)))
            telemetry[arena_id] = [
                ChunkStats(chunk_id=n.page_id, nbytes=page_bytes,
                           accesses=n.hits, age=step - n.birth_step,
                           fast=self.pool.pages[n.page_id].hbm_slot
                           is not None)
                for n in nodes]
        self._telemetry = telemetry
        return IntervalProfile(step, rows, 0, 0.0)

    def telemetry(self) -> Mapping[int, Sequence[ChunkStats]]:
        return self._telemetry

    def reweight(self, decay: float) -> None:
        for node in self.cache.by_page.values():
            node.hits *= decay
            self.pool.pages[node.page_id].accesses *= decay

    def on_plan(self, plan: MigrationPlan) -> None:
        self.last_recs = dict(plan.chunk_placement)

    def enforce(self, plan: MigrationPlan) -> MoveStats:
        stats = MoveStats()
        pages = self.pool.pages
        page_bytes = self.pool.page_bytes
        cached = self.cache.by_page
        demote = [pid for pid, fast in plan.chunk_placement.items()
                  if not fast and pid in cached
                  and pages[pid].hbm_slot is not None
                  and pages[pid].refcount == 1]
        want = [pid for pid, fast in plan.chunk_placement.items()
                if fast and pid in cached and pages[pid].hbm_slot is None]
        # Feasibility bounds mirror exchange(): demotions fund promotions
        # and vice versa; excess promotions are refused, not crashed.
        demote = demote[:len(want) + len(self.pool.free_host)]
        room = len(demote) + len(self.pool.free_hbm)
        promote, refused = want[:room], want[room:]
        self.pool.exchange(demote, promote)
        stats.bytes_demoted = page_bytes * len(demote)
        stats.bytes_promoted = page_bytes * len(promote)
        for pid in refused:
            stats.dropped_promotions += 1
            self.last_recs[pid] = False
        return stats

    def fast_bytes(self) -> int:
        return self.pool.page_bytes * sum(
            1 for pid in self.cache.by_page
            if self.pool.pages[pid].hbm_slot is not None)
