"""The front-door generation API: ``LLM.generate`` / ``LLM.submit``.

This is the surface behind which guided KV tiering stays invisible — the
paper's "no source code modification" claim applied to serving: callers
express *what* to generate (prompts + ``SamplingParams``), and the engine's
continuous batching, paged two-tier KV cache, preemption-by-recompute and
Algorithm-1 page placement all happen behind it.

Two entry points over one shared engine:

* ``generate(prompts, params)`` — batch-blocking: submit everything, step
  the engine until every request finishes, return ``RequestOutput`` rows in
  prompt order.
* ``submit(prompt, params) -> RequestHandle`` — streaming: the handle is an
  iterable of ``(token, finish_reason)`` deltas produced as the engine
  steps; ``finish_reason`` is ``None`` until the final delta (``stop`` /
  ``length`` / ``truncated`` / ``cancelled``).  Iterating a handle drives
  the shared engine, so concurrent handles make progress together.
  ``LLM.cancel(request_id)`` withdraws a live request; its handle ends
  with a ``cancelled`` delta instead of dangling.

Determinism: with ``temperature=0`` the output is bitwise-equal to greedy
decode; with a seeded ``temperature > 0`` the stream is a pure function of
(request stream, seed, position), so engine-internal preemption and
recompute never change what a caller observes (DESIGN.md §10).
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, Iterator, List, Optional, Sequence, Tuple, Union

import numpy as np

from ..core import TPU_V5E, HardwareModel
from .cluster import ReplicaLostError, Router
from .engine import Engine, ServeConfig
from .sampling import DEFAULT_MAX_TOKENS, SamplingParams

Prompt = Sequence[int]
Delta = Tuple[Optional[int], Optional[str]]


@dataclasses.dataclass
class RequestOutput:
    """One finished request, as the front door reports it."""

    request_id: int
    prompt_token_ids: List[int]
    token_ids: List[int]
    finish_reason: str            # stop | length | truncated | cancelled
    params: SamplingParams


class RequestHandle:
    """Streaming view of one submitted request.

    Iterate to receive ``(token, finish_reason)`` deltas: every generated
    token arrives as ``(token, None)`` except the last, which carries the
    finish reason; a request finished without a token this step (capacity
    truncation) emits a final tokenless ``(None, reason)`` delta.
    Iteration drives the shared engine, so other in-flight handles advance
    too.  ``result()`` drains to completion and returns the
    ``RequestOutput``.
    """

    def __init__(self, llm: "LLM", request_id: int, prompt: Prompt,
                 params: SamplingParams):
        self._llm = llm
        self.request_id = request_id
        self.prompt_token_ids = [int(t) for t in prompt]
        self.params = params
        self.token_ids: List[int] = []
        self.finish_reason: Optional[str] = None
        self._deltas: Deque[Delta] = deque()
        self._queued = 0        # prefix of req.generated queued as deltas

    @property
    def finished(self) -> bool:
        return self.finish_reason is not None

    def __iter__(self) -> Iterator[Delta]:
        while True:
            delta = self.next_delta()
            if delta is None:
                return
            yield delta

    def next_delta(self) -> Optional[Delta]:
        """Block (stepping the engine) until this request's next delta, or
        ``None`` when the stream is exhausted."""
        while not self._deltas:
            if self.finished:
                return None
            # The engine may have been stepped directly (bypassing
            # llm.step): absorb any finish before deciding to step again.
            self._llm._absorb_finished()
            if self._deltas or self.finished:
                continue
            req = self._llm.engine.requests.get(self.request_id)
            if req is None:
                # The owning replica may have left the cluster without a
                # survivor to rebuild the request: a NAMED error, never a
                # hang or a raw KeyError (cold migration is transparent —
                # a recovered request is simply live again on its new
                # replica by the time we look).
                lost = getattr(self._llm.engine, "lost_requests", None)
                if lost and self.request_id in lost:
                    raise ReplicaLostError(
                        f"request {self.request_id} was lost: "
                        f"{lost[self.request_id]}; resubmit to retry")
                # Not live and not absorbable from engine.finished (the
                # absorb above would have caught that): the result was
                # drained behind our back — fail loudly rather than
                # busy-stepping an engine that no longer has the request.
                raise RuntimeError(
                    f"request {self.request_id} left the engine without "
                    f"its result reaching this handle (was "
                    f"engine.pop_finished called directly?)")
            if req.state in ("paused", "preempted"):
                # Single-threaded driver: stepping can never advance a
                # request the caller parked, so spinning would hang.
                raise RuntimeError(
                    f"request {self.request_id} is {req.state}; resume() "
                    f"it before consuming its stream")
            self._llm.step()
        return self._deltas.popleft()

    def result(self) -> RequestOutput:
        for _ in self:
            pass
        return RequestOutput(
            request_id=self.request_id,
            prompt_token_ids=list(self.prompt_token_ids),
            token_ids=list(self.token_ids),
            finish_reason=self.finish_reason,
            params=self.params)


class LLM:
    """Generation front end over a cluster of serving engine replicas.

    Construct from a built model (``LLM(model, params)``) or straight from
    the architecture registry (``LLM.from_arch("llama3_2_1b")``).  All
    tiering/scheduling knobs stay on ``ServeConfig``; per-request behaviour
    stays on ``SamplingParams`` — the caller never touches pages, tiers or
    batches.  ``replicas=N`` puts N engines behind the same front door
    (``serve.cluster.Router``): requests dispatch least-loaded, and replica
    failure/drain migrates in-flight streams bitwise instead of dropping
    them.  The default ``replicas=1`` behaves exactly like the old
    single-engine LLM — ``llm.engine`` then delegates engine attributes
    transparently.
    """

    def __init__(self, model, params, cfg: Optional[ServeConfig] = None,
                 hw: HardwareModel = TPU_V5E, replicas: int = 1,
                 heartbeat_timeout: float = 8.0):
        cfg = cfg or ServeConfig()
        self.cluster = Router(
            lambda: Engine(model, params, cfg, hw),
            n_replicas=replicas, heartbeat_timeout=heartbeat_timeout)
        self._handles: Dict[int, RequestHandle] = {}
        self._next_id = 0

    @property
    def engine(self) -> Router:
        """The engine-shaped control surface (the ``Router``): merged
        ``requests``/``finished`` views, cluster ``stats()``, and — on a
        one-replica cluster — transparent delegation of single-engine
        attributes (``engine.pool``, ``engine.prefix_cache``, ...)."""
        return self.cluster

    @classmethod
    def from_arch(cls, arch: str, smoke: bool = True,
                  cfg: Optional[ServeConfig] = None,
                  seed: int = 0, replicas: int = 1) -> "LLM":
        import jax

        from ..configs import get, get_smoke
        from ..models import build_model

        mcfg = get_smoke(arch) if smoke else get(arch)
        mcfg = dataclasses.replace(mcfg, remat=False)
        model = build_model(mcfg)
        return cls(model, model.init(jax.random.PRNGKey(seed)), cfg,
                   replicas=replicas)

    # ------------------------------------------------------------ submit
    def submit(self, prompt: Prompt,
               params: Optional[SamplingParams] = None,
               request_id: Optional[int] = None,
               replica_id: Optional[int] = None) -> RequestHandle:
        """Enqueue one request and return its streaming handle.  Dispatch
        is least-loaded across alive replicas; ``replica_id`` pins it."""
        params = params if params is not None else SamplingParams()
        rid = request_id if request_id is not None else self._next_id
        self._next_id = max(self._next_id, rid + 1)
        # The generation budget resolves inside add_request (max_tokens,
        # else DEFAULT_MAX_TOKENS) — one owner, no api-side duplicate.
        self.cluster.add_request(rid, [int(t) for t in prompt],
                                 params=params, replica_id=replica_id)
        handle = RequestHandle(self, rid, prompt, params)
        self._handles[rid] = handle
        return handle

    def generate(self,
                 prompts: Union[Prompt, Sequence[Prompt]],
                 params: Union[None, SamplingParams,
                               Sequence[SamplingParams]] = None,
                 ) -> List[RequestOutput]:
        """Batch-blocking generation: one output row per prompt, in order.

        ``prompts`` is a list of token-id lists (a single flat token list
        is treated as one prompt); ``params`` is shared or per-prompt.
        """
        if prompts and isinstance(prompts[0], (int, np.integer)):
            prompts = [prompts]
        if params is None or isinstance(params, SamplingParams):
            plist: List[Optional[SamplingParams]] = [params] * len(prompts)
        else:
            if len(params) != len(prompts):
                raise ValueError(
                    f"{len(params)} SamplingParams for {len(prompts)} "
                    f"prompts")
            plist = list(params)
        handles = [self.submit(p, sp) for p, sp in zip(prompts, plist)]
        while any(not h.finished for h in handles):
            self.step()
        return [h.result() for h in handles]

    # ----------------------------------------------------------- driving
    def step(self) -> Dict[int, int]:
        """Advance the engine one step and route deltas to their handles."""
        out = self.engine.step()
        for rid in out:
            h = self._handles.get(rid)
            req = (self.engine.requests.get(rid)
                   or self.engine.finished.get(rid))
            if h is not None and req is not None:
                self._route(h, req.generated)
        self._absorb_finished()
        return out

    @staticmethod
    def _route(h: RequestHandle, generated: Sequence[int]) -> None:
        """Queue every not-yet-queued token of the request's authoritative
        stream as a ``(token, None)`` delta.  Routing always reconciles
        against ``req.generated`` with a per-handle cursor, so tokens
        produced while the engine was stepped directly (bypassing
        ``llm.step``) are delivered in order, never duplicated."""
        for tok in generated[h._queued:]:
            h.token_ids.append(int(tok))
            h._deltas.append((int(tok), None))
        h._queued = len(generated)

    def _absorb_finished(self) -> None:
        """Move engine finishes onto their handles: the final undelivered
        token delta gains the ``finish_reason``; a request that finished
        without producing a token this step (capacity truncation) gets a
        trailing tokenless ``(None, reason)`` delta."""
        for rid in list(self.engine.finished):
            # Finished handles leave the routing table: the handle object
            # itself (with its tokens) belongs to the caller, and keeping a
            # reference per past request would grow without bound in a
            # long-lived server (the API-layer twin of the engine's
            # finished-request leak fix).
            h = self._handles.pop(rid, None)
            if h is None or h.finished:
                continue                 # engine driven directly / drained
            req = self.engine.pop_finished(rid)
            h.finish_reason = req.finish_reason
            self._route(h, req.generated)
            if h._deltas and h._deltas[-1][1] is None:
                tok, _ = h._deltas.pop()
                h._deltas.append((tok, req.finish_reason))
            else:
                h._deltas.append((None, req.finish_reason))

    # -------------------------------------------------- session controls
    def pause(self, request_id: int) -> None:
        self.engine.pause(request_id)

    def resume(self, request_id: int) -> None:
        self.engine.resume(request_id)

    def cancel(self, request_id: int) -> None:
        """Withdraw a live request.  Its streaming handle terminates with
        a final ``(token-or-None, "cancelled")`` delta (tokens generated
        before the cancel are still delivered); ``result()`` returns them
        with ``finish_reason="cancelled"``.  Finished or unknown ids raise
        the engine's named ``ValueError``."""
        self.engine.cancel(request_id)
        self._absorb_finished()

    def is_live(self, request_id: int) -> bool:
        """True while the request is still inside the engine (any state
        short of finished) — the guard session drivers use before
        pause/resume, which raise on finished/unknown ids."""
        return request_id in self.engine.requests

    def stats(self) -> Dict[str, float]:
        return self.engine.stats()
