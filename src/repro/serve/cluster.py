"""Elastic multi-replica serving: replica lifecycle + routing front door.

One ``Engine`` is a replaceable unit here, not the serving stack.  The
``Router`` owns N ``EngineReplica`` wrappers and presents the same control
surface an ``Engine`` does (``add_request`` / ``step`` / ``pause`` /
``resume`` / ``pop_finished`` / ``requests`` / ``finished`` / ``stats``),
so ``serve.api.LLM`` routes instead of owning a single engine and every
existing driver keeps working at N=1.

* **Dispatch** is least-loaded and policy-aware: a new request goes to
  the alive replica with the smallest ``Engine.queue_delay_estimate()``
  (un-ingested prompt-token backlog over per-step prefill capacity, plus
  decode occupancy — so a replica stuffed with queued 32k prompts repels
  new work even while its pages-in-use still look modest), then fewest
  allocated pages, ties broken by replica id — deterministic, so a
  replayed workload routes identically.

* **Lifecycle** runs through the seed's ``ft.HeartbeatMonitor`` with an
  injected step-tick clock (``router.step`` is the heartbeat cadence):
  wall-clock never enters the control path, so failover timelines replay
  deterministically in tests (and rule FT01 keeps it that way).  A replica
  that stops beating (``fail`` — a simulated crash, or any driver that
  stops stepping it) is detected after ``heartbeat_timeout`` ticks and its
  requests are recovered.

* **Recovery** migrates in-flight work instead of dropping it, on two
  paths with one decision rule — *warm when the dead engine's memory is
  still reachable, cold otherwise*:

  - **cold** (crash): the router's ``RequestTicket`` ledger — prompt,
    params, generated-so-far, maintained from step outputs, never read
    from the failed engine — is replayed on a survivor via
    ``Engine.import_request(ticket)``.  Preemption-by-recompute makes this
    bitwise: seeds are explicit or derived from the (preserved) request
    id, and the sampler folds absolute stream positions.
  - **warm** (``drain`` — graceful restart/scale-down): KV pages hand off
    via ``PagedKVPool.export_pages`` → ``import_pages`` (batched staging,
    bitwise), prefix-cache blocks re-attach on the destination by chain
    hash, and decoding resumes with zero recompute.  A warm import that
    does not fit falls back to cold transparently.

* **Loss is loud, not silent**: when no survivor exists, the affected
  request ids land in ``lost_requests`` and their streaming handles raise
  ``ReplicaLostError`` instead of hanging or leaking a raw ``KeyError``.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Optional, Tuple

from ..ft import HeartbeatMonitor
from .engine import Engine, Request, RequestTicket


class ReplicaLostError(RuntimeError):
    """A request's owning replica left the cluster with no survivor able to
    rebuild it (or it was removed with ``migrate=False``).  Streaming
    handles raise this instead of spinning; resubmitting through the
    router is the caller's retry path."""


class EngineReplica:
    """One engine plus its cluster-membership state.

    ``alive`` replicas take new work and step; ``draining`` replicas step
    (finishing the handoff) but receive nothing new; ``failed`` replicas
    are unreachable — the router neither steps nor reads them (a crashed
    process's memory is gone; recovery uses the router's tickets) until
    detection moves them to ``dead``."""

    def __init__(self, replica_id: int, engine: Engine):
        self.replica_id = replica_id
        self.engine = engine
        self.state = "alive"     # alive | draining | failed | dead

    @property
    def reachable(self) -> bool:
        return self.state in ("alive", "draining")

    def load(self) -> Tuple[float, int]:
        """(queue-delay estimate, pages in use) — the least-loaded
        dispatch key.  The delay estimate (in engine steps) weighs queued
        prompt SIZE and decode occupancy, not just how many requests are
        waiting."""
        return (self.engine.queue_delay_estimate(),
                len(self.engine.pool.pages))

    def __repr__(self) -> str:
        return (f"EngineReplica(id={self.replica_id}, state={self.state}, "
                f"load={self.load()})")


class Router:
    """Engine-shaped front door over N replicas (see module docstring).

    ``engine_factory`` builds one fresh ``Engine`` per replica — replicas
    share model/params through the factory's closure but own private KV
    pools, prefix caches, and guidance runtimes.  On a one-replica cluster
    unknown attributes delegate to that engine (``router.pool``,
    ``router.prefix_cache``, ...), so single-engine tooling and tests keep
    working unchanged; with more replicas the same access raises a named
    ``AttributeError`` instead of silently picking one.
    """

    def __init__(self, engine_factory: Callable[[], Engine],
                 n_replicas: int = 1, heartbeat_timeout: float = 8.0,
                 clock: Optional[Callable[[], float]] = None):
        if n_replicas < 1:
            raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
        self.engine_factory = engine_factory
        self.replicas: List[EngineReplica] = []
        self._next_replica_id = 0
        self._ticks = 0
        # The injected clock defaults to router step ticks: heartbeat
        # timelines are then a pure function of the driving loop.
        self.clock = clock if clock is not None else lambda: float(self._ticks)
        self.monitor = HeartbeatMonitor(
            n_nodes=0, timeout_s=heartbeat_timeout, clock=self.clock)
        self.owner: Dict[int, EngineReplica] = {}
        self.tickets: Dict[int, RequestTicket] = {}
        # Finished results whose engine left the cluster before the caller
        # drained them — served by pop_finished like any other result.
        self._orphan_finished: Dict[int, Request] = {}
        self.lost_requests: Dict[int, str] = {}    # rid -> why
        # ----------------------------------------------------- counters
        self.migrations_warm = 0
        self.migrations_cold = 0
        self.failovers = 0
        self.restarts = 0
        self.requests_lost = 0
        for _ in range(n_replicas):
            self.add_replica()

    # ------------------------------------------------------- membership
    def add_replica(self) -> EngineReplica:
        """Grow the cluster by one fresh replica (ids are never reused, so
        a restarted replica is observably a new member)."""
        rep = EngineReplica(self._next_replica_id, self.engine_factory())
        self._next_replica_id += 1
        self.replicas.append(rep)
        self.monitor.add_node(rep.replica_id)
        return rep

    def _by_id(self, replica_id: int) -> EngineReplica:
        for rep in self.replicas:
            if rep.replica_id == replica_id:
                return rep
        raise ValueError(
            f"unknown replica {replica_id}: cluster members are "
            f"{[r.replica_id for r in self.replicas]}")

    def _alive(self) -> List[EngineReplica]:
        return [r for r in self.replicas if r.state == "alive"]

    def fail(self, replica_id: int) -> None:
        """Simulate a crash: the replica stops beating and stepping, and
        its memory becomes unreachable.  Detection (and cold recovery of
        its requests from the ticket ledger) happens in ``step()`` once
        the heartbeat timeout elapses — the failover window the chaos
        benchmark measures."""
        rep = self._by_id(replica_id)
        if not rep.reachable:
            raise ValueError(
                f"cannot fail replica {replica_id}: already {rep.state}")
        rep.state = "failed"

    def drain(self, replica_id: int) -> int:
        """Gracefully empty a reachable replica: warm-migrate every one of
        its requests to the alive survivors (cold fallback per request
        when a survivor's pool cannot take the pages), and move undrained
        finished results onto the router.  Returns the number of requests
        migrated; the replica is left empty in ``draining`` state —
        ``remove_replica`` completes a scale-down, ``restart_replica`` a
        rolling restart."""
        rep = self._by_id(replica_id)
        if not rep.reachable:
            raise ValueError(
                f"cannot drain replica {replica_id}: {rep.state}")
        rep.state = "draining"
        if not self._alive():
            rep.state = "alive"
            raise ValueError(
                f"cannot drain replica {replica_id}: no other alive "
                f"replica to take its requests (add_replica first)")
        for rid, req in rep.engine.pop_finished().items():
            self._orphan_finished[rid] = req
            t = self.tickets.get(rid)
            if t is not None:
                t.finish_reason = req.finish_reason
        moved = 0
        for rid in sorted(rep.engine.requests):
            self._migrate_from(rep, rid)
            moved += 1
        return moved

    def remove_replica(self, replica_id: int, migrate: bool = True) -> None:
        """Take a replica out of the cluster.  ``migrate=True`` drains it
        first (nothing is lost); ``migrate=False`` abandons whatever it
        still holds — those requests land in ``lost_requests`` and their
        handles raise ``ReplicaLostError``."""
        rep = self._by_id(replica_id)
        if rep.reachable:
            if migrate and rep.engine.requests and self._alive_except(rep):
                self.drain(replica_id)
            for rid, req in rep.engine.pop_finished().items():
                self._orphan_finished[rid] = req
            for rid in sorted(rep.engine.requests):
                self._mark_lost(
                    rid, f"replica {replica_id} was removed without "
                         f"migration")
        rep.state = "dead"
        self.replicas.remove(rep)
        self.monitor.remove_node(rep.replica_id)

    def restart_replica(self, replica_id: int) -> EngineReplica:
        """One rolling-restart move: drain -> remove -> add a fresh
        replica.  In-flight requests migrate to survivors (bitwise), and
        the replacement joins empty as the preferred dispatch target."""
        rep = self._by_id(replica_id)
        if rep.engine.requests and self._alive_except(rep):
            self.drain(replica_id)
        self.remove_replica(replica_id)
        self.restarts += 1
        return self.add_replica()

    def _alive_except(self, rep: EngineReplica) -> List[EngineReplica]:
        return [r for r in self._alive() if r is not rep]

    # --------------------------------------------------------- dispatch
    def _pick(self) -> EngineReplica:
        alive = self._alive()
        if not alive:
            raise ReplicaLostError(
                "no alive replica to dispatch to (all failed, draining, "
                "or removed)")
        return min(alive, key=lambda r: (*r.load(), r.replica_id))

    def add_request(self, request_id: int, prompt,
                    max_new: Optional[int] = None,
                    params=None, replica_id: Optional[int] = None) -> None:
        """Route one request to the least-loaded alive replica (or pin it
        with ``replica_id`` — tests and cache-affinity callers).  The
        ticket ledger entry is cut AFTER engine validation, so a rejected
        request leaves no cluster state behind."""
        rep = (self._by_id(replica_id) if replica_id is not None
               else self._pick())
        if rep.state != "alive":
            raise ValueError(
                f"cannot route request {request_id} to replica "
                f"{rep.replica_id}: {rep.state}")
        rep.engine.add_request(request_id, prompt, max_new=max_new,
                               params=params)
        req = rep.engine.requests.get(request_id)
        if req is None:                      # admitted straight to finished
            req = rep.engine.finished[request_id]
        self.tickets[request_id] = RequestTicket(
            request_id=request_id, prompt=list(req.tokens),
            max_new=req.max_new, params=req.params,
            generated=list(req.generated),
            finish_reason=req.finish_reason)
        self.owner[request_id] = rep

    # --------------------------------------------------------- stepping
    def step(self) -> Dict[int, int]:
        """One cluster step: beat + detect failures + recover, then step
        every reachable replica and fold the new tokens into the ticket
        ledger (the cold-recovery source of truth)."""
        self._ticks += 1
        for rep in self.replicas:
            if rep.reachable:
                self.monitor.beat(rep.replica_id)
        for nid in self.monitor.check_failures():
            rep = next((r for r in self.replicas if r.replica_id == nid),
                       None)
            if rep is not None and rep.state == "failed":
                self._recover(rep)
            else:
                # Spurious detection (e.g. an injected clock jumped):
                # the replica is still stepping — revive its monitor entry
                # rather than recovering requests that never stalled.
                self.monitor.dead.discard(nid)
        out: Dict[int, int] = {}
        for rep in self.replicas:
            if rep.reachable:
                out.update(rep.engine.step())
        for rid, tok in out.items():
            t = self.tickets.get(rid)
            if t is not None:
                t.generated.append(int(tok))
        for rep in self.replicas:
            if not rep.reachable:
                continue
            for rid, req in rep.engine.finished.items():
                t = self.tickets.get(rid)
                if t is not None and t.finish_reason is None:
                    t.finish_reason = req.finish_reason
                    t.generated = list(req.generated)
        return out

    # --------------------------------------------------------- recovery
    def _recover(self, rep: EngineReplica) -> None:
        """Cold failover after a detected crash: every request the dead
        replica owned is rebuilt on a survivor from its ticket — never by
        reading the dead engine."""
        rep.state = "dead"
        self.replicas.remove(rep)
        self.monitor.remove_node(rep.replica_id)
        self.failovers += 1
        mine = sorted(rid for rid, r in self.owner.items() if r is rep)
        for rid in mine:
            t = self.tickets.get(rid)
            if t is None:
                continue
            if t.finish_reason is not None:
                # Finished before the crash but never drained: the ticket
                # already holds the full stream — synthesize the result.
                self._orphan_finished[rid] = Request(
                    request_id=rid, tokens=list(t.prompt),
                    max_new=t.max_new, params=t.params,
                    generated=list(t.generated), state="finished",
                    finish_reason=t.finish_reason,
                    truncated=t.finish_reason == "truncated")
                self.owner.pop(rid, None)
                continue
            self._migrate_cold(rid, t)

    def _migrate_cold(self, rid: int, ticket: RequestTicket) -> None:
        alive = self._alive()
        if not alive:
            self._mark_lost(
                rid, f"request {rid}'s replica died with no alive replica "
                     f"left to rebuild it")
            return
        target = min(alive, key=lambda r: (*r.load(), r.replica_id))
        target.engine.import_request(ticket)
        self.owner[rid] = target
        self.migrations_cold += 1

    def _migrate_from(self, src: EngineReplica, rid: int) -> None:
        """Move one live request off a draining replica.  Warm (KV pages
        ride along, zero recompute) when it holds pages; cold (recompute
        from the ticket) when it holds none or the target cannot fit the
        pages.  Either way the stream is bitwise-unchanged; pause state
        does not survive — a migrated request resumes running."""
        ticket = src.engine.export_request(rid)
        t = self.tickets.get(rid)
        if t is not None:                 # the ledger tracks the handoff
            ticket = RequestTicket(
                request_id=rid, prompt=ticket.prompt,
                max_new=ticket.max_new, params=ticket.params,
                generated=list(ticket.generated))
            self.tickets[rid] = ticket
        pages = src.engine.pool.request_pages(rid)
        if src.engine.requests[rid].state == "prefilling":
            # A mid-ingest request's pages are only written up to ``pos``;
            # the warm path would resume decode as if the whole prompt were
            # in KV.  Cold recompute re-prefills it correctly (and bitwise).
            pages = []
        target = self._pick()
        if pages:
            export = src.engine.pool.export_pages(
                [p.page_id for p in pages])
            try:
                target.engine.import_request(ticket, kv=export)
                self.migrations_warm += 1
            except MemoryError:
                target.engine.import_request(ticket)
                self.migrations_cold += 1
        else:
            target.engine.import_request(ticket)
            self.migrations_cold += 1
        src.engine.remove_request(rid)
        self.owner[rid] = target

    def _mark_lost(self, rid: int, why: str) -> None:
        self.lost_requests[rid] = why
        self.owner.pop(rid, None)
        self.tickets.pop(rid, None)
        self.requests_lost += 1

    # ------------------------------------------------- engine-shaped API
    @property
    def requests(self) -> Dict[int, Request]:
        """Merged live-request view.  A failed-but-undetected replica's
        requests stay visible (the router has not noticed the crash yet);
        they disappear at detection and reappear on their new owner."""
        out: Dict[int, Request] = {}
        for rep in self.replicas:
            if rep.state != "dead":
                out.update(rep.engine.requests)
        return out

    @property
    def finished(self) -> Dict[int, Request]:
        out = dict(self._orphan_finished)
        for rep in self.replicas:
            if rep.reachable:
                out.update(rep.engine.finished)
        return out

    def pop_finished(self, request_id: Optional[int] = None):
        """Drain finished results across the cluster (orphans included)."""
        if request_id is not None:
            if request_id in self._orphan_finished:
                req = self._orphan_finished.pop(request_id)
            else:
                rep = self.owner.get(request_id)
                if rep is None or not rep.reachable:
                    raise KeyError(request_id)
                req = rep.engine.pop_finished(request_id)
            self.tickets.pop(request_id, None)
            self.owner.pop(request_id, None)
            return req
        out, self._orphan_finished = self._orphan_finished, {}
        for rep in self.replicas:
            if rep.reachable:
                out.update(rep.engine.pop_finished())
        for rid in out:
            self.tickets.pop(rid, None)
            self.owner.pop(rid, None)
        return out

    def _owner_or_raise(self, request_id: int, verb: str) -> EngineReplica:
        if request_id in self.lost_requests:
            raise ReplicaLostError(
                f"cannot {verb} request {request_id}: "
                f"{self.lost_requests[request_id]}")
        rep = self.owner.get(request_id)
        if rep is None:
            raise ValueError(
                f"cannot {verb} request {request_id}: unknown id")
        if not rep.reachable:
            raise ReplicaLostError(
                f"cannot {verb} request {request_id}: its replica "
                f"{rep.replica_id} is unreachable (failover pending)")
        return rep

    def pause(self, request_id: int) -> None:
        self._owner_or_raise(request_id, "pause").engine.pause(request_id)

    def resume(self, request_id: int) -> None:
        self._owner_or_raise(request_id, "resume").engine.resume(request_id)

    def cancel(self, request_id: int) -> None:
        """Withdraw a live request on its owning replica.  The ticket is
        stamped immediately, so a crash between cancel and drain still
        resolves to a ``cancelled`` result rather than a recompute."""
        rep = self._owner_or_raise(request_id, "cancel")
        req = rep.engine.cancel(request_id)
        t = self.tickets.get(request_id)
        if t is not None:
            t.finish_reason = req.finish_reason
            t.generated = list(req.generated)

    def stats(self) -> Dict[str, float]:
        """Cluster-aggregate engine counters (summed over reachable
        replicas, with the prefix hit rate recomputed from the summed
        components) plus ``cluster_*`` lifecycle counters.  At N=1 this is
        the single engine's stats dict plus the cluster scalars."""
        agg: Dict[str, float] = {}
        for rep in self.replicas:
            if not rep.reachable:
                continue
            # No float cast: summing preserves each counter's own type, so
            # int counters stay ints (pre-cluster consumers %d-format them).
            for k, v in rep.engine.stats().items():
                agg[k] = agg.get(k, 0) + v
        # Per-replica means do not sum; report the replica average (the
        # same ``mean_`` convention serving_summary applies).
        n_reachable = sum(1 for r in self.replicas if r.reachable)
        if n_reachable > 1:
            for k in agg:
                if "mean_" in k:
                    agg[k] = agg[k] / n_reachable
        if agg.get("prefix_lookups"):
            agg["prefix_hit_rate"] = (agg["prefix_hit_requests"]
                                      / agg["prefix_lookups"])
        agg.update({
            "cluster_replicas": sum(
                1 for r in self.replicas if r.reachable),
            "cluster_migrations_warm": self.migrations_warm,
            "cluster_migrations_cold": self.migrations_cold,
            "cluster_failovers": self.failovers,
            "cluster_restarts": self.restarts,
            "cluster_requests_lost": self.requests_lost,
        })
        return agg

    def __getattr__(self, name: str):
        # Single-replica transparency: `.pool`, `.prefix_cache`,
        # `.runtime`, `.prefill_dispatches`, `._preempt_one`, ... resolve
        # to the sole reachable engine so Engine-era tooling (tests drive
        # internals like `_preempt_one` directly) works unchanged at N=1.
        # Dunders never delegate: protocol probes (pickle, copy, ipython)
        # must see the Router's own absence, not an engine method.
        if name.startswith("__"):
            raise AttributeError(name)
        reps = self.__dict__.get("replicas") or []
        live = [r for r in reps if r.reachable]
        if len(live) == 1:
            return getattr(live[0].engine, name)
        raise AttributeError(
            f"Router has no attribute {name!r} and cannot delegate it: "
            f"{len(live)} reachable replicas (single-engine attributes "
            f"are only transparent on a one-replica cluster; address "
            f"router.replicas[i].engine.{name} explicitly)")
