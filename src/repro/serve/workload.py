"""Deterministic traffic synthesis and trace replay for the serving stack.

The paper's claim — online guidance converges to offline-profile quality
"after a short startup period" — is only falsifiable under live, bursty,
multi-tenant load.  This module generates that load and replays it against
an ``LLM`` with NO wall-clock anywhere (rule FT01) and every random draw
from one seeded generator (rule SCHED01): the same ``WorkloadConfig`` seed
always yields the same ``Trace``, and replaying a trace against the same
engine config always schedules, samples, and scores identically.

Three layers:

* ``synthesize(WorkloadConfig) -> Trace`` — per-tenant arrival processes
  (Poisson, or bursty on/off-modulated Poisson) on the engine's step-tick
  clock, with categorical prompt/output length mixtures.  A ``Trace`` is
  plain data (JSON-serializable, versioned) — captured production traffic
  can be replayed through the same door.
* ``TraceReplayer`` — drives an ``LLM`` step by step, submitting each
  trace request at its arrival step and recording when its first token and
  finish land.  Time is measured two ways at once: in engine steps
  (exact), and in *modeled milliseconds* via ``StepCostModel`` — a
  deterministic linear cost per step (base + prefill tokens + decode
  tokens, like core's ``modeled_swap_seconds``) that makes a 256-token
  one-shot prefill stall VISIBLE as a p99 inter-token spike without
  letting host timing noise into CI.
* ``ReplayReport`` — per-request TTFT/TPOT in both time domains plus
  goodput-under-SLO (fraction of requests finishing with TTFT and TPOT
  inside the ``SLO`` bounds), per tenant or overall.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .sampling import SamplingParams

TRACE_VERSION = 1


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant's traffic shape.

    arrival: ``poisson`` (rate per step) or ``bursty`` — the Poisson rate
      is modulated by an on/off square wave: ``rate * burst_factor``
      during the on phase (``burst_duty`` of each ``burst_period`` steps),
      0 in the off phase.
    prompt_mix / output_mix: categorical ``((length, weight), ...)``
      mixtures; lengths in tokens.
    priority / deadline_steps / temperature: stamped onto each request's
      ``SamplingParams``.
    """

    name: str
    arrival: str = "poisson"          # poisson | bursty
    rate: float = 0.2                 # mean arrivals per engine step
    burst_factor: float = 8.0
    burst_period: int = 32
    burst_duty: float = 0.25
    priority: int = 0
    prompt_mix: Tuple[Tuple[int, float], ...] = ((8, 1.0),)
    output_mix: Tuple[Tuple[int, float], ...] = ((8, 1.0),)
    deadline_steps: Optional[int] = None
    temperature: float = 0.0

    def __post_init__(self):
        if self.arrival not in ("poisson", "bursty"):
            raise ValueError(
                f"TenantSpec.arrival must be 'poisson' or 'bursty', got "
                f"{self.arrival!r}")
        if not (0.0 < self.burst_duty <= 1.0):
            raise ValueError(
                f"burst_duty must be in (0, 1], got {self.burst_duty}")


@dataclasses.dataclass(frozen=True)
class WorkloadConfig:
    tenants: Tuple[TenantSpec, ...]
    horizon_steps: int = 128          # arrival window, in engine steps
    vocab: int = 256                  # prompt tokens drawn from [0, vocab)
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class TraceRequest:
    """One arrival: everything ``LLM.submit`` needs, plus the step it
    lands.  ``seed`` is explicit (== request id) so the sampled stream is
    pinned by the trace itself, not by replay-side id assignment."""

    request_id: int
    arrival_step: int
    tenant: str
    priority: int
    prompt: Tuple[int, ...]
    max_tokens: int
    seed: int
    temperature: float = 0.0
    deadline_steps: Optional[int] = None

    def sampling_params(self) -> SamplingParams:
        return SamplingParams(
            temperature=self.temperature, seed=self.seed,
            max_tokens=self.max_tokens, priority=self.priority,
            tenant=self.tenant, deadline_steps=self.deadline_steps)


@dataclasses.dataclass
class Trace:
    """An ordered arrival sequence (by step, then request id)."""

    requests: List[TraceRequest]
    version: int = TRACE_VERSION

    def __len__(self) -> int:
        return len(self.requests)

    def to_json(self) -> str:
        return json.dumps({
            "version": self.version,
            "requests": [dataclasses.asdict(r) for r in self.requests],
        }, indent=1)

    @classmethod
    def from_json(cls, text: str) -> "Trace":
        obj = json.loads(text)
        if obj.get("version") != TRACE_VERSION:
            raise ValueError(
                f"trace version {obj.get('version')!r} != "
                f"{TRACE_VERSION} (regenerate the trace)")
        reqs = []
        for row in obj["requests"]:
            row = dict(row)
            row["prompt"] = tuple(row["prompt"])
            reqs.append(TraceRequest(**row))
        return cls(requests=reqs)


def _draw_mix(rng: np.random.Generator,
              mix: Sequence[Tuple[int, float]]) -> int:
    values = [int(v) for v, _ in mix]
    weights = np.asarray([w for _, w in mix], dtype=np.float64)
    return values[int(rng.choice(len(values), p=weights / weights.sum()))]


def synthesize(cfg: WorkloadConfig) -> Trace:
    """Deterministically expand a workload spec into a concrete trace.

    One seeded generator drives everything; tenants are visited in spec
    order at each step, so the draw sequence (hence the trace) is a pure
    function of ``cfg``."""
    rng = np.random.default_rng(cfg.seed)
    requests: List[TraceRequest] = []
    rid = 0
    for step in range(cfg.horizon_steps):
        for spec in cfg.tenants:
            rate = spec.rate
            if spec.arrival == "bursty":
                on = (step % spec.burst_period) < (spec.burst_period
                                                   * spec.burst_duty)
                rate = spec.rate * spec.burst_factor if on else 0.0
            n = int(rng.poisson(rate)) if rate > 0 else 0
            for _ in range(n):
                n_prompt = max(_draw_mix(rng, spec.prompt_mix), 1)
                n_out = max(_draw_mix(rng, spec.output_mix), 1)
                prompt = tuple(
                    int(t) for t in rng.integers(0, cfg.vocab, n_prompt))
                requests.append(TraceRequest(
                    request_id=rid, arrival_step=step, tenant=spec.name,
                    priority=spec.priority, prompt=prompt,
                    max_tokens=n_out, seed=rid % (2 ** 31),
                    temperature=spec.temperature,
                    deadline_steps=spec.deadline_steps))
                rid += 1
    return Trace(requests=requests)


@dataclasses.dataclass(frozen=True)
class StepCostModel:
    """Deterministic modeled wall time for one engine step: the fixed
    dispatch overhead plus linear costs for the prompt tokens ingested and
    the decode tokens produced that step.  Coefficients are deliberately
    round numbers — the model exists to expose SCHEDULING effects (a
    one-shot 256-token prefill makes one step 50x longer; interleaving
    amortizes it) deterministically, not to predict a specific TPU."""

    base_ms: float = 1.0
    prefill_ms_per_token: float = 0.2
    decode_ms_per_token: float = 0.5

    def step_ms(self, prefill_tokens: int, decode_tokens: int) -> float:
        return (self.base_ms
                + self.prefill_ms_per_token * prefill_tokens
                + self.decode_ms_per_token * decode_tokens)


@dataclasses.dataclass(frozen=True)
class SLO:
    """Per-request service objective in MODELED milliseconds: time to
    first token, and the worst single inter-token gap."""

    ttft_ms: float = 200.0
    tpot_ms: float = 50.0


@dataclasses.dataclass
class RequestMetrics:
    request_id: int
    tenant: str
    arrival_step: int
    arrival_ms: float = 0.0           # modeled clock at submit
    first_token_step: Optional[int] = None
    finish_step: Optional[int] = None
    finish_reason: Optional[str] = None
    n_tokens: int = 0
    # Modeled ms from ARRIVAL to first token (queueing included).
    ttft_ms: Optional[float] = None
    # Worst single inter-token gap (p100 TPOT) — the stall metric a
    # monopolizing prefill inflates.
    max_tpot_ms: Optional[float] = None
    mean_tpot_ms: Optional[float] = None

    @property
    def ttft_steps(self) -> Optional[int]:
        if self.first_token_step is None:
            return None
        return self.first_token_step - self.arrival_step


def _pct(values: Sequence[float], q: float) -> float:
    if not values:
        return float("nan")
    return float(np.percentile(np.asarray(values, dtype=np.float64), q))


@dataclasses.dataclass
class ReplayReport:
    """Everything a replay produced, plus summary reducers."""

    metrics: Dict[int, RequestMetrics]
    steps_run: int
    modeled_ms: float
    token_ids: Dict[int, List[int]]   # per-request sampled streams

    def _rows(self, tenant: Optional[str]) -> List[RequestMetrics]:
        return [m for m in self.metrics.values()
                if tenant is None or m.tenant == tenant]

    def summary(self, tenant: Optional[str] = None,
                slo: Optional[SLO] = None) -> Dict[str, float]:
        rows = self._rows(tenant)
        done = [m for m in rows if m.finish_step is not None]
        ttft = [m.ttft_ms for m in rows if m.ttft_ms is not None]
        tpot = [m.max_tpot_ms for m in rows if m.max_tpot_ms is not None]
        out = {
            "requests": float(len(rows)),
            "finished": float(len(done)),
            "p50_ttft_ms": _pct(ttft, 50),
            "p99_ttft_ms": _pct(ttft, 99),
            "p50_tpot_ms": _pct(tpot, 50),
            "p99_tpot_ms": _pct(tpot, 99),
        }
        if slo is not None:
            good = [m for m in done
                    if m.ttft_ms is not None and m.ttft_ms <= slo.ttft_ms
                    and (m.max_tpot_ms is None
                         or m.max_tpot_ms <= slo.tpot_ms)]
            out["goodput_slo"] = (len(good) / len(rows)) if rows else 0.0
        return out


class TraceReplayer:
    """Drive an ``LLM`` through a trace on the engine's step clock.

    Each loop iteration submits the requests arriving at the current step,
    advances the engine one step, charges the ``StepCostModel`` with the
    prompt tokens ingested and decode tokens produced by that step (both
    read off engine counters — eager admission prefill included), and
    timestamps first-token/finish events in both time domains."""

    def __init__(self, llm, trace: Trace,
                 cost: Optional[StepCostModel] = None,
                 slo: Optional[SLO] = None):
        self.llm = llm
        self.trace = trace
        self.cost = cost if cost is not None else StepCostModel()
        self.slo = slo if slo is not None else SLO()

    def run(self, max_steps: int = 4096) -> ReplayReport:
        llm = self.llm
        by_step: Dict[int, List[TraceRequest]] = {}
        for tr in self.trace.requests:
            by_step.setdefault(tr.arrival_step, []).append(tr)
        horizon = max(by_step) if by_step else 0
        metrics: Dict[int, RequestMetrics] = {}
        handles: Dict[int, object] = {}
        token_ms: Dict[int, List[float]] = {}
        clock_ms = 0.0
        step = 0
        live = True
        while step <= horizon or (live and step < max_steps):
            # Eager admission prefill happens INSIDE submit, so the ingest
            # counter snapshots BEFORE the submits: the whole iteration's
            # ingest (admission + interleaved chunks) charges this step.
            before = llm.stats()["prefill_tokens"]
            for tr in by_step.get(step, ()):
                metrics[tr.request_id] = RequestMetrics(
                    request_id=tr.request_id, tenant=tr.tenant,
                    arrival_step=step, arrival_ms=clock_ms)
                handles[tr.request_id] = llm.submit(
                    list(tr.prompt), tr.sampling_params(),
                    request_id=tr.request_id)
            out = llm.step()
            after = llm.stats()["prefill_tokens"]
            clock_ms += self.cost.step_ms(int(after - before), len(out))
            step += 1
            for rid in out:
                m = metrics.get(rid)
                if m is None:
                    continue
                token_ms.setdefault(rid, []).append(clock_ms)
                if m.first_token_step is None:
                    m.first_token_step = step
                    m.ttft_ms = clock_ms - m.arrival_ms
            for h in handles.values():
                m = metrics[h.request_id]
                if m.finish_step is None and h.finished:
                    m.finish_step = step
                    m.finish_reason = h.finish_reason
                    m.n_tokens = len(h.token_ids)
            live = any(not h.finished for h in handles.values())
        for rid, stamps in token_ms.items():
            m = metrics[rid]
            if len(stamps) > 1:
                gaps = [b - a for a, b in zip(stamps, stamps[1:])]
                m.max_tpot_ms = max(gaps)
                m.mean_tpot_ms = sum(gaps) / len(gaps)
        # Arrival-side prefill accounting means submits before the FIRST
        # step are charged to that step; the counters make the charge
        # explicit rather than silently dropping it.
        return ReplayReport(
            metrics=metrics, steps_run=step, modeled_ms=clock_ms,
            token_ids={rid: list(h.token_ids)
                       for rid, h in handles.items()})
