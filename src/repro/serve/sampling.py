"""Per-request sampling parameters for the generation API.

``SamplingParams`` is the user-facing half of the sampling-determinism
contract (DESIGN.md §10): everything that influences the sampled stream is
carried here per request, and the engine threads it into the jitted decode
dispatch as batched arrays — sampling itself runs on-device
(``kernels.ops.sample_tokens``), never as host-side post-processing.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# Generation budget when neither ``SamplingParams.max_tokens`` nor the
# engine caller's ``max_new`` says otherwise — owned here, resolved in ONE
# place (``Engine.add_request``).
DEFAULT_MAX_TOKENS = 16


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """How one request decodes.

    temperature: 0.0 (the default) is greedy argmax, bitwise-equal to the
      pre-sampling engine; > 0 samples via seeded Gumbel-max.
    top_k: keep only the k most probable tokens (0 disables).
    top_p: nucleus filter — keep the smallest probability-sorted prefix
      covering ``top_p`` mass (1.0 disables; the argmax token always
      survives).
    seed: per-request PRNG seed; the per-token key is
      ``fold_in(PRNGKey(seed), position)``, so replay-by-recompute and
      one-shot-vs-chunked prefill resample identically.  ``None`` (the
      default) derives the seed from the request id — identical prompts
      submitted as different requests sample independent streams, while
      each request's own stream stays exactly replayable.
    stop_token_ids: finish with ``finish_reason="stop"`` when a sampled
      token is in this set (an EOS id is just a stop token).  The stop
      token is included in the generated stream.
    max_tokens: generation budget; ``None`` defers to the engine caller's
      ``max_new``.  Exhausting it finishes with ``finish_reason="length"``.

    The last three fields are SCHEDULING metadata, not sampling knobs:
    they never enter the jitted dispatch (streams are bitwise-identical
    whatever they say — scheduling moves WHEN tokens appear, never WHICH),
    but they ride inside ``RequestTicket``s so a migrated request keeps
    its class, tenant, and deadline on the destination replica.

    priority: strict scheduling class for the ``priority`` policy (higher
      = served sooner); other policies ignore it.
    tenant: fairness bucket for the ``drr`` policy (deficit-round-robin
      shares service across tenants, not requests).
    deadline_steps: optional SLO deadline, in engine steps from enqueue;
      the ``priority`` policy orders earliest-deadline-first within a
      class, and the workload replayer scores goodput against it.
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: Optional[int] = None
    stop_token_ids: Tuple[int, ...] = ()
    max_tokens: Optional[int] = None
    priority: int = 0
    tenant: str = "default"
    deadline_steps: Optional[int] = None

    def __post_init__(self):
        if self.temperature < 0.0:
            raise ValueError(f"temperature must be >= 0, got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if not (0.0 < self.top_p <= 1.0):
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if self.max_tokens is not None and self.max_tokens < 1:
            raise ValueError(f"max_tokens must be >= 1, got {self.max_tokens}")
        if self.deadline_steps is not None and self.deadline_steps < 1:
            raise ValueError(
                f"deadline_steps must be None or >= 1, got "
                f"{self.deadline_steps}")
        if self.seed is not None and not (0 <= self.seed < 2**31):
            # The seed rides into the jitted dispatch as an int32 row; a
            # silently-wrapped 64-bit seed would collide streams that the
            # caller believes are distinct.
            raise ValueError(
                f"seed must be None or in [0, 2**31), got {self.seed}")
        # Normalize so engine membership checks and dataclass equality are
        # stable however the caller spelled the set.
        object.__setattr__(self, "stop_token_ids",
                           tuple(int(t) for t in self.stop_token_ids))

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0
