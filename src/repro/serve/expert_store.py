"""Tiered MoE expert-weight store: guided expert tiering (ROADMAP item 1).

The third tier-object class under the paper's online-guidance loop, after
KV pages (PR 4) and shared prefixes (PR 6).  MoE expert FFN blocks are the
largest tier-able objects in the system; keeping only a bounded cache of
them in HBM opens larger-than-HBM model configs on the same hardware.

Three layers live here:

* **ExpertStore** — owns per-(layer, expert) weight blocks.  The host tier
  is the authoritative, immutable copy of every block, flattened to
  ``(n_layers * n_experts, ...)`` arrays on a pinned-host sharding; the
  HBM tier is a bounded ``cache_slots``-row cache shared by all layers.
  Movement mirrors ``PagedKVPool.swap_in_many``: one gather + one staged
  ``jax.device_put`` + one scatter per weight array per direction, never a
  per-block loop.  Expert weights never change, so *demotion is
  metadata-only* — the slot is released and the host copy stays
  authoritative; ``bytes_demoted`` counts the logical bytes leaving the
  fast tier.

* **Double-buffered prefetch** — while layer L's grouped GEMM dispatch is
  in flight, the predicted working set for the next layer is staged onto
  the device on a second buffer (``prefetch``), and the cache scatter is
  committed when that layer actually dispatches (``_commit_pending``).
  Prediction fuses recency (that layer's previous dispatch) with the
  guidance profile (hottest non-resident blocks by access count — the
  same counters the controller consumes).  A misprediction falls back to
  the blocking demand fetch, so results are bitwise-identical with the
  prefetcher on or off.

* **ExpertBackend** — the ``TierBackend`` face: arena = one layer's expert
  population, chunk = one expert block.  Per-dispatch ``group_sizes`` from
  ``route_tokens`` double as the access profile (no extra
  instrumentation); ski-rental decides promote/demote; blocks named in the
  most recent dispatch of their layer never demote (the
  never-demote-while-dispatching rule), because the slot map handed to an
  in-flight grouped GEMM must keep meaning what it said.

Correctness bar: the cache-slot indirection rides the grouped GEMM's
existing ``group_experts`` remap (``models/moe.apply_dropless_flat``), so
any dispatch whose working set fits the cache is bitwise-equal to the
fully-resident path.  A working set that cannot fit raises
``ExpertCacheMissError`` naming ``ServeConfig.expert_cache_size`` — never
a silent wrong-weight dispatch.

Modeled decode time: the engine cannot observe real PCIe overlap on a CPU
test host, so the store accumulates a deterministic modeled clock in the
``StepCostModel`` idiom (deliberately round constants): each dispatch adds
its weight-read time at fast-tier bandwidth to ``m_compute_s``; a blocking
demand fetch adds transfer time at slow-tier bandwidth plus a fixed launch
latency to ``m_blocked_s``; a committed prefetch adds only the part of
that cost the overlap window (the previous dispatch's compute plus two
dispatch launches) could not hide.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import MigrationPlan, MoveStats
from ..core.fragmentation import ChunkStats
from ..core.hwmodel import TPU_V5E, HardwareModel
from ..core.profiler import ArenaProfile, IntervalProfile
from .kvcache import DEVICE_KIND, HOST_KIND

# Modeled-time constants (StepCostModel idiom: deliberately round numbers
# so overlap effects are deterministic on any host).
FETCH_LATENCY_S = 10e-6       # per batched host->HBM staged transfer
DISPATCH_OVERHEAD_S = 20e-6   # per jitted dispatch launch

_WEIGHTS = ("w_gate", "w_up", "w_down")


class ExpertCacheMissError(RuntimeError):
    """A dispatch's expert working set cannot fit the HBM expert cache.

    Raised *before* any grouped GEMM runs with an incomplete slot map —
    the tiered path never silently dispatches against wrong weights.  The
    message names the knob (``ServeConfig.expert_cache_size``)."""


@dataclasses.dataclass
class ExpertBlock:
    """Tier state for one (layer, expert) FFN weight block."""

    layer: int
    expert: int
    slot: Optional[int] = None    # HBM cache row, None = host-only
    accesses: float = 0.0         # routed-token count (decayed by reweight)
    birth_step: int = 0
    last_used: int = -1           # step of the last dispatch that read it
    fetches: int = 0


@dataclasses.dataclass
class _PendingFetch:
    """One in-flight double-buffer: blocks staged on device, scatter
    deferred until the target layer dispatches."""

    layer: int
    experts: List[int]
    slots: List[int]
    staged: Tuple[jax.Array, ...]
    hide_s: float                 # modeled overlap window at issue time


class ExpertStore:
    """Host-authoritative expert weights with a bounded HBM cache.

    ``moe_params`` is the engine's stacked MoE param dict —
    ``w_gate/w_up/w_down`` shaped ``(n_layers, E, ...)``.  The store takes
    bitwise copies into its own tier layout; the caller may drop its dense
    resident arrays afterwards.
    """

    def __init__(self, moe_params: Mapping[str, jax.Array], n_layers: int,
                 n_experts: int, cache_slots: int, *,
                 double_buffer: bool = True, hw: HardwareModel = TPU_V5E,
                 window_bytes: int = 0):
        if cache_slots <= 0:
            raise ValueError(
                f"ExpertStore needs at least one cache slot, got "
                f"{cache_slots} (ServeConfig.expert_cache_size)")
        L, E = n_layers, n_experts
        self.n_layers = L
        self.n_experts = E
        self.cache_slots = min(cache_slots, L * E)
        self.double_buffer = double_buffer
        self.hw = hw
        self.window_bytes = int(window_bytes)

        dev = jax.devices()[0]
        kinds: List[str] = []
        # Capability probe, as in PagedKVPool: jaxlibs without memory-kind
        # support either lack the method or refuse it; both mean one tier.
        try:
            kinds = [m.kind for m in dev.addressable_memories()]
        except (AttributeError, RuntimeError, NotImplementedError):
            pass
        self._dev_sharding = jax.sharding.SingleDeviceSharding(
            dev, memory_kind=DEVICE_KIND if DEVICE_KIND in kinds else None)
        self._host_sharding = (
            jax.sharding.SingleDeviceSharding(dev, memory_kind=HOST_KIND)
            if HOST_KIND in kinds else self._dev_sharding)

        self.block_bytes = 0
        for name in _WEIGHTS:
            w = moe_params[name]
            assert w.shape[:2] == (L, E), (name, w.shape, L, E)
            flat = jnp.reshape(w, (L * E,) + w.shape[2:])
            setattr(self, name + "_host",
                    jax.device_put(flat, self._host_sharding))
            setattr(self, name + "_cache", jax.device_put(
                jnp.zeros((self.cache_slots,) + w.shape[2:], w.dtype),
                self._dev_sharding))
            self.block_bytes += int(np.prod(w.shape[2:])) * w.dtype.itemsize

        self.blocks: Dict[Tuple[int, int], ExpertBlock] = {
            (l, e): ExpertBlock(l, e) for l in range(L) for e in range(E)}
        self._free: List[int] = list(range(self.cache_slots))
        self._owner: List[Optional[Tuple[int, int]]] = (
            [None] * self.cache_slots)
        self._pinned: FrozenSet[int] = frozenset()   # last dispatch's slots
        self._reserved: set = set()                  # pending-prefetch slots
        self._pending: Dict[int, _PendingFetch] = {}
        self._last_prefetched: Dict[int, FrozenSet[int]] = {}
        self.dispatching: Dict[int, FrozenSet[int]] = {}
        self.prev_needed: Dict[int, List[int]] = {}
        self._last_window_s = 0.0
        self._rental_bytes = 0
        self.reset_counters()

    # ------------------------------------------------------------ identity
    def chunk_id(self, layer: int, expert: int) -> int:
        return layer * self.n_experts + expert

    def from_chunk(self, cid: int) -> Tuple[int, int]:
        return divmod(cid, self.n_experts)

    def is_resident(self, layer: int, expert: int) -> bool:
        return self.blocks[(layer, expert)].slot is not None

    def resident_count(self) -> int:
        return self.cache_slots - len(self._free) - len(self._reserved)

    @property
    def cache_bytes(self) -> int:
        return self.cache_slots * self.block_bytes

    # ------------------------------------------------------------ counters
    def reset_counters(self) -> None:
        self.demand_fetches = 0
        self.prefetch_fetches = 0
        self.prefetch_hits = 0
        self.dropped_prefetches = 0
        self.evictions = 0
        self.bytes_fetched = 0
        self.transfer_events = 0
        self.m_compute_s = 0.0
        self.m_blocked_s = 0.0

    def take_rental_bytes(self) -> int:
        """Drain bytes demand-fetched since the last drain — the engine
        feeds them to ``GuidanceRuntime.record_rental`` (slow-tier rent
        actually paid, the ski-rental input), mirroring KV swap-ins."""
        nb, self._rental_bytes = self._rental_bytes, 0
        return nb

    # ------------------------------------------------------------ movement
    def _transfer(self, pairs: Sequence[Tuple[int, int]]):
        """ONE batched host->device stage per weight array: gather the
        flattened host rows, land them on the device sharding.  Returns the
        staged arrays; the cache scatter happens at `_install`."""
        idx = jnp.asarray(
            [l * self.n_experts + e for l, e in pairs], dtype=jnp.int32)
        staged = []
        for name in _WEIGHTS:
            host = getattr(self, name + "_host")
            rows = np.asarray(jax.device_get(jnp.take(host, idx, axis=0)))
            staged.append(jax.device_put(rows, self._dev_sharding))
            self.transfer_events += 1
        self.bytes_fetched += self.block_bytes * len(pairs)
        return tuple(staged)

    def _install(self, pairs: Sequence[Tuple[int, int]], slots: Sequence[int],
                 staged, step: int, *, prefetched: bool) -> None:
        dst = jnp.asarray(list(slots), dtype=jnp.int32)
        for name, rows in zip(_WEIGHTS, staged):
            cache = getattr(self, name + "_cache")
            setattr(self, name + "_cache", cache.at[dst].set(rows))
        for (l, e), s in zip(pairs, slots):
            b = self.blocks[(l, e)]
            b.slot = int(s)
            b.last_used = step
            b.fetches += 1
            self._owner[int(s)] = (l, e)
        if prefetched:
            self.prefetch_fetches += len(pairs)
        else:
            self.demand_fetches += len(pairs)
            self._rental_bytes += self.block_bytes * len(pairs)

    def _evict(self, block: ExpertBlock) -> int:
        """Metadata-only demotion: the host copy is authoritative and
        immutable, so no bytes move back."""
        s = block.slot
        assert s is not None
        block.slot = None
        self._owner[s] = None
        self.evictions += 1
        return s

    def _evictable(self, protect: FrozenSet[int]) -> List[ExpertBlock]:
        out = [b for b in self.blocks.values()
               if b.slot is not None and b.slot not in protect
               and b.slot not in self._reserved]
        # LRU with a total deterministic order.
        out.sort(key=lambda b: (b.last_used, b.layer, b.expert))
        return out

    def _acquire_slots(self, n: int, protect: FrozenSet[int]) -> List[int]:
        """Take ``n`` cache slots: free list first, then LRU eviction of
        unprotected residents.  Returns fewer than ``n`` when the cache is
        too pinned — callers decide whether that is an error."""
        slots: List[int] = []
        while self._free and len(slots) < n:
            slots.append(self._free.pop())
        if len(slots) < n:
            for b in self._evictable(protect)[:n - len(slots)]:
                slots.append(self._evict(b))
        return slots

    # ------------------------------------------------------------ prefetch
    def _commit_pending(self, layer: int, step: int) -> None:
        pend = self._pending.pop(layer, None)
        if pend is None:
            return
        self._reserved.difference_update(pend.slots)
        pairs = [(layer, e) for e in pend.experts]
        self._install(pairs, pend.slots, pend.staged, step, prefetched=True)
        cost = (len(pairs) * self.block_bytes
                / (self.hw.slow.read_bw_GBps * 1e9) + FETCH_LATENCY_S)
        self.m_blocked_s += max(0.0, cost - pend.hide_s)
        self._last_prefetched[layer] = frozenset(pend.experts)

    def prefetch(self, layer: int, step: int,
                 predicted: Optional[Sequence[int]] = None) -> int:
        """Issue the double-buffer for ``layer``: stage its predicted
        working set onto the device while the current dispatch computes.

        ``predicted`` is the engine's speculative-gating forecast (the
        layer's own router applied to the residual stream one attention
        delta early, hottest first) — when given, exactly its non-resident
        members are staged.  Without it (the wrap-around to the next
        step's first dispatch, whose input token does not exist yet) the
        store falls back to recency + the guidance profile's hottest
        blocks.  Returns the number of blocks put in flight."""
        if not self.double_buffer or layer in self._pending:
            return 0
        if predicted is not None:
            want_set = [int(e) for e in predicted]
            targets = [e for e in want_set if not self.is_resident(layer, e)]
        else:
            prev = self.prev_needed.get(layer)
            if not prev:
                return 0                  # never dispatched: no prediction
            want = len(prev)
            # Recency first (last dispatch of this layer), then the layer's
            # hottest blocks by profile access count — guided prefetch.
            ranked = sorted(
                (self.blocks[(layer, e)] for e in range(self.n_experts)
                 if e not in prev),
                key=lambda b: (-b.accesses, b.expert))
            want_set = list(prev)
            targets = [e for e in prev if not self.is_resident(layer, e)]
            targets += [b.expert for b in ranked
                        if b.slot is None][:max(want - len(targets), 0)]
            targets = targets[:want]
        if not targets:
            return 0
        # A prefetch must not evict what it is predicting around: protect
        # the current dispatch's pins AND the predicted set's already-
        # resident members (evicting those would turn forecast hits into
        # the very demand misses the buffer exists to avoid).
        protect = self._pinned | frozenset(
            self.blocks[(layer, e)].slot for e in want_set
            if self.blocks[(layer, e)].slot is not None)
        slots = self._acquire_slots(len(targets), protect)
        if len(slots) < len(targets):
            self.dropped_prefetches += len(targets) - len(slots)
            targets = targets[:len(slots)]
        if not targets:
            return 0
        staged = self._transfer([(layer, e) for e in targets])
        self._reserved.update(slots)
        self._pending[layer] = _PendingFetch(
            layer, targets, slots, staged, self._last_window_s)
        return len(targets)

    # ------------------------------------------------------------ dispatch
    def dispatch(self, layer: int, counts, step: int) -> np.ndarray:
        """Make ``layer``'s routed experts resident and return the (E,)
        slot map for the grouped GEMM (−1 for absent-and-unrouted blocks).

        ``counts`` is the dispatch's per-expert routed-token histogram —
        the ``group_sizes`` the GEMM consumes anyway, doubling as the
        access profile.  Order of operations: commit any in-flight
        prefetch for this layer, pin the needed set (a block this dispatch
        reads must never lose its slot mid-dispatch), demand-fetch the
        misses in one batched transfer, then account.
        """
        counts = np.asarray(counts)
        assert counts.shape == (self.n_experts,), counts.shape
        needed = [int(e) for e in np.nonzero(counts)[0]]
        self._commit_pending(layer, step)

        committed = self._last_prefetched.pop(layer, frozenset())
        self.prefetch_hits += len(committed.intersection(needed))

        resident_slots = frozenset(
            self.blocks[(layer, e)].slot for e in needed
            if self.blocks[(layer, e)].slot is not None)
        missing = [e for e in needed
                   if self.blocks[(layer, e)].slot is None]
        if missing:
            slots = self._acquire_slots(len(missing), resident_slots)
            if len(slots) < len(missing):
                for s in slots:           # undo: nothing was transferred
                    self._free.append(s)
                have = len(needed) - len(missing) + len(slots)
                raise ExpertCacheMissError(
                    f"expert cache cannot hold layer {layer}'s dispatch "
                    f"working set: {len(needed)} distinct experts routed "
                    f"but only {have} fit a {self.cache_slots}-slot cache "
                    f"({len(self._reserved)} reserved by in-flight "
                    f"prefetch); raise ServeConfig.expert_cache_size or "
                    f"disable ServeConfig.expert_offchip")
            staged = self._transfer([(layer, e) for e in missing])
            self._install([(layer, e) for e in missing], slots, staged,
                          step, prefetched=False)
            self.m_blocked_s += (
                len(missing) * self.block_bytes
                / (self.hw.slow.read_bw_GBps * 1e9) + FETCH_LATENCY_S)

        slot_map = np.full(self.n_experts, -1, dtype=np.int32)
        for e in needed:
            b = self.blocks[(layer, e)]
            b.accesses += float(counts[e])
            b.last_used = step
            slot_map[e] = b.slot
        self._pinned = frozenset(
            int(slot_map[e]) for e in needed)
        self.dispatching[layer] = frozenset(needed)
        self.prev_needed[layer] = needed

        t_comp = ((self.window_bytes + len(needed) * self.block_bytes)
                  / (self.hw.fast.read_bw_GBps * 1e9))
        self.m_compute_s += t_comp + DISPATCH_OVERHEAD_S
        # Overlap a prefetch issued *now* can hide: this dispatch's weight
        # reads plus the two jitted launches before the next FFN needs it.
        self._last_window_s = t_comp + 2 * DISPATCH_OVERHEAD_S
        return slot_map

    # ----------------------------------------------------- controller face
    def drop_many(self, pairs: Sequence[Tuple[int, int]]
                  ) -> List[Tuple[int, int]]:
        """Demote blocks (metadata-only).  Blocks named in their layer's
        most recent dispatch, pinned or reserved slots are refused — the
        never-demote-while-dispatching rule."""
        dropped = []
        for l, e in pairs:
            b = self.blocks[(l, e)]
            if b.slot is None or b.slot in self._pinned \
                    or b.slot in self._reserved \
                    or e in self.dispatching.get(l, frozenset()):
                continue
            self._free.append(self._evict(b))
            dropped.append((l, e))
        return dropped

    def fetch_many(self, pairs: Sequence[Tuple[int, int]], step: int
                   ) -> Tuple[List[Tuple[int, int]], List[Tuple[int, int]]]:
        """Promote blocks into *free* slots only (one batched transfer);
        the controller never evicts on promote — refusals are reported
        back so the plan reflects reality."""
        want = [(l, e) for l, e in pairs if not self.is_resident(l, e)]
        room = len(self._free)
        take, refused = want[:room], want[room:]
        if take:
            slots = [self._free.pop() for _ in take]
            staged = self._transfer(take)
            self._install(take, slots, staged, step, prefetched=True)
        return take, refused

    def reweight(self, decay: float) -> None:
        for b in self.blocks.values():
            b.accesses *= decay

    def fast_resident_bytes(self) -> int:
        return sum(self.block_bytes for b in self.blocks.values()
                   if b.slot is not None)


class ExpertBackend:
    """``TierBackend`` over an ``ExpertStore``: arena = one layer's expert
    population, chunk = one (layer, expert) block.  Same contract as
    ``PagedKVBackend`` — demotions first, promotions bounded by free
    slots, refusals reflected into ``last_recs``."""

    name = "expert"

    def __init__(self, store: ExpertStore, clock):
        self.store = store
        self.clock = clock
        self.last_recs: Dict[int, bool] = {}
        self._telemetry: Dict[int, List[ChunkStats]] = {}

    # ------------------------------------------------------------- protocol
    def snapshot(self) -> IntervalProfile:
        st = self.store
        step = self.clock()
        rows: List[ArenaProfile] = []
        telemetry: Dict[int, List[ChunkStats]] = {}
        for l in range(st.n_layers):
            blocks = [st.blocks[(l, e)] for e in range(st.n_experts)]
            fast = sum(1 for b in blocks if b.slot is not None)
            rows.append(ArenaProfile(
                arena_id=l, site_id=l, label=f"moe_l{l}",
                accesses=sum(b.accesses for b in blocks),
                resident_bytes=len(blocks) * st.block_bytes,
                fast_fraction=fast / len(blocks)))
            telemetry[l] = [
                ChunkStats(chunk_id=st.chunk_id(l, b.expert),
                           nbytes=st.block_bytes, accesses=b.accesses,
                           age=step - b.birth_step,
                           fast=b.slot is not None)
                for b in blocks]
        self._telemetry = telemetry
        return IntervalProfile(step, rows, 0, 0.0)

    def telemetry(self) -> Mapping[int, Sequence[ChunkStats]]:
        return self._telemetry

    def reweight(self, decay: float) -> None:
        self.store.reweight(decay)

    def on_plan(self, plan: MigrationPlan) -> None:
        self.last_recs = dict(plan.chunk_placement)

    def enforce(self, plan: MigrationPlan) -> MoveStats:
        stats = MoveStats()
        st = self.store
        placement = sorted(plan.chunk_placement.items())
        demote = [st.from_chunk(cid) for cid, fast in placement if not fast]
        dropped = st.drop_many(demote)
        # Logical bytes leaving the fast tier; demotion is metadata-only
        # (immutable weights never copy back).
        stats.bytes_demoted = st.block_bytes * len(dropped)
        want = [st.from_chunk(cid) for cid, fast in placement if fast]
        done, refused = st.fetch_many(want, self.clock())
        stats.bytes_promoted = st.block_bytes * len(done)
        for l, e in refused:
            stats.dropped_promotions += 1
            self.last_recs[st.chunk_id(l, e)] = False
        return stats

    def fast_bytes(self) -> int:
        return self.store.fast_resident_bytes()
