"""Continuous-batching serving engine with guided KV-page tiering.

The engine serves dense/MoE decoder models from a paged two-tier KV cache
(serve/kvcache.py).  Each *request* is an allocation site; its pages are the
chunks.  The request lifecycle is explicit:

    waiting --admit--> [prefilling -->] active <--pause/resume--> paused
        ^                                 |                         |
        +------------- preempt ----------/ <----------------------/
    (any live state) ------------------- finish/cancel ---------> finished

* **Admission** order, preemption victims, decode packing, and the
  per-step prefill/decode budget split are POLICY decisions, delegated to
  a ``SchedulerPolicy`` (serve/scheduler.py; ``ServeConfig.scheduler``
  picks ``fifo`` — bitwise the pre-policy engine — ``priority``, or
  ``drr`` per-tenant fairness).  A request is admitted when its prompt's
  pages fit the pool's free logical capacity (no raw ``IndexError`` /
  ``MemoryError`` escapes for work that merely has to wait); admission
  never skips past a request that does not fit.  Requests that can
  *never* run — prompt + generation budget past
  ``max_pages_per_seq * page_size``, or a prompt bigger than the usable HBM
  pool — are rejected at ``add_request`` with an error naming the knob.
* **Prefill** is one-shot: a single jitted dispatch writes the whole
  prompt's K/V directly into page-table slots and attends with per-token
  causal lengths (``kernels.ops.paged_prefill``).  The chunked path
  (``prefill="chunked"``: step the prompt through decode one token at a
  time) survives as the bitwise-equality oracle.  With
  ``prefill_chunk_tokens > 0`` long prompts are instead INTERLEAVED: an
  admitted request enters a ``prefilling`` state and each engine step
  ingests at most that many prompt tokens (one bucketed dispatch per
  chunk, at the chunk's absolute start position) alongside the decode
  batch, so a 32k-token prompt cannot monopolize the step loop — and
  because one-shot == chunked == decode bitwise, interleaving changes
  WHEN tokens appear, never WHICH tokens.  With
  ``enable_prefix_cache`` the cross-request radix prefix cache
  (serve/prefix_cache.py) is consulted FIRST: matched full-page blocks are
  attached by reference (refcounted, copy-on-write) and the dispatch runs
  only over the uncovered suffix at its absolute positions — a full hit
  skips prefill entirely — with cached-vs-uncached logits bitwise-equal
  (K/V depend only on tokens and positions, and suffix == whole-prompt
  prefill by the one-shot == chunked == decode equality).
* **Scheduling** each step packs up to ``max_batch`` active requests in
  the policy's decode order under two budgets — usable HBM slots and free
  logical pages — so a batch can always be made resident without evicting
  its own members; requests that do not fit are starved this step, not
  crashed.
* **Preemption**: paused requests can lose their pages entirely (preempt by
  recompute — deterministic re-prefill of prompt+generated on resume makes
  this lossless, *because* one-shot prefill == decode bitwise) when the
  wait-queue head needs logical pages.
* **Decoding** samples INSIDE the jitted dispatch (``ops.sample_tokens``):
  each scheduled row's ``SamplingParams`` ride along as batched
  temperature/top-k/top-p/seed arrays, and the per-row PRNG key folds the
  token's absolute stream position — so preemption-by-recompute and
  one-shot-vs-chunked prefill replay to identical sampled streams, and
  ``temperature=0`` rows are bitwise-equal to greedy argmax.
* **Finish** carries a reason — ``stop`` (a sampled token hit
  ``SamplingParams.stop_token_ids``), ``length`` (``max_new`` /
  ``max_tokens`` exhausted), ``truncated`` (capacity) or ``cancelled``
  (``Engine.cancel`` withdrew a live request) — frees pages,
  prunes the request from ``engine.requests`` and its pages from the
  eviction policy's ``last_recs`` view; results move to
  ``engine.finished`` (drain with ``pop_finished``; per-reason totals in
  ``stats()``).

Most callers should not drive ``Engine`` directly: ``serve.api.LLM``
(``generate`` / ``submit`` streaming handles) is the front door behind
which all of this stays invisible.

Algorithm 1 itself is NOT implemented here: the engine exposes its page pool
to the shared controller through ``PagedKVBackend`` (a
``core.runtime.TierBackend``) and a ``GuidanceRuntime`` drives the paper's
machinery — profile -> age-fragmented thermos -> ski-rental -> page
migrations — at the decision interval.  All page movement (enforcement,
demand residency, eviction) goes through the pool's batched
``swap_in_many``/``swap_out_many``, so an N-page migration costs a constant
number of host<->device transfers per direction.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import TPU_V5E, GuidanceConfig, GuidanceRuntime, HardwareModel, MoveStats
from ..core.fragmentation import ChunkStats
from ..core.profiler import ArenaProfile, IntervalProfile
from ..core.runtime import MigrationPlan
from ..dist.sharding import active_mesh
from ..models.layers import lm_head, mlp, rmsnorm, rope
from ..models.moe import apply_dropless_flat, moe_decode, route_tokens
from ..models.transformer import Model
from .eviction import make_eviction_policy
from .expert_store import ExpertBackend, ExpertCacheMissError, ExpertStore
from .kvcache import PageExport, PagedKVPool
from .prefix_cache import PrefixBackend, PrefixCache
from .sampling import DEFAULT_MAX_TOKENS, SamplingParams
from .scheduler import make_scheduler_policy

F32 = jnp.float32


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 4
    page_size: int = 16
    hbm_pages: int = 64
    host_pages: int = 256
    policy: str = "gdt"            # gdt | lru | fifo (eviction registry)
    interval_steps: int = 16
    strategy: str = "thermos"
    num_fragments: int = 4
    max_pages_per_seq: int = 32
    # Algorithm 1's optional ReweightProfile: decay access counters each
    # interval so placement tracks recent behaviour (sessions pause/resume
    # far faster than HPC phase shifts, so serving defaults to decaying).
    access_decay: float = 0.5
    # Prompt ingestion: "one_shot" = single jitted dispatch per prompt;
    # "chunked" = step prompt tokens through decode (the bitwise oracle).
    prefill: str = "one_shot"
    # Scheduling policy (serve/scheduler.py registry): "fifo" is bitwise
    # the pre-policy engine; "priority" = strict classes + EDF; "drr" =
    # deficit-round-robin per-tenant fairness.
    scheduler: str = "fifo"
    # Chunked-prefill interleaving budget: > 0 caps how many prompt tokens
    # may ingest per engine step (one_shot mode only — an admitted request
    # sits in ``prefilling`` state and co-schedules with decode).  0 keeps
    # eager whole-suffix prefill at admission (bitwise the pre-policy
    # engine).
    prefill_chunk_tokens: int = 0
    # Cross-request radix prefix cache (serve/prefix_cache.py): requests
    # whose prompts start with the same full-page token blocks share those
    # pages by reference and prefill only the uncovered suffix.  Off by
    # default: sharing changes page-lifetime accounting (cached pages
    # outlive their requests), so workloads opt in.
    enable_prefix_cache: bool = False
    # A prefix enters the cache only when it spans at least this many FULL
    # pages — gates tree churn from trivially short shared prefixes.
    min_prefix_pages: int = 1
    # Debug: copy every scheduled row's logits to host into
    # ``engine.last_logits`` (a full (B, vocab) transfer per step — keep
    # off on the decode hot path; the parity tests turn it on).
    keep_logits: bool = False
    # -------- guided expert-weight tiering (serve/expert_store.py) -------
    # Keep MoE expert FFN weights host-resident and dispatch through a
    # bounded HBM expert cache.  Decoding runs layer-by-layer (router picks
    # sync to the host between attention and FFN) and is bitwise-equal to
    # the resident single-scan path whenever each dispatch's expert working
    # set fits the cache.
    expert_offchip: bool = False
    # HBM cache capacity in expert blocks, shared across layers; 0 means
    # every (layer, expert) block fits (n_layers * n_experts slots).  Must
    # hold at least one dispatch's working set:
    # min(n_experts, max_batch * top_k).
    expert_cache_size: int = 0
    # Double-buffered prefetch: while layer L's grouped GEMM is in flight,
    # the predicted working set for the next layer stages on a second
    # buffer.  Off = every miss is a blocking demand fetch (same results,
    # more modeled stall).
    expert_double_buffer: bool = True


@dataclasses.dataclass
class Request:
    request_id: int
    tokens: List[int]
    max_new: int
    params: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    generated: List[int] = dataclasses.field(default_factory=list)
    # waiting | prefilling | active | paused | preempted | finished
    state: str = "waiting"
    pos: int = 0                   # tokens written to KV so far
    last_scheduled: int = 0
    # Step this request (re-)entered the wait queue — admission-wait
    # accounting and the deadline base for SLO-aware policies.
    queued_step: int = 0
    truncated: bool = False        # finished early for capacity, not EOS
    # stop | length | truncated | cancelled
    finish_reason: Optional[str] = None

    @property
    def context(self) -> List[int]:
        """Prompt + everything generated so far — what a (re-)prefill must
        ingest (minus the final token, which the next decode step feeds)."""
        return self.tokens + self.generated


@dataclasses.dataclass
class RequestTicket:
    """The serializable identity of an in-flight request — everything a
    DIFFERENT engine needs to continue its stream bitwise.

    ``(prompt, params, generated)`` pins the token stream completely: the
    sampling seed is either explicit in ``params`` or derived from
    ``request_id`` (engine._run_batch), and the PRNG folds the absolute
    stream position, so replaying prompt+generated through prefill on any
    replica resumes the identical sampled stream (the
    preemption-by-recompute guarantee, applied across engines).  The page
    table is deliberately NOT here — it is reconstructible (cold path) or
    rides along separately as a ``PageExport`` (warm path)."""

    request_id: int
    prompt: List[int]
    max_new: int
    params: SamplingParams
    generated: List[int] = dataclasses.field(default_factory=list)
    # Set once the owning engine finished the request — a crashed replica's
    # undrained result is rebuilt from the ticket, never re-decoded.
    finish_reason: Optional[str] = None


class PagedKVBackend:
    """``TierBackend`` over the engine's paged KV pool.

    Arena = one request's page list; chunk = one page.  ``enforce`` is
    capacity-safe: the reserved scratch slot never appears in the free list,
    demotions run first, and promotions that would exceed the free HBM slots
    are *refused* — and reflected back into ``last_recs`` so the eviction
    policy sees the placement that actually exists, not the one that was
    merely planned.  Each direction is realized as ONE batched pool
    migration, not a per-page loop.
    """

    name = "paged_kv"

    def __init__(self, pool: PagedKVPool, requests: Dict[int, Request],
                 clock):
        self.pool = pool
        self.requests = requests
        self.clock = clock
        self.last_recs: Dict[int, bool] = {}   # page_id -> recommended fast
        self._telemetry: Dict[int, List[ChunkStats]] = {}

    # ------------------------------------------------------------- protocol
    def snapshot(self) -> IntervalProfile:
        rows: List[ArenaProfile] = []
        telemetry: Dict[int, List[ChunkStats]] = {}
        page_bytes = self.pool.page_bytes
        step = self.clock()
        for rid in self.requests:
            # Shared prefix pages are the PrefixBackend's tier objects —
            # profiling them per-request would double-govern one page under
            # two controllers (and double-count its accesses).
            pages = [p for p in self.pool.request_pages(rid)
                     if not p.shared]
            if not pages:
                continue
            fast_pages = sum(1 for p in pages if p.hbm_slot is not None)
            rows.append(ArenaProfile(
                arena_id=rid, site_id=rid, label=f"req{rid}",
                accesses=sum(p.accesses for p in pages),
                resident_bytes=len(pages) * page_bytes,
                fast_fraction=fast_pages / len(pages)))
            telemetry[rid] = [
                ChunkStats(chunk_id=p.page_id, nbytes=page_bytes,
                           accesses=p.accesses,
                           age=step - p.birth_step,
                           fast=p.hbm_slot is not None)
                for p in pages]
        self._telemetry = telemetry
        return IntervalProfile(step, rows, 0, 0.0)

    def telemetry(self) -> Mapping[int, Sequence[ChunkStats]]:
        return self._telemetry

    def reweight(self, decay: float) -> None:
        # Float counters: int(1 * 0.5) would zero any page with a single
        # access per interval, erasing the recency ordering decay exists to
        # preserve.  Shared pages decay under the PrefixBackend instead.
        for p in self.pool.pages.values():
            if not p.shared:
                p.accesses = p.accesses * decay

    def on_plan(self, plan: MigrationPlan) -> None:
        # Track the plan every interval (even when the break-even rule says
        # "wait") — the guided eviction policy keys off it.
        self.last_recs = dict(plan.chunk_placement)

    def enforce(self, plan: MigrationPlan) -> MoveStats:
        stats = MoveStats()
        pages = self.pool.pages
        page_bytes = self.pool.page_bytes
        # Demotions first (one batched transfer): free slots for promotions.
        demote = [pid for pid, fast in plan.chunk_placement.items()
                  if not fast and pid in pages
                  and pages[pid].hbm_slot is not None]
        self.pool.swap_out_many(demote)
        stats.bytes_demoted = page_bytes * len(demote)
        # Promotions (one batched transfer), bounded by free HBM slots.
        want = [pid for pid, fast in plan.chunk_placement.items()
                if fast and pid in pages and pages[pid].hbm_slot is None]
        room = len(self.pool.free_hbm)
        promote, refused = want[:room], want[room:]
        self.pool.swap_in_many(promote)
        stats.bytes_promoted = page_bytes * len(promote)
        for pid in refused:
            stats.dropped_promotions += 1
            self.last_recs[pid] = False
        return stats

    def forget_pages(self, page_ids: Sequence[int]) -> None:
        """Drop freed pages from the recommendation view so ``last_recs``
        never accumulates stale ids across request generations."""
        for pid in page_ids:
            self.last_recs.pop(pid, None)

    def fast_bytes(self) -> int:
        return self.pool.hbm_used() * self.pool.page_bytes


class Engine:
    def __init__(self, model: Model, params, cfg: ServeConfig,
                 hw: HardwareModel = TPU_V5E):
        assert model.cfg.family in ("dense", "moe"), \
            "paged engine serves decoder LMs"
        if model.cfg.family == "moe" and model.cfg.moe_parallelism == "ep":
            # Fail at construction, not mid-decode: an ep pad target that
            # doesn't divide over the live mesh's model axis would otherwise
            # surface as a shape error deep inside the jitted step.
            mesh = active_mesh()
            if mesh is not None and "model" in mesh.shape:
                model.moe_cfg.validate_ep_axis(int(mesh.shape["model"]))
        if cfg.prefill not in ("one_shot", "chunked"):
            raise ValueError(
                f"ServeConfig.prefill must be 'one_shot' or 'chunked', "
                f"got {cfg.prefill!r}")
        if cfg.prefill_chunk_tokens < 0:
            raise ValueError(
                f"ServeConfig.prefill_chunk_tokens must be >= 0, got "
                f"{cfg.prefill_chunk_tokens}")
        if cfg.expert_offchip:
            if model.cfg.family != "moe":
                raise ValueError(
                    "ServeConfig.expert_offchip requires a MoE model: "
                    f"family={model.cfg.family!r} has no expert weights "
                    "to tier")
            if model.cfg.moe_parallelism == "ep":
                raise ValueError(
                    "ServeConfig.expert_offchip drives the flat dropless "
                    "dispatch; ep parallelism already shards experts "
                    "across the mesh — pick one placement mechanism")
            E = model.moe_cfg.padded_experts
            floor = min(E, cfg.max_batch * model.moe_cfg.top_k)
            size = cfg.expert_cache_size or model.cfg.n_layers * E
            if cfg.expert_cache_size < 0 or 0 < size < floor:
                raise ValueError(
                    f"ServeConfig.expert_cache_size={cfg.expert_cache_size}"
                    f" cannot hold one dispatch's expert working set: a "
                    f"decode batch of max_batch={cfg.max_batch} rows with "
                    f"top_k={model.moe_cfg.top_k} picks can route up to "
                    f"min(n_experts={E}, max_batch*top_k)={floor} distinct "
                    f"experts in one layer; raise expert_cache_size to at "
                    f"least {floor} (0 = fully resident cache) or lower "
                    f"max_batch")
        self.model = model
        self.params = params
        self.cfg = cfg
        self.hw = hw
        mc = model.cfg
        self.pool = PagedKVPool(
            n_layers=mc.n_layers, page_size=cfg.page_size,
            kv_heads=mc.kv_heads, head_dim=model.attn_cfg.head_dim,
            hbm_pages=cfg.hbm_pages, host_pages=cfg.host_pages,
            dtype=mc.dtype)
        self.requests: Dict[int, Request] = {}
        self.finished: Dict[int, Request] = {}
        self.wait_queue: Deque[int] = deque()
        self.step_count = 0
        self.eviction = make_eviction_policy(cfg.policy)
        # Pluggable scheduling decisions (admission / preemption / decode
        # order / per-step budget split).  A FRESH instance per engine —
        # stateful policies (DRR deficits) must not bleed across replicas.
        self.scheduler = make_scheduler_policy(cfg.scheduler)
        # Prefix-cache chains matched at admission for requests still in
        # ``prefilling`` state — insertion into the cache happens only once
        # the whole prompt is ingested.
        self._pending_chains: Dict[int, list] = {}
        # Reserve one HBM slot as the write target for inactive batch rows,
        # so the batched scatter never collides with a real page.
        self.scratch_slot = self.pool.free_hbm.pop(0)
        # Cross-request prefix sharing: the radix cache itself, plus (under
        # the guided policy) a SECOND GuidanceRuntime whose tier objects are
        # the shared prefixes — per-interval hit counts as the access
        # profile, ski-rental promote/demote, batched-exchange enforcement.
        self.prefix_cache: Optional[PrefixCache] = None
        self.prefix_backend: Optional[PrefixBackend] = None
        self.prefix_runtime: Optional[GuidanceRuntime] = None
        if cfg.enable_prefix_cache:
            self.prefix_cache = PrefixCache(
                self.pool, cfg.page_size, min_pages=cfg.min_prefix_pages)
        # Guided expert-weight tiering: the ExpertStore exists whenever
        # expert weights are off-chip (LRU demand management works under
        # any policy); the third GuidanceRuntime rides only under gdt,
        # like the KV and prefix controllers.
        self.expert_store: Optional[ExpertStore] = None
        self.expert_backend: Optional[ExpertBackend] = None
        self.expert_runtime: Optional[GuidanceRuntime] = None
        if cfg.expert_offchip:
            E = self.model.moe_cfg.padded_experts
            moe_params = params["layers"]["moe"]
            # Overlap window for the modeled prefetch clock: the attention
            # weight bytes each layer reads before the next FFN needs its
            # experts.
            attn_bytes = (4 * mc.d_model * model.attn_cfg.n_heads
                          * model.attn_cfg.head_dim
                          * moe_params["w_gate"].dtype.itemsize)
            self.expert_store = ExpertStore(
                moe_params, mc.n_layers, E,
                cfg.expert_cache_size or mc.n_layers * E,
                double_buffer=cfg.expert_double_buffer, hw=hw,
                window_bytes=attn_bytes)
            # The dense expert stacks live in the store's tiers now; serve
            # against a param view holding only the router.  ``params``
            # itself is untouched (replicas re-derive their own stores).
            layers = dict(params["layers"])
            layers["moe"] = {"router": moe_params["router"]}
            self.params = {**params, "layers": layers}
        self.kv_backend: Optional[PagedKVBackend] = None
        self.runtime: Optional[GuidanceRuntime] = None
        if cfg.policy == "gdt":
            self.kv_backend = PagedKVBackend(
                self.pool, self.requests, clock=lambda: self.step_count)
            self.runtime = GuidanceRuntime(
                self.kv_backend, hw,
                GuidanceConfig(
                    strategy=cfg.strategy,
                    # The reserved scratch slot is not placeable capacity.
                    fast_capacity_bytes=(cfg.hbm_pages - 1) * self.pool.page_bytes,
                    interval_steps=cfg.interval_steps,
                    decay=cfg.access_decay,
                    num_fragments=cfg.num_fragments,
                    skip_empty_intervals=True),
                clock=lambda: self.step_count)
            if self.prefix_cache is not None:
                self.prefix_backend = PrefixBackend(
                    self.prefix_cache, clock=lambda: self.step_count)
                self.prefix_runtime = GuidanceRuntime(
                    self.prefix_backend, hw,
                    GuidanceConfig(
                        strategy=cfg.strategy,
                        fast_capacity_bytes=(cfg.hbm_pages - 1)
                        * self.pool.page_bytes,
                        interval_steps=cfg.interval_steps,
                        decay=cfg.access_decay,
                        num_fragments=cfg.num_fragments,
                        skip_empty_intervals=True),
                    clock=lambda: self.step_count)
            if self.expert_store is not None:
                self.expert_backend = ExpertBackend(
                    self.expert_store, clock=lambda: self.step_count)
                self.expert_runtime = GuidanceRuntime(
                    self.expert_backend, hw,
                    GuidanceConfig(
                        strategy=cfg.strategy,
                        fast_capacity_bytes=self.expert_store.cache_bytes,
                        interval_steps=cfg.interval_steps,
                        decay=cfg.access_decay,
                        num_fragments=cfg.num_fragments,
                        skip_empty_intervals=True),
                    clock=lambda: self.step_count)
        self._decode_greedy = jax.jit(self._build_decode(with_sampler=False))
        self._decode_sampled = jax.jit(self._build_decode(with_sampler=True))
        self._prefill = jax.jit(self._build_prefill())
        if self.expert_store is not None:
            self._build_tiered_closures()
        self.last_logits: Dict[int, np.ndarray] = {}
        # --------------------------------------------------- counters
        self.swap_in_events = 0
        self.prefill_dispatches = 0    # jitted dispatches spent on prefill
        self.prefill_tokens = 0        # prompt tokens ingested
        self.prefill_chunks = 0        # interleaved chunk dispatches
        self.admissions = 0
        # Sum over admissions of (admit step - queued step); the mean rides
        # in stats() as ``mean_admission_wait_steps``.
        self.admission_wait_steps = 0
        self.preemptions = 0           # paused requests evicted wholesale
        self.starved_steps = 0         # request-steps skipped for capacity
        self.truncations = 0           # requests finished early for capacity
        self.saved_prefill_tokens = 0  # prompt tokens served from the cache
        # Per-finish_reason totals (monotonic — surviving pop_finished
        # drains), reported through stats() and serving_summary.
        self.finish_counts: Dict[str, int] = {
            "stop": 0, "length": 0, "truncated": 0, "cancelled": 0}

    # ------------------------------------------------- telemetry shims
    @property
    def decisions(self):
        """Deprecated: ski-rental decisions now live on the runtime's
        event stream (``engine.runtime.events``)."""
        return self.runtime.decisions if self.runtime is not None else []

    @property
    def last_recs(self) -> Dict[int, bool]:
        """Latest planned placement across BOTH controllers (per-request KV
        pages and shared prefixes) — what guided eviction consults.  Page
        ids are globally unique, so the merge cannot collide."""
        recs: Dict[int, bool] = {}
        if self.kv_backend is not None:
            recs.update(self.kv_backend.last_recs)
        if self.prefix_backend is not None:
            recs.update(self.prefix_backend.last_recs)
        return recs

    @property
    def usable_hbm_pages(self) -> int:
        return self.cfg.hbm_pages - 1          # minus the scratch slot

    def free_logical_pages(self) -> int:
        """Unallocated pages across both tiers — what admission/allocation
        budgets against."""
        return len(self.pool.free_hbm) + len(self.pool.free_host)

    def queue_delay_estimate(self) -> float:
        """Deterministic estimate (in engine steps) of how long a NEW
        request would wait before decoding: the un-ingested prompt-token
        backlog (waiting + mid-prefill) over the per-step prefill capacity,
        plus current decode occupancy.  The Router's dispatch key — a
        replica stuffed with queued 32k prompts now repels new work even
        while its pages-in-use still look modest."""
        backlog = 0
        n_active = 0
        for r in self.requests.values():
            if r.state == "waiting":
                backlog += max(len(r.context) - 1, 1)
            elif r.state == "prefilling":
                backlog += max(len(r.context) - 1 - r.pos, 0)
            elif r.state == "active":
                n_active += 1
        per_step = self.scheduler.step_budget(self).prefill_tokens
        if per_step <= 0:
            # Eager prefill ingests a whole prompt per admission; the
            # page-sized batch capacity is the natural per-step unit.
            per_step = self.cfg.page_size * self.cfg.max_batch
        return backlog / per_step + n_active / self.cfg.max_batch

    # ================================================== shared layer body
    def _layer_body(self, lp, x, kp, vp, *, positions, write_slot,
                    write_off, row_mask, lane_mask, rows, unrows, attend):
        """ONE transformer layer body shared by the jitted decode and
        one-shot prefill closures — a single definition is what keeps
        one-shot prefill bitwise-equal to decode (the invariant
        preemption-by-recompute losslessness rests on).

        The two paths differ only in where the row axis lives (decode:
        batch of B single-token rows, x (B,1,d); prefill: one sequence of
        S token rows, x (1,S,d)) and in the attention call.  ``rows``
        flattens a (.., ., H, dh) projection to (R, H, dh), ``unrows``
        lifts an (R, d) result back to x's layout, ``attend(q, kp, vp)``
        returns (R, H, dh).  Masked rows scatter zeros to the reserved
        scratch slot and carry zero residuals — deterministic, never
        garbage.
        """
        x, h2, kp, vp = self._attn_half(
            lp, x, kp, vp, positions=positions, write_slot=write_slot,
            write_off=write_off, row_mask=row_mask, lane_mask=lane_mask,
            rows=rows, unrows=unrows, attend=attend)
        x = self._ffn_half(lp, x, h2, lane_mask)
        return x, kp, vp

    def _attn_half(self, lp, x, kp, vp, *, positions, write_slot, write_off,
                   row_mask, lane_mask, rows, unrows, attend):
        """Attention through the pre-FFN rmsnorm.  Split from
        ``_layer_body`` so the tiered expert path (expert_offchip) can run
        the identical ops up to the router, sync the routing picks to the
        host, and resume with ``_ffn_half``'s math against cache slots —
        the split point changes WHERE the jit boundary falls, never which
        ops run, which is what keeps tiered output bitwise-equal."""
        acfg = self.model.attn_cfg
        h = rmsnorm(lp["ln1"], x)
        q = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wq"])
        k1 = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wk"])
        v1 = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wv"])
        q = rope(q, positions, acfg.rope_theta)
        k1 = rope(k1, positions, acfg.rope_theta)
        m = row_mask[:, None, None]
        kp = kp.at[write_slot, write_off].set(
            jnp.where(m, rows(k1), 0).astype(kp.dtype))
        vp = vp.at[write_slot, write_off].set(
            jnp.where(m, rows(v1), 0).astype(vp.dtype))
        o = attend(rows(q), kp, vp)                      # (R, H, dh)
        y = jnp.einsum("rhk,hkd->rd",
                       o.reshape(o.shape[0], acfg.n_heads, acfg.head_dim),
                       lp["attn"]["wo"])
        x = x + jnp.where(lane_mask, unrows(y), 0)
        h2 = rmsnorm(lp["ln2"], x)
        return x, h2, kp, vp

    def _ffn_half(self, lp, x, h2, lane_mask):
        """FFN + residual, the second half of ``_layer_body``."""
        model = self.model
        if model.cfg.family == "moe":
            # Same dropless routing + grouped GEMM as model.prefill, so a
            # token's expert assignment never depends on how the stream is
            # chunked or batched.
            d = moe_decode(lp["moe"], h2, model.moe_cfg)
        else:
            d = mlp(lp["mlp"], h2)
        return x + jnp.where(lane_mask, d, 0)

    # ========================================================= jit decode
    def _build_decode(self, with_sampler: bool):
        """Two jitted variants share one body: the greedy variant's epilogue
        is a plain ``argmax`` (bitwise the pre-sampling engine, zero
        sampling overhead on the default path); the sampled variant runs
        the full in-dispatch sampler.  They agree bitwise on greedy rows
        (the sampler short-circuits ``temperature<=0`` to the same argmax),
        so the scheduler picks per batch and each compiles only on first
        use — a pure-greedy workload never compiles the sampled path."""
        model = self.model
        acfg = model.attn_cfg
        from ..kernels.ops import paged_attention, sample_tokens

        def step(params, k_pool, v_pool, tokens, page_table, lengths,
                 write_slot, write_off, active, seeds, temperature, top_k,
                 top_p):
            """tokens: (B,1); page_table: (B,MP) HBM slots or -1;
            lengths: (B,) incl. new token; write_slot/off: (B,) where the
            new token's KV goes; active: (B,) bool — inactive rows are
            masked to deterministic zeros rather than carrying garbage;
            seeds/temperature/top_k/top_p: (B,) per-request sampling knobs
            (the sampler runs INSIDE this dispatch, with the next token's
            stream position ``lengths`` as the PRNG fold — the replay
            contract)."""
            x = jnp.take(params["embed"]["tok"], tokens, axis=0)  # (B,1,d)

            def body(carry, xs):
                lp, kp, vp = xs          # kp/vp: (N,P,K,dh)
                x, kp, vp = self._layer_body(
                    lp, carry, kp, vp,
                    positions=(lengths - 1)[:, None],
                    write_slot=write_slot, write_off=write_off,
                    row_mask=active, lane_mask=active[:, None, None],
                    rows=lambda t: t[:, 0], unrows=lambda y: y[:, None],
                    attend=lambda q, kp, vp: paged_attention(
                        q, kp, vp, page_table, lengths, window=acfg.window))
                return x, (kp, vp)

            x, (nk, nv) = jax.lax.scan(
                body, x, (params["layers"], k_pool, v_pool))
            x = rmsnorm(params["final_ln"], x)
            logits = lm_head(params["head"], x)[:, 0]
            logits = jnp.where(active[:, None], logits, 0.0)
            if with_sampler:
                next_tokens = sample_tokens(logits, seeds, lengths,
                                            temperature, top_k, top_p)
            else:
                next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return logits, next_tokens, nk, nv

        return step

    # ======================================================== jit prefill
    def _build_prefill(self):
        """One-shot prompt ingestion: a single jitted call writes S tokens'
        K/V into their page-table slots and attends with per-token causal
        lengths via ``ops.paged_prefill`` — the same layer body decode
        runs, so the result is bitwise-equal to chunked ingestion."""
        model = self.model
        acfg = model.attn_cfg
        from ..kernels.ops import paged_prefill

        def prefill(params, k_pool, v_pool, tokens, page_table, slots, offs,
                    n_real, start):
            """tokens: (S,) padded suffix; page_table: (MP,) the request's
            pages (cache-covered prefix included); slots/offs: (S,) physical
            write target per token (the scratch slot for padded rows);
            n_real: () int32 live rows; start: () int32 absolute position of
            row 0 (0 for an uncached prompt, the covered token count after a
            prefix-cache hit — traced, so hits never recompile).  Rows
            attend by ABSOLUTE length over the page table, so a suffix-only
            dispatch replays the whole-prompt computation bitwise."""
            S = tokens.shape[0]
            local = jnp.arange(S, dtype=jnp.int32)
            positions = start + local
            valid = local < n_real
            lengths = jnp.where(valid, positions + 1, 0)
            x = jnp.take(params["embed"]["tok"], tokens[None], axis=0)

            def body(carry, xs):
                lp, kp, vp = xs
                x, kp, vp = self._layer_body(
                    lp, carry, kp, vp,
                    positions=positions[None],
                    write_slot=slots, write_off=offs,
                    row_mask=valid, lane_mask=valid[None, :, None],
                    rows=lambda t: t[0], unrows=lambda y: y[None],
                    attend=lambda q, kp, vp: paged_prefill(
                        q, kp, vp, page_table, lengths, window=acfg.window))
                return x, (kp, vp)

            _, (nk, nv) = jax.lax.scan(
                body, x, (params["layers"], k_pool, v_pool))
            return nk, nv

        return prefill

    # ==================================================== tiered expert path
    def _build_tiered_closures(self):
        """Jitted pieces of the layer-by-layer pipeline that serves MoE
        FFN weights out of the bounded HBM expert cache
        (``ServeConfig.expert_offchip``).

        The resident path runs one jitted scan over all layers; the tiered
        path cannot (each layer's routed expert set must reach the host so
        the store can ensure residency before the grouped GEMM), so the
        SAME layer ops are recomposed as: per-layer jitted
        attention+router (``_attn_half`` + ``route_tokens``), a host sync
        of the picks, then a jitted FFN-from-cache + residual
        (``apply_dropless_flat`` with the slot map as ``group_experts``).
        Every op and its order is identical to the resident scan — only
        the jit boundaries move — which is the invariant the bitwise
        parity tests pin.  While one layer's FFN dispatch is in flight the
        store stages the next layer's predicted experts (double buffer).
        """
        model = self.model
        acfg = model.attn_cfg
        mcfg = model.moe_cfg
        from ..kernels.ops import paged_attention, paged_prefill, sample_tokens

        def slice_layer(tree, l):
            return jax.tree_util.tree_map(
                lambda a: jax.lax.dynamic_index_in_dim(
                    a, l, 0, keepdims=False), tree)

        def embed(params, tokens):
            return jnp.take(params["embed"]["tok"], tokens, axis=0)

        def decode_attn(params, k_pool, v_pool, x, l, page_table, lengths,
                        write_slot, write_off, active):
            lp = slice_layer(params["layers"], l)
            kp = jax.lax.dynamic_index_in_dim(k_pool, l, 0, keepdims=False)
            vp = jax.lax.dynamic_index_in_dim(v_pool, l, 0, keepdims=False)
            x, h2, kp, vp = self._attn_half(
                lp, x, kp, vp, positions=(lengths - 1)[:, None],
                write_slot=write_slot, write_off=write_off,
                row_mask=active, lane_mask=active[:, None, None],
                rows=lambda t: t[:, 0], unrows=lambda y: y[:, None],
                attend=lambda q, kp, vp: paged_attention(
                    q, kp, vp, page_table, lengths, window=acfg.window))
            B, S, d = h2.shape
            gates, experts = route_tokens(
                lp["moe"]["router"], h2.reshape(B * S, d), mcfg)
            k_pool = jax.lax.dynamic_update_index_in_dim(k_pool, kp, l, 0)
            v_pool = jax.lax.dynamic_update_index_in_dim(v_pool, vp, l, 0)
            return x, h2, gates, experts, k_pool, v_pool

        def prefill_prologue(params, tokens, n_real, start):
            S = tokens.shape[0]
            local = jnp.arange(S, dtype=jnp.int32)
            positions = start + local
            valid = local < n_real
            lengths = jnp.where(valid, positions + 1, 0)
            x = jnp.take(params["embed"]["tok"], tokens[None], axis=0)
            return x, positions, lengths, valid

        def prefill_attn(params, k_pool, v_pool, x, l, page_table, slots,
                         offs, positions, lengths, valid):
            lp = slice_layer(params["layers"], l)
            kp = jax.lax.dynamic_index_in_dim(k_pool, l, 0, keepdims=False)
            vp = jax.lax.dynamic_index_in_dim(v_pool, l, 0, keepdims=False)
            x, h2, kp, vp = self._attn_half(
                lp, x, kp, vp, positions=positions[None],
                write_slot=slots, write_off=offs,
                row_mask=valid, lane_mask=valid[None, :, None],
                rows=lambda t: t[0], unrows=lambda y: y[None],
                attend=lambda q, kp, vp: paged_prefill(
                    q, kp, vp, page_table, lengths, window=acfg.window))
            B, S, d = h2.shape
            gates, experts = route_tokens(
                lp["moe"]["router"], h2.reshape(B * S, d), mcfg)
            k_pool = jax.lax.dynamic_update_index_in_dim(k_pool, kp, l, 0)
            v_pool = jax.lax.dynamic_update_index_in_dim(v_pool, vp, l, 0)
            return x, h2, gates, experts, k_pool, v_pool

        def ffn(x, h2, wg, wu, wd, slot_map, gates, experts, lane_mask):
            d = apply_dropless_flat(gates, experts, h2, wg, wu, wd, mcfg,
                                    expert_slots=slot_map)
            return x + jnp.where(lane_mask, d, 0)

        def spec_route(params, x, nl):
            # Speculative gating: layer ``nl``'s own pre-FFN norm + router
            # applied to the residual stream as it leaves layer nl-1 —
            # i.e. one attention delta early.  The residual stream
            # dominates the router input, so these probabilities forecast
            # the next dispatch's working set; the store stages the top of
            # the ranking while this layer's grouped GEMM is in flight.
            # Full (T, E) probs, not top-k picks: borderline tokens flip
            # their picks under the missing attention delta, but the mass
            # ranking is stabler than any single pick.  Predictions never
            # touch results — a miss means a blocking demand fetch.
            lp = slice_layer(params["layers"], nl)
            h2 = rmsnorm(lp["ln2"], x)
            B, S, d = h2.shape
            logits = jnp.einsum("td,de->te",
                                h2.reshape(B * S, d).astype(jnp.float32),
                                lp["moe"]["router"])
            return jax.nn.softmax(logits, axis=-1)

        def make_tail(with_sampler):
            def tail(params, x, lengths, active, seeds, temperature, top_k,
                     top_p):
                x = rmsnorm(params["final_ln"], x)
                logits = lm_head(params["head"], x)[:, 0]
                logits = jnp.where(active[:, None], logits, 0.0)
                if with_sampler:
                    next_tokens = sample_tokens(logits, seeds, lengths,
                                                temperature, top_k, top_p)
                else:
                    next_tokens = jnp.argmax(logits, axis=-1).astype(
                        jnp.int32)
                return logits, next_tokens
            return tail

        self._t_embed = jax.jit(embed)
        self._t_spec_route = jax.jit(spec_route)
        self._t_decode_attn = jax.jit(decode_attn)
        self._t_prefill_prologue = jax.jit(prefill_prologue)
        self._t_prefill_attn = jax.jit(prefill_attn)
        self._t_ffn = jax.jit(ffn)
        self._t_tail_greedy = jax.jit(make_tail(False))
        self._t_tail_sampled = jax.jit(make_tail(True))

    def _tiered_ffn(self, l, x, h2, gates, experts, lane_mask, token_mask,
                    wrap_prefetch=True):
        """Host half of one tiered layer: sync the routed picks, make them
        resident (commit the in-flight prefetch / demand-fetch misses /
        LRU-evict), dispatch the FFN against cache slots, then put the
        NEXT layer's predicted experts in flight while this FFN runs.

        ``token_mask`` (host bool, one per routed token) excludes padded
        prefill rows and inactive batch rows from the residency working
        set: their FFN outputs are zeroed by ``lane_mask`` in BOTH paths
        and the dropless dispatch is per-row independent, so their picks
        may legally hit absent experts (slot −1) without affecting any
        live row's bits — and must not inflate the cache requirement or
        the access profile."""
        store = self.expert_store
        E = self.model.moe_cfg.padded_experts
        counts = np.bincount(
            np.asarray(experts).reshape(len(token_mask), -1)
            [token_mask].reshape(-1), minlength=E)
        slot_map = store.dispatch(l, counts, self.step_count)
        nb = store.take_rental_bytes()
        if nb and self.expert_runtime is not None:
            self.expert_runtime.record_rental(nb, source="expert_miss")
        x = self._t_ffn(x, h2, store.w_gate_cache, store.w_up_cache,
                        store.w_down_cache, jnp.asarray(slot_map), gates,
                        experts, lane_mask)
        # Double buffer: stage the next dispatch's predicted working set
        # while this one's grouped GEMM is in flight.  Within a step the
        # prediction is speculative gating — the next layer's router run
        # on the residual stream one attention delta early; at the
        # wrap-around the next step's first dispatch routes a token that
        # does not exist yet, so the store falls back to recency + the
        # guidance profile.
        nl = (l + 1) % self.model.cfg.n_layers
        if nl:
            self._spec_prefetch(nl, x, token_mask)
        elif wrap_prefetch:
            store.prefetch(0, self.step_count)
        return x

    def _spec_prefetch(self, l, x, token_mask):
        """Forecast layer ``l``'s routed experts from the residual stream
        ``x`` (speculative gating) and put the likeliest non-resident ones
        in flight.  Live rows only — masked rows route garbage by design
        (see ``_tiered_ffn``).  The forecast covers the rows' top-k picks
        plus a small margin of next-likeliest experts by router mass: the
        margin costs overlapped (hidden) bytes and buys back the picks
        the missing attention delta flips."""
        mcfg = self.model.moe_cfg
        live = int(np.sum(token_mask))
        if not live:
            return
        probs = np.asarray(self._t_spec_route(self.params, x, jnp.int32(l)))
        mass = probs[np.asarray(token_mask)].sum(axis=0)
        order = np.argsort(-mass, kind="stable")
        cap = min(mcfg.padded_experts, live * mcfg.top_k + 2)
        self.expert_store.prefetch(
            l, self.step_count, predicted=[int(e) for e in order[:cap]])

    def _tiered_decode(self, tokens, page_table, lengths, write_slot,
                       write_off, active, seeds, temperature, top_k, top_p,
                       use_sampler):
        x = self._t_embed(self.params, tokens)
        kq, vq = self.pool.k_hbm, self.pool.v_hbm
        lane = active[:, None, None]
        mask = np.asarray(active)
        for l in range(self.model.cfg.n_layers):
            x, h2, gates, experts, kq, vq = self._t_decode_attn(
                self.params, kq, vq, x, jnp.int32(l), page_table, lengths,
                write_slot, write_off, active)
            x = self._tiered_ffn(l, x, h2, gates, experts, lane, mask,
                                 wrap_prefetch=False)
        tail = self._t_tail_sampled if use_sampler else self._t_tail_greedy
        logits, next_tokens = tail(self.params, x, lengths, active, seeds,
                                   temperature, top_k, top_p)
        # The sampled token IS the next step's layer-0 residual stream
        # (x = embed(token)), so the one dispatch the in-step speculation
        # cannot see — the wrap-around to the next step's first layer —
        # gets its own forecast here, hiding the fetch under the tail +
        # host scheduling gap.  Batch rotation between steps makes this a
        # forecast, not an oracle; mispredictions demand-fetch as usual.
        if self.expert_store.double_buffer:
            self._spec_prefetch(
                0, self._t_embed(self.params, next_tokens[:, None]), mask)
        return logits, next_tokens, kq, vq

    def _tiered_prefill(self, tokens, page_table, slots, offs, n_real,
                        start):
        x, positions, lengths, valid = self._t_prefill_prologue(
            self.params, tokens, n_real, start)
        kq, vq = self.pool.k_hbm, self.pool.v_hbm
        lane = valid[None, :, None]
        mask = np.asarray(valid)
        for l in range(self.model.cfg.n_layers):
            x, h2, gates, experts, kq, vq = self._t_prefill_attn(
                self.params, kq, vq, x, jnp.int32(l), page_table, slots,
                offs, positions, lengths, valid)
            x = self._tiered_ffn(l, x, h2, gates, experts, lane, mask)
        return kq, vq

    def _run_prefill(self, tokens, page_table, slots, offs, n_real, start):
        """One bucketed prefill dispatch: the resident single-jit scan, or
        the layer-by-layer tiered pipeline when expert weights are
        off-chip.  Both return the updated (nk, nv) pools."""
        args = (jnp.asarray(tokens), jnp.asarray(page_table),
                jnp.asarray(slots), jnp.asarray(offs), jnp.int32(n_real),
                jnp.int32(start))
        if self.expert_store is not None:
            return self._tiered_prefill(*args)
        return self._prefill(self.params, self.pool.k_hbm, self.pool.v_hbm,
                             *args)

    # ========================================================== requests
    def add_request(self, request_id: int, prompt: List[int],
                    max_new: Optional[int] = None,
                    params: Optional[SamplingParams] = None) -> None:
        """Validate and enqueue; admission happens immediately if the pool
        has room, else at a later ``step()``.  ``params`` carries the
        request's sampling/stop behaviour.  The generation budget resolves
        HERE and nowhere else: ``params.max_tokens`` when set, else
        ``max_new``, else ``DEFAULT_MAX_TOKENS``."""
        if request_id in self.requests or request_id in self.finished:
            raise ValueError(f"duplicate request_id {request_id}")
        if params is None:
            params = SamplingParams()
        if params.max_tokens is not None:
            max_new = params.max_tokens
        elif max_new is None:
            max_new = DEFAULT_MAX_TOKENS
        self._validate_budget(request_id, prompt, max_new)
        req = Request(request_id=request_id, tokens=list(prompt),
                      max_new=max_new, params=params,
                      queued_step=self.step_count)
        self.requests[request_id] = req
        self.wait_queue.append(request_id)
        self._admit_waiting()

    def _validate_budget(self, request_id: int, prompt: Sequence[int],
                         max_new: int) -> None:
        """Reject requests that can NEVER run on this engine, with an error
        naming the knob (shared by ``add_request`` and the migration import
        path — a ticket must clear the same bars as a fresh submit)."""
        if not prompt:
            raise ValueError("empty prompt")
        P = self.cfg.page_size
        MP = self.cfg.max_pages_per_seq
        total_tokens = len(prompt) - 1 + max_new   # tokens written to KV
        if total_tokens > MP * P:
            raise ValueError(
                f"request {request_id} needs {total_tokens} KV tokens "
                f"({len(prompt)} prompt + {max_new} new) but "
                f"max_pages_per_seq={MP} * page_size={P} caps a sequence at "
                f"{MP * P}; raise ServeConfig.max_pages_per_seq or shorten "
                f"the request")
        prompt_pages = -(-max(len(prompt) - 1, 1) // P)
        lifetime_pages = -(-total_tokens // P)
        if min(prompt_pages + 1, lifetime_pages) > self.usable_hbm_pages:
            raise ValueError(
                f"request {request_id}'s prompt needs {prompt_pages} pages "
                f"(+1 to decode) but only {self.usable_hbm_pages} usable "
                f"HBM pages exist (hbm_pages={self.cfg.hbm_pages} minus the "
                f"scratch slot); raise ServeConfig.hbm_pages")

    # ------------------------------------------------- live migration
    def export_request(self, request_id: int) -> RequestTicket:
        """Snapshot a live request's serializable identity (see
        ``RequestTicket``): what another engine needs to continue the
        stream.  Read-only — pairs with ``remove_request`` once the
        handoff lands."""
        req = self.requests.get(request_id)
        if req is None:
            raise ValueError(
                f"cannot export request {request_id}: unknown or finished "
                f"id (finished results hand off as results, not tickets)")
        return RequestTicket(
            request_id=req.request_id, prompt=list(req.tokens),
            max_new=req.max_new, params=req.params,
            generated=list(req.generated))

    def remove_request(self, request_id: int) -> Request:
        """Withdraw a live request wholesale — live migration moved it to
        another engine.  Frees pages and prunes the live tables WITHOUT
        marking the request finished (its stream continues elsewhere);
        stale wait-queue entries self-clean in ``_admit_waiting``."""
        req = self.requests.pop(request_id, None)
        if req is None:
            raise ValueError(
                f"cannot remove request {request_id}: unknown or finished "
                f"id")
        self._release_pages(request_id)
        self._pending_chains.pop(request_id, None)
        self.last_logits.pop(request_id, None)
        return req

    def import_request(self, ticket: RequestTicket,
                       kv: Optional["PageExport"] = None) -> Request:
        """Continue another engine's request on THIS engine.

        Cold path (``kv=None``): rebuild by recompute — the ticket enters
        the wait queue with its generated tokens preloaded, and admission
        re-prefills prompt+generated exactly as a preempted request would
        (bitwise, because one-shot prefill == decode and sampling folds
        absolute positions).  Warm path (``kv`` from the source pool's
        ``export_pages``): re-attach any leading blocks this engine's
        prefix cache already holds (by chain hash — equal token chains mean
        equal keys mean bitwise-equal pages), import the remaining pages
        into the local pool, and resume decoding with zero recompute.  A
        warm import that cannot fit raises ``MemoryError`` with all partial
        state rolled back, so callers can retry cold."""
        rid = ticket.request_id
        if rid in self.requests or rid in self.finished:
            raise ValueError(f"duplicate request_id {rid}")
        self._validate_budget(rid, ticket.prompt, ticket.max_new)
        req = Request(request_id=rid, tokens=list(ticket.prompt),
                      max_new=ticket.max_new, params=ticket.params,
                      generated=list(ticket.generated),
                      queued_step=self.step_count)
        if kv is None:
            self.requests[rid] = req
            self.wait_queue.append(rid)
            self._admit_waiting()
            return req
        context = req.context
        n_ingest = len(context) - 1
        self.requests[rid] = req
        try:
            chain = []
            if self.prefix_cache is not None:
                chain = self.prefix_cache.match(
                    context[:n_ingest], self.step_count, count=False)
                hit_ids = [n.page_id for n in chain]
                missing = [pid for pid in hit_ids
                           if self.pool.pages[pid].hbm_slot is None]
                if missing:
                    self._ensure_free_hbm(len(missing), needed=hit_ids)
                    self.pool.swap_in_many(missing)
                for node in chain:
                    self.pool.attach(rid, node.page_id, self.step_count)
            self.pool.import_pages(kv.select_from(len(chain)), rid,
                                   self.step_count)
        except MemoryError:
            self._release_pages(rid)
            self.requests.pop(rid, None)
            raise
        req.pos = n_ingest
        req.state = "active"
        req.last_scheduled = self.step_count
        # Adopt the migrated prompt's full-page blocks into the local cache
        # under their (identical) chain hashes, so sharing survives the
        # membership change on the destination replica.
        self._insert_prefix(req, context, n_ingest, chain)
        return req

    # The explicit lifecycle contract (DESIGN.md §7): transitions outside
    # it raise a named ValueError instead of silently mutating queue state.
    #   pause:  active -> paused; paused -> no-op (idempotent);
    #           anything else raises.
    #   resume: paused -> active; preempted -> waiting (re-enqueue);
    #           active/waiting -> no-op (already running / already queued);
    #           finished or unknown ids raise.
    def _lookup(self, request_id: int, verb: str) -> Request:
        req = self.requests.get(request_id)
        if req is None:
            if request_id in self.finished:
                raise ValueError(
                    f"cannot {verb} request {request_id}: already finished "
                    f"(drain the result with pop_finished)")
            raise ValueError(
                f"cannot {verb} request {request_id}: unknown id")
        return req

    def pause(self, request_id: int):
        req = self._lookup(request_id, "pause")
        if req.state == "paused":
            return                       # idempotent
        if req.state != "active":
            raise ValueError(
                f"cannot pause request {request_id} in state "
                f"{req.state!r}: only active requests pause (a "
                f"{req.state} request holds no schedulable position)")
        req.state = "paused"

    def resume(self, request_id: int):
        req = self._lookup(request_id, "resume")
        if req.state in ("active", "waiting"):
            return                       # idempotent / already queued
        if req.state == "paused":
            req.state = "active"
        elif req.state == "preempted":
            # Pages were dropped; re-prefill via the admission path (exact:
            # one-shot prefill == decode bitwise, and sampling folds the
            # absolute stream position, so replay resamples identically).
            req.state = "waiting"
            req.queued_step = self.step_count
            self.wait_queue.append(request_id)
            self._admit_waiting()

    def cancel(self, request_id: int) -> Request:
        """Withdraw a live request in ANY state (waiting, prefilling,
        active, paused, preempted): pages free immediately, the stale
        wait-queue entry self-cleans at the next admission sweep, and the
        result parks in ``finished`` with ``finish_reason="cancelled"``
        (tokens generated so far are kept).  Finished or unknown ids raise
        the usual named ``ValueError``."""
        req = self._lookup(request_id, "cancel")
        self._finish(req, reason="cancelled")
        return req

    def pop_finished(self, request_id: Optional[int] = None):
        """Drain finished requests (all, or one) so long-lived engines do
        not accumulate results forever."""
        if request_id is not None:
            return self.finished.pop(request_id)
        out, self.finished = self.finished, {}
        return out

    # ------------------------------------------------------- admission
    def _admit_waiting(self):
        """Policy-ordered admission: admit the policy's head while its
        (re-)prefill pages fit the free logical capacity, preempting
        paused requests' pages when that unblocks the head.  Admission
        never skips past a head that does not fit (bounded head-of-line
        blocking is what keeps every policy starvation-free)."""
        P = self.cfg.page_size
        while self.wait_queue:
            head = self.requests.get(self.wait_queue[0])
            if head is None or head.state != "waiting":  # cancelled/stale
                self.wait_queue.popleft()
                continue
            waiting = [r for r in (self.requests.get(rid)
                                   for rid in self.wait_queue)
                       if r is not None and r.state == "waiting"]
            req = self.scheduler.admission_order(waiting, self)[0]
            n_ingest = len(req.context) - 1
            n_pages = -(-n_ingest // P) if n_ingest else 0
            remaining = req.max_new - len(req.generated)
            pages_total = -(-(n_ingest + remaining) // P)
            if min(n_pages + 1, pages_total) > self.usable_hbm_pages:
                # A preempted request whose regenerated context outgrew the
                # fast tier can never decode again: finish it, don't wedge
                # the queue head forever.
                self.wait_queue.remove(req.request_id)
                self._finish(req, reason="truncated")
                continue
            # Admit with one page of growth slack (capped at the request's
            # real lifetime need), so an admitted request can always decode
            # at least a page's worth before capacity pressure returns.
            if min(n_pages + 1, pages_total) > self.free_logical_pages():
                # Cold cached prefixes yield their logical pages before any
                # live request is preempted.
                shortfall = (min(n_pages + 1, pages_total)
                             - self.free_logical_pages())
                if (self.prefix_cache is not None
                        and self.prefix_cache.reclaim(shortfall)):
                    continue
                if not self._preempt_one():
                    return              # head waits; order preserved
                continue
            self.wait_queue.remove(req.request_id)
            self.admissions += 1
            self.admission_wait_steps += self.step_count - req.queued_step
            self._admit(req)

    def _admit(self, req: Request) -> None:
        """Move an admitted request out of the wait queue: straight to
        ``active`` via eager whole-suffix prefill, or — with
        chunked-prefill interleaving on — into ``prefilling``, where
        ``_advance_prefills`` ingests budgeted chunks each step."""
        budget = self.scheduler.step_budget(self)
        if self.cfg.prefill == "one_shot" and budget.prefill_tokens > 0:
            self._begin_prefill(req)
        else:
            self._prefill_request(req)
            req.state = "active"
        req.last_scheduled = self.step_count

    def _begin_prefill(self, req: Request) -> None:
        """Start an interleaved prefill: consult the prefix cache, allocate
        the WHOLE uncovered suffix's pages now (admission already budgeted
        them — allocating lazily per chunk could lose the race against
        later admissions), and park the request in ``prefilling`` state.
        Trivial ingests (empty / full cache hit) go straight to active."""
        context = req.context
        n_ingest = len(context) - 1
        if n_ingest == 0:
            req.pos = 0
            req.state = "active"
            return
        P = self.cfg.page_size
        rid = req.request_id
        chain = self._match_prefix(req, context, n_ingest)
        covered = len(chain) * P
        if n_ingest - covered == 0:
            req.pos = n_ingest           # full hit: nothing to dispatch
            req.state = "active"
            return
        n_prefix_pages = covered // P
        n_pages = -(-n_ingest // P) - n_prefix_pages
        self._ensure_free_hbm(
            n_pages, needed=[p.page_id
                             for p in self.pool.request_pages(rid)])
        for idx in range(n_pages):
            self.pool.allocate(rid, n_prefix_pages + idx, self.step_count)
        req.pos = covered
        req.state = "prefilling"
        self._pending_chains[rid] = chain

    def _advance_prefills(self) -> None:
        """Spend this step's prefill token budget across ``prefilling``
        requests in the policy's prefill order; a request whose prompt
        completes joins the decode-eligible actives the same step."""
        prefilling = [r for r in self.requests.values()
                      if r.state == "prefilling"]
        if not prefilling:
            return
        budget = self.scheduler.step_budget(self).prefill_tokens
        if budget <= 0:                  # budget turned off mid-flight:
            budget = float("inf")        # drain rather than wedge forever
        for req in self.scheduler.prefill_order(prefilling, self):
            if budget <= 0:
                break
            n_ingest = len(req.context) - 1
            n = int(min(budget, n_ingest - req.pos))
            self._prefill_chunk(req, n)
            budget -= n
            self.scheduler.on_tokens(req, n, self)
            if req.pos >= n_ingest:
                chain = self._pending_chains.pop(req.request_id, [])
                self._insert_prefix(req, req.context, n_ingest, chain)
                req.state = "active"
                req.last_scheduled = self.step_count

    def _prefill_chunk(self, req: Request, n: int) -> None:
        """Ingest ``req.context[req.pos : req.pos+n]`` with one bucketed
        dispatch at absolute start ``req.pos`` — the same jitted closure as
        one-shot prefill, so every chunking of a prompt produces
        bitwise-identical K/V (rows attend by absolute length over the
        request's full page table)."""
        context = req.context
        P = self.cfg.page_size
        MP = self.cfg.max_pages_per_seq
        rid = req.request_id
        start = req.pos
        my_pages = self.pool.request_pages(rid)
        # The dispatch's table covers every page, so the whole sequence
        # must be HBM-resident (earlier chunks' pages may have been evicted
        # between steps — demand swap-in is a rental like any other).  Same
        # atomic batched exchange as _prepare_batch: evictions and swap-ins
        # stage together, so residency succeeds even when both free lists
        # are empty (an evict-then-swap-in order would need host slots that
        # a tightly-sized pool does not have).
        missing = [p.page_id for p in my_pages if p.hbm_slot is None]
        if missing:
            shortfall = len(missing) - len(self.pool.free_hbm)
            victims: List[int] = []
            if shortfall > 0:
                exclude = {p.page_id for p in my_pages}
                cands = [p for p in self.pool.pages.values()
                         if p.hbm_slot is not None
                         and p.page_id not in exclude]
                victims = self.eviction.pick_many(cands, self, shortfall)
                if len(victims) < shortfall:
                    raise MemoryError("no evictable page")  # unreachable:
            self.pool.exchange(victims, missing)      # chunk pages <= usable
            self._note_swap_in(len(missing))
            my_pages = self.pool.request_pages(rid)
        by_index = {p.index_in_seq: p for p in my_pages}
        S = max(P, 1 << (n - 1).bit_length())
        tokens = np.zeros((S,), np.int32)
        tokens[:n] = context[start:start + n]
        slots = np.full((S,), self.scratch_slot, np.int32)
        offs = np.zeros((S,), np.int32)
        written = set()
        for t in range(n):
            idx, off = divmod(start + t, P)
            page = by_index[idx]
            slots[t] = page.hbm_slot
            offs[t] = off
            page.tokens_used = max(page.tokens_used, off + 1)
            written.add(idx)
        table = np.full((MP,), -1, np.int32)
        for p in my_pages:
            table[p.index_in_seq] = p.hbm_slot
        nk, nv = self._run_prefill(tokens, table, slots, offs, n, start)
        self.pool.k_hbm, self.pool.v_hbm = nk, nv
        req.pos = start + n
        for idx in written:
            if not by_index[idx].shared:
                by_index[idx].accesses += 1   # chunk's write set
        self.prefill_dispatches += 1
        self.prefill_chunks += 1
        self.prefill_tokens += n

    def _preempt_one(self) -> bool:
        """Drop ALL pages of the policy's chosen paused victim (preempt by
        recompute: resume re-prefills prompt+generated)."""
        victims = [r for r in self.requests.values()
                   if r.state == "paused"
                   and self.pool.request_pages(r.request_id)]
        if not victims:
            return False
        victim = self.scheduler.preempt_paused(victims, self)
        self._release_pages(victim.request_id)
        victim.pos = 0
        victim.state = "preempted"
        self.preemptions += 1
        return True

    def _release_pages(self, request_id: int):
        """Drop every page reference the request holds.  Shared prefix
        pages survive on the cache's reference; only pages that actually
        died leave the eviction policy's recommendation view."""
        freed = self.pool.release_request(request_id)
        if self.kv_backend is not None:
            self.kv_backend.forget_pages(freed)

    def _reclaim_logical_pages(self):
        """Nothing schedulable while active requests exist — logical pages
        are exhausted.  Reclaim by preempting a paused page-holder first,
        else the policy's running victim (active or mid-prefill; it
        re-enters the wait queue and recomputes later).  A request that is
        alone against the whole pool can never grow or finish: truncate
        it."""
        if self.prefix_cache is not None and self.prefix_cache.reclaim(1):
            return
        if self._preempt_one():
            return
        cands = [r for r in self.requests.values()
                 if r.state in ("active", "prefilling")]
        holders = [r for r in cands
                   if self.pool.request_pages(r.request_id)]
        if not holders:
            return
        if len(cands) == 1 and holders == cands:
            self._finish(cands[0], reason="truncated")
            return
        victim = self.scheduler.preempt_active(holders, self)
        self._release_pages(victim.request_id)
        victim.pos = 0
        victim.state = "waiting"
        victim.queued_step = self.step_count
        self._pending_chains.pop(victim.request_id, None)
        self.wait_queue.append(victim.request_id)
        self.preemptions += 1

    # -------------------------------------------------------- prefill
    def _match_prefix(self, req: Request, context: List[int],
                      n_ingest: int) -> list:
        """Consult the prefix cache and attach every matched full-page
        block to the request by reference.  Returns the matched node chain
        (empty without a cache or on a miss).  Matched pages are made
        HBM-resident HERE: the suffix dispatch (or first decode step, on a
        full hit) attends over them."""
        if self.prefix_cache is None:
            return []
        chain = self.prefix_cache.match(context[:n_ingest], self.step_count)
        if not chain:
            return []
        hit_ids = [n.page_id for n in chain]
        missing = [pid for pid in hit_ids
                   if self.pool.pages[pid].hbm_slot is None]
        if missing:
            self._ensure_free_hbm(len(missing), needed=hit_ids)
            self.pool.swap_in_many(missing)
            self.swap_in_events += len(missing)
            # A hit on a demoted prefix is a rental payment against the
            # PREFIX controller's ledger (it made the demotion call).
            if self.prefix_runtime is not None:
                self.prefix_runtime.record_rental(
                    self.pool.page_bytes * len(missing),
                    source="prefix_hit")
        for node in chain:
            self.pool.attach(req.request_id, node.page_id, self.step_count)
        self.saved_prefill_tokens += len(chain) * self.cfg.page_size
        return chain

    def _insert_prefix(self, req: Request, context: List[int],
                       n_ingest: int, chain: list) -> None:
        """Adopt the request's freshly written full-page PROMPT blocks into
        the cache (generated tokens never extend a shareable prefix — the
        reuse signal is the shared system prompt, and K/V content is
        token-determined either way).  ``chain`` is what ``_match_prefix``
        already covered; insertion continues the radix walk from there."""
        if self.prefix_cache is None:
            return
        self.prefix_cache.insert(
            context[:n_ingest], self.pool.request_pages(req.request_id),
            limit=min(n_ingest, len(req.tokens)), step=self.step_count,
            chain=chain)

    def _prefill_request(self, req: Request):
        """Ingest ``req.context[:-1]`` (the last token is fed by the first
        decode step).  The prefix cache is consulted first: matched blocks
        attach by reference and only the uncovered suffix is ingested — one
        jitted dispatch in one_shot mode (a FULL hit dispatches nothing);
        the chunked oracle steps suffix tokens through decode."""
        context = req.context
        n_ingest = len(context) - 1
        if n_ingest == 0:
            req.pos = 0
            return
        P = self.cfg.page_size
        rid = req.request_id
        chain = self._match_prefix(req, context, n_ingest)
        covered = len(chain) * P
        n_suffix = n_ingest - covered
        if n_suffix == 0:
            # Full hit: every ingested token is already in shared pages.
            req.pos = n_ingest
            return
        if self.cfg.prefill == "chunked":
            req.pos = covered
            for t in context[covered:-1]:
                self._decode_one(req, t)
            self.prefill_tokens += n_suffix
            self._insert_prefix(req, context, n_ingest, chain)
            return
        MP = self.cfg.max_pages_per_seq
        n_prefix_pages = covered // P
        n_pages = -(-n_ingest // P) - n_prefix_pages
        self._ensure_free_hbm(
            n_pages, needed=[p.page_id
                             for p in self.pool.request_pages(rid)])
        pages = [self.pool.allocate(rid, n_prefix_pages + idx,
                                    self.step_count)
                 for idx in range(n_pages)]
        # Pad the token axis to a power-of-two bucket (>= one page) so jit
        # compiles per bucket, not per suffix length.
        S = max(P, 1 << (n_suffix - 1).bit_length())
        tokens = np.zeros((S,), np.int32)
        tokens[:n_suffix] = context[covered:n_ingest]
        slots = np.full((S,), self.scratch_slot, np.int32)
        offs = np.zeros((S,), np.int32)
        # ``covered`` is page-aligned, so suffix token t lands at page t//P
        # offset t%P of the private tail.
        for t in range(n_suffix):
            slots[t] = pages[t // P].hbm_slot
            offs[t] = t % P
        table = np.full((MP,), -1, np.int32)
        for p in self.pool.request_pages(rid):
            table[p.index_in_seq] = p.hbm_slot
        nk, nv = self._run_prefill(tokens, table, slots, offs, n_suffix,
                                   covered)
        self.pool.k_hbm, self.pool.v_hbm = nk, nv
        req.pos = n_ingest
        for i, p in enumerate(pages):
            p.accesses += 1         # the dispatch's access set: every page
            p.tokens_used = min(P, n_suffix - i * P)
        self.prefill_dispatches += 1
        self.prefill_tokens += n_suffix
        self._insert_prefix(req, context, n_ingest, chain)

    # ------------------------------------------------------- page mgmt
    def _note_swap_in(self, n_pages: int):
        """Demand swap-ins are rental payments; one batched transfer is
        still ``n_pages`` pages of rent."""
        self.swap_in_events += n_pages
        if self.runtime is not None and n_pages:
            self.runtime.record_rental(self.pool.page_bytes * n_pages,
                                       source="swap_in")

    def _page_for_write(self, req: Request) -> Tuple[int, int]:
        """(hbm_slot, offset) for the next token.  The batch-prepare pass
        has already made every page resident and allocated the write page.
        Copy-on-write guard: sharing is full-page granular, so the write
        target is never shared on the normal path (a request's first
        private token lands past the covered prefix on a fresh page) — but
        if a shared page IS the target, the request gets a private copy
        rather than corrupting every other holder's KV."""
        idx, off = divmod(req.pos, self.cfg.page_size)
        page = self.pool.request_pages(req.request_id)[idx]
        if page.refcount > 1 or page.shared:
            page = self.pool.copy_page(page.page_id, req.request_id,
                                       self.step_count)
        page.tokens_used = off + 1
        return page.hbm_slot, off

    def _prepare_batch(self, reqs: List[Request]):
        """Make the whole scheduled batch resident with ONE atomic batched
        exchange (evictions + swap-ins staged together, so it succeeds even
        when both free lists are empty), then allocate write pages."""
        P = self.cfg.page_size
        need_ids: List[int] = []
        missing: List[int] = []
        n_alloc = 0
        for r in reqs:
            pages = self.pool.request_pages(r.request_id)
            need_ids.extend(p.page_id for p in pages)
            missing.extend(p.page_id for p in pages if p.hbm_slot is None)
            if r.pos // P >= len(pages):
                n_alloc += 1
        shortfall = len(missing) + n_alloc - len(self.pool.free_hbm)
        victims: List[int] = []
        if shortfall > 0:
            exclude = set(need_ids)
            cands = [p for p in self.pool.pages.values()
                     if p.hbm_slot is not None and p.page_id not in exclude]
            victims = self.eviction.pick_many(cands, self, shortfall)
            if len(victims) < shortfall:
                raise MemoryError("no evictable page")   # unreachable under
        if victims or missing:                           # scheduler budgets
            self.pool.exchange(victims, missing)
            self._note_swap_in(len(missing))
        for r in reqs:
            idx = r.pos // P
            if idx >= len(self.pool.request_pages(r.request_id)):
                self.pool.allocate(r.request_id, idx, self.step_count)

    def _ensure_free_hbm(self, n: int, needed: List[int]):
        shortfall = n - len(self.pool.free_hbm)
        if shortfall <= 0:
            return
        exclude = set(needed)
        cands = [p for p in self.pool.pages.values()
                 if p.hbm_slot is not None and p.page_id not in exclude]
        victims = self.eviction.pick_many(cands, self, shortfall)
        if len(victims) < shortfall:
            raise MemoryError("no evictable page")   # unreachable under
        self.pool.swap_out_many(victims)             # scheduler budgets

    # ============================================================ stepping
    def _decode_one(self, req: Request, token: int) -> int:
        """Single-request decode (the chunked-prefill oracle path)."""
        self._prepare_batch([req])
        self.prefill_dispatches += 1
        return self._run_batch([(req, token)])[0]

    def _schedule(self) -> List[Request]:
        """Pack active requests (policy decode order) under the HBM-slot
        and logical-page budgets, so the batch can always be made resident
        without evicting its own members and every allocation can succeed."""
        active = [r for r in self.requests.values() if r.state == "active"]
        if not active:
            return []
        budget = self.scheduler.step_budget(self)
        row_cap = min(self.cfg.max_batch, max(budget.decode_requests, 0))
        P = self.cfg.page_size
        sched: List[Request] = []
        hbm_budget = self.usable_hbm_pages
        logical_budget = self.free_logical_pages()
        for r in self.scheduler.decode_order(active, self):
            if len(sched) == row_cap:
                break
            n_pages = len(self.pool.request_pages(r.request_id))
            need = max(n_pages, r.pos // P + 1)
            if need > self.usable_hbm_pages:
                # Outgrew the fast tier entirely: can never decode again.
                self._finish(r, reason="truncated")
                continue
            grow = need - n_pages
            if need > hbm_budget or grow > logical_budget:
                self.starved_steps += 1     # waits, aging via last_scheduled
                continue
            sched.append(r)
            hbm_budget -= need
            logical_budget -= grow
        return sched

    def step(self) -> Dict[int, int]:
        """One engine step: admit, advance interleaved prefills, schedule,
        decode, bookkeeping."""
        self.step_count += 1
        self.scheduler.on_step(self)
        self._admit_waiting()
        self._advance_prefills()
        sched = self._schedule()
        if not sched and any(r.state == "active"
                             for r in self.requests.values()):
            self._reclaim_logical_pages()
            sched = self._schedule()
        out: Dict[int, int] = {}
        if sched:
            pairs = []
            for r in sched:
                nxt = (r.generated[-1] if r.generated
                       else (r.tokens[-1] if r.tokens else 1))
                pairs.append((r, nxt))
            self._prepare_batch(sched)
            toks = self._run_batch(pairs)
            for r, t in zip(sched, toks):
                r.generated.append(int(t))
                self.scheduler.on_tokens(r, 1, self)
                out[r.request_id] = int(t)
                if int(t) in r.params.stop_token_ids:
                    self._finish(r, reason="stop")
                elif len(r.generated) >= r.max_new:
                    self._finish(r, reason="length")
        self._tick_controllers()
        return out

    def _tick_controllers(self) -> None:
        """MaybeMigrate for every guidance controller, in a FIXED order:
        KV pages -> shared prefixes -> expert weights.  The order is part
        of the engine's replay contract — each controller's event stream
        is pinned by regression tests, and a reorder would change which
        controller sees the interval's free HBM first.  Add new
        controllers at the END of this list."""
        for rt in (self.runtime, self.prefix_runtime, self.expert_runtime):
            if rt is not None:
                rt.on_step()

    def _finish(self, req: Request, reason: str = "length"):
        """Lifecycle cleanup: free pages, prune the live tables (requests,
        eviction recs, logits), park the result in ``finished`` with its
        ``finish_reason`` (stop | length | truncated | cancelled)."""
        assert reason in ("stop", "length", "truncated", "cancelled"), reason
        self._pending_chains.pop(req.request_id, None)
        self._release_pages(req.request_id)
        req.state = "finished"
        req.finish_reason = reason
        req.truncated = reason == "truncated"
        if req.truncated:
            self.truncations += 1
        self.finish_counts[reason] += 1
        self.requests.pop(req.request_id, None)
        self.last_logits.pop(req.request_id, None)
        self.finished[req.request_id] = req

    def _run_batch(self, pairs) -> List[int]:
        """Decode one batch.  Pages are already resident and write pages
        allocated (``_prepare_batch``).  The next token comes back sampled
        from inside the jitted dispatch: each row's ``SamplingParams`` ride
        along as batched arrays, and the PRNG folds the row's absolute
        stream position (== ``lengths``), so a preempted-and-recomputed
        request resamples the identical stream."""
        B = self.cfg.max_batch
        MP = self.cfg.max_pages_per_seq
        tokens = np.zeros((B, 1), np.int32)
        table = np.full((B, MP), -1, np.int32)
        lengths = np.zeros((B,), np.int32)
        wslot = np.full((B,), self.scratch_slot, np.int32)
        woff = np.zeros((B,), np.int32)
        active = np.zeros((B,), bool)
        seeds = np.zeros((B,), np.int32)
        temperature = np.zeros((B,), np.float32)   # 0 = greedy argmax
        top_k = np.zeros((B,), np.int32)
        top_p = np.ones((B,), np.float32)
        for i, (req, tok) in enumerate(pairs):
            req.last_scheduled = self.step_count
            slot, off = self._page_for_write(req)
            req.pos += 1
            pages = self.pool.request_pages(req.request_id)
            for p in pages:
                p.accesses += 1          # exact access model
                table[i, p.index_in_seq] = p.hbm_slot
            tokens[i, 0] = tok
            lengths[i] = req.pos
            wslot[i] = slot
            woff[i] = off
            active[i] = True
            sp = req.params
            # seed=None means "independent stream per request": derive from
            # the request id so identical prompts in one batch do not
            # sample bitwise-identical tokens, while replay (same request
            # id, same positions) stays exact.  Auto-derived seeds live in
            # the int32 SIGN-BIT half of the space — explicit seeds are
            # validated to [0, 2**31), so a user seed can never alias a
            # request-id-derived stream.
            if sp.seed is not None:
                seeds[i] = sp.seed
            else:
                seeds[i] = (0x80000000 | (req.request_id & 0x7FFFFFFF)) \
                    - (1 << 32)
            temperature[i] = sp.temperature
            top_k[i] = sp.top_k
            top_p[i] = sp.top_p
        # Greedy-only batches (the default) take the argmax-epilogue
        # variant: no sort/cumsum/Gumbel work on the hot path, and the
        # sampled variant is never even compiled unless someone samples.
        greedy = all(req.params.greedy for req, _ in pairs)
        args = (jnp.asarray(tokens), jnp.asarray(table),
                jnp.asarray(lengths), jnp.asarray(wslot), jnp.asarray(woff),
                jnp.asarray(active), jnp.asarray(seeds),
                jnp.asarray(temperature), jnp.asarray(top_k),
                jnp.asarray(top_p))
        if self.expert_store is not None:
            logits, toks, nk, nv = self._tiered_decode(
                *args, use_sampler=not greedy)
        else:
            decode = self._decode_greedy if greedy else self._decode_sampled
            logits, toks, nk, nv = decode(
                self.params, self.pool.k_hbm, self.pool.v_hbm, *args)
        self.pool.k_hbm, self.pool.v_hbm = nk, nv
        if self.cfg.keep_logits:
            logits_np = np.asarray(logits)
            for i, (req, _) in enumerate(pairs):
                self.last_logits[req.request_id] = logits_np[i]
        toks = np.asarray(toks)
        return [int(toks[i]) for i in range(len(pairs))]

    # --------------------------------------------------------- telemetry
    def stats(self) -> Dict[str, float]:
        pc = self.prefix_cache
        prefix = {
            "prefix_lookups": pc.lookups,
            "prefix_hit_requests": pc.hit_requests,
            "prefix_hit_pages": pc.hit_pages,
            "prefix_hit_rate": pc.hit_rate,
            "prefix_cached_pages": len(pc),
            "prefix_inserted_pages": pc.inserted_pages,
            "prefix_evicted_pages": pc.evicted_pages,
        } if pc is not None else {}
        es = self.expert_store
        expert = {
            "expert_cache_slots": es.cache_slots,
            "expert_resident_blocks": sum(
                1 for b in es.blocks.values() if b.slot is not None),
            "expert_demand_fetches": es.demand_fetches,
            "expert_prefetch_fetches": es.prefetch_fetches,
            "expert_prefetch_hits": es.prefetch_hits,
            "expert_dropped_prefetches": es.dropped_prefetches,
            "expert_evictions": es.evictions,
            "expert_bytes_fetched": es.bytes_fetched,
            "expert_transfer_events": es.transfer_events,
        } if es is not None else {}
        return {
            **prefix,
            **expert,
            "saved_prefill_tokens": self.saved_prefill_tokens,
            "steps": self.step_count,
            "swap_ins": self.pool.swaps_in,
            "swap_outs": self.pool.swaps_out,
            "bytes_moved": self.pool.bytes_moved,
            "transfer_events": self.pool.transfer_events,
            "exported_pages": self.pool.exported_pages,
            "imported_pages": self.pool.imported_pages,
            "hbm_pages_used": self.pool.hbm_used(),
            "live_requests": len(self.requests),
            "waiting_requests": len(self.wait_queue),
            # Queue depth counts LIVE waiting requests (stale queue entries
            # from cancel/migrate excluded), plus mid-prefill occupancy.
            "queue_depth": sum(1 for r in self.requests.values()
                               if r.state == "waiting"),
            "prefilling_requests": sum(1 for r in self.requests.values()
                                       if r.state == "prefilling"),
            "finished_requests": len(self.finished),
            "prefill_dispatches": self.prefill_dispatches,
            "prefill_tokens": self.prefill_tokens,
            "prefill_chunks": self.prefill_chunks,
            "admissions": self.admissions,
            "admission_wait_steps": self.admission_wait_steps,
            "mean_admission_wait_steps": (
                self.admission_wait_steps / max(self.admissions, 1)),
            "preemptions": self.preemptions,
            "starved_steps": self.starved_steps,
            "truncations": self.truncations,
            "finished_stop": self.finish_counts["stop"],
            "finished_length": self.finish_counts["length"],
            "finished_truncated": self.finish_counts["truncated"],
            "finished_cancelled": self.finish_counts["cancelled"],
        }
