"""Batched serving engine with guided KV-page tiering.

The engine serves dense/MoE decoder models from a paged two-tier KV cache
(serve/kvcache.py).  Each *request* is an allocation site; its pages are the
chunks.  Every decode step the engine (a) schedules up to ``max_batch``
active requests, (b) ensures their pages are HBM-resident — swap-ins are the
rental the controller pays for wrong placement, (c) runs the jitted paged
decode step, (d) updates exact per-page access counts.  At the decision
interval the paper's machinery runs end to end: profile -> age-fragmented
thermos -> ski-rental break-even -> page migrations.

Eviction between intervals (when a swap-in needs a free slot) follows the
last recommendation; pages recommended fast never lose to pages recommended
slow.  Policies "lru" and "fifo" are selectable baselines for the serving
benchmark.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import CLX, TPU_V5E, GDTConfig, HardwareModel
from ..core.fragmentation import ChunkStats, collapse_to_chunks, explode_profile
from ..core.profiler import ArenaProfile, IntervalProfile
from ..core.recommend import recommend
from ..core.skirental import decide
from ..models.layers import lm_head, mlp, rmsnorm, rope
from ..models.moe import moe
from ..models.transformer import Model
from .kvcache import PagedKVPool

F32 = jnp.float32


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 4
    page_size: int = 16
    hbm_pages: int = 64
    host_pages: int = 256
    policy: str = "gdt"            # gdt | lru | fifo
    interval_steps: int = 16
    strategy: str = "thermos"
    num_fragments: int = 4
    max_pages_per_seq: int = 32
    # Algorithm 1's optional ReweightProfile: decay access counters each
    # interval so placement tracks recent behaviour (sessions pause/resume
    # far faster than HPC phase shifts, so serving defaults to decaying).
    access_decay: float = 0.5


@dataclasses.dataclass
class Request:
    request_id: int
    tokens: List[int]
    max_new: int
    generated: List[int] = dataclasses.field(default_factory=list)
    state: str = "active"          # active | paused | finished
    pos: int = 0                   # tokens written to KV so far
    last_scheduled: int = 0


class Engine:
    def __init__(self, model: Model, params, cfg: ServeConfig,
                 hw: HardwareModel = TPU_V5E):
        assert model.cfg.family in ("dense", "moe"), \
            "paged engine serves decoder LMs"
        self.model = model
        self.params = params
        self.cfg = cfg
        self.hw = hw
        mc = model.cfg
        self.pool = PagedKVPool(
            n_layers=mc.n_layers, page_size=cfg.page_size,
            kv_heads=mc.kv_heads, head_dim=model.attn_cfg.head_dim,
            hbm_pages=cfg.hbm_pages, host_pages=cfg.host_pages,
            dtype=mc.dtype)
        self.requests: Dict[int, Request] = {}
        self.step_count = 0
        self.last_recs: Dict[int, bool] = {}   # page_id -> recommended fast
        # Reserve one HBM slot as the write target for inactive batch rows,
        # so the batched scatter never collides with a real page.
        self.scratch_slot = self.pool.free_hbm.pop(0)
        self._decode = jax.jit(self._build_decode())
        self.swap_in_events = 0
        self.decisions = []

    # ========================================================= jit decode
    def _build_decode(self):
        model, cfg = self.model, self.cfg
        mc = model.cfg
        acfg = model.attn_cfg
        K, dh = mc.kv_heads, acfg.head_dim
        P = cfg.page_size
        from ..kernels.ops import paged_attention

        def step(params, k_pool, v_pool, tokens, page_table, lengths,
                 write_slot, write_off, active):
            """tokens: (B,1); page_table: (B,MP) HBM slots or -1;
            lengths: (B,) incl. new token; write_slot/off: (B,) where the
            new token's KV goes; active: (B,) bool."""
            x = jnp.take(params["embed"]["tok"], tokens, axis=0)  # (B,1,d)

            def body(carry, xs):
                x = carry
                lp, kp, vp = xs          # kp/vp: (N,P,K,dh)
                h = rmsnorm(lp["ln1"], x)
                B = h.shape[0]
                q = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wq"])[:, 0]
                k1 = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wk"])[:, 0]
                v1 = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wv"])[:, 0]
                posn = (lengths - 1)[:, None]
                q = rope(q[:, None], posn, acfg.rope_theta)[:, 0]
                k1 = rope(k1[:, None], posn, acfg.rope_theta)[:, 0]
                # Inactive rows target the reserved scratch slot, so the
                # batched scatter is always collision-free.
                kp = kp.at[write_slot, write_off].set(k1.astype(kp.dtype))
                vp = vp.at[write_slot, write_off].set(v1.astype(vp.dtype))
                o = paged_attention(q, kp, vp, page_table, lengths,
                                    window=acfg.window)
                y = jnp.einsum("bhk,hkd->bd", o.reshape(B, acfg.n_heads, dh),
                               lp["attn"]["wo"])[:, None]
                x = x + y
                h2 = rmsnorm(lp["ln2"], x)
                if mc.family == "moe":
                    x = x + moe(lp["moe"], h2, model.moe_cfg)
                else:
                    x = x + mlp(lp["mlp"], h2)
                return x, (kp, vp)

            x, (nk, nv) = jax.lax.scan(
                body, x, (params["layers"], k_pool, v_pool))
            x = rmsnorm(params["final_ln"], x)
            logits = lm_head(params["head"], x)[:, 0]
            return logits, nk, nv

        return step

    # ========================================================== requests
    def add_request(self, request_id: int, prompt: List[int],
                    max_new: int = 8) -> None:
        req = Request(request_id=request_id, tokens=list(prompt),
                      max_new=max_new)
        self.requests[request_id] = req
        # Prefill by stepping the prompt tokens through decode (exact; the
        # contiguous fast path is model.prefill + paginate, not needed at
        # engine-test scale).  The last prompt token is fed by the first
        # step(), whose logits produce the first generated token.
        for t in prompt[:-1]:
            self._decode_one(req, t)

    def pause(self, request_id: int):
        self.requests[request_id].state = "paused"

    def resume(self, request_id: int):
        req = self.requests[request_id]
        if req.state == "paused":
            req.state = "active"

    # ------------------------------------------------------- page mgmt
    def _page_for_write(self, req: Request) -> tuple:
        """(hbm_slot, offset) for the next token; allocates as needed."""
        idx, off = divmod(req.pos, self.cfg.page_size)
        pages = self.pool.request_pages(req.request_id)
        if idx >= len(pages):
            self._ensure_free_hbm(1, needed=[p.page_id for p in pages])
            page = self.pool.allocate(req.request_id, idx, self.step_count)
            pages.append(page)
        page = pages[idx]
        if page.hbm_slot is None:
            self._ensure_free_hbm(
                1, needed=[p.page_id for p in pages])
            self.pool.swap_in(page.page_id)
            self.swap_in_events += 1
        page.tokens_used = off + 1
        return page.hbm_slot, off

    def _ensure_resident(self, req: Request):
        pages = self.pool.request_pages(req.request_id)
        needed = [p.page_id for p in pages]
        for p in pages:
            if p.hbm_slot is None:
                self._ensure_free_hbm(1, needed=needed)
                self.pool.swap_in(p.page_id)
                self.swap_in_events += 1

    def _ensure_free_hbm(self, n: int, needed: List[int]):
        while len(self.pool.free_hbm) < n:
            victim = self._pick_victim(exclude=set(needed))
            if victim is None:
                raise MemoryError("no evictable page")
            self.pool.swap_out(victim)

    def _pick_victim(self, exclude) -> Optional[int]:
        cands = [p for p in self.pool.pages.values()
                 if p.hbm_slot is not None and p.page_id not in exclude]
        if not cands:
            return None
        if self.cfg.policy == "gdt" and self.last_recs:
            # Demote pages the last recommendation wanted slow first.
            cold = [p for p in cands if not self.last_recs.get(p.page_id,
                                                               False)]
            if cold:
                cands = cold
        if self.cfg.policy == "fifo":
            return min(cands, key=lambda p: p.birth_step).page_id
        # lru (and gdt tie-break): least recently used request first.
        return min(
            cands,
            key=lambda p: self.requests[p.request_id].last_scheduled
        ).page_id

    # ============================================================ stepping
    def _decode_one(self, req: Request, token: int) -> int:
        """Single-request decode (prefill path)."""
        return self._run_batch([(req, token)])[0]

    def step(self) -> Dict[int, int]:
        """One engine step: schedule, decode, bookkeeping."""
        self.step_count += 1
        active = [r for r in self.requests.values() if r.state == "active"]
        active.sort(key=lambda r: r.last_scheduled)
        sched = active[: self.cfg.max_batch]
        out: Dict[int, int] = {}
        if sched:
            pairs = []
            for r in sched:
                nxt = (r.generated[-1] if r.generated
                       else (r.tokens[-1] if r.tokens else 1))
                pairs.append((r, nxt))
            toks = self._run_batch(pairs)
            for r, t in zip(sched, toks):
                r.generated.append(int(t))
                out[r.request_id] = int(t)
                if len(r.generated) >= r.max_new:
                    r.state = "finished"
                    for p in self.pool.request_pages(r.request_id):
                        self.pool.free(p.page_id)
        if (self.cfg.policy == "gdt"
                and self.step_count % self.cfg.interval_steps == 0):
            self._gdt_interval()
        return out

    def _run_batch(self, pairs) -> List[int]:
        B = self.cfg.max_batch
        MP = self.cfg.max_pages_per_seq
        tokens = np.zeros((B, 1), np.int32)
        table = np.full((B, MP), -1, np.int32)
        lengths = np.zeros((B,), np.int32)
        wslot = np.full((B,), self.scratch_slot, np.int32)
        woff = np.zeros((B,), np.int32)
        active = np.zeros((B,), bool)
        for i, (req, tok) in enumerate(pairs):
            req.last_scheduled = self.step_count
            self._ensure_resident(req)
            slot, off = self._page_for_write(req)
            req.pos += 1
            pages = self.pool.request_pages(req.request_id)
            for p in pages:
                p.accesses += 1          # exact access model
                table[i, p.index_in_seq] = p.hbm_slot
            tokens[i, 0] = tok
            lengths[i] = req.pos
            wslot[i] = slot
            woff[i] = off
            active[i] = True
        logits, nk, nv = self._decode(
            self.params, self.pool.k_hbm, self.pool.v_hbm,
            jnp.asarray(tokens), jnp.asarray(table), jnp.asarray(lengths),
            jnp.asarray(wslot), jnp.asarray(woff), jnp.asarray(active))
        self.pool.k_hbm, self.pool.v_hbm = nk, nv
        toks = np.asarray(jnp.argmax(logits, axis=-1))
        return [int(toks[i]) for i in range(len(pairs))]

    # ======================================================= GDT interval
    def _gdt_interval(self):
        """The paper's MaybeMigrate over request sites / page chunks."""
        rows, telemetry = [], {}
        page_bytes = self.pool.page_bytes
        for rid, req in self.requests.items():
            pages = self.pool.request_pages(rid)
            if not pages:
                continue
            accs = sum(p.accesses for p in pages)
            nbytes = len(pages) * page_bytes
            fast_b = sum(1 for p in pages if p.hbm_slot is not None)
            rows.append(ArenaProfile(
                arena_id=rid, site_id=rid, label=f"req{rid}",
                accesses=accs, resident_bytes=nbytes,
                fast_fraction=fast_b / len(pages)))
            telemetry[rid] = [
                ChunkStats(chunk_id=p.page_id, nbytes=page_bytes,
                           accesses=p.accesses,
                           age=self.step_count - p.birth_step,
                           fast=p.hbm_slot is not None)
                for p in pages]
        if not rows:
            return
        profile = IntervalProfile(self.step_count, rows, 0, 0.0)
        exploded, frags = explode_profile(
            profile, telemetry, num_fragments=self.cfg.num_fragments)
        if self.cfg.access_decay < 1.0:   # ReweightProfile (Sec. 4.2)
            for p_ in self.pool.pages.values():
                p_.accesses = int(p_.accesses * self.cfg.access_decay)
        cap = (self.cfg.hbm_pages - 1) * page_bytes   # minus scratch slot
        recs = recommend(exploded, cap, self.cfg.strategy)
        decision = decide(exploded, recs, self.hw)
        self.decisions.append(decision)
        placement = collapse_to_chunks(frags, recs.fractions)
        self.last_recs = placement
        if not decision.migrate:
            return
        # Demotions first (free slots), then promotions.
        for pid, fast in placement.items():
            if pid in self.pool.pages and not fast and \
                    self.pool.pages[pid].hbm_slot is not None:
                self.pool.swap_out(pid)
        for pid, fast in placement.items():
            if pid in self.pool.pages and fast and \
                    self.pool.pages[pid].hbm_slot is None:
                if self.pool.free_hbm:
                    self.pool.swap_in(pid)

    # --------------------------------------------------------- telemetry
    def stats(self) -> Dict[str, float]:
        return {
            "steps": self.step_count,
            "swap_ins": self.pool.swaps_in,
            "swap_outs": self.pool.swaps_out,
            "bytes_moved": self.pool.bytes_moved,
            "hbm_pages_used": self.pool.hbm_used(),
        }
