"""Batched serving engine with guided KV-page tiering.

The engine serves dense/MoE decoder models from a paged two-tier KV cache
(serve/kvcache.py).  Each *request* is an allocation site; its pages are the
chunks.  Every decode step the engine (a) schedules up to ``max_batch``
active requests, (b) ensures their pages are HBM-resident — swap-ins are the
rental the controller pays for wrong placement, (c) runs the jitted paged
decode step, (d) updates exact per-page access counts.

Algorithm 1 itself is NOT implemented here: the engine exposes its page pool
to the shared controller through ``PagedKVBackend`` (a
``core.runtime.TierBackend``) and a ``GuidanceRuntime`` drives the paper's
machinery — profile -> age-fragmented thermos -> ski-rental -> page
migrations — at the decision interval.

Eviction between intervals (when a swap-in needs a free slot) is a
first-class policy object (serve/eviction.py): ``gdt`` follows the last
enforced recommendation; ``lru`` and ``fifo`` are selectable baselines.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core import TPU_V5E, GuidanceConfig, GuidanceRuntime, HardwareModel, MoveStats
from ..core.fragmentation import ChunkStats
from ..core.profiler import ArenaProfile, IntervalProfile
from ..core.runtime import MigrationPlan
from ..dist.sharding import active_mesh
from ..models.layers import lm_head, mlp, rmsnorm, rope
from ..models.moe import moe_decode
from ..models.transformer import Model
from .eviction import make_eviction_policy
from .kvcache import PagedKVPool

F32 = jnp.float32


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 4
    page_size: int = 16
    hbm_pages: int = 64
    host_pages: int = 256
    policy: str = "gdt"            # gdt | lru | fifo (eviction registry)
    interval_steps: int = 16
    strategy: str = "thermos"
    num_fragments: int = 4
    max_pages_per_seq: int = 32
    # Algorithm 1's optional ReweightProfile: decay access counters each
    # interval so placement tracks recent behaviour (sessions pause/resume
    # far faster than HPC phase shifts, so serving defaults to decaying).
    access_decay: float = 0.5


@dataclasses.dataclass
class Request:
    request_id: int
    tokens: List[int]
    max_new: int
    generated: List[int] = dataclasses.field(default_factory=list)
    state: str = "active"          # active | paused | finished
    pos: int = 0                   # tokens written to KV so far
    last_scheduled: int = 0


class PagedKVBackend:
    """``TierBackend`` over the engine's paged KV pool.

    Arena = one request's page list; chunk = one page.  ``enforce`` is
    capacity-safe: the reserved scratch slot never appears in the free list,
    demotions run first, and promotions that would exceed the free HBM slots
    are *refused* — and reflected back into ``last_recs`` so the eviction
    policy sees the placement that actually exists, not the one that was
    merely planned.
    """

    name = "paged_kv"

    def __init__(self, pool: PagedKVPool, requests: Dict[int, Request],
                 clock):
        self.pool = pool
        self.requests = requests
        self.clock = clock
        self.last_recs: Dict[int, bool] = {}   # page_id -> recommended fast
        self._telemetry: Dict[int, List[ChunkStats]] = {}

    # ------------------------------------------------------------- protocol
    def snapshot(self) -> IntervalProfile:
        rows: List[ArenaProfile] = []
        telemetry: Dict[int, List[ChunkStats]] = {}
        page_bytes = self.pool.page_bytes
        step = self.clock()
        for rid in self.requests:
            pages = self.pool.request_pages(rid)
            if not pages:
                continue
            fast_pages = sum(1 for p in pages if p.hbm_slot is not None)
            rows.append(ArenaProfile(
                arena_id=rid, site_id=rid, label=f"req{rid}",
                accesses=sum(p.accesses for p in pages),
                resident_bytes=len(pages) * page_bytes,
                fast_fraction=fast_pages / len(pages)))
            telemetry[rid] = [
                ChunkStats(chunk_id=p.page_id, nbytes=page_bytes,
                           accesses=p.accesses,
                           age=step - p.birth_step,
                           fast=p.hbm_slot is not None)
                for p in pages]
        self._telemetry = telemetry
        return IntervalProfile(step, rows, 0, 0.0)

    def telemetry(self) -> Mapping[int, Sequence[ChunkStats]]:
        return self._telemetry

    def reweight(self, decay: float) -> None:
        for p in self.pool.pages.values():
            p.accesses = int(p.accesses * decay)

    def on_plan(self, plan: MigrationPlan) -> None:
        # Track the plan every interval (even when the break-even rule says
        # "wait") — the guided eviction policy keys off it.
        self.last_recs = dict(plan.chunk_placement)

    def enforce(self, plan: MigrationPlan) -> MoveStats:
        stats = MoveStats()
        pages = self.pool.pages
        page_bytes = self.pool.page_bytes
        # Demotions first: free slots for the promotions below.
        for pid, fast in plan.chunk_placement.items():
            if not fast and pid in pages and pages[pid].hbm_slot is not None:
                self.pool.swap_out(pid)
                stats.bytes_demoted += page_bytes
        # Promotions, bounded by the actually-free HBM slots.
        for pid, fast in plan.chunk_placement.items():
            if fast and pid in pages and pages[pid].hbm_slot is None:
                if self.pool.free_hbm:
                    self.pool.swap_in(pid)
                    stats.bytes_promoted += page_bytes
                else:
                    stats.dropped_promotions += 1
                    self.last_recs[pid] = False
        return stats

    def fast_bytes(self) -> int:
        return self.pool.hbm_used() * self.pool.page_bytes


class Engine:
    def __init__(self, model: Model, params, cfg: ServeConfig,
                 hw: HardwareModel = TPU_V5E):
        assert model.cfg.family in ("dense", "moe"), \
            "paged engine serves decoder LMs"
        if model.cfg.family == "moe" and model.cfg.moe_parallelism == "ep":
            # Fail at construction, not mid-decode: an ep pad target that
            # doesn't divide over the live mesh's model axis would otherwise
            # surface as a shape error deep inside the jitted step.
            mesh = active_mesh()
            if mesh is not None and "model" in mesh.shape:
                model.moe_cfg.validate_ep_axis(int(mesh.shape["model"]))
        self.model = model
        self.params = params
        self.cfg = cfg
        self.hw = hw
        mc = model.cfg
        self.pool = PagedKVPool(
            n_layers=mc.n_layers, page_size=cfg.page_size,
            kv_heads=mc.kv_heads, head_dim=model.attn_cfg.head_dim,
            hbm_pages=cfg.hbm_pages, host_pages=cfg.host_pages,
            dtype=mc.dtype)
        self.requests: Dict[int, Request] = {}
        self.step_count = 0
        self.eviction = make_eviction_policy(cfg.policy)
        # Reserve one HBM slot as the write target for inactive batch rows,
        # so the batched scatter never collides with a real page.
        self.scratch_slot = self.pool.free_hbm.pop(0)
        self.kv_backend: Optional[PagedKVBackend] = None
        self.runtime: Optional[GuidanceRuntime] = None
        if cfg.policy == "gdt":
            self.kv_backend = PagedKVBackend(
                self.pool, self.requests, clock=lambda: self.step_count)
            self.runtime = GuidanceRuntime(
                self.kv_backend, hw,
                GuidanceConfig(
                    strategy=cfg.strategy,
                    # The reserved scratch slot is not placeable capacity.
                    fast_capacity_bytes=(cfg.hbm_pages - 1) * self.pool.page_bytes,
                    interval_steps=cfg.interval_steps,
                    decay=cfg.access_decay,
                    num_fragments=cfg.num_fragments,
                    skip_empty_intervals=True),
                clock=lambda: self.step_count)
        self._decode = jax.jit(self._build_decode())
        self.swap_in_events = 0

    # ------------------------------------------------- telemetry shims
    @property
    def decisions(self):
        """Deprecated: ski-rental decisions now live on the runtime's
        event stream (``engine.runtime.events``)."""
        return self.runtime.decisions if self.runtime is not None else []

    @property
    def last_recs(self) -> Dict[int, bool]:
        return self.kv_backend.last_recs if self.kv_backend is not None else {}

    # ========================================================= jit decode
    def _build_decode(self):
        model, cfg = self.model, self.cfg
        mc = model.cfg
        acfg = model.attn_cfg
        K, dh = mc.kv_heads, acfg.head_dim
        P = cfg.page_size
        from ..kernels.ops import paged_attention

        def step(params, k_pool, v_pool, tokens, page_table, lengths,
                 write_slot, write_off, active):
            """tokens: (B,1); page_table: (B,MP) HBM slots or -1;
            lengths: (B,) incl. new token; write_slot/off: (B,) where the
            new token's KV goes; active: (B,) bool."""
            x = jnp.take(params["embed"]["tok"], tokens, axis=0)  # (B,1,d)

            def body(carry, xs):
                x = carry
                lp, kp, vp = xs          # kp/vp: (N,P,K,dh)
                h = rmsnorm(lp["ln1"], x)
                B = h.shape[0]
                q = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wq"])[:, 0]
                k1 = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wk"])[:, 0]
                v1 = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wv"])[:, 0]
                posn = (lengths - 1)[:, None]
                q = rope(q[:, None], posn, acfg.rope_theta)[:, 0]
                k1 = rope(k1[:, None], posn, acfg.rope_theta)[:, 0]
                # Inactive rows target the reserved scratch slot, so the
                # batched scatter is always collision-free.
                kp = kp.at[write_slot, write_off].set(k1.astype(kp.dtype))
                vp = vp.at[write_slot, write_off].set(v1.astype(vp.dtype))
                o = paged_attention(q, kp, vp, page_table, lengths,
                                    window=acfg.window)
                y = jnp.einsum("bhk,hkd->bd", o.reshape(B, acfg.n_heads, dh),
                               lp["attn"]["wo"])[:, None]
                x = x + y
                h2 = rmsnorm(lp["ln2"], x)
                if mc.family == "moe":
                    # Same dropless routing + grouped GEMM as model.prefill,
                    # so the engine's chunked prefill (prompt tokens stepped
                    # through this path) computes the identical function.
                    x = x + moe_decode(lp["moe"], h2, model.moe_cfg)
                else:
                    x = x + mlp(lp["mlp"], h2)
                return x, (kp, vp)

            x, (nk, nv) = jax.lax.scan(
                body, x, (params["layers"], k_pool, v_pool))
            x = rmsnorm(params["final_ln"], x)
            logits = lm_head(params["head"], x)[:, 0]
            return logits, nk, nv

        return step

    # ========================================================== requests
    def add_request(self, request_id: int, prompt: List[int],
                    max_new: int = 8) -> None:
        req = Request(request_id=request_id, tokens=list(prompt),
                      max_new=max_new)
        self.requests[request_id] = req
        # Chunked prefill: step the prompt tokens through the decode path.
        # Exact by construction — dropless MoE dispatch and per-token
        # routing make step-by-step ingestion compute the same function as
        # batched model.prefill (the contiguous fast path + paginate is a
        # perf option, not a correctness one, at engine-test scale).  The
        # last prompt token is fed by the first step(), whose logits
        # produce the first generated token.
        for t in prompt[:-1]:
            self._decode_one(req, t)

    def pause(self, request_id: int):
        self.requests[request_id].state = "paused"

    def resume(self, request_id: int):
        req = self.requests[request_id]
        if req.state == "paused":
            req.state = "active"

    # ------------------------------------------------------- page mgmt
    def _note_swap_in(self):
        """A demand swap-in is a rental payment; log it on the stream."""
        self.swap_in_events += 1
        if self.runtime is not None:
            self.runtime.record_rental(self.pool.page_bytes, source="swap_in")

    def _page_for_write(self, req: Request) -> tuple:
        """(hbm_slot, offset) for the next token; allocates as needed."""
        idx, off = divmod(req.pos, self.cfg.page_size)
        pages = self.pool.request_pages(req.request_id)
        if idx >= len(pages):
            self._ensure_free_hbm(1, needed=[p.page_id for p in pages])
            page = self.pool.allocate(req.request_id, idx, self.step_count)
            pages.append(page)
        page = pages[idx]
        if page.hbm_slot is None:
            self._ensure_free_hbm(
                1, needed=[p.page_id for p in pages])
            self.pool.swap_in(page.page_id)
            self._note_swap_in()
        page.tokens_used = off + 1
        return page.hbm_slot, off

    def _ensure_resident(self, req: Request):
        pages = self.pool.request_pages(req.request_id)
        needed = [p.page_id for p in pages]
        for p in pages:
            if p.hbm_slot is None:
                self._ensure_free_hbm(1, needed=needed)
                self.pool.swap_in(p.page_id)
                self._note_swap_in()

    def _ensure_free_hbm(self, n: int, needed: List[int]):
        while len(self.pool.free_hbm) < n:
            victim = self._pick_victim(exclude=set(needed))
            if victim is None:
                raise MemoryError("no evictable page")
            self.pool.swap_out(victim)

    def _pick_victim(self, exclude) -> Optional[int]:
        cands = [p for p in self.pool.pages.values()
                 if p.hbm_slot is not None and p.page_id not in exclude]
        return self.eviction.pick(cands, self)

    # ============================================================ stepping
    def _decode_one(self, req: Request, token: int) -> int:
        """Single-request decode (prefill path)."""
        return self._run_batch([(req, token)])[0]

    def step(self) -> Dict[int, int]:
        """One engine step: schedule, decode, bookkeeping."""
        self.step_count += 1
        active = [r for r in self.requests.values() if r.state == "active"]
        active.sort(key=lambda r: r.last_scheduled)
        sched = active[: self.cfg.max_batch]
        out: Dict[int, int] = {}
        if sched:
            pairs = []
            for r in sched:
                nxt = (r.generated[-1] if r.generated
                       else (r.tokens[-1] if r.tokens else 1))
                pairs.append((r, nxt))
            toks = self._run_batch(pairs)
            for r, t in zip(sched, toks):
                r.generated.append(int(t))
                out[r.request_id] = int(t)
                if len(r.generated) >= r.max_new:
                    r.state = "finished"
                    for p in self.pool.request_pages(r.request_id):
                        self.pool.free(p.page_id)
        if self.runtime is not None:
            self.runtime.on_step()        # MaybeMigrate at the interval
        return out

    def _run_batch(self, pairs) -> List[int]:
        B = self.cfg.max_batch
        MP = self.cfg.max_pages_per_seq
        tokens = np.zeros((B, 1), np.int32)
        table = np.full((B, MP), -1, np.int32)
        lengths = np.zeros((B,), np.int32)
        wslot = np.full((B,), self.scratch_slot, np.int32)
        woff = np.zeros((B,), np.int32)
        active = np.zeros((B,), bool)
        for i, (req, tok) in enumerate(pairs):
            req.last_scheduled = self.step_count
            self._ensure_resident(req)
            slot, off = self._page_for_write(req)
            req.pos += 1
            pages = self.pool.request_pages(req.request_id)
            for p in pages:
                p.accesses += 1          # exact access model
                table[i, p.index_in_seq] = p.hbm_slot
            tokens[i, 0] = tok
            lengths[i] = req.pos
            wslot[i] = slot
            woff[i] = off
            active[i] = True
        logits, nk, nv = self._decode(
            self.params, self.pool.k_hbm, self.pool.v_hbm,
            jnp.asarray(tokens), jnp.asarray(table), jnp.asarray(lengths),
            jnp.asarray(wslot), jnp.asarray(woff), jnp.asarray(active))
        self.pool.k_hbm, self.pool.v_hbm = nk, nv
        toks = np.asarray(jnp.argmax(logits, axis=-1))
        return [int(toks[i]) for i in range(len(pairs))]

    # --------------------------------------------------------- telemetry
    def stats(self) -> Dict[str, float]:
        return {
            "steps": self.step_count,
            "swap_ins": self.pool.swaps_in,
            "swap_outs": self.pool.swaps_out,
            "bytes_moved": self.pool.bytes_moved,
            "hbm_pages_used": self.pool.hbm_used(),
        }
