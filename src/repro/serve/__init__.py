from .api import DEFAULT_MAX_TOKENS, LLM, RequestHandle, RequestOutput
from .cluster import EngineReplica, ReplicaLostError, Router
from .engine import Engine, PagedKVBackend, Request, RequestTicket, ServeConfig
from .eviction import (
    EVICTION_POLICIES,
    EvictionPolicy,
    make_eviction_policy,
    register_eviction_policy,
)
from .kvcache import Page, PageExport, PagedKVPool
from .prefix_cache import PrefixBackend, PrefixCache, PrefixNode, block_hash
from .sampling import SamplingParams

__all__ = [
    "DEFAULT_MAX_TOKENS",
    "EVICTION_POLICIES",
    "Engine",
    "EngineReplica",
    "EvictionPolicy",
    "LLM",
    "Page",
    "PageExport",
    "PagedKVBackend",
    "PagedKVPool",
    "PrefixBackend",
    "PrefixCache",
    "PrefixNode",
    "ReplicaLostError",
    "Request",
    "RequestHandle",
    "RequestOutput",
    "RequestTicket",
    "Router",
    "SamplingParams",
    "ServeConfig",
    "block_hash",
    "make_eviction_policy",
    "register_eviction_policy",
]
