from .api import DEFAULT_MAX_TOKENS, LLM, RequestHandle, RequestOutput
from .engine import Engine, PagedKVBackend, Request, ServeConfig
from .eviction import (
    EVICTION_POLICIES,
    EvictionPolicy,
    make_eviction_policy,
    register_eviction_policy,
)
from .kvcache import Page, PagedKVPool
from .prefix_cache import PrefixBackend, PrefixCache, PrefixNode, block_hash
from .sampling import SamplingParams

__all__ = [
    "DEFAULT_MAX_TOKENS",
    "EVICTION_POLICIES",
    "Engine",
    "EvictionPolicy",
    "LLM",
    "Page",
    "PagedKVBackend",
    "PagedKVPool",
    "PrefixBackend",
    "PrefixCache",
    "PrefixNode",
    "Request",
    "RequestHandle",
    "RequestOutput",
    "SamplingParams",
    "ServeConfig",
    "block_hash",
    "make_eviction_policy",
    "register_eviction_policy",
]
