from .api import DEFAULT_MAX_TOKENS, LLM, RequestHandle, RequestOutput
from .cluster import EngineReplica, ReplicaLostError, Router
from .engine import Engine, PagedKVBackend, Request, RequestTicket, ServeConfig
from .eviction import (
    EVICTION_POLICIES,
    EvictionPolicy,
    make_eviction_policy,
    register_eviction_policy,
)
from .expert_store import ExpertBackend, ExpertCacheMissError, ExpertStore
from .kvcache import Page, PageExport, PagedKVPool
from .prefix_cache import PrefixBackend, PrefixCache, PrefixNode, block_hash
from .sampling import SamplingParams
from .scheduler import (
    SCHEDULER_POLICIES,
    DrrPolicy,
    FifoPolicy,
    PriorityPolicy,
    SchedulerPolicy,
    StepBudget,
    make_scheduler_policy,
    register_scheduler_policy,
)
from .workload import (
    SLO,
    ReplayReport,
    StepCostModel,
    TenantSpec,
    Trace,
    TraceReplayer,
    TraceRequest,
    WorkloadConfig,
    synthesize,
)

__all__ = [
    "DEFAULT_MAX_TOKENS",
    "EVICTION_POLICIES",
    "DrrPolicy",
    "Engine",
    "EngineReplica",
    "EvictionPolicy",
    "ExpertBackend",
    "ExpertCacheMissError",
    "ExpertStore",
    "FifoPolicy",
    "LLM",
    "Page",
    "PageExport",
    "PagedKVBackend",
    "PagedKVPool",
    "PrefixBackend",
    "PrefixCache",
    "PrefixNode",
    "PriorityPolicy",
    "ReplayReport",
    "ReplicaLostError",
    "Request",
    "RequestHandle",
    "RequestOutput",
    "RequestTicket",
    "Router",
    "SCHEDULER_POLICIES",
    "SLO",
    "SamplingParams",
    "SchedulerPolicy",
    "ServeConfig",
    "StepBudget",
    "StepCostModel",
    "TenantSpec",
    "Trace",
    "TraceReplayer",
    "TraceRequest",
    "WorkloadConfig",
    "block_hash",
    "make_eviction_policy",
    "make_scheduler_policy",
    "register_eviction_policy",
    "register_scheduler_policy",
    "synthesize",
]
