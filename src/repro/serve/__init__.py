from .api import DEFAULT_MAX_TOKENS, LLM, RequestHandle, RequestOutput
from .engine import Engine, PagedKVBackend, Request, ServeConfig
from .eviction import (
    EVICTION_POLICIES,
    EvictionPolicy,
    make_eviction_policy,
    register_eviction_policy,
)
from .kvcache import Page, PagedKVPool
from .sampling import SamplingParams

__all__ = [
    "DEFAULT_MAX_TOKENS",
    "EVICTION_POLICIES",
    "Engine",
    "EvictionPolicy",
    "LLM",
    "Page",
    "PagedKVBackend",
    "PagedKVPool",
    "Request",
    "RequestHandle",
    "RequestOutput",
    "SamplingParams",
    "ServeConfig",
    "make_eviction_policy",
    "register_eviction_policy",
]
