from .engine import Engine, PagedKVBackend, Request, ServeConfig
from .eviction import (
    EVICTION_POLICIES,
    EvictionPolicy,
    make_eviction_policy,
    register_eviction_policy,
)
from .kvcache import Page, PagedKVPool

__all__ = [
    "EVICTION_POLICIES",
    "Engine",
    "EvictionPolicy",
    "Page",
    "PagedKVBackend",
    "PagedKVPool",
    "Request",
    "ServeConfig",
    "make_eviction_policy",
    "register_eviction_policy",
]
