from .engine import Engine, Request, ServeConfig
from .kvcache import Page, PagedKVPool

__all__ = ["Engine", "Page", "PagedKVPool", "Request", "ServeConfig"]
