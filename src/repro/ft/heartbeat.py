"""Failure detection and straggler mitigation for multi-node runs.

On a real cluster each host runs a ``HeartbeatMonitor`` peer; here the
monitor is driven by the launcher/trainer loop (and by fault-injection in
tests), but the logic — missed-beat failure detection, EWMA step-time
straggler scoring, hot-spare replacement planning — is the production code
path.

Recovery contract (launch/train.py): on a detected failure the run (a)
marks the node dead, (b) computes the rescale plan (ft/elastic.py), (c)
restores the latest checkpoint onto the surviving mesh, (d) resumes.  The
trainer's checkpoint cadence bounds lost work.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Set


@dataclasses.dataclass
class NodeStats:
    node_id: int
    last_beat: float
    step_time_ewma: float = 0.0
    beats: int = 0


class HeartbeatMonitor:
    def __init__(self, n_nodes: int, timeout_s: float = 60.0,
                 straggler_factor: float = 1.8, ewma: float = 0.2,
                 clock=time.monotonic):
        self.timeout_s = timeout_s
        self.straggler_factor = straggler_factor
        self.ewma = ewma
        self.clock = clock
        now = clock()
        self.nodes: Dict[int, NodeStats] = {
            i: NodeStats(i, now) for i in range(n_nodes)}
        self.dead: Set[int] = set()
        self.spares: List[int] = []

    def add_spare(self, node_id: int):
        self.spares.append(node_id)

    # ---------------------------------------------------------- membership
    def add_node(self, node_id: int):
        """Register a node after construction — elastic membership (a
        serving replica joining the cluster, a spare being activated).
        The node starts alive with its beat clock at ``now``."""
        if node_id in self.nodes and node_id not in self.dead:
            raise ValueError(
                f"cannot add node {node_id}: already monitored and alive")
        self.dead.discard(node_id)
        self.nodes[node_id] = NodeStats(node_id, self.clock())

    def remove_node(self, node_id: int):
        """Forget a node entirely (graceful leave, or cleanup after its
        failure was handled) — unknown ids are a no-op so teardown paths
        can call it unconditionally."""
        self.nodes.pop(node_id, None)
        self.dead.discard(node_id)

    def beat(self, node_id: int, step_time_s: Optional[float] = None):
        if node_id in self.dead:
            return
        st = self.nodes[node_id]
        st.last_beat = self.clock()
        st.beats += 1
        if step_time_s is not None:
            if st.step_time_ewma == 0.0:
                st.step_time_ewma = step_time_s
            else:
                st.step_time_ewma = (
                    (1 - self.ewma) * st.step_time_ewma
                    + self.ewma * step_time_s)

    # ---------------------------------------------------------- detection
    def check_failures(self) -> List[int]:
        now = self.clock()
        newly = [
            nid for nid, st in self.nodes.items()
            if nid not in self.dead and now - st.last_beat > self.timeout_s
        ]
        self.dead.update(newly)
        return newly

    def stragglers(self) -> List[int]:
        """Nodes whose EWMA step time exceeds straggler_factor x median."""
        alive = [st for nid, st in self.nodes.items()
                 if nid not in self.dead and st.step_time_ewma > 0]
        if len(alive) < 3:
            return []
        times = sorted(st.step_time_ewma for st in alive)
        median = times[len(times) // 2]
        return [st.node_id for st in alive
                if st.step_time_ewma > self.straggler_factor * median]

    # ----------------------------------------------------------- recovery
    def plan_replacement(self, failed: List[int]) -> Dict[int, Optional[int]]:
        """Map failed/straggler node -> spare (or None -> shrink)."""
        plan: Dict[int, Optional[int]] = {}
        for nid in failed:
            plan[nid] = self.spares.pop(0) if self.spares else None
        return plan

    @property
    def alive(self) -> List[int]:
        return [nid for nid in self.nodes if nid not in self.dead]
