"""Elastic rescale planning: choose a new mesh for the surviving devices and
re-shard the training state onto it.

Policy: keep the model axis intact whenever possible (TP degree is baked
into layout efficiency) and shrink the data axis; if fewer than one model
group survives, shrink the model axis to the largest power-of-two divisor
of the device count that divides the head/ffn dims.  Global batch is
preserved by raising gradient accumulation (synchronous semantics keep the
loss curve comparable across rescales).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
from jax.sharding import Mesh

from ..models.common import param_shardings


@dataclasses.dataclass(frozen=True)
class RescalePlan:
    old_shape: Tuple[int, ...]
    new_shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    accum_factor: int           # multiply grad-accumulation by this

    @property
    def new_device_count(self) -> int:
        n = 1
        for s in self.new_shape:
            n *= s
        return n


def plan_rescale(n_alive: int, old_shape: Tuple[int, ...],
                 axis_names: Tuple[str, ...] = ("data", "model")) -> RescalePlan:
    """Shrink the data axis first; keep model axis if any full group fits."""
    *lead, data, model = old_shape
    lead_n = 1
    for s in lead:
        lead_n *= s
    groups = n_alive // (model * lead_n)
    if groups >= 1:
        new_shape = tuple(lead) + (groups, model)
    else:
        # Not even one model group: shrink model to largest p2 divisor.
        m = 1
        while m * 2 <= n_alive:
            m *= 2
        new_shape = tuple(1 for _ in lead) + (1, m)
    # Global batch is preserved by gradient accumulation: the factor is the
    # data-axis shrink ratio (ceil — never under-accumulate), computed the
    # same way on both branches since the model-shrink branch also collapses
    # the data axis to 1.
    accum = -(-data // new_shape[-2])
    return RescalePlan(old_shape=old_shape, new_shape=new_shape,
                       axis_names=axis_names, accum_factor=max(1, accum))


def reshard_state(tree, defs, new_mesh: Mesh, rules=None):
    """Re-place a (host or device) pytree onto the new mesh according to the
    same logical-axis declarations used at init."""
    shardings = param_shardings(defs, new_mesh, rules)
    return jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
