from .elastic import RescalePlan, plan_rescale, reshard_state
from .heartbeat import HeartbeatMonitor, NodeStats

__all__ = ["HeartbeatMonitor", "NodeStats", "RescalePlan", "plan_rescale",
           "reshard_state"]
