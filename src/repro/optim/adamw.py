"""AdamW with decoupled weight decay.

Moments are f32 regardless of param dtype; the update is computed in f32 and
cast back.  Weight decay is masked off for 1-D parameters (norm scales,
biases, per-head gate vectors) — the conventional grouping.

Moments are first-class *allocation sites* for the paper's tiering runtime:
``moment_sites()`` groups them exactly like the parameter sites so the
``GuidanceRuntime`` controller can decide HBM-vs-host placement per group.  On the
production mesh their ``layers`` dimension additionally shards over the data
axis (ZeRO-1 style) via the MOMENTS_RULES overlay in ``repro.dist.sharding``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

F32 = jnp.float32


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: Optional[float] = 1.0

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, F32)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            m=jax.tree.map(zeros, params),
            v=jax.tree.map(zeros, params),
        )

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else self.lr

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        if self.grad_clip is not None:
            gnorm = jnp.sqrt(sum(
                jnp.sum(jnp.square(g.astype(F32)))
                for g in jax.tree.leaves(grads)))
            scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-9))
        else:
            gnorm = jnp.zeros((), F32)
            scale = jnp.ones((), F32)
        lr = jnp.asarray(self._lr(step), F32)
        b1, b2 = self.b1, self.b2

        def upd(g, m, v, p):
            g = g.astype(F32) * scale
            m2 = b1 * m + (1 - b1) * g
            v2 = b2 * v + (1 - b2) * jnp.square(g)
            mhat = m2 / (1 - b1 ** step.astype(F32))
            vhat = v2 / (1 - b2 ** step.astype(F32))
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay and p.ndim > 1:
                delta = delta + self.weight_decay * p.astype(F32)
            new_p = p.astype(F32) - lr * delta
            return new_p.astype(p.dtype), m2, v2

        flat = jax.tree.map(upd, grads, state.m, state.v, params)
        new_params = jax.tree.map(lambda t: t[0], flat,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t: t[1], flat,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda t: t[2], flat,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_params, AdamWState(step, new_m, new_v), gnorm


def cosine_schedule(peak: float, warmup: int, total: int,
                    floor: float = 0.1):
    def lr(step):
        step = step.astype(F32)
        warm = peak * step / max(warmup, 1)
        import numpy as np

        progress = jnp.clip((step - warmup) / max(total - warmup, 1), 0, 1)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(np.pi * progress))
        return jnp.where(step < warmup, warm, peak * cos)

    return lr
