"""zamba2-7b [hybrid Mamba2 + shared attention, arXiv:2411.15242].

81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000, ssm_state=64.
The Mamba2 backbone is interleaved with a *shared* attention+MLP block
(applied every 6 layers, one parameter set reused — zamba2's signature
memory saving; we model one shared block, DESIGN.md Sec. 5)."""

import dataclasses

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch="zamba2_7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    kv_heads=32,
    d_ff=14336,
    vocab=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    attn_every=6,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=7, d_model=128, n_heads=4, kv_heads=4, d_ff=256,
    vocab=512, attn_every=3, ssm_state=16, ssm_head_dim=32,
)
