"""seamless-m4t-medium [audio enc-dec, arXiv:2308.11596; hf].

12L d_model=1024 16H (GQA kv=16) d_ff=4096 vocab=256206.  The speech
frontend (w2v-BERT conformer) is a stub: input_specs supplies precomputed
frame embeddings (B, S, d).  We split the 12 transformer layers as 12 enc +
12 dec is the full model's text-decoder depth; the assigned spec says 12L,
which we read as 12 encoder + 12 decoder blocks of the stated geometry
(total params ~= the published medium checkpoint)."""

import dataclasses

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch="seamless_m4t_medium",
    family="encdec",
    n_layers=12,            # decoder blocks
    enc_layers=12,          # encoder blocks
    d_model=1024,
    n_heads=16,
    kv_heads=16,
    d_ff=4096,
    vocab=256206,
    frontend="audio",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, enc_layers=2, d_model=64, n_heads=4, kv_heads=4,
    d_ff=128, vocab=512,
)
