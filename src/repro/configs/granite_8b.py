"""granite-8b [dense llama-arch code model, arXiv:2405.04324; hf].

36L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=49152."""

import dataclasses

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch="granite_8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    kv_heads=8,
    d_ff=14336,
    vocab=49152,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=128, n_heads=4, kv_heads=2, d_ff=256,
    vocab=512,
)
