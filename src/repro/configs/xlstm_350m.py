"""xlstm-350m [sLSTM + mLSTM blocks, arXiv:2405.04517; unverified].

24L d_model=1024 4H d_ff=0 vocab=50304.  d_ff=0: xLSTM blocks carry their
own projections (mLSTM up-factor 2, sLSTM gated FFN 4/3).  One sLSTM block
per 8 layers (xLSTM[7:1] ratio), the rest mLSTM."""

import dataclasses

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch="xlstm_350m",
    family="xlstm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    kv_heads=4,
    d_ff=0,
    vocab=50304,
    slstm_every=8,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=4, d_model=64, n_heads=2, kv_heads=2, vocab=512,
    slstm_every=4,
)
