"""stablelm-3b [dense, hf:stabilityai/stablelm-2; unverified].

32L d_model=2560 32H (GQA kv=32) d_ff=6912 vocab=50304."""

import dataclasses

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch="stablelm_3b",
    family="dense",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    kv_heads=32,
    d_ff=6912,
    vocab=50304,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=128, n_heads=4, kv_heads=4, d_ff=256,
    vocab=512,
)
