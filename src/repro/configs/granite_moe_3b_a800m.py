"""granite-moe-3b-a800m [MoE 40 experts top-8; hf:ibm-granite].

32L d_model=1536 24H (GQA kv=8) d_ff=512 vocab=49155, MoE 40e top-8.
(The assignment's prose says "32 experts"; we follow the structured spec:
40 experts, top-8 — see DESIGN.md Sec. 5.)  40 experts do not divide the
16-way model axis, so the default parallelism is TP-MoE; padded-EP (40->48,
dropless ragged all-to-alls — no capacity fallback, no drops) is available
via moe_parallelism="ep"."""

import dataclasses

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch="granite_moe_3b_a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    kv_heads=8,
    d_ff=512,
    vocab=49155,
    n_experts=40,
    top_k=8,
    # Dropless dispatch (top-8 over 40 experts overflows capacity buffers
    # easily; sorted ragged routing drops nothing) in both parallelism
    # modes — ep runs ragged all-to-alls, not the capacity path.
    moe_dispatch="dropless",
    head_dim=64,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=96, n_heads=6, kv_heads=2, d_ff=64,
    vocab=512, n_experts=8, top_k=2, head_dim=16,
)
