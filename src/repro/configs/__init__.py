from .base import ARCHS, LONG_CONTEXT_OK, get, get_smoke, shapes_for

__all__ = ["ARCHS", "LONG_CONTEXT_OK", "get", "get_smoke", "shapes_for"]
