"""minitron-4b [dense, pruned nemotron, arXiv:2407.14679; hf].

32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000."""

import dataclasses

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch="minitron_4b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    kv_heads=8,
    d_ff=9216,
    vocab=256000,
    head_dim=128,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=96, n_heads=6, kv_heads=2, d_ff=192,
    vocab=512, head_dim=16,
)
