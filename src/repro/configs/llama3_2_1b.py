"""llama3.2-1b [dense, hf:meta-llama/Llama-3.2-1B; unverified].

16L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=128256."""

import dataclasses

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch="llama3_2_1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=32,
    kv_heads=8,
    d_ff=8192,
    vocab=128256,
    rope_theta=500000.0,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=128, n_heads=8, kv_heads=2, d_ff=256,
    vocab=512,
)
