"""phi-3-vision-4.2b [VLM, hf:microsoft/Phi-3-vision-128k-instruct].

32L d_model=3072 32H (GQA kv=32) d_ff=8192 vocab=32064.  The CLIP vision
tower is a stub: input_specs supplies precomputed patch embeddings
(B, frontend_tokens, d) which a learned adapter projects into the LM."""

import dataclasses

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch="phi3_vision_4_2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    kv_heads=32,
    d_ff=8192,
    vocab=32064,
    frontend="vision",
    frontend_tokens=576,     # 24x24 patch grid
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=128, n_heads=4, kv_heads=4, d_ff=256,
    vocab=512, frontend_tokens=16,
)
