"""Architecture registry.

Each ``configs/<arch>.py`` defines ``CONFIG`` (the exact published
configuration) and ``SMOKE`` (a reduced same-family configuration for CPU
tests).  ``get(arch)`` / ``get_smoke(arch)`` resolve by id; ``ARCHS`` lists
all ten assigned architectures.

``long_500k`` applicability (DESIGN.md Sec. 5): sub-quadratic decode memory
is required, so only the SSM/hybrid/windowed archs run it; pure
full-attention archs skip it (their KV cache alone exceeds the budget).
"""

from __future__ import annotations

import importlib
from typing import List

from ..models.config import ModelConfig, SHAPES, SMOKE_SHAPES, ShapeConfig

ARCHS: List[str] = [
    "seamless_m4t_medium",
    "zamba2_7b",
    "minitron_4b",
    "granite_8b",
    "stablelm_3b",
    "llama3_2_1b",
    "mixtral_8x7b",
    "granite_moe_3b_a800m",
    "phi3_vision_4_2b",
    "xlstm_350m",
]

# Archs whose long_500k cell runs (sub-quadratic decode state).
LONG_CONTEXT_OK = {"zamba2_7b", "mixtral_8x7b", "xlstm_350m"}


def _module(arch: str):
    return importlib.import_module(f"repro.configs.{arch}")


def get(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_smoke(arch: str) -> ModelConfig:
    return _module(arch).SMOKE


def shapes_for(arch: str, smoke: bool = False):
    """The (shape -> ShapeConfig) cells this arch runs, with documented
    skips applied."""
    table = SMOKE_SHAPES if smoke else SHAPES
    out = {}
    for name, sc in table.items():
        if name == "long_500k" and arch not in LONG_CONTEXT_OK:
            continue
        out[name] = sc
    return out
