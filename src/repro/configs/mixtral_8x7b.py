"""mixtral-8x7b [MoE 8 experts top-2, SWA, arXiv:2401.04088; hf].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000.  Sliding-window
attention (4096) bounds the decode KV cache, which is what makes the
long_500k cell runnable for this arch."""

import dataclasses

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch="mixtral_8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    kv_heads=8,
    d_ff=14336,
    vocab=32000,
    n_experts=8,
    top_k=2,
    # Dropless sorted-ragged dispatch: prefill and decode route identically,
    # which ring-KV serving correctness depends on (tests/test_ring_kv.py).
    moe_dispatch="dropless",
    window=4096,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=128, n_heads=4, kv_heads=2, d_ff=256,
    vocab=512, n_experts=4, top_k=2, window=64,
)
