"""Hand-scheduled ring collectives for compute/communication overlap.

The reference implementations use XLA's fused collectives (``all_gather`` /
``psum_scatter``): correct, but the gather must *complete* before the matmul
starts.  The ring variants decompose the collective into ``n-1`` point-to-
point ``ppermute`` steps interleaved with partial matmuls, so the compiler
can overlap each hop's transfer with the previous chunk's compute — the HLO
contains ``collective-permute`` ops instead of ``all-gather``.

All four kernels are written for use inside ``shard_map`` over one named
mesh axis.  ``psum(1, axis)`` is the standard static-axis-size idiom.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Version-portable shard_map: newer JAX exposes it at top level.  Callers
# on older JAX import it from here instead of ``jax.shard_map``.
try:
    from jax import shard_map  # type: ignore[attr-defined]
except ImportError:  # pragma: no cover - version-dependent
    from jax.experimental.shard_map import shard_map

from jax.sharding import PartitionSpec as P


def _axis_size(axis_name: str) -> int:
    return jax.lax.psum(1, axis_name)


# ------------------------------------------------------- allgather-matmul
def allgather_matmul_reference(x_shard, w_col, axis_name: str):
    """out[:, col_shard] = allgather(x) @ w_col — the unfused baseline.

    ``x_shard``: (m, K) row shard of x; ``w_col``: (K, n_col) column shard.
    Returns the full-row (n*m, n_col) product for this device's columns.
    """
    x = jax.lax.all_gather(x_shard, axis_name, axis=0, tiled=True)
    return x @ w_col


def ring_allgather_matmul(x_shard, w_col, axis_name: str):
    """Ring-overlapped allgather+matmul: each step multiplies the chunk
    currently held and forwards it one hop around the ring."""
    n = _axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    m = x_shard.shape[0]
    out_dtype = jnp.result_type(x_shard.dtype, w_col.dtype)
    out = jnp.zeros((n * m, w_col.shape[1]), out_dtype)
    perm = [(j, (j - 1) % n) for j in range(n)]  # receive from the right
    blk = x_shard
    for i in range(n):
        src = (idx + i) % n  # origin of the chunk currently held
        out = jax.lax.dynamic_update_slice(
            out, (blk @ w_col).astype(out_dtype), (src * m, 0))
        if i != n - 1:
            blk = jax.lax.ppermute(blk, axis_name, perm)
    return out


# --------------------------------------------------- matmul-reducescatter
def matmul_reducescatter_reference(h, w_row, axis_name: str):
    """scatter(psum(h @ w_row)) — the unfused baseline.

    ``h``: (M, k) column shard of activations; ``w_row``: (k, N) row shard.
    Returns this device's (M/n, N) row block of the summed product.
    """
    partial = h @ w_row
    return jax.lax.psum_scatter(
        partial, axis_name, scatter_dimension=0, tiled=True)


def ring_matmul_reducescatter(h, w_row, axis_name: str):
    """Ring-overlapped matmul+reduce-scatter: the partial sum destined for
    each device accumulates as it travels the ring."""
    n = _axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    m = h.shape[0] // n
    k = h.shape[1]

    def contrib(dest):
        rows = jax.lax.dynamic_slice(h, (dest * m, 0), (m, k))
        return rows @ w_row

    perm = [(j, (j + 1) % n) for j in range(n)]  # send to the right
    acc = contrib((idx - 1) % n)
    for i in range(1, n):
        acc = jax.lax.ppermute(acc, axis_name, perm)
        acc = acc + contrib((idx - i - 1) % n)
    return acc


# ------------------------------------------------- ragged all-to-all (ep MoE)
def shard_map_compat(f, mesh, in_specs, out_specs):
    """shard_map without replication checking, across JAX versions (the
    kwarg was renamed check_rep -> check_vma when shard_map moved to the
    top level).  The ragged collectives below produce outputs the checker
    cannot always prove replicated, so callers use this wrapper."""
    try:
        return shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)
    except TypeError:  # pragma: no cover - version-dependent
        return shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_vma=False)


def _exclusive_cumsum(sizes):
    c = jnp.cumsum(sizes)
    return (c - sizes).astype(jnp.int32)


def _place_chunk(out, chunk, offset, size, out_rows: int):
    """Deposit the first ``size`` rows of ``chunk`` at ``offset`` in ``out``.

    Invalid rows are zeroed and steered to index ``out_rows`` (dropped by
    the scatter), so a full-capacity chunk never clobbers a neighbouring
    block; each valid output row receives exactly one contribution, which
    makes the zero-initialized scatter-add exact."""
    i = jnp.arange(chunk.shape[0], dtype=jnp.int32)
    valid = i < size
    tgt = jnp.where(valid, offset + i, out_rows)
    return out.at[tgt].add(jnp.where(valid[:, None], chunk, 0), mode="drop")


def ring_ragged_all_to_all(rows, send_sizes, recv_sizes, axis_name: str, *,
                           chunk_rows: int, out_rows: int):
    """Dropless (ragged) all-to-all over one named axis, decomposed into
    ``n-1`` ``ppermute`` rotations of one static ``(chunk_rows, d)`` buffer
    — the ragged sibling of the ring kernels above.

    ``rows``: (R, d) send buffer with rows grouped contiguously by
    destination shard in ascending order; ``send_sizes``: (n,) rows
    destined to each peer (sum <= R); ``recv_sizes``: (n,) rows each peer
    sends here — the caller knows both from its routing metadata exchange
    (an all-gather of per-expert counts in the MoE ep path), so no extra
    size handshake happens here.

    ``chunk_rows`` bounds the rows any single peer pair exchanges (static);
    ``out_rows`` is the receive capacity.  Returns (out_rows, d) with the
    received rows packed contiguously, grouped by source shard in ascending
    order; slots beyond the ragged total stay zero.
    """
    n = _axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    d = rows.shape[1]
    send_offs = _exclusive_cumsum(send_sizes)
    recv_offs = _exclusive_cumsum(recv_sizes)
    # Pad the source so a full-capacity dynamic_slice near the ragged end
    # never clamps backwards into a neighbour's rows.
    src_buf = jnp.concatenate(
        [rows, jnp.zeros((chunk_rows, d), rows.dtype)], axis=0)
    out = jnp.zeros((out_rows, d), rows.dtype)

    def chunk_for(dest):
        return jax.lax.dynamic_slice(
            src_buf, (jnp.take(send_offs, dest), 0), (chunk_rows, d))

    # Self block: local copy, no hop.
    out = _place_chunk(out, chunk_for(idx), jnp.take(recv_offs, idx),
                       jnp.take(recv_sizes, idx), out_rows)
    for shift in range(1, n):
        dst = (idx + shift) % n
        src = (idx - shift) % n
        perm = [(j, (j + shift) % n) for j in range(n)]
        got = jax.lax.ppermute(chunk_for(dst), axis_name, perm)
        out = _place_chunk(out, got, jnp.take(recv_offs, src),
                           jnp.take(recv_sizes, src), out_rows)
    return out


def ragged_all_to_all_reference(rows, send_sizes, recv_sizes,
                                axis_name: str, *, chunk_rows: int,
                                out_rows: int):
    """Dense-gather oracle for ``ring_ragged_all_to_all``: all-gather every
    peer's full buffer and size table, then select this shard's blocks.
    Same contract, different data path (all-gather HLO instead of
    collective-permute) — the correctness anchor for the ring tests."""
    n = _axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    d = rows.shape[1]
    all_rows = jax.lax.all_gather(rows, axis_name, axis=0)     # (n, R, d)
    all_sizes = jax.lax.all_gather(send_sizes, axis_name, axis=0)  # (n, n)
    all_offs = (jnp.cumsum(all_sizes, axis=1) - all_sizes).astype(jnp.int32)
    recv_offs = _exclusive_cumsum(recv_sizes)
    pad = jnp.zeros((chunk_rows, d), rows.dtype)
    out = jnp.zeros((out_rows, d), rows.dtype)
    for j in range(n):
        src = jnp.concatenate([all_rows[j], pad], axis=0)
        chunk = jax.lax.dynamic_slice(
            src, (all_offs[j, idx], 0), (chunk_rows, d))
        out = _place_chunk(out, chunk, jnp.take(recv_offs, j),
                           all_sizes[j, idx], out_rows)
    return out


# ------------------------------------------------------------ fused MLP
def make_overlapped_mlp(mesh, overlap: bool = True):
    """Jitted tensor-parallel MLP ``relu(x @ w1) @ w2`` over the ``model``
    axis.  ``overlap=True`` uses the ring kernels (collective-permute HLO);
    ``overlap=False`` uses the fused-collective references (all-gather HLO).
    """
    axis = "model"

    def mlp(x, w1, w2):
        if overlap:
            h = jax.nn.relu(ring_allgather_matmul(x, w1, axis))
            return ring_matmul_reducescatter(h, w2, axis)
        h = jax.nn.relu(allgather_matmul_reference(x, w1, axis))
        return matmul_reducescatter_reference(h, w2, axis)

    return jax.jit(shard_map(
        mlp, mesh=mesh,
        in_specs=(P(axis, None), P(None, axis), P(axis, None)),
        out_specs=P(axis, None)))
