"""Hand-scheduled ring collectives for compute/communication overlap.

The reference implementations use XLA's fused collectives (``all_gather`` /
``psum_scatter``): correct, but the gather must *complete* before the matmul
starts.  The ring variants decompose the collective into ``n-1`` point-to-
point ``ppermute`` steps interleaved with partial matmuls, so the compiler
can overlap each hop's transfer with the previous chunk's compute — the HLO
contains ``collective-permute`` ops instead of ``all-gather``.

All four kernels are written for use inside ``shard_map`` over one named
mesh axis.  ``psum(1, axis)`` is the standard static-axis-size idiom.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Version-portable shard_map: newer JAX exposes it at top level.  Callers
# on older JAX import it from here instead of ``jax.shard_map``.
try:
    from jax import shard_map  # type: ignore[attr-defined]
except ImportError:  # pragma: no cover - version-dependent
    from jax.experimental.shard_map import shard_map

from jax.sharding import PartitionSpec as P


def _axis_size(axis_name: str) -> int:
    return jax.lax.psum(1, axis_name)


# ------------------------------------------------------- allgather-matmul
def allgather_matmul_reference(x_shard, w_col, axis_name: str):
    """out[:, col_shard] = allgather(x) @ w_col — the unfused baseline.

    ``x_shard``: (m, K) row shard of x; ``w_col``: (K, n_col) column shard.
    Returns the full-row (n*m, n_col) product for this device's columns.
    """
    x = jax.lax.all_gather(x_shard, axis_name, axis=0, tiled=True)
    return x @ w_col


def ring_allgather_matmul(x_shard, w_col, axis_name: str):
    """Ring-overlapped allgather+matmul: each step multiplies the chunk
    currently held and forwards it one hop around the ring."""
    n = _axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    m = x_shard.shape[0]
    out_dtype = jnp.result_type(x_shard.dtype, w_col.dtype)
    out = jnp.zeros((n * m, w_col.shape[1]), out_dtype)
    perm = [(j, (j - 1) % n) for j in range(n)]  # receive from the right
    blk = x_shard
    for i in range(n):
        src = (idx + i) % n  # origin of the chunk currently held
        out = jax.lax.dynamic_update_slice(
            out, (blk @ w_col).astype(out_dtype), (src * m, 0))
        if i != n - 1:
            blk = jax.lax.ppermute(blk, axis_name, perm)
    return out


# --------------------------------------------------- matmul-reducescatter
def matmul_reducescatter_reference(h, w_row, axis_name: str):
    """scatter(psum(h @ w_row)) — the unfused baseline.

    ``h``: (M, k) column shard of activations; ``w_row``: (k, N) row shard.
    Returns this device's (M/n, N) row block of the summed product.
    """
    partial = h @ w_row
    return jax.lax.psum_scatter(
        partial, axis_name, scatter_dimension=0, tiled=True)


def ring_matmul_reducescatter(h, w_row, axis_name: str):
    """Ring-overlapped matmul+reduce-scatter: the partial sum destined for
    each device accumulates as it travels the ring."""
    n = _axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    m = h.shape[0] // n
    k = h.shape[1]

    def contrib(dest):
        rows = jax.lax.dynamic_slice(h, (dest * m, 0), (m, k))
        return rows @ w_row

    perm = [(j, (j + 1) % n) for j in range(n)]  # send to the right
    acc = contrib((idx - 1) % n)
    for i in range(1, n):
        acc = jax.lax.ppermute(acc, axis_name, perm)
        acc = acc + contrib((idx - i - 1) % n)
    return acc


# ------------------------------------------------------------ fused MLP
def make_overlapped_mlp(mesh, overlap: bool = True):
    """Jitted tensor-parallel MLP ``relu(x @ w1) @ w2`` over the ``model``
    axis.  ``overlap=True`` uses the ring kernels (collective-permute HLO);
    ``overlap=False`` uses the fused-collective references (all-gather HLO).
    """
    axis = "model"

    def mlp(x, w1, w2):
        if overlap:
            h = jax.nn.relu(ring_allgather_matmul(x, w1, axis))
            return ring_matmul_reducescatter(h, w2, axis)
        h = jax.nn.relu(allgather_matmul_reference(x, w1, axis))
        return matmul_reducescatter_reference(h, w2, axis)

    return jax.jit(shard_map(
        mlp, mesh=mesh,
        in_specs=(P(axis, None), P(None, axis), P(axis, None)),
        out_specs=P(axis, None)))
