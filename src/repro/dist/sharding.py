"""Logical-axis sharding rules — the layer every pspec in the system flows
through.

Model code names tensor dimensions *logically* (``batch``, ``kv_heads``,
``mlp``, ...).  A ruleset maps each logical name to one mesh axis (str),
one joint axis group (tuple), or None; ``logical_to_pspec`` resolves the
names of one tensor against a concrete (or abstract) mesh, enforcing the
invariants the partitioner requires:

* axes the mesh lacks are dropped from the group, so a rule like
  ``batch -> ("pod", "data")`` spans both data-parallel axes on a
  multi-pod mesh and degrades transparently to ``data`` alone on one pod;
* a mesh axis is consumed by at most one dimension of the tensor;
* a dimension is only sharded if its size is divisible by the product of
  the remaining axes' sizes — trailing axes are shed until it divides,
  and the dimension replicates when none fit (the GQA fallback: 4 KV
  heads on an 8-way model axis must replicate, not crash).
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import PartitionSpec

# logical name -> mesh axis (str), axis group (tuple, applied jointly), or
# None (never shard).  Names absent from the ruleset replicate.  Callers
# override per-cell with plain ``dict(base, kv_seq="model", head_dim=None)``.
RuleValue = Optional[object]  # None | str | Tuple[str, ...]
Rules = Dict[str, RuleValue]

DEFAULT_RULES: Rules = {
    # data-parallel batch: spans pod+data on a multi-pod mesh; axes missing
    # from the mesh are dropped, so one rule serves both topologies.
    "batch": ("pod", "data"),
    "seq": None,                 # replicated in the default (TP) layout
    "kv_seq": None,
    "embed": None,               # activations/residual dim stays replicated
    "head_dim": None,
    "qdh": None,
    "layers": None,              # scan-stacked layer dim is never sharded
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "vocab": "model",
    "experts": "model",
    "d_inner": "model",          # SSM/xLSTM inner dim
    "ssm_heads": "model",
}

# FSDP-style parameter layout: weights also shard their non-TP dim over the
# data axis so each replica holds 1/|data| of the parameters.
FSDP_RULES: Rules = {
    **DEFAULT_RULES,
    "embed": "data",
}

# Optimizer moments follow the FSDP parameter layout (they are per-parameter
# state and never participate in TP matmuls directly).
MOMENTS_RULES: Rules = {
    **FSDP_RULES,
}

# Decode: tiny per-step batches; batch on data alone, heads on model, and
# the KV sequence dimension replicated (paged pools shard physically).
DECODE_RULES: Rules = {
    **DEFAULT_RULES,
    "batch": "data",
}

# Sequence-parallel decode: long-context shards the KV sequence over the
# model axis (ring attention); KV head dims then replicate.
SP_DECODE_RULES: Rules = {
    **DECODE_RULES,
    "kv_seq": "model",
    "kv_heads": None,
}


_ACTIVE_RULES = threading.local()


@contextlib.contextmanager
def use_rules(rules: Rules):
    """Make ``rules`` the ambient ruleset for ``constrain`` and for
    ``logical_to_pspec(..., rules=None)`` within the block."""
    stack = getattr(_ACTIVE_RULES, "stack", None)
    if stack is None:
        stack = _ACTIVE_RULES.stack = []
    stack.append(rules)
    try:
        yield rules
    finally:
        stack.pop()


def active_rules() -> Rules:
    stack = getattr(_ACTIVE_RULES, "stack", None)
    return stack[-1] if stack else DEFAULT_RULES


def _axis_sizes(mesh) -> Dict[str, int]:
    # Mesh.shape and AbstractMesh.shape are both name->size mappings.
    return dict(mesh.shape)


def _shed_until_divisible(axes, axis_sizes, size):
    """THE divisibility fallback: drop trailing axes until ``size`` divides
    the product of the remainder; shedding everything replicates (the GQA
    fallback).  Shared by ``logical_to_pspec`` and ``batch_data_axes`` so
    the rule cannot drift between the pspec resolver and the shard_map
    paths that mirror it."""
    axes = list(axes)
    while axes:
        total = 1
        for a in axes:
            total *= axis_sizes[a]
        if total > 0 and size % total == 0:
            break
        axes.pop()
    return axes


def logical_to_pspec(
    names: Sequence[Optional[str]],
    sizes: Sequence[int],
    mesh,
    rules: Optional[Rules] = None,
) -> PartitionSpec:
    """Resolve logical dimension names to a PartitionSpec on ``mesh``.

    ``names[i]`` may be None (always replicated).  ``rules=None`` uses the
    ambient ruleset (``use_rules``), falling back to ``DEFAULT_RULES``.
    Works with both ``Mesh`` and ``AbstractMesh`` — only axis names and
    sizes are consulted.
    """
    if len(names) != len(sizes):
        raise ValueError("names and sizes must have equal length")
    if rules is None:
        rules = active_rules()
    axis_sizes = _axis_sizes(mesh)
    used: set = set()
    entries = []
    for name, size in zip(names, sizes):
        value = rules.get(name) if name is not None else None
        if value is None:
            axes = []
        elif isinstance(value, str):
            axes = [value]
        else:
            axes = list(value)
        # Axes the mesh lacks, or that an earlier dim consumed, drop out —
        # the same rule serves meshes of different topology.
        axes = [a for a in axes if a in axis_sizes and a not in used]
        axes = _shed_until_divisible(axes, axis_sizes, size)
        used.update(axes)
        entries.append(
            tuple(axes) if len(axes) > 1 else (axes[0] if axes else None))
    return PartitionSpec(*entries)


def _active_mesh():
    """The mesh installed by ``with mesh:``, or None outside any context."""
    try:
        from jax._src.mesh import thread_resources

        mesh = thread_resources.env.physical_mesh
        if mesh is not None and not mesh.empty:
            return mesh
    # check: disable=EXC01 -- probes a private jax API across versions;
    # ANY failure (ImportError, renamed attrs, changed types) means "no
    # ambient mesh", and None is that contract.
    except Exception:  # pragma: no cover - private-API drift
        pass
    return None


def active_mesh():
    """Public accessor for the ambient ``with mesh:`` context (or None).
    Model code uses it to decide whether an explicit shard_map path (the
    ragged ep MoE dispatch) applies."""
    return _active_mesh()


def constrain(x: jax.Array, names: Sequence[Optional[str]]) -> jax.Array:
    """``with_sharding_constraint`` by logical axis names.

    Model code annotates activations with logical names; under an active
    mesh context the names resolve through the ambient ruleset, and outside
    any mesh (single-device tests, CPU smoke runs) this is the identity.
    """
    mesh = _active_mesh()
    if mesh is None:
        return x
    spec = logical_to_pspec(names, x.shape, mesh, active_rules())
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec))


def batch_data_axes(mesh, size: Optional[int] = None) -> Tuple[str, ...]:
    """The data-parallel axes of ``mesh`` (``pod``+``data`` when present)
    that can shard a dimension of ``size``: trailing axes are shed until
    the size divides evenly, the same fallback ``logical_to_pspec``
    applies.  ``size=None`` skips the divisibility check.  This is THE
    definition of which mesh axes carry the batch — the shard_map MoE ep
    path and the data pipeline both resolve through it."""
    sizes = _axis_sizes(mesh)
    axes = [a for a in ("pod", "data") if a in sizes]
    if size is not None:
        axes = _shed_until_divisible(axes, sizes, size)
    return tuple(axes)


def batch_pspec(mesh) -> PartitionSpec:
    """PartitionSpec sharding dim 0 over the data-parallel axes of ``mesh``
    (pod+data when present).  Used by the data pipeline for host batches."""
    axes = batch_data_axes(mesh)
    if not axes:
        return PartitionSpec()
    return PartitionSpec(axes if len(axes) > 1 else axes[0])


def abstract_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]):
    """Version-portable ``AbstractMesh`` constructor.

    Newer JAX takes ``AbstractMesh(shape, names)``; older releases take one
    ``((name, size), ...)`` tuple.  Tests and tooling use this helper so the
    rule resolver stays exercisable on either API.
    """
    shapes = tuple(int(s) for s in axis_shapes)
    names = tuple(axis_names)
    try:
        return jax.sharding.AbstractMesh(shapes, names)
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(zip(names, shapes)))
