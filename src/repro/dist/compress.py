"""Gradient compression: block-wise symmetric int8 quantization with
error feedback.

``quantize`` flattens a tensor, pads it to a multiple of ``BLOCK`` elements
and stores one f32 scale per block (absmax / 127).  The round-trip error is
therefore bounded by ``0.5 * block_absmax / 127`` per element.  Everything is
pure ``jnp`` and jit-safe — the train step applies ``quantize_roundtrip`` to
gradient pytrees inside the compiled step when ``--compression int8`` is on.

``ErrorFeedback`` implements the classic EF-SGD trick: the quantization
residual is carried to the next step and added back before compressing, so
the *accumulated* compressed signal is unbiased even though each individual
quantization is not.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


@dataclasses.dataclass(frozen=True)
class QuantizedTensor:
    """int8 payload + per-block scales + the original shape/length."""

    q: jax.Array          # (n_blocks, BLOCK) int8
    scale: jax.Array      # (n_blocks, 1) f32
    shape: Tuple[int, ...]
    length: int           # valid elements before padding

    @property
    def nbytes_compressed(self) -> int:
        return int(self.q.size + self.scale.size * 4)


def _tree_flatten(qt):
    return (qt.q, qt.scale), (qt.shape, qt.length)


def _tree_unflatten(aux, children):
    q, scale = children
    shape, length = aux
    return QuantizedTensor(q=q, scale=scale, shape=shape, length=length)


jax.tree_util.register_pytree_node(QuantizedTensor, _tree_flatten, _tree_unflatten)


def quantize(x: jax.Array, block: int = BLOCK) -> QuantizedTensor:
    x = jnp.asarray(x)
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(blocks / safe), -127, 127).astype(jnp.int8)
    return QuantizedTensor(q=q, scale=scale, shape=tuple(x.shape), length=n)


def dequantize(qx: QuantizedTensor) -> jax.Array:
    flat = (qx.q.astype(jnp.float32) * qx.scale).reshape(-1)
    return flat[: qx.length].reshape(qx.shape)


def quantize_roundtrip(x: jax.Array, block: int = BLOCK) -> jax.Array:
    """quantize -> dequantize; the lossy identity the train step applies."""
    return dequantize(quantize(x, block))


@dataclasses.dataclass(frozen=True)
class ErrorFeedback:
    """Carried quantization residual (one per compressed tensor)."""

    residual: jax.Array

    @classmethod
    def init(cls, x: jax.Array) -> "ErrorFeedback":
        return cls(residual=jnp.zeros_like(x, dtype=jnp.float32))


jax.tree_util.register_pytree_node(
    ErrorFeedback,
    lambda ef: ((ef.residual,), None),
    lambda aux, children: ErrorFeedback(residual=children[0]),
)


def compress_with_feedback(
    x: jax.Array, ef: ErrorFeedback, block: int = BLOCK
) -> Tuple[QuantizedTensor, ErrorFeedback]:
    """Compress ``x + residual``; the new residual is what the quantizer
    dropped this round."""
    target = jnp.asarray(x, jnp.float32) + ef.residual
    qx = quantize(target, block)
    residual = target - dequantize(qx)
    return qx, ErrorFeedback(residual=residual)
