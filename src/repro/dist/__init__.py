"""repro.dist — distribution substrate: logical-axis sharding rules,
gradient compression, and hand-scheduled (overlapped) collectives.

Everything here is CPU-runnable: the sharding resolver works on
``AbstractMesh`` (no devices needed), compression is plain jnp, and the
collectives run under ``shard_map`` on fake XLA host devices.
"""

from .compress import (
    ErrorFeedback,
    compress_with_feedback,
    dequantize,
    quantize,
    quantize_roundtrip,
)
from .sharding import (
    DECODE_RULES,
    DEFAULT_RULES,
    FSDP_RULES,
    MOMENTS_RULES,
    SP_DECODE_RULES,
    abstract_mesh,
    active_mesh,
    batch_data_axes,
    batch_pspec,
    constrain,
    logical_to_pspec,
    use_rules,
)

__all__ = [
    "DECODE_RULES",
    "DEFAULT_RULES",
    "ErrorFeedback",
    "FSDP_RULES",
    "MOMENTS_RULES",
    "SP_DECODE_RULES",
    "abstract_mesh",
    "active_mesh",
    "batch_data_axes",
    "batch_pspec",
    "compress_with_feedback",
    "constrain",
    "dequantize",
    "logical_to_pspec",
    "quantize",
    "quantize_roundtrip",
    "use_rules",
]
