"""The rule registry and the per-module analysis context.

A rule is a class with a stable ``id``, a one-line ``title`` and a
``check(module)`` generator.  Registration is by decorator; the CLI and
the test suite both iterate ``all_rules()``, so a rule module only needs
to be imported (``rules/__init__.py`` does that) to participate.
"""

from __future__ import annotations

import ast
import dataclasses
from typing import Dict, Iterator, List, Set, Type

from . import callgraph, suppress
from .report import Finding


@dataclasses.dataclass
class Module:
    """Everything a rule needs to know about one source file."""

    path: str
    source: str
    lines: List[str]
    tree: ast.Module
    imports: callgraph.Imports
    parents: Dict[ast.AST, ast.AST]
    functions: Dict[str, ast.FunctionDef]
    suppressions: Dict[int, Set[str]]
    malformed: List[suppress.Malformed]

    @classmethod
    def load(cls, path: str, source: str) -> "Module":
        tree = ast.parse(source, filename=path)
        lines = source.splitlines()
        suppressed, malformed = suppress.parse(lines)
        return cls(
            path=path, source=source, lines=lines, tree=tree,
            imports=callgraph.Imports.of(tree),
            parents=callgraph.parent_map(tree),
            functions=callgraph.local_functions(tree),
            suppressions=suppressed, malformed=malformed)

    def finding(self, node: ast.AST, rule: str, message: str) -> Finding:
        return Finding(path=self.path, line=getattr(node, "lineno", 1),
                       col=getattr(node, "col_offset", 0) + 1,
                       rule=rule, message=message)

    def suppressed(self, f: Finding) -> bool:
        rules = self.suppressions.get(f.line, ())
        return f.rule in rules


class Rule:
    """Base class; subclasses set ``id``/``title`` and yield Findings."""

    id: str = ""
    title: str = ""

    def check(self, module: Module) -> Iterator[Finding]:
        raise NotImplementedError


_RULES: Dict[str, Rule] = {}


def register(cls: Type[Rule]) -> Type[Rule]:
    assert cls.id and cls.title, f"{cls.__name__} must set id and title"
    assert cls.id not in _RULES, f"duplicate rule id {cls.id}"
    _RULES[cls.id] = cls()
    return cls


def all_rules() -> Dict[str, Rule]:
    from . import rules  # noqa: F401  (importing registers everything)

    return dict(sorted(_RULES.items()))


def get_rule(rule_id: str) -> Rule:
    return all_rules()[rule_id]
