"""File discovery + the check loop: parse each module once, run every
registered rule over it, filter suppressions, sort deterministically."""

from __future__ import annotations

import os
from typing import Iterable, List, Optional, Sequence

from .registry import Module, all_rules
from .report import Finding

# Directory names skipped during traversal.  ``check_fixtures`` holds the
# deliberately-contract-violating rule fixtures: the tests check them by
# explicit path (explicit files always win over the exclude list).
DEFAULT_EXCLUDE_DIRS = {"check_fixtures", "__pycache__", ".git",
                        ".pytest_cache", "results"}


def iter_py_files(paths: Sequence[str],
                  exclude_dirs: Optional[Iterable[str]] = None) -> List[str]:
    excluded = (DEFAULT_EXCLUDE_DIRS if exclude_dirs is None
                else set(exclude_dirs))
    out: List[str] = []
    for path in paths:
        if os.path.isfile(path):
            out.append(path)
            continue
        for root, dirs, files in os.walk(path):
            dirs[:] = sorted(d for d in dirs if d not in excluded)
            out.extend(os.path.join(root, f) for f in sorted(files)
                       if f.endswith(".py"))
    return sorted(dict.fromkeys(out))


def load_module(path: str) -> Module:
    with open(path, "r", encoding="utf-8") as f:
        return Module.load(path, f.read())


def run_check(paths: Sequence[str],
              rule_ids: Optional[Sequence[str]] = None,
              exclude_dirs: Optional[Iterable[str]] = None) -> List[Finding]:
    """Run the (selected) rules over every .py file under ``paths``.

    Returns the unsuppressed findings, sorted by (path, line, col, rule).
    An unparsable file yields one CHK00 finding instead of crashing the
    sweep.
    """
    rules = all_rules()
    if rule_ids is not None:
        unknown = sorted(set(rule_ids) - set(rules))
        if unknown:
            raise ValueError(f"unknown rule ids: {', '.join(unknown)} "
                             f"(registered: {', '.join(rules)})")
        rules = {rid: rules[rid] for rid in rules if rid in set(rule_ids)}

    findings: List[Finding] = []
    for path in iter_py_files(paths, exclude_dirs=exclude_dirs):
        try:
            module = load_module(path)
        except (SyntaxError, UnicodeDecodeError) as e:
            findings.append(Finding(
                path=path, line=getattr(e, "lineno", 1) or 1, col=1,
                rule="CHK00",
                message=f"file does not parse: {type(e).__name__}: {e}"))
            continue
        for rule in rules.values():
            for f in rule.check(module):
                if not module.suppressed(f):
                    findings.append(f)
    return sorted(findings)
