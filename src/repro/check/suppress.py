"""Inline suppressions: ``# check: disable=RULE[,RULE...] -- reason``.

The reason is mandatory — a suppression is a reviewed exception to a
contract, and the justification must live next to it.  A directive with
no reason (or no parseable rule list) is reported as CHK00 instead of
honored.

Placement: a trailing comment binds to its own line; a comment-only line
binds to the next line of code below it (blank lines and further
comments are skipped downward).  Directives are recognized in real
comment tokens only, so docstrings that *mention* the syntax are inert.
"""

from __future__ import annotations

import dataclasses
import io
import re
import tokenize
from typing import Dict, List, Set, Tuple

_DIRECTIVE = re.compile(
    r"#\s*check:\s*disable=([A-Za-z0-9_,\s]*?)\s*(?:--\s*(\S.*))?$")


@dataclasses.dataclass
class Malformed:
    line: int
    message: str


def _comments(lines: List[str]):
    """(line, col, text) for every comment token; tolerant of files that
    tokenize rejects (the parser reports those separately)."""
    out = []
    reader = io.StringIO("\n".join(lines) + "\n").readline
    try:
        for tok in tokenize.generate_tokens(reader):
            if tok.type == tokenize.COMMENT:
                out.append((tok.start[0], tok.start[1], tok.string))
    except (tokenize.TokenizeError, IndentationError, SyntaxError):
        pass
    return out


def _bind_line(lines: List[str], line: int, col: int) -> int:
    """Standalone comments bind to the next code line below them."""
    if lines[line - 1][:col].strip():
        return line                   # trailing comment: binds in place
    j = line + 1
    while j <= len(lines):
        s = lines[j - 1].strip()
        if s and not s.startswith("#"):
            return j
        j += 1
    return line


def parse(lines: List[str]) -> Tuple[Dict[int, Set[str]], List[Malformed]]:
    """Map line -> suppressed rule ids, plus the malformed directives."""
    suppressed: Dict[int, Set[str]] = {}
    malformed: List[Malformed] = []
    for line, col, text in _comments(lines):
        m = _DIRECTIVE.search(text)
        if m is None:
            if "check: disable" in text:
                malformed.append(Malformed(
                    line, "unparseable suppression directive (expected "
                          "'# check: disable=RULE -- reason')"))
            continue
        rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
        reason = m.group(2)
        if not rules:
            malformed.append(Malformed(
                line, "suppression names no rules (expected "
                      "'# check: disable=RULE -- reason')"))
            continue
        if not reason:
            malformed.append(Malformed(
                line, f"suppression of {','.join(sorted(rules))} has no "
                      f"reason — append ' -- <why this exception is "
                      f"sound>'"))
            continue
        target = _bind_line(lines, line, col)
        suppressed.setdefault(target, set()).update(rules)
    return suppressed, malformed
