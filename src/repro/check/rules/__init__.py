"""Importing this package registers every rule with the registry."""

from . import (  # noqa: F401
    chk00,
    det01,
    det02,
    exc01,
    ft01,
    krn01,
    kv01,
    sched01,
    spmd01,
)
