"""Importing this package registers every rule with the registry."""

from . import chk00, det01, det02, exc01, krn01, kv01, spmd01  # noqa: F401
