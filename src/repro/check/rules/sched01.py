"""SCHED01 — serve/ft randomness must come from an explicitly seeded
generator.

The scheduling and workload layers are deterministic-by-contract: a
replayed trace must schedule, sample, and score identically
(DESIGN.md §14), and the conformance tests compare whole token streams
bitwise.  One unseeded or global-state random draw anywhere in those
packages breaks every downstream replay guarantee — silently, because the
run still "works".

Flagged inside any ``serve``/``ft`` directory (same scope rule as FT01):

* ``numpy.random.default_rng()`` with no seed argument (or an explicit
  ``None``) — a fresh OS-entropy generator.
* Any draw on numpy's GLOBAL legacy state (``numpy.random.poisson``,
  ``numpy.random.rand``, ...) — shared mutable state whose sequence
  depends on every other caller in the process.
* The stdlib ``random`` module's global functions, and ``random.Random()``
  constructed without a seed.

The sanctioned pattern threads one seeded generator::

    rng = np.random.default_rng(cfg.seed)   # SCHED01-clean
    n = rng.poisson(rate)
"""

from __future__ import annotations

import ast
from typing import Iterator

from ..registry import Module, Rule, register
from ..report import Finding
from .ft01 import _in_scope

# numpy's global-state draw/seed surface (legacy RandomState module
# functions).  Methods on a Generator object never match: their qualname
# roots at the local variable, not at ``numpy.random``.
_NUMPY_GLOBAL = {
    "beta", "binomial", "bytes", "chisquare", "choice", "dirichlet",
    "exponential", "gamma", "geometric", "gumbel", "integers", "laplace",
    "lognormal", "multinomial", "normal", "permutation", "poisson",
    "rand", "randint", "randn", "random", "random_sample", "ranf",
    "sample", "seed", "shuffle", "standard_normal", "uniform", "vonmises",
    "weibull", "zipf",
}

_STDLIB_RANDOM = {
    "betavariate", "choice", "choices", "expovariate", "gauss",
    "getrandbits", "lognormvariate", "normalvariate", "paretovariate",
    "randbytes", "randint", "random", "randrange", "sample", "seed",
    "shuffle", "triangular", "uniform", "vonmisesvariate",
    "weibullvariate",
}


def _unseeded(node: ast.Call) -> bool:
    """No positional seed and no seed= keyword, or an explicit None."""
    if node.args:
        first = node.args[0]
        return isinstance(first, ast.Constant) and first.value is None
    for kw in node.keywords:
        if kw.arg == "seed":
            return (isinstance(kw.value, ast.Constant)
                    and kw.value.value is None)
    return True


@register
class Sched01(Rule):
    id = "SCHED01"
    title = ("unseeded or global-state randomness in serve/ or ft/ — "
             "draw from an explicitly seeded np.random.default_rng(seed)")

    def check(self, module: Module) -> Iterator[Finding]:
        if not _in_scope(module.path):
            return
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Call):
                continue
            qn = module.imports.qualname(node.func)
            if qn is None:
                continue
            if qn == "numpy.random.default_rng" and _unseeded(node):
                yield module.finding(
                    node, self.id,
                    f"unseeded default_rng() in {module.path} — pass an "
                    f"explicit seed so traces and schedules replay "
                    f"identically")
            elif (qn.startswith("numpy.random.")
                  and qn.rsplit(".", 1)[1] in _NUMPY_GLOBAL):
                yield module.finding(
                    node, self.id,
                    f"numpy GLOBAL-state draw '{qn}()' in {module.path} — "
                    f"its sequence depends on every other caller in the "
                    f"process; draw from a local seeded "
                    f"np.random.default_rng(seed) instead")
            elif (qn.startswith("random.")
                  and qn.rsplit(".", 1)[1] in _STDLIB_RANDOM):
                yield module.finding(
                    node, self.id,
                    f"stdlib global random call '{qn}()' in {module.path} "
                    f"— use a local seeded np.random.default_rng(seed)")
            elif qn == "random.Random" and _unseeded(node):
                yield module.finding(
                    node, self.id,
                    f"unseeded random.Random() in {module.path} — "
                    f"construct with an explicit seed (or use "
                    f"np.random.default_rng(seed))")
