"""CHK00 — linter hygiene.

Malformed suppression directives (no rule list, or no ``-- reason``)
surface here instead of being silently honored or ignored: a suppression
is a reviewed contract exception and must carry its justification.
Unparsable files are reported under the same id by the driver.
"""

from __future__ import annotations

from typing import Iterator

from ..registry import Module, Rule, register
from ..report import Finding


@register
class Chk00(Rule):
    id = "CHK00"
    title = ("linter hygiene: unparsable file or malformed suppression "
             "(reason is mandatory)")

    def check(self, module: Module) -> Iterator[Finding]:
        for m in module.malformed:
            yield Finding(path=module.path, line=m.line, col=1,
                          rule=self.id, message=m.message)
