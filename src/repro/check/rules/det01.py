"""DET01 — nondeterminism must not reach traced code.

Walks the module-local call graph from every jit entry point (functions
decorated with / passed to ``jax.jit``, ``pl.pallas_call``,
``shard_map``, ``jax.pmap``) and flags, anywhere reachable:

  * stdlib ``random.*`` and ``np.random.*`` calls — their values bake
    into the trace as constants that differ between traces (and between
    processes), silently breaking replay and cache hits;
  * wall-clock reads (``time.time``/``perf_counter``/``monotonic``,
    ``datetime.now``) — same trace-constant hazard;
  * iteration over a set literal / ``set()``/``frozenset()`` value —
    iteration order depends on PYTHONHASHSEED, so the traced program
    (op order, and with it numerics) differs run to run.

``jax.random.*`` with explicit keys is the sanctioned path and is not
flagged.  The walk is module-local by design: each module is analyzed
from its own entry points, and cross-module helpers are covered when
their defining module is swept.
"""

from __future__ import annotations

import ast
from typing import Iterator, List, Optional

from .. import callgraph
from ..registry import Module, Rule, register
from ..report import Finding

_JIT_ENTRY_SUFFIXES = ("jax.jit", "pallas.pallas_call", "pl.pallas_call",
                       "jax.pmap")
_SHARD_MAP_NAMES = ("shard_map", "shard_map_compat")

_TIME_CALLS = {
    "time.time", "time.time_ns", "time.perf_counter",
    "time.perf_counter_ns", "time.monotonic", "time.monotonic_ns",
    "datetime.datetime.now", "datetime.datetime.utcnow", "datetime.now",
    "datetime.utcnow",
}


def _is_tracing_transform(qn: Optional[str]) -> bool:
    if qn is None:
        return False
    if qn.endswith(_JIT_ENTRY_SUFFIXES):
        return True
    return qn.split(".")[-1] in _SHARD_MAP_NAMES


def _callee_expr(node: ast.expr, module: Module) -> Optional[ast.AST]:
    """Resolve the traced function from a transform's argument: a bare
    local name, a lambda, or ``functools.partial(name, ...)``."""
    if isinstance(node, ast.Lambda):
        return node
    if isinstance(node, ast.Name):
        return module.functions.get(node.id)
    if isinstance(node, ast.Call):
        qn = module.imports.qualname(node.func)
        if qn is not None and qn.split(".")[-1] == "partial" and node.args:
            return _callee_expr(node.args[0], module)
    return None


def _entry_points(module: Module) -> List[ast.AST]:
    entries: List[ast.AST] = []
    for fn in module.functions.values():
        for dec in fn.decorator_list:
            target = dec.func if isinstance(dec, ast.Call) else dec
            qn = module.imports.qualname(target)
            if _is_tracing_transform(qn):
                entries.append(fn)
            elif (isinstance(dec, ast.Call)
                    and qn is not None and qn.split(".")[-1] == "partial"
                    and dec.args
                    and _is_tracing_transform(
                        module.imports.qualname(dec.args[0]))):
                entries.append(fn)
    for node in ast.walk(module.tree):
        if isinstance(node, ast.Call) and \
                _is_tracing_transform(module.imports.qualname(node.func)):
            if node.args:
                body = _callee_expr(node.args[0], module)
                if body is not None:
                    entries.append(body)
    return entries


def _set_valued(node: ast.expr) -> bool:
    if isinstance(node, ast.Set):
        return True
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
        return node.func.id in ("set", "frozenset")
    return False


@register
class Det01(Rule):
    id = "DET01"
    title = ("nondeterministic source (random/np.random/clock/set "
             "iteration) reachable from a jit/pallas/shard_map entry")

    def check(self, module: Module) -> Iterator[Finding]:
        entries = _entry_points(module)
        if not entries:
            return
        seen = set()
        for fn in callgraph.reachable(entries, module.functions):
            where = getattr(fn, "name", "<lambda>")
            for node in ast.walk(fn):
                if id(node) in seen:
                    continue
                seen.add(id(node))
                if isinstance(node, ast.Call):
                    qn = module.imports.qualname(node.func)
                    if qn is None:
                        continue
                    if qn.startswith("random.") or \
                            qn.startswith("numpy.random."):
                        yield module.finding(
                            node, self.id,
                            f"'{qn}' inside traced code (via '{where}') "
                            f"bakes a different constant into every "
                            f"trace — use jax.random with an explicit "
                            f"key")
                    elif qn in _TIME_CALLS:
                        yield module.finding(
                            node, self.id,
                            f"wall-clock read '{qn}' inside traced code "
                            f"(via '{where}') is a trace-time constant — "
                            f"hoist it out of the jitted region")
                elif isinstance(node, (ast.For, ast.comprehension)):
                    it = node.iter
                    if _set_valued(it):
                        yield module.finding(
                            it, self.id,
                            f"iteration over a set inside traced code "
                            f"(via '{where}') depends on PYTHONHASHSEED "
                            f"— sort it or use a list/tuple")
