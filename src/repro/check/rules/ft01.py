"""FT01 — serving/fault-tolerance code must take its clock by injection.

The elastic-serving layer (``serve/``) and the fault-tolerance package
(``ft/``) are tested against deterministic failure timelines: the router
beats its ``HeartbeatMonitor`` with a step-counter clock, and the tests
replay crashes at exact ticks.  A direct ``time.time()`` /
``time.monotonic()`` (or ``perf_counter``) call inside those packages
reads the wall clock behind the injected clock's back, so heartbeat
timeouts, straggler EWMAs and failover decisions stop being replayable.

The sanctioned pattern passes the clock in as a parameter and *calls the
parameter*::

    def __init__(self, ..., clock: Callable[[], float] = time.monotonic):
        self.clock = clock          # reference, not a call — FT01-clean
        ...
        now = self.clock()

Only files whose directory path contains a ``serve`` or ``ft``
component are in scope; launchers and benchmarks may time themselves
with the wall clock freely.
"""

from __future__ import annotations

import ast
import os
from typing import Iterator

from ..registry import Module, Rule, register
from ..report import Finding

_WALL_CALLS = {
    "time.time", "time.time_ns", "time.monotonic", "time.monotonic_ns",
    "time.perf_counter", "time.perf_counter_ns",
}

_SCOPE_DIRS = {"serve", "ft"}


def _in_scope(path: str) -> bool:
    parts = os.path.normpath(path).split(os.sep)
    return any(p in _SCOPE_DIRS for p in parts[:-1])


@register
class Ft01(Rule):
    id = "FT01"
    title = ("wall-clock call in serve/ or ft/ — inject the clock "
             "(clock=time.monotonic parameter) instead")

    def check(self, module: Module) -> Iterator[Finding]:
        if not _in_scope(module.path):
            return
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Call):
                continue
            qn = module.imports.qualname(node.func)
            if qn in _WALL_CALLS:
                yield module.finding(
                    node, self.id,
                    f"direct wall-clock call '{qn}()' in {module.path} — "
                    f"serve/ft code must call an injected clock parameter "
                    f"(default it to {qn} instead of calling it) so "
                    f"failure timelines stay replayable")
