"""KRN01 — the Pallas grid / BlockSpec / masked-store contract.

For every ``pl.pallas_call`` site the rule recovers the declared grid
(literal tuple, or a name bound to one in the enclosing function), the
scalar-prefetch count (``PrefetchScalarGridSpec``), the Block Specs and
the kernel function, then checks:

* **arity** — every index map must take exactly ``rank(grid) +
  num_scalar_prefetch`` positional parameters (closure-capture defaults
  like ``lambda i, j, G=G:`` don't count);
* **rank** — an index map returning a literal tuple must return one
  block index per ``block_shape`` dimension;
* **bounds** — literal block indices are interpreted against the literal
  grid/out_shape when both are static: negative indices, or a constant
  index >= the block count of that dimension, are flagged;
* **revisited stores** (the PR 2/3 grouped-GEMM bug class) — when an
  output BlockSpec's index map ignores a grid axis, or gathers its block
  index through a scalar-prefetch array (``mids[i]``), several grid
  steps hit the same output block.  Every plain store to that output ref
  must then be masked: under a ``pl.when``-decorated sub-function, or a
  ``jnp.where`` select.  An unguarded ``ref[...] = x`` there is exactly
  the ragged-boundary overwrite that produced garbage at segment ends.

Sites whose grid or specs cannot be resolved statically are skipped —
the rule under-reports rather than guessing.
"""

from __future__ import annotations

import ast
from typing import Iterator, List, Optional, Sequence, Tuple

from .. import callgraph
from ..registry import Module, Rule, register
from ..report import Finding


def _last(qn: Optional[str]) -> str:
    return qn.split(".")[-1] if qn else ""


def _kwarg(call: ast.Call, name: str) -> Optional[ast.expr]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _int_literal(node: Optional[ast.expr]) -> Optional[int]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return node.value
    return None


def _resolve_tuple(node: Optional[ast.expr], module: Module,
                   site: ast.AST) -> Optional[ast.Tuple]:
    """A tuple expression, following one level of local assignment."""
    if isinstance(node, ast.Tuple):
        return node
    if isinstance(node, ast.Name):
        scope = callgraph.enclosing(
            site, module.parents, (ast.FunctionDef, ast.AsyncFunctionDef))
        value = callgraph.resolve_assignment(
            node.id, scope, module.tree)
        if isinstance(value, ast.Tuple):
            return value
    return None


def _spec_list(node: Optional[ast.expr]) -> List[ast.Call]:
    """BlockSpec calls from an in_specs/out_specs expression."""
    if node is None:
        return []
    items = node.elts if isinstance(node, (ast.List, ast.Tuple)) else [node]
    return [it for it in items
            if isinstance(it, ast.Call) and _last_name(it.func) == "BlockSpec"]


def _last_name(node: ast.expr) -> str:
    while isinstance(node, ast.Attribute):
        return node.attr
    return node.id if isinstance(node, ast.Name) else ""


def _block_spec_parts(spec: ast.Call):
    """(block_shape tuple|None, index_map lambda|None)."""
    shape = spec.args[0] if spec.args else _kwarg(spec, "block_shape")
    imap = (spec.args[1] if len(spec.args) > 1
            else _kwarg(spec, "index_map"))
    shape_t = shape if isinstance(shape, ast.Tuple) else None
    imap_l = imap if isinstance(imap, ast.Lambda) else None
    return shape_t, imap_l


def _kernel_def(arg: ast.expr, module: Module) -> Optional[ast.FunctionDef]:
    if isinstance(arg, ast.Name):
        return module.functions.get(arg.id)
    if isinstance(arg, ast.Call) and _last(module.imports.qualname(
            arg.func)) == "partial" and arg.args:
        return _kernel_def(arg.args[0], module)
    return None


def _positional_params(fn) -> List[str]:
    args = fn.args
    names = [a.arg for a in args.posonlyargs + args.args]
    n_default = len(args.defaults)
    return names[: len(names) - n_default] if n_default else names


def _guarded(store: ast.AST, kernel: ast.FunctionDef,
             parents) -> bool:
    """Masked store: under a pl.when-decorated def / a conditional, or a
    where-select value."""
    value = getattr(store, "value", None)
    for node in ast.walk(value) if value is not None else []:
        if isinstance(node, ast.Call) and _last_name(node.func) == "where":
            return True
    cur = parents.get(store)
    while cur is not None and cur is not kernel:
        if isinstance(cur, ast.If):
            return True
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in cur.decorator_list:
                target = dec.func if isinstance(dec, ast.Call) else dec
                if _last_name(target) == "when":
                    return True
        cur = parents.get(cur)
    return False


class _Site:
    """One resolved pallas_call invocation."""

    def __init__(self, call: ast.Call, module: Module):
        self.call = call
        self.module = module
        self.n_prefetch = 0
        spec = _kwarg(call, "grid_spec")
        if isinstance(spec, ast.Name):
            scope = callgraph.enclosing(
                call, module.parents,
                (ast.FunctionDef, ast.AsyncFunctionDef))
            spec = callgraph.resolve_assignment(
                spec.id, scope, module.tree) or spec
        src = call
        if isinstance(spec, ast.Call) and \
                _last_name(spec.func) == "PrefetchScalarGridSpec":
            src = spec
            self.n_prefetch = _int_literal(
                _kwarg(spec, "num_scalar_prefetch")) or 0
        self.grid = _resolve_tuple(_kwarg(src, "grid"), module, call)
        self.rank = len(self.grid.elts) if self.grid is not None else None
        self.in_specs = _spec_list(_kwarg(src, "in_specs"))
        self.out_specs = _spec_list(_kwarg(src, "out_specs"))
        self.out_shape = _kwarg(call, "out_shape")
        self.kernel = (_kernel_def(call.args[0], module)
                       if call.args else None)

    def grid_extent(self, axis: int) -> Optional[int]:
        if self.grid is None or axis >= len(self.grid.elts):
            return None
        return _int_literal(self.grid.elts[axis])


def _block_counts(site: _Site, shape_t: ast.Tuple) -> List[Optional[int]]:
    """Blocks per dimension when out_shape and block_shape are literal."""
    dims: Sequence[Optional[int]] = []
    out = site.out_shape
    if isinstance(out, ast.Call) and \
            _last_name(out.func) == "ShapeDtypeStruct" and out.args:
        tup = out.args[0]
        if isinstance(tup, ast.Tuple):
            dims = [_int_literal(e) for e in tup.elts]
    counts: List[Optional[int]] = []
    for i, be in enumerate(shape_t.elts):
        b = _int_literal(be)
        d = dims[i] if i < len(dims) else None
        counts.append(-(-d // b) if (b and d is not None) else None)
    return counts


@register
class Krn01(Rule):
    id = "KRN01"
    title = ("Pallas BlockSpec contract: index-map arity/rank, literal "
             "out-of-bounds blocks, unguarded stores to revisited "
             "output blocks")

    def check(self, module: Module) -> Iterator[Finding]:
        for node in ast.walk(module.tree):
            if not (isinstance(node, ast.Call)
                    and _last(module.imports.qualname(
                        node.func)) == "pallas_call"):
                continue
            site = _Site(node, module)
            specs = ([(s, False) for s in site.in_specs]
                     + [(s, True) for s in site.out_specs])
            for spec, is_out in specs:
                yield from self._check_spec(site, spec, is_out)

    def _check_spec(self, site: _Site, spec: ast.Call,
                    is_out: bool) -> Iterator[Finding]:
        module = site.module
        shape_t, imap = _block_spec_parts(spec)
        if imap is None:
            return
        params = [a.arg for a in imap.args.args]
        n_default = len(imap.args.defaults)
        positional = params[: len(params) - n_default] if n_default \
            else params
        if site.rank is not None:
            expected = site.rank + site.n_prefetch
            if len(positional) != expected:
                yield module.finding(
                    imap, self.id,
                    f"index map takes {len(positional)} grid/prefetch "
                    f"parameters but the grid declares {site.rank} "
                    f"axes + {site.n_prefetch} scalar-prefetch refs "
                    f"(= {expected})")
                return
        body = imap.body
        returned = body.elts if isinstance(body, ast.Tuple) else None
        if returned is not None and shape_t is not None and \
                len(returned) != len(shape_t.elts):
            yield module.finding(
                imap, self.id,
                f"index map returns {len(returned)} block indices for a "
                f"{len(shape_t.elts)}-dimensional block_shape")
            return
        if returned is not None and shape_t is not None and is_out:
            counts = _block_counts(site, shape_t)
            for dim, expr in enumerate(returned):
                v = _int_literal(expr)
                neg = (isinstance(expr, ast.UnaryOp)
                       and isinstance(expr.op, ast.USub)
                       and _int_literal(expr.operand) is not None)
                if neg:
                    yield module.finding(
                        expr, self.id,
                        f"index map emits a negative block index for "
                        f"output dimension {dim}")
                elif v is not None and dim < len(counts) and \
                        counts[dim] is not None and v >= counts[dim]:
                    yield module.finding(
                        expr, self.id,
                        f"constant block index {v} is out of bounds for "
                        f"output dimension {dim} "
                        f"({counts[dim]} blocks)")
        if is_out and site.rank is not None:
            yield from self._check_revisit(site, imap, positional)

    def _check_revisit(self, site: _Site, imap: ast.Lambda,
                       positional: List[str]) -> Iterator[Finding]:
        module = site.module
        grid_params = positional[: site.rank]
        prefetch_params = set(positional[site.rank:])
        used = {n.id for n in ast.walk(imap.body)
                if isinstance(n, ast.Name)}
        unused_axes = [p for p in grid_params if p not in used]
        # Grid axes of literal extent 1 can't revisit anything.
        unused_axes = [p for p in unused_axes
                       if site.grid_extent(grid_params.index(p)) != 1]
        gathered = any(
            isinstance(n, ast.Subscript) and isinstance(n.value, ast.Name)
            and n.value.id in prefetch_params
            for n in ast.walk(imap.body))
        if not unused_axes and not gathered:
            return
        kernel = site.kernel
        if kernel is None:
            return
        n_in = len(site.in_specs)
        params = _positional_params(kernel)
        out_slot = site.n_prefetch + n_in
        n_out = max(len(site.out_specs), 1)
        out_refs = set(params[out_slot: out_slot + n_out])
        if not out_refs:
            return
        parents = callgraph.parent_map(kernel)
        why = (f"grid axis '{unused_axes[0]}' is unused by the out-spec "
               f"index map" if unused_axes else
               "the out-spec block index gathers through a "
               "scalar-prefetch array")
        for node in ast.walk(kernel):
            if isinstance(node, ast.Assign) and any(
                    isinstance(t, ast.Subscript)
                    and isinstance(t.value, ast.Name)
                    and t.value.id in out_refs for t in node.targets):
                if not _guarded(node, kernel, parents):
                    ref = next(t.value.id for t in node.targets
                               if isinstance(t, ast.Subscript)
                               and isinstance(t.value, ast.Name))
                    yield module.finding(
                        node, self.id,
                        f"output block is revisited across grid steps "
                        f"({why}) but kernel '{kernel.name}' stores to "
                        f"'{ref}' unguarded — wrap the store in pl.when "
                        f"or mask it with jnp.where")
