"""DET02 — PRNG key discipline (the PR 5 sampling-determinism contract).

Two patterns:

1. *Key reuse*: the same key variable feeding two ``jax.random.*``
   consumers without an intervening ``split``/``fold_in``/reassignment.
   Reused keys make "independent" draws identical — the exact bug class
   the per-row ``fold_in(PRNGKey(seed), position)`` scheme exists to
   prevent.  The analysis is function-local and branch-aware (uses on
   the two arms of an ``if`` don't accumulate against each other); a
   consumer inside a loop whose key was created outside it counts as
   reuse, because every iteration redraws the same bits.

2. *Hardcoded fallback keys*: ``PRNGKey(<literal>)`` as a parameter
   default or as the fallback arm of ``x if x is not None else ...`` /
   ``x or ...``.  A silent constant default makes every caller share
   one stream while looking seeded — require the key (or an explicit
   seed) instead.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Set, Tuple

from ..registry import Module, Rule, register
from ..report import Finding

# jax.random.* callables that CONSUME a key (draw bits from it).  split /
# fold_in / key utilities derive fresh keys and are the sanctioned way to
# use one key twice.
_DERIVERS = {"split", "fold_in", "PRNGKey", "key", "key_data",
             "wrap_key_data", "clone", "key_impl"}


def _is_jax_random(qn) -> bool:
    return qn is not None and (qn.startswith("jax.random.")
                               or qn.startswith("jax._src.random."))


def _consumed_key(node: ast.Call, module: Module):
    """The key variable name if this call consumes a bare Name key."""
    qn = module.imports.qualname(node.func)
    if not _is_jax_random(qn) or qn.split(".")[-1] in _DERIVERS:
        return None
    args = list(node.args) + [kw.value for kw in node.keywords
                              if kw.arg in ("key", "rng")]
    if args and isinstance(args[0], ast.Name):
        return args[0].id
    return None


def _assigned_names(target: ast.expr) -> Set[str]:
    out: Set[str] = set()
    for n in ast.walk(target):
        if isinstance(n, ast.Name):
            out.add(n.id)
    return out


class _FnScan:
    """Sequential, branch-forking scan of one function body."""

    def __init__(self, module: Module):
        self.module = module
        self.findings: List[Tuple[int, Finding]] = []
        self.reported: Set[Tuple[int, str]] = set()

    def run(self, fn: ast.AST) -> List[Finding]:
        self._stmts(list(getattr(fn, "body", [])), {}, in_loop=False)
        return [f for _, f in sorted(self.findings,
                                     key=lambda t: (t[0], t[1].line))]

    # counts: key name -> consumptions since its last (re)definition
    def _stmts(self, body, counts: Dict[str, int], in_loop: bool) -> None:
        for stmt in body:
            self._stmt(stmt, counts, in_loop)

    def _stmt(self, stmt: ast.stmt, counts: Dict[str, int],
              in_loop: bool) -> None:
        if isinstance(stmt, ast.If):
            a, b = dict(counts), dict(counts)
            self._stmts(stmt.body, a, in_loop)
            self._stmts(stmt.orelse, b, in_loop)
            for k in set(a) | set(b):
                counts[k] = max(a.get(k, 0), b.get(k, 0))
            return
        if isinstance(stmt, (ast.For, ast.While)):
            # Keys minted before the loop and consumed inside it redraw
            # the same bits every iteration: scan the body twice so the
            # second pass sees the first pass's consumption.
            self._stmts(stmt.body, counts, in_loop=True)
            self._stmts(stmt.body, counts, in_loop=True)
            self._stmts(stmt.orelse, counts, in_loop)
            return
        if isinstance(stmt, (ast.With,)):
            self._stmts(stmt.body, counts, in_loop)
            return
        if isinstance(stmt, (ast.Try,)):
            self._stmts(stmt.body, counts, in_loop)
            for h in stmt.handlers:
                self._stmts(h.body, dict(counts), in_loop)
            self._stmts(stmt.orelse, counts, in_loop)
            self._stmts(stmt.finalbody, counts, in_loop)
            return
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return                    # nested defs get their own scan
        # Straight-line statement: consumptions first, then redefinitions.
        for node in ast.walk(stmt):
            if isinstance(node, ast.Call):
                name = _consumed_key(node, self.module)
                if name is None:
                    continue
                counts[name] = counts.get(name, 0) + 1
                if counts[name] > 1:
                    key = (node.lineno, name)
                    if key not in self.reported:
                        self.reported.add(key)
                        self.findings.append((node.lineno, self.module.finding(
                            node, "DET02",
                            f"PRNG key '{name}' reused by a second "
                            f"jax.random consumer — split or fold_in "
                            f"between draws, or the streams are "
                            f"identical")))
        if isinstance(stmt, ast.Assign):
            for t in stmt.targets:
                for name in _assigned_names(t):
                    counts[name] = 0
        elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
            for name in _assigned_names(stmt.target):
                counts[name] = 0


def _literal_prngkey(node: ast.expr, module: Module) -> bool:
    if not isinstance(node, ast.Call):
        return False
    qn = module.imports.qualname(node.func)
    if qn is None or qn.split(".")[-1] not in ("PRNGKey", "key"):
        return False
    if not _is_jax_random(qn) and not qn.endswith(
            ("random.PRNGKey", "random.key")):
        return False
    return bool(node.args) and isinstance(node.args[0], ast.Constant)


@register
class Det02(Rule):
    id = "DET02"
    title = ("PRNG key reuse without split/fold_in, or a hardcoded "
             "PRNGKey(<literal>) fallback default")

    def check(self, module: Module) -> Iterator[Finding]:
        for fn in module.functions.values():
            yield from _FnScan(module).run(fn)
        for node in ast.walk(module.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
                defaults = (list(node.args.defaults)
                            + [d for d in node.args.kw_defaults if d])
                for d in defaults:
                    if _literal_prngkey(d, module):
                        yield module.finding(
                            d, self.id,
                            "hardcoded PRNGKey literal as a parameter "
                            "default shares one stream across all "
                            "callers — require a key or an explicit "
                            "seed")
            elif isinstance(node, ast.IfExp):
                for arm in (node.body, node.orelse):
                    if _literal_prngkey(arm, module):
                        yield module.finding(
                            arm, self.id,
                            "hardcoded PRNGKey literal as a silent "
                            "fallback — require a key or derive from an "
                            "explicit config seed")
            elif isinstance(node, ast.BoolOp) and isinstance(node.op,
                                                             ast.Or):
                for arm in node.values[1:]:
                    if _literal_prngkey(arm, module):
                        yield module.finding(
                            arm, self.id,
                            "hardcoded PRNGKey literal as an 'or' "
                            "fallback — require a key or derive from an "
                            "explicit config seed")
