"""KV01 — the PagedKVPool acquire/copy_page/release_request protocol.

The pool's refcount protocol (DESIGN.md Sec. 11) has three statically
checkable caller obligations.  Receivers are matched by name — the rule
applies to attribute calls whose object chain mentions ``pool`` (so
``threading.Lock.acquire`` and friends never false-positive), and the
class that *implements* the protocol (defines ``acquire``, ``free`` and
``release_request`` itself) is exempt:

1. **Leaked references** — a class (or a module's top-level functions)
   that calls ``pool.acquire(...)`` must somewhere drop references too
   (``pool.free``/``pool.release_request``): references taken but never
   returned pin physical pages forever.
2. **Copy-on-write** — a handle obtained ``acquire(..., shared=True)``
   is immutable; mutating its bookkeeping (``tokens_used`` etc.) without
   an intervening ``copy_page`` corrupts every other holder's KV.
3. **Freeing held pages** — a page reached through
   ``pool.request_pages(rid)`` is still in the pool's authoritative
   ``_seq`` table; ``pool.free`` on it desynchronizes the table from the
   refcounts.  Ownership is dropped per-request via ``release_request``.
"""

from __future__ import annotations

import ast
from typing import Iterator, List, Optional, Set

from ..registry import Module, Rule, register
from ..report import Finding

_PROTOCOL = {"acquire", "free", "release_request"}
_RELEASERS = {"free", "release_request"}
# Page bookkeeping a shared handle may still touch (the eviction clock).
_SAFE_SHARED_ATTRS = {"last_used"}


def _pool_method(node: ast.AST, name: str) -> bool:
    """True for ``<...pool...>.name(...)`` attribute calls."""
    if not (isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)
            and node.func.attr == name):
        return False
    chain: List[str] = []
    cur = node.func.value
    while isinstance(cur, ast.Attribute):
        chain.append(cur.attr)
        cur = cur.value
    if isinstance(cur, ast.Name):
        chain.append(cur.id)
    return any("pool" in part.lower() for part in chain)


def _implements_protocol(cls: ast.ClassDef) -> bool:
    defined = {n.name for n in cls.body
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
    return _PROTOCOL <= defined


def _shared_kwarg(call: ast.Call) -> bool:
    for kw in call.keywords:
        if kw.arg == "shared" and isinstance(kw.value, ast.Constant):
            return bool(kw.value.value)
    return False


@register
class Kv01(Rule):
    id = "KV01"
    title = ("PagedKVPool protocol: acquire without release, shared-page "
             "mutation without copy_page, free on a held request page")

    def check(self, module: Module) -> Iterator[Finding]:
        scopes: List[ast.AST] = [module.tree]
        for node in ast.walk(module.tree):
            if isinstance(node, ast.ClassDef):
                scopes.append(node)
        for scope in scopes:
            yield from self._check_scope(module, scope)
        for fn in module.functions.values():
            yield from self._check_shared_mutation(module, fn)
            yield from self._check_free_held(module, fn)

    # ------------------------------------------------ 1. leaked acquires
    def _check_scope(self, module: Module,
                     scope: ast.AST) -> Iterator[Finding]:
        if isinstance(scope, ast.ClassDef):
            if _implements_protocol(scope):
                return
            nodes = list(ast.walk(scope))
        else:
            # Module scope: everything not inside a class.
            in_class: Set[int] = set()
            for cls in ast.walk(scope):
                if isinstance(cls, ast.ClassDef):
                    in_class.update(id(n) for n in ast.walk(cls))
            nodes = [n for n in ast.walk(scope) if id(n) not in in_class]
        acquires = [n for n in nodes if _pool_method(n, "acquire")]
        if not acquires:
            return
        releases = any(_pool_method(n, r)
                       for n in nodes for r in _RELEASERS)
        if releases:
            return
        where = (f"class {scope.name}" if isinstance(scope, ast.ClassDef)
                 else "module scope")
        for node in acquires:
            yield module.finding(
                node, self.id,
                f"pool.acquire takes a page reference but {where} never "
                f"calls free/release_request — the reference (and its "
                f"physical slot at refcount>0) leaks")

    # ------------------------------------- 2. shared handles are immutable
    def _check_shared_mutation(self, module: Module,
                               fn: ast.AST) -> Iterator[Finding]:
        shared: dict = {}
        copy_lines: List[int] = [
            n.lineno for n in ast.walk(fn)
            if isinstance(n, ast.Call)
            and isinstance(n.func, ast.Attribute)
            and n.func.attr == "copy_page"]
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name) \
                    and _pool_method(node.value, "acquire") \
                    and _shared_kwarg(node.value):
                shared[node.targets[0].id] = node.lineno
        if not shared:
            return
        for node in ast.walk(fn):
            target: Optional[ast.expr] = None
            if isinstance(node, ast.Assign):
                target = node.targets[0] if len(node.targets) == 1 else None
            elif isinstance(node, ast.AugAssign):
                target = node.target
            if not (isinstance(target, ast.Attribute)
                    and isinstance(target.value, ast.Name)
                    and target.value.id in shared
                    and target.attr not in _SAFE_SHARED_ATTRS):
                continue
            acquired_at = shared[target.value.id]
            if any(acquired_at < line <= node.lineno
                   for line in copy_lines):
                continue
            yield module.finding(
                node, self.id,
                f"'{target.value.id}' was acquired shared=True; writing "
                f"'.{target.attr}' mutates a page every holder shares — "
                f"take a private copy_page first")

    # ------------------------------------------ 3. free on held pages
    def _check_free_held(self, module: Module,
                         fn: ast.AST) -> Iterator[Finding]:
        held_lists: Set[str] = set()
        held_pages: Set[str] = set()
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                name, value = node.targets[0].id, node.value
                if _pool_method(value, "request_pages"):
                    held_lists.add(name)
                elif isinstance(value, ast.Subscript):
                    if isinstance(value.value, ast.Name) \
                            and value.value.id in held_lists:
                        held_pages.add(name)
                    elif _pool_method(value.value, "request_pages"):
                        held_pages.add(name)
            elif isinstance(node, ast.For) \
                    and isinstance(node.target, ast.Name):
                it = node.iter
                if (isinstance(it, ast.Name) and it.id in held_lists) \
                        or _pool_method(it, "request_pages"):
                    held_pages.add(node.target.id)
        if not held_pages:
            return
        for node in ast.walk(fn):
            if not _pool_method(node, "free") or not node.args:
                continue
            arg = node.args[0]
            if isinstance(arg, ast.Attribute) and arg.attr == "page_id" \
                    and isinstance(arg.value, ast.Name) \
                    and arg.value.id in held_pages:
                yield module.finding(
                    node, self.id,
                    f"pool.free on '{arg.value.id}' obtained from "
                    f"request_pages — the page is still in the pool's "
                    f"sequence table; drop the request's ownership with "
                    f"release_request instead")
