"""EXC01 — broad exception handlers must not swallow silently.

A bare ``except:`` or ``except Exception/BaseException:`` whose body
neither re-raises nor visibly reports (logging/warnings/traceback
print) hides real failures — the PR 1-6 bug hunts each started from a
silent handler.  Narrow the type to what the guarded call can actually
raise, or log and re-raise.  Genuinely-broad probes (sweep drivers that
record per-case failures, private-API capability probes) carry a
documented ``# check: disable=EXC01 -- reason`` suppression instead.
"""

from __future__ import annotations

import ast
from typing import Iterator

from ..registry import Module, Rule, register
from ..report import Finding

_BROAD = {"Exception", "BaseException"}
# A call to any of these inside the handler counts as visible reporting.
_REPORTING_ATTRS = {"warn", "warning", "error", "exception", "critical",
                    "log", "print_exc"}
_REPORTING_ROOTS = {"logging", "warnings", "logger", "log"}


def _is_broad(handler: ast.ExceptHandler) -> bool:
    t = handler.type
    if t is None:
        return True
    names = t.elts if isinstance(t, ast.Tuple) else [t]
    for n in names:
        if isinstance(n, ast.Name) and n.id in _BROAD:
            return True
        if isinstance(n, ast.Attribute) and n.attr in _BROAD:
            return True
    return False


def _handles(handler: ast.ExceptHandler, module: Module) -> bool:
    for node in ast.walk(handler):
        if isinstance(node, ast.Raise):
            return True
        if isinstance(node, ast.Call):
            qn = module.imports.qualname(node.func)
            if qn is None:
                continue
            parts = qn.split(".")
            if parts[0] in _REPORTING_ROOTS or \
                    parts[-1] in _REPORTING_ATTRS:
                return True
    return False


@register
class Exc01(Rule):
    id = "EXC01"
    title = ("broad except (bare/Exception/BaseException) that neither "
             "re-raises nor logs")

    def check(self, module: Module) -> Iterator[Finding]:
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            if _is_broad(node) and not _handles(node, module):
                what = ("bare except" if node.type is None
                        else "except Exception")
                yield module.finding(
                    node, self.id,
                    f"{what} swallows errors silently — narrow the "
                    f"exception type, or log/re-raise (suppress with a "
                    f"documented reason if breadth is the contract)")
