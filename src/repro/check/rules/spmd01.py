"""SPMD01 — shard_map axis-name hygiene and ppermute perm coverage.

Two checks, both resolution-gated (an unresolvable site is skipped, not
guessed at):

* **Axis binding** — inside a function handed to ``shard_map`` /
  ``shard_map_compat``, every ``jax.lax`` collective must name an axis
  the enclosing mesh actually binds.  Bound names are recovered from the
  ``PartitionSpec``/``P`` literals in the call's in/out specs (following
  one level of local assignment) and from an inline ``Mesh(...,
  axis_names=...)``; the walk follows module-local helpers.  An unbound
  name fails at runtime only when that code path is first traced on a
  real mesh — exactly the kind of latent break the ep dispatch hit.

* **Perm coverage** — a ``ppermute`` perm given as a literal list must
  be a permutation: duplicate sources would send two payloads into one
  receive buffer, duplicate destinations drop data, and a gap in
  ``0..max(src)`` silently zero-fills a shard.  The rotation idiom
  ``[(j, (j ± k) % n) for j in range(n)]`` is recognized as covering.
"""

from __future__ import annotations

import ast
from typing import Iterator, List, Optional, Set

from .. import callgraph
from ..registry import Module, Rule, register
from ..report import Finding

_SHARD_MAP_NAMES = {"shard_map", "shard_map_compat"}
# jax.lax collective -> index of its axis-name argument.
_COLLECTIVES = {"psum": 1, "pmean": 1, "pmax": 1, "pmin": 1,
                "all_gather": 1, "all_to_all": 1, "ppermute": 1,
                "psum_scatter": 1, "pshuffle": 1, "axis_index": 0}


def _last_name(node: ast.expr) -> str:
    while isinstance(node, ast.Attribute):
        return node.attr
    return node.id if isinstance(node, ast.Name) else ""


def _kwarg(call: ast.Call, name: str) -> Optional[ast.expr]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _axis_strings(expr: Optional[ast.expr], module: Module,
                  *scopes) -> Optional[Set[str]]:
    """Axis names bound by a specs expression.  None = unresolvable."""
    if expr is None:
        return None
    if isinstance(expr, ast.Name):
        expr = callgraph.resolve_assignment(expr.id, *scopes)
        if expr is None:
            return None
    axes: Set[str] = set()
    resolvable = True
    for node in ast.walk(expr):
        if isinstance(node, ast.Call) and \
                _last_name(node.func) in ("P", "PartitionSpec"):
            for arg in node.args:
                found = _axis_value(arg, *scopes)
                if found is None:
                    resolvable = False
                else:
                    axes.update(found)
    return axes if resolvable else None


def _axis_value(arg: ast.expr, *scopes) -> Optional[Set[str]]:
    """Strings named by one PartitionSpec entry; None = unresolvable."""
    if isinstance(arg, ast.Constant):
        if arg.value is None:
            return set()
        return {arg.value} if isinstance(arg.value, str) else None
    if isinstance(arg, ast.Tuple):
        out: Set[str] = set()
        for e in arg.elts:
            got = _axis_value(e, *scopes)
            if got is None:
                return None
            out.update(got)
        return out
    if isinstance(arg, ast.Name):
        s = callgraph.resolve_str(arg.id, *scopes)
        return {s} if s is not None else None
    return None


def _mesh_axes(call: ast.Call, *scopes) -> Set[str]:
    mesh = _kwarg(call, "mesh")
    if mesh is None and len(call.args) > 1:
        mesh = call.args[1]
    if isinstance(mesh, ast.Name):
        mesh = callgraph.resolve_assignment(mesh.id, *scopes)
    if isinstance(mesh, ast.Call) and _last_name(mesh.func) == "Mesh":
        names = _kwarg(mesh, "axis_names")
        if names is None and len(mesh.args) > 1:
            names = mesh.args[1]
        got = _axis_value(names, *scopes) if names is not None else None
        return got or set()
    return set()


def _rotation_comprehension(expr: ast.expr) -> bool:
    """[(j, f(j)) for j in range(n)] — covers every source once."""
    if not isinstance(expr, (ast.ListComp, ast.GeneratorExp)):
        return False
    if len(expr.generators) != 1:
        return False
    gen = expr.generators[0]
    over_range = (isinstance(gen.iter, ast.Call)
                  and _last_name(gen.iter.func) == "range")
    if not (over_range and isinstance(gen.target, ast.Name)):
        return False
    elt = expr.elt
    return (isinstance(elt, ast.Tuple) and len(elt.elts) == 2
            and isinstance(elt.elts[0], ast.Name)
            and elt.elts[0].id == gen.target.id)


@register
class Spmd01(Rule):
    id = "SPMD01"
    title = ("collective inside shard_map names an axis the mesh does "
             "not bind, or a ppermute perm with duplicate/missing "
             "sources")

    def check(self, module: Module) -> Iterator[Finding]:
        for node in ast.walk(module.tree):
            if isinstance(node, ast.Call) and \
                    _last_name(node.func) in _SHARD_MAP_NAMES:
                yield from self._check_site(module, node)
        # Perm validity matters wherever a ppermute appears, shard_map
        # context or not (helper functions are used from inside one).
        for node in ast.walk(module.tree):
            if isinstance(node, ast.Call) and \
                    _last_name(node.func) == "ppermute":
                yield from self._check_perm(module, node)

    def _check_site(self, module: Module,
                    call: ast.Call) -> Iterator[Finding]:
        scope = callgraph.enclosing(
            call, module.parents, (ast.FunctionDef, ast.AsyncFunctionDef))
        scopes = (scope, module.tree)
        bound: Set[str] = set()
        known = True
        for kw in ("in_specs", "out_specs"):
            axes = _axis_strings(_kwarg(call, kw), module, *scopes)
            if axes is None:
                known = False
            else:
                bound |= axes
        # shard_map_compat(f, mesh, in_specs, out_specs) is positional.
        for pos in (2, 3):
            if len(call.args) > pos:
                axes = _axis_strings(call.args[pos], module, *scopes)
                if axes is None:
                    known = False
                else:
                    bound |= axes
        bound |= _mesh_axes(call, *scopes)
        if not known or not bound:
            return                     # unresolvable site: stay silent
        body = self._body_fn(module, call)
        if body is None:
            return
        for fn in callgraph.reachable([body], module.functions):
            for node in ast.walk(fn):
                axis = self._collective_axis(module, node, scopes)
                if axis is not None and axis not in bound:
                    yield module.finding(
                        node, self.id,
                        f"collective uses axis '{axis}' but the "
                        f"enclosing shard_map binds only "
                        f"{sorted(bound)} — an unbound name fails at "
                        f"trace time on a real mesh")

    def _body_fn(self, module: Module, call: ast.Call):
        if not call.args:
            return None
        arg = call.args[0]
        if isinstance(arg, ast.Lambda):
            return arg
        if isinstance(arg, ast.Name):
            return module.functions.get(arg.id)
        return None

    def _collective_axis(self, module: Module, node: ast.AST,
                         scopes) -> Optional[str]:
        if not isinstance(node, ast.Call):
            return None
        name = _last_name(node.func)
        if name not in _COLLECTIVES:
            return None
        qn = module.imports.qualname(node.func)
        if qn is not None and "lax" not in qn and not qn.startswith("jax."):
            return None                # some other psum/all_gather
        axis = _kwarg(node, "axis_name")
        if axis is None:
            idx = _COLLECTIVES[name]
            if len(node.args) > idx:
                axis = node.args[idx]
        if isinstance(axis, ast.Constant) and isinstance(axis.value, str):
            return axis.value
        if isinstance(axis, ast.Name):
            return callgraph.resolve_str(axis.id, *scopes)
        return None

    def _check_perm(self, module: Module,
                    call: ast.Call) -> Iterator[Finding]:
        perm = _kwarg(call, "perm")
        if perm is None and len(call.args) > 2:
            perm = call.args[2]
        if isinstance(perm, ast.Name):
            scope = callgraph.enclosing(
                call, module.parents,
                (ast.FunctionDef, ast.AsyncFunctionDef))
            perm = callgraph.resolve_assignment(
                perm.id, scope, module.tree)
        if perm is None or _rotation_comprehension(perm):
            return
        if not isinstance(perm, (ast.List, ast.Tuple)):
            return
        pairs: List[tuple] = []
        for e in perm.elts:
            if not (isinstance(e, ast.Tuple) and len(e.elts) == 2
                    and all(isinstance(x, ast.Constant)
                            and isinstance(x.value, int)
                            for x in e.elts)):
                return                 # not fully literal: stay silent
            pairs.append((e.elts[0].value, e.elts[1].value))
        if not pairs:
            return
        srcs = [s for s, _ in pairs]
        dsts = [d for _, d in pairs]
        if len(set(srcs)) != len(srcs):
            yield module.finding(
                call, self.id,
                f"ppermute perm has duplicate sources "
                f"{sorted(s for s in set(srcs) if srcs.count(s) > 1)} — "
                f"two payloads race into one receive buffer")
            return
        if len(set(dsts)) != len(dsts):
            yield module.finding(
                call, self.id,
                f"ppermute perm has duplicate destinations "
                f"{sorted(d for d in set(dsts) if dsts.count(d) > 1)} — "
                f"one shard receives twice, another's data is dropped")
            return
        missing = sorted(set(range(max(srcs) + 1)) - set(srcs))
        if missing:
            yield module.finding(
                call, self.id,
                f"ppermute perm covers sources {sorted(set(srcs))} but "
                f"skips {missing} — uncovered shards receive zeros on "
                f"the axis")
