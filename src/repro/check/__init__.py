"""repro.check — static contract linter for the repo's own invariants.

The runtime layer carries hard contracts (bitwise-deterministic sampled
streams, masked ragged-boundary stores in Pallas kernels, the PagedKVPool
acquire/copy_page/release_request refcount protocol, shard-local SPMD
dispatch) that example-based tests can only spot-check.  This package
enforces them statically, over the whole tree, on every commit:

    python -m repro.check src tests benchmarks [--format json]

Exit code == number of findings (capped at 255), so CI gates on zero.

Rules (see DESIGN.md Sec. 12 for the catalog and the motivating PRs):

  DET01  nondeterminism reaching traced code (random/time/np.random/set
         iteration, via a module-local call-graph walk from jit entries)
  DET02  PRNG key reuse and hardcoded PRNGKey fallback defaults
  KRN01  Pallas BlockSpec/grid contract: index-map arity and rank,
         out-of-bounds literal blocks, unguarded stores to revisited
         output blocks
  KV01   PagedKVPool protocol: acquire without release, mutation of
         shared pages without copy_page, free on a held request page
  SPMD01 collectives inside shard_map must use mesh-bound axis names;
         ppermute perms must cover the axis without duplicates
  EXC01  broad except that swallows without re-raise or logging
  CHK00  linter hygiene: unparsable file, malformed suppression

Suppressions are inline and must carry a reason:

    # check: disable=EXC01 -- private jax API probe; None is the contract

either on the finding's line or on a comment line directly above it.
A suppression without a reason is itself a CHK00 finding.

Directories named ``check_fixtures`` (the known-bad rule fixtures) are
skipped during traversal; explicitly listed files are always checked.
"""

from .driver import run_check, iter_py_files, load_module
from .registry import Rule, all_rules, get_rule, register
from .report import Finding, render_json, render_text

__all__ = [
    "Finding",
    "Rule",
    "all_rules",
    "get_rule",
    "iter_py_files",
    "load_module",
    "register",
    "render_json",
    "render_text",
    "run_check",
]
