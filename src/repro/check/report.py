"""Findings and the two output renderers (text, JSON).

A ``Finding`` is one rule violation at one source location.  Rule ids are
stable API: CI artifacts, suppression comments and the golden fixture
tests all key on them, so renaming one is a breaking change.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, List


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    path: str
    line: int
    col: int
    rule: str
    message: str

    def location(self) -> str:
        return f"{self.path}:{self.line}:{self.col}"


def render_text(findings: List[Finding]) -> str:
    """One ``path:line:col: RULE message`` row per finding + a summary."""
    rows = [f"{f.location()}: {f.rule} {f.message}" for f in findings]
    n = len(findings)
    rows.append(f"{n} finding{'s' if n != 1 else ''}")
    return "\n".join(rows)


def render_json(findings: List[Finding],
                rule_index: Dict[str, str]) -> str:
    """Machine-readable report: the findings plus the registered-rule
    index (id -> one-line title), so a consumer can tell "rule absent"
    from "rule clean"."""
    payload = {
        "version": 1,
        "rules": dict(sorted(rule_index.items())),
        "count": len(findings),
        "findings": [dataclasses.asdict(f) for f in findings],
    }
    return json.dumps(payload, indent=1, sort_keys=False)
