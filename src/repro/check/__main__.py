"""CLI: ``python -m repro.check <paths...>``.

Exit code is the finding count (capped at 255 so it survives the shell),
which makes both ``scripts/check.sh`` and the CI gate a bare invocation.
"""

from __future__ import annotations

import argparse
import sys
from typing import List, Optional

from .driver import run_check
from .registry import all_rules
from .report import render_json, render_text


def main(argv: Optional[List[str]] = None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.check",
        description="Static contract linter for the repro tree "
                    "(determinism, kernel-safety, page-protocol rules).")
    p.add_argument("paths", nargs="*", default=["src"],
                   help="files or directories to check (default: src)")
    p.add_argument("--format", choices=["text", "json"], default="text")
    p.add_argument("--rules", default=None,
                   help="comma-separated rule ids to run (default: all)")
    p.add_argument("--output", default=None,
                   help="also write the report to this file")
    p.add_argument("--list-rules", action="store_true",
                   help="print the registered rules and exit")
    args = p.parse_args(argv)

    rules = all_rules()
    if args.list_rules:
        for rid, rule in rules.items():
            print(f"{rid}  {rule.title}")
        return 0

    rule_ids = ([r.strip() for r in args.rules.split(",") if r.strip()]
                if args.rules else None)
    try:
        findings = run_check(args.paths, rule_ids=rule_ids)
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    if args.format == "json":
        report = render_json(
            findings, {rid: r.title for rid, r in rules.items()})
    else:
        report = render_text(findings)
    print(report)
    if args.output:
        with open(args.output, "w", encoding="utf-8") as f:
            f.write(report + "\n")
    return min(len(findings), 255)


if __name__ == "__main__":
    sys.exit(main())
