"""Import-aware name resolution and the module-local call graph.

Rules reason about *which library function* an AST call hits, so names
must resolve through the module's import aliases (``np.random.rand`` ->
``numpy.random.rand`` whatever numpy was imported as).  The call graph is
deliberately module-local: a rule walking from a ``jax.jit`` entry point
follows calls to functions defined in the same file and stops at module
boundaries — best-effort by design, the whole-tree sweep catches each
module from its own entries.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set


class Imports:
    """Local name -> dotted origin, from the module's import statements."""

    def __init__(self) -> None:
        self.aliases: Dict[str, str] = {}

    @classmethod
    def of(cls, tree: ast.Module) -> "Imports":
        out = cls()
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    if a.asname:
                        out.aliases[a.asname] = a.name
                    else:
                        # ``import os.path`` binds the top package name.
                        top = a.name.split(".")[0]
                        out.aliases[top] = top
            elif isinstance(node, ast.ImportFrom):
                mod = node.module or ""
                for a in node.names:
                    out.aliases[a.asname or a.name] = (
                        f"{mod}.{a.name}" if mod else a.name)
        return out

    def qualname(self, node: ast.AST) -> Optional[str]:
        """Best-effort dotted name of an attribute chain.

        The root resolves through the import table when it can; otherwise
        the bare chain is returned (``self.pool.acquire``) so rules can
        still pattern-match on suffixes.  Non-name roots (calls,
        subscripts) resolve to None.
        """
        parts: List[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        root = self.aliases.get(node.id, node.id)
        return ".".join([root] + list(reversed(parts)))


def local_functions(tree: ast.Module) -> Dict[str, ast.FunctionDef]:
    """Every (possibly nested) function definition in the module, by bare
    name.  On collisions the first definition wins — enough for the
    helper-lookup the rules do."""
    out: Dict[str, ast.FunctionDef] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            out.setdefault(node.name, node)
    return out


def called_names(fn: ast.AST) -> Set[str]:
    """Bare names referenced as callables or passed by name inside ``fn``
    (higher-order uses like ``jax.lax.scan(body, ...)`` count: the callee
    runs under the same trace)."""
    names: Set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            if isinstance(node.func, ast.Name):
                names.add(node.func.id)
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                if isinstance(arg, ast.Name):
                    names.add(arg.id)
    return names


def reachable(entries: Iterable[ast.AST],
              funcs: Dict[str, ast.FunctionDef]) -> List[ast.AST]:
    """BFS over the module-local call graph from the given entry bodies.

    Returns the entries plus every module-local function transitively
    referenced from them, each node once, in first-seen order.
    """
    seen: Set[int] = set()
    order: List[ast.AST] = []
    queue: List[ast.AST] = list(entries)
    while queue:
        fn = queue.pop(0)
        if id(fn) in seen:
            continue
        seen.add(id(fn))
        order.append(fn)
        for name in sorted(called_names(fn)):
            callee = funcs.get(name)
            if callee is not None and id(callee) not in seen:
                queue.append(callee)
    return order


def parent_map(tree: ast.Module) -> Dict[ast.AST, ast.AST]:
    """Child -> parent links (ast doesn't carry them natively)."""
    parents: Dict[ast.AST, ast.AST] = {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            parents[child] = node
    return parents


def enclosing(node: ast.AST, parents: Dict[ast.AST, ast.AST],
              kinds) -> Optional[ast.AST]:
    """Nearest ancestor of one of the given AST types, or None."""
    cur = parents.get(node)
    while cur is not None:
        if isinstance(cur, kinds):
            return cur
        cur = parents.get(cur)
    return None


def resolve_str(name: str, *scopes: ast.AST) -> Optional[str]:
    """Resolve ``name`` to a string constant via a single plain assignment
    in any of the given scopes (innermost first)."""
    for scope in scopes:
        if scope is None:
            continue
        for node in ast.walk(scope):
            if isinstance(node, ast.Assign) and len(node.targets) == 1:
                t = node.targets[0]
                if (isinstance(t, ast.Name) and t.id == name
                        and isinstance(node.value, ast.Constant)
                        and isinstance(node.value.value, str)):
                    return node.value.value
    return None


def resolve_assignment(name: str, *scopes: ast.AST) -> Optional[ast.expr]:
    """The value expression of ``name``'s single plain assignment in the
    given scopes (innermost first), or None if absent/ambiguous."""
    for scope in scopes:
        if scope is None:
            continue
        found: List[ast.expr] = []
        for node in ast.walk(scope):
            if isinstance(node, ast.Assign) and len(node.targets) == 1:
                t = node.targets[0]
                if isinstance(t, ast.Name) and t.id == name:
                    found.append(node.value)
        if len(found) == 1:
            return found[0]
        if found:
            return None               # ambiguous: refuse to guess
    return None
