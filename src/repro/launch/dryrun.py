"""Multi-pod dry run: AOT-lower and compile every (architecture x input
shape) cell on the production meshes, prove per-device memory fits, and
extract the roofline inputs (FLOPs, bytes, collective traffic).

MUST be run as a module entry point (the XLA_FLAGS block below runs before
any jax import — importing this module from an already-initialized process
will not get 512 devices; library importers, e.g. the test suite's
trace-only artifact fixture, must not have a 512-device XLA_FLAGS leaked
into os.environ where sibling subprocess-based tests would inherit it).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                  # everything
  PYTHONPATH=src python -m repro.launch.dryrun --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3_2_1b --shape train_4k
Results land in results/dryrun/<mesh>/<arch>__<shape>.json (incremental).
"""

import os

if __name__ == "__main__":
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=512 "
        + os.environ.get("XLA_FLAGS", ""))

import argparse
import json
import re
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCHS, get as get_config, shapes_for
from ..dist.sharding import (
    DECODE_RULES,
    DEFAULT_RULES,
    FSDP_RULES,
    MOMENTS_RULES,
    SP_DECODE_RULES,
    logical_to_pspec,
    use_rules,
)
from ..models import SHAPES, build_model
from ..models.common import abstract_params, param_pspecs
from ..optim.adamw import AdamW, AdamWState
from ..train.step import StepConfig, make_train_step
from .analysis import collective_bytes, jaxpr_cost
from .mesh import make_production_mesh

F32 = jnp.float32
COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")
# result shapes like f32[16,4096]{1,0} or bf16[2]{0}; tuples contain several.
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?\S+\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\b(.*)$")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8}


def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Sum result bytes of every collective op in the (partitioned) HLO,
    grouped by op kind; also records op counts and max replica-group size."""
    out: Dict[str, Dict[str, float]] = {
        op: {"bytes": 0.0, "count": 0, "max_group": 0} for op in COLLECTIVE_OPS
    }
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        result_sig, op, rest = m.groups()
        if op + "-done" in line and "-start" not in line:
            # -done carries the same shape as -start; count once (on start
            # for async pairs, on the plain op otherwise).
            pass
        if "-done" in line.split("=")[1]:
            continue
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(result_sig):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        g = _GROUPS_RE.search(rest)
        group = len(g.group(1).split(",")) if g else 0
        rec = out[op]
        rec["bytes"] += nbytes
        rec["count"] += 1
        rec["max_group"] = max(rec["max_group"], group)
    return out


def _input_pspec(name: str, sds, mesh, rules):
    axes_by_name = {
        "tokens": ("batch", "seq"),
        "labels": ("batch", "seq"),
        "frames": ("batch", "seq", None),
        "patches": ("batch", "seq", None),
        "pos": (),
    }
    axes = axes_by_name.get(name, tuple([None] * len(sds.shape)))
    axes = axes[: len(sds.shape)]
    return logical_to_pspec(axes, sds.shape, mesh, rules)


def build_cell(arch: str, shape_name: str, mesh, quick_layers: int = 0,
               profile: str = "tp", moments: str = "zero1",
               remat: bool = True):
    """Returns (fn, args, in_shardings, out_shardings, rules)."""
    from jax.sharding import NamedSharding

    cfg = get_config(arch)
    import dataclasses

    if quick_layers:
        cfg = dataclasses.replace(
            cfg, n_layers=min(cfg.n_layers, quick_layers))
    if not remat:
        cfg = dataclasses.replace(cfg, remat=False)
    model = build_model(cfg)
    shape = SHAPES[shape_name]
    base = FSDP_RULES if profile == "fsdp" else DEFAULT_RULES
    if shape_name == "long_500k":
        rules = dict(base, kv_seq="data")
    elif shape.kind == "decode":
        rules = dict(base, kv_seq="model", head_dim=None, qdh=None)
    else:
        rules = base

    defs = model.param_defs()
    a_params = abstract_params(defs)
    p_spec = param_pspecs(defs, mesh, rules)
    ns = lambda spec: NamedSharding(mesh, spec)
    p_sh = jax.tree.map(ns, p_spec)
    specs = model.input_specs(shape)

    if shape.kind == "train":
        opt = AdamW(lr=1e-4)
        step = make_train_step(model, opt, StepConfig())
        f32_like = lambda t: jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, F32), t)
        m_rules = dict(rules, layers="data") if moments == "zero1" else rules
        m_spec = param_pspecs(defs, mesh, m_rules)
        a_opt = AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32),
                           m=f32_like(a_params), v=f32_like(a_params))
        opt_sh = AdamWState(
            step=ns(logical_to_pspec((), (), mesh, rules)),
            m=jax.tree.map(ns, m_spec), v=jax.tree.map(ns, m_spec))
        batch_sh = {
            k: ns(_input_pspec(k, v, mesh, rules)) for k, v in specs.items()}
        args = (a_params, a_opt, specs)
        in_sh = (p_sh, opt_sh, batch_sh)
        out_sh = (p_sh, opt_sh, None)
        fn = step
    elif shape.kind == "prefill":
        cache_defs = model.cache_defs(shape.global_batch, shape.seq_len)
        a_cache = jax.tree.map(
            lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), cache_defs,
            is_leaf=lambda x: hasattr(x, "axes"))
        cache_sh = jax.tree.map(ns, param_pspecs(cache_defs, mesh, rules))
        batch_sh = {
            k: ns(_input_pspec(k, v, mesh, rules)) for k, v in specs.items()}
        args = (a_params, specs, a_cache)
        in_sh = (p_sh, batch_sh, cache_sh)
        out_sh = (None, cache_sh)
        fn = model.prefill
    else:  # decode
        cache_sds = specs["cache"]
        cache_defs = model.cache_defs(shape.global_batch, shape.seq_len)
        cache_sh = jax.tree.map(ns, param_pspecs(cache_defs, mesh, rules))
        tok_sh = ns(_input_pspec("tokens", specs["tokens"], mesh, rules))
        pos_sh = ns(logical_to_pspec((), (), mesh, rules))
        args = (a_params, cache_sds, specs["tokens"], specs["pos"])
        in_sh = (p_sh, cache_sh, tok_sh, pos_sh)
        out_sh = (None, cache_sh)
        fn = model.decode
    return fn, args, in_sh, out_sh, rules


def run_cell(arch: str, shape_name: str, mesh, mesh_name: str,
             outdir: str, quick_layers: int = 0,
             keep_hlo: bool = False, profile: str = "tp",
             moments: str = "zero1", remat: bool = True,
             trace_only: bool = False) -> Dict[str, Any]:
    """``trace_only`` stops after the jaxpr: exact loop-aware ``global_cost``
    without lowering/compiling on the production mesh.  FLOPs/bytes in the
    jaxpr are mesh-independent, so a 1x1 mesh suffices — this is how the
    test suite regenerates cost artifacts without 256 host devices."""
    os.makedirs(outdir, exist_ok=True)
    out_path = os.path.join(outdir, f"{arch}__{shape_name}.json")
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "devices": int(np.prod(list(mesh.shape.values()))),
        "status": "running",
    }
    t0 = time.time()
    try:
        fn, args, in_sh, out_sh, rules = build_cell(
            arch, shape_name, mesh, quick_layers, profile=profile,
            moments=moments, remat=remat)
        with use_rules(rules), mesh:
            jaxpr = jax.make_jaxpr(fn)(*args)
            global_cost = jaxpr_cost(jaxpr)
            if trace_only:
                rec.update({
                    "status": "ok",
                    "trace_only": True,
                    "global_cost": global_cost,
                })
                rec["wall_seconds"] = round(time.time() - t0, 2)
                with open(out_path, "w") as f:
                    json.dump(rec, f, indent=1)
                return rec
            lowered = jax.jit(fn, in_shardings=in_sh,
                              out_shardings=out_sh).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis() or {}
            if isinstance(cost, (list, tuple)):   # older jaxlib: [dict]
                cost = cost[0] if cost else {}
            hlo = compiled.as_text()
        rec.update({
            "status": "ok",
            "lower_seconds": round(t_lower, 2),
            "compile_seconds": round(t_compile, 2),
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "generated_code_bytes": mem.generated_code_size_in_bytes,
            },
            "cost": {k: float(v) for k, v in cost.items()
                     if isinstance(v, (int, float))
                     and k in ("flops", "bytes accessed")},
            "global_cost": global_cost,   # exact, loop-aware, whole program
            "collectives": collective_bytes(hlo),  # per device, loop-aware
            "hlo_bytes": len(hlo),
        })
        if keep_hlo:
            with open(out_path.replace(".json", ".hlo.txt"), "w") as f:
                f.write(hlo)
    # check: disable=EXC01 -- sweep driver: one cell failing to lower or
    # compile must not kill the remaining cells; the failure is recorded
    # (type, message, traceback) in the cell's JSON artifact.
    except Exception as e:  # noqa: BLE001 - record the failure, keep sweeping
        rec.update({
            "status": "fail",
            "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-4000:],
        })
    rec["wall_seconds"] = round(time.time() - t0, 2)
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None, help="comma list; default all")
    p.add_argument("--shape", default=None, help="comma list; default all")
    p.add_argument("--mesh", default="both", choices=["single", "multi",
                                                      "both"])
    p.add_argument("--outdir", default="results/dryrun")
    p.add_argument("--quick-layers", type=int, default=0,
                   help="truncate layer count (CI smoke only)")
    p.add_argument("--profile", default="tp", choices=["tp", "fsdp"],
                   help="sharding profile (see dist/sharding.py)")
    p.add_argument("--moments", default="zero1", choices=["zero1", "tp"],
                   help="optimizer-moment sharding")
    p.add_argument("--no-remat", action="store_true",
                   help="disable activation rematerialization")
    p.add_argument("--keep-hlo", action="store_true")
    args = p.parse_args()

    archs = args.arch.split(",") if args.arch else ARCHS
    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("pod256", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("pod512", make_production_mesh(multi_pod=True)))

    failures = []
    for mesh_name, mesh in meshes:
        for arch in archs:
            cells = shapes_for(arch)
            shape_names = (args.shape.split(",") if args.shape
                           else list(cells))
            for shape_name in shape_names:
                if shape_name not in cells:
                    print(f"SKIP {mesh_name} {arch} {shape_name} "
                          f"(documented inapplicability)", flush=True)
                    continue
                rec = run_cell(arch, shape_name, mesh, mesh_name,
                               os.path.join(args.outdir, mesh_name),
                               quick_layers=args.quick_layers,
                               keep_hlo=args.keep_hlo,
                               profile=args.profile, moments=args.moments,
                               remat=not args.no_remat)
                flops = rec.get("global_cost", {}).get("flops", float("nan"))
                coll = sum(v["bytes"] for v in
                           rec.get("collectives", {}).values()) if \
                    rec.get("collectives") else float("nan")
                print(f"{rec['status']:4s} {mesh_name} {arch:22s} "
                      f"{shape_name:12s} {rec['wall_seconds']:8.1f}s "
                      f"gflops={flops/1e9:.3e} collMB={coll/1e6:.1f}",
                      flush=True)
                if rec["status"] != "ok":
                    failures.append((mesh_name, arch, shape_name,
                                     rec.get("error")))
    if failures:
        print("FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("dry run complete: all cells compiled")


if __name__ == "__main__":
    main()
