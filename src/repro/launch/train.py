"""Training launcher.

Single-process CPU runs use smoke configs end-to-end; on a real pod the same
driver builds the production mesh and shards via the logical-axis rules.
Includes the fault-tolerance loop: heartbeat monitoring, checkpoint cadence,
and restart-from-latest on failure (see --simulate-failure for the drill).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch llama3_2_1b --smoke \
      --steps 50 --gdt-budget-mb 8
  PYTHONPATH=src python -m repro.launch.train --arch granite_8b --smoke \
      --steps 20 --ckpt-dir /tmp/ck --ckpt-every 10
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp

from ..configs import ARCHS, get, get_smoke
from ..core import GuidanceConfig
from ..data import SyntheticLM
from ..ft import HeartbeatMonitor
from ..models import build_model
from ..optim import AdamW, cosine_schedule
from ..train import StepConfig, Trainer, TrainerConfig


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True, choices=ARCHS)
    p.add_argument("--smoke", action="store_true",
                   help="reduced config (CPU-runnable)")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--lr", type=float, default=3e-3)
    p.add_argument("--seed", type=int, default=0,
                   help="param-init PRNG seed (threaded to the Trainer)")
    p.add_argument("--accum", type=int, default=1)
    p.add_argument("--compression", choices=["int8"], default=None)
    p.add_argument("--gdt-budget-mb", type=float, default=0,
                   help="enable online guided tiering with this HBM budget")
    p.add_argument("--gdt-interval", type=int, default=10)
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--ckpt-every", type=int, default=0)
    p.add_argument("--restore", action="store_true")
    p.add_argument("--simulate-failure", type=int, default=0,
                   help="inject a failure at this step and restart")
    args = p.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get(args.arch)
    cfg = dataclasses.replace(cfg, remat=not args.smoke)
    model = build_model(cfg)
    opt = AdamW(lr=cosine_schedule(args.lr, warmup=max(args.steps // 20, 1),
                                   total=args.steps))
    gdt = None
    if args.gdt_budget_mb:
        gdt = GuidanceConfig(enabled=True, strategy="thermos",
                        fast_capacity_bytes=int(args.gdt_budget_mb * 2**20),
                        interval_steps=args.gdt_interval,
                        promotion_threshold=64 * 1024)
    tcfg = TrainerConfig(
        steps=args.steps, log_every=max(args.steps // 20, 1),
        ckpt_every=args.ckpt_every, ckpt_dir=args.ckpt_dir, gdt=gdt,
        step=StepConfig(accum=args.accum, compression=args.compression),
        seed=args.seed)
    trainer = Trainer(model, opt, tcfg)
    if args.restore and args.ckpt_dir:
        meta = trainer.restore_checkpoint()
        print(f"restored checkpoint at step {meta['step']}")

    src = SyntheticLM(cfg.vocab, args.seq, args.batch, seed=0)
    monitor = HeartbeatMonitor(n_nodes=1, timeout_s=600.0)

    def batches():
        i = 0
        for b in src.iter_host():
            if args.simulate_failure and i == args.simulate_failure:
                raise RuntimeError("injected node failure")
            monitor.beat(0, 0.0)
            i += 1
            yield {k: jnp.asarray(v) for k, v in b.items()}

    t0 = time.time()
    try:
        result = trainer.run(batches())
    except RuntimeError as e:
        if "injected node failure" not in str(e) or not args.ckpt_dir:
            raise
        print(f"failure detected ({e}); restarting from checkpoint")
        trainer = Trainer(model, opt, dataclasses.replace(
            tcfg, steps=args.steps - args.simulate_failure))
        trainer.restore_checkpoint()
        src2 = SyntheticLM(cfg.vocab, args.seq, args.batch, seed=0)
        result = trainer.run(
            {k: jnp.asarray(v) for k, v in b.items()}
            for b in src2.iter_host())
    result["total_wall_seconds"] = round(time.time() - t0, 2)
    print(json.dumps(result, indent=1))
    for m in trainer.metrics_log[-5:]:
        print(f"  step {int(m['step']):5d}  loss {m['loss']:.4f}")


if __name__ == "__main__":
    main()
