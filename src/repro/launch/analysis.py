"""Cost accounting for the dry run.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies **once** (verified
on this jaxlib), which silently drops a factor of n_layers × inner-chunk
loops from FLOP/byte numbers.  We therefore derive roofline inputs from two
loop-aware sources:

* ``jaxpr_cost`` — exact *global* FLOPs/bytes from the closed jaxpr: scan
  primitives carry their trip count, so the walk multiplies body costs
  exactly; dot_general dominates and is counted exactly
  (2 * batch * M * N * K).  Byte counts come in two flavours:
  ``bytes_naive`` (every primitive's operands+outputs — a fusion-naive upper
  bound) and ``bytes_dot`` (operands/outputs of dot/gather/scatter/conv plus
  scan carries — a post-fusion estimate of HBM traffic).

* ``collective_bytes`` — parsed from the partitioned HLO with while-loop
  expansion: computations are indexed, each ``while`` op's body collectives
  are multiplied by the loop's trip count (largest integer constant compared
  against the induction variable in the condition computation; exact for
  every scan/fori the framework emits).

It also hosts ``guidance_summary`` — the consumer of the GuidanceRuntime's
structured event stream (interval decisions + rental payments), which the
serving/training benchmarks and reports read instead of poking at
per-subsystem counters.
"""

from __future__ import annotations

import math
import re
from typing import Any, Dict, Iterable, Optional

import jax
import numpy as np
from jax import core as jcore

# ===================================================== guidance telemetry
def guidance_summary(events: Iterable[Any]) -> Dict[str, float]:
    """Aggregate a ``GuidanceRuntime`` event stream into report scalars.

    Accepts the runtime's ``events`` list (mixed ``IntervalEvent`` /
    ``RentalEvent``, discriminated by ``.kind``).  Every consumer — the
    serving and training benchmarks, launch reports — reads tiering
    telemetry through this one function.
    """
    intervals = [e for e in events if getattr(e, "kind", None) == "interval"]
    rentals = [e for e in events if getattr(e, "kind", None) == "rental"]
    migrations = [e for e in intervals if e.migrated]
    ratios = [e.decision.ratio for e in intervals
              if e.decision is not None and math.isfinite(e.decision.ratio)]
    return {
        "intervals": float(len(intervals)),
        "migrations": float(len(migrations)),
        "bytes_migrated": float(sum(e.bytes_moved for e in intervals)),
        "dropped_promotions": float(
            sum(e.dropped_promotions for e in intervals)),
        "rental_events": float(len(rentals)),
        "rental_bytes": float(sum(e.nbytes for e in rentals)),
        "mean_decision_ratio": (sum(ratios) / len(ratios)) if ratios else 0.0,
        "profile_seconds": float(
            sum(e.profile_seconds for e in intervals)),
    }


def serving_summary(engine) -> Dict[str, Any]:
    """One view over the serving engine's scheduler/migration counters and
    (when guided) the controller's event stream.

    Engine-side scalars are prefixed ``engine_`` (swap and transfer probes,
    prefill dispatch/token counts, admission/preemption/starvation totals,
    prefix-cache hit/saved-token counters when the cache is enabled, and
    the per-``finish_reason`` counts ``engine_finished_stop`` /
    ``engine_finished_length`` / ``engine_finished_truncated``); guidance
    scalars keep the ``guidance_summary`` names — the per-request KV
    controller's unprefixed, the shared-prefix controller's under
    ``prefix_``.  Benchmarks and reports read serving telemetry through
    this function rather than poking at per-subsystem counters.

    A ``serve.cluster.Router`` is accepted wherever an ``Engine`` is: the
    top level is then the cluster AGGREGATE (counters summed over reachable
    replicas — ``mean_``-prefixed scalars averaged — the prefix hit rate
    recomputed from summed components, and the router's ``cluster_*``
    lifecycle counters), with each replica's own flat summary under
    ``summary["replicas"]["replica<id>"]``.  At N=1 the aggregate equals
    the single engine's summary plus the ``cluster_*`` scalars, so
    consumers indexing ``engine_*`` keys work at any replica count.
    """
    if hasattr(engine, "replicas") and hasattr(engine, "tickets"):
        router = engine
        per = {f"replica{rep.replica_id}": serving_summary(rep.engine)
               for rep in router.replicas if rep.reachable}
        agg: Dict[str, Any] = {}
        means: Dict[str, list] = {}
        for summary in per.values():
            for k, v in summary.items():
                if not isinstance(v, (int, float)):
                    continue
                if "mean_" in k:
                    means.setdefault(k, []).append(float(v))
                else:
                    agg[k] = agg.get(k, 0.0) + float(v)
        for k, vals in means.items():
            agg[k] = sum(vals) / len(vals)
        if agg.get("engine_prefix_lookups"):
            agg["engine_prefix_hit_rate"] = (
                agg.get("engine_prefix_hit_requests", 0.0)
                / agg["engine_prefix_lookups"])
        agg.update({
            "cluster_replicas": float(len(per)),
            "cluster_migrations_warm": float(router.migrations_warm),
            "cluster_migrations_cold": float(router.migrations_cold),
            "cluster_failovers": float(router.failovers),
            "cluster_restarts": float(router.restarts),
            "cluster_requests_lost": float(router.requests_lost),
        })
        if len(per) > 1:
            agg["replicas"] = per
        return agg
    out = {f"engine_{k}": float(v) for k, v in engine.stats().items()}
    if getattr(engine, "runtime", None) is not None:
        out.update(guidance_summary(engine.runtime.events))
    if getattr(engine, "prefix_runtime", None) is not None:
        out.update({f"prefix_{k}": v for k, v in
                    guidance_summary(engine.prefix_runtime.events).items()})
    if getattr(engine, "expert_runtime", None) is not None:
        out.update({f"expert_{k}": v for k, v in
                    guidance_summary(engine.expert_runtime.events).items()})
    return out


# ============================================================ jaxpr costs
_DTYPE_BYTES = {"pred": 1}


def _nbytes(aval) -> int:
    # Abstract tokens / effects have no shape or dtype; everything else
    # costs what its array says.
    try:
        return int(np.prod(aval.shape)) * aval.dtype.itemsize
    except (AttributeError, TypeError):
        return 0


_ELEMENTWISE_FLOPS = {
    "add", "sub", "mul", "div", "max", "min", "neg", "exp", "log", "tanh",
    "logistic", "rsqrt", "sqrt", "pow", "integer_pow", "erf", "abs",
    "floor", "ceil", "round", "sign", "cos", "sin",
}


def jaxpr_cost(closed_jaxpr) -> Dict[str, float]:
    """Walk a ClosedJaxpr, multiplying loop bodies by their trip counts."""

    def walk(jaxpr) -> Dict[str, float]:
        total = {"flops": 0.0, "bytes_naive": 0.0, "bytes_dot": 0.0}
        for eqn in jaxpr.eqns:
            prim = eqn.primitive.name
            out_b = sum(_nbytes(v.aval) for v in eqn.outvars)
            in_b = sum(_nbytes(v.aval) for v in eqn.invars
                       if hasattr(v, "aval"))
            total["bytes_naive"] += in_b + out_b

            if prim == "dot_general":
                dnums = eqn.params["dimension_numbers"]
                (lc, rc), (lb, rb) = dnums
                lhs, rhs = (v.aval for v in eqn.invars[:2])
                batch = int(np.prod([lhs.shape[i] for i in lb])) if lb else 1
                contract = int(np.prod([lhs.shape[i] for i in lc])) if lc else 1
                m = int(np.prod([s for i, s in enumerate(lhs.shape)
                                 if i not in lc and i not in lb]))
                n = int(np.prod([s for i, s in enumerate(rhs.shape)
                                 if i not in rc and i not in rb]))
                total["flops"] += 2.0 * batch * m * n * contract
                total["bytes_dot"] += in_b + out_b
            elif prim == "gather":
                # HBM traffic ~ gathered bytes + indices, NOT the full pool
                # operand (XLA reads only the addressed rows).
                idx_b = _nbytes(eqn.invars[1].aval) if len(eqn.invars) > 1 else 0
                total["bytes_dot"] += 2 * out_b + idx_b
            elif prim in ("scatter", "scatter-add", "scatter_add",
                          "scatter-update"):
                # in-place update: read+write the touched rows + indices.
                upd_b = _nbytes(eqn.invars[-1].aval)
                idx_b = _nbytes(eqn.invars[1].aval) if len(eqn.invars) > 2 else 0
                total["bytes_dot"] += 3 * upd_b + idx_b
            elif prim == "dynamic_update_slice":
                upd_b = _nbytes(eqn.invars[1].aval)
                total["bytes_dot"] += 3 * upd_b
            elif prim == "dynamic_slice":
                total["bytes_dot"] += 2 * out_b
            elif prim in ("conv_general_dilated", "cumsum", "sort", "top_k",
                          "concatenate"):
                total["bytes_dot"] += in_b + out_b
                if prim == "conv_general_dilated":
                    total["flops"] += 2.0 * out_b  # negligible in our models
            elif prim in _ELEMENTWISE_FLOPS:
                total["flops"] += float(
                    int(np.prod(eqn.outvars[0].aval.shape)))
            elif prim == "scan":
                body = walk(eqn.params["jaxpr"].jaxpr)
                length = eqn.params["length"]
                for k in total:
                    total[k] += body[k] * length
                # scan-carried xs/ys traffic
                total["bytes_dot"] += in_b + out_b
            elif prim == "while":
                body = walk(eqn.params["body_jaxpr"].jaxpr)
                # Trip count is not in the jaxpr; our model code only uses
                # bounded fori in hand-rolled collectives.  Estimate from the
                # cond jaxpr's integer literals (max), else 1.
                trips = _while_trip_guess(eqn)
                for k in total:
                    total[k] += body[k] * trips
            elif prim == "cond":
                branches = [walk(b.jaxpr) for b in eqn.params["branches"]]
                for k in total:
                    total[k] += max(b[k] for b in branches)
            else:
                # Generic: recurse into any jaxpr-valued params exactly once
                # (pjit, remat2, custom_vjp/jvp calls, named_call, ...).
                for sub in _sub_jaxprs(eqn.params):
                    body = walk(sub)
                    for k in total:
                        total[k] += body[k]
        return total

    return walk(closed_jaxpr.jaxpr)


def _sub_jaxprs(params: Dict[str, Any]):
    """Yield every Jaxpr found in an eqn's params (depth 1 lists/tuples)."""
    for v in params.values():
        vs = v if isinstance(v, (list, tuple)) else (v,)
        for item in vs:
            if hasattr(item, "jaxpr") and hasattr(item.jaxpr, "eqns"):
                yield item.jaxpr
            elif hasattr(item, "eqns"):
                yield item


def _while_trip_guess(eqn) -> int:
    # A while eqn without the expected cond_jaxpr/Literal structure (jax
    # version drift) estimates one trip rather than crashing the report.
    try:
        consts = []
        for e in eqn.params["cond_jaxpr"].jaxpr.eqns:
            for v in e.invars:
                if isinstance(v, jcore.Literal) and np.ndim(v.val) == 0 \
                        and np.issubdtype(np.asarray(v.val).dtype, np.integer):
                    consts.append(int(v.val))
        return max(consts) if consts else 1
    except (KeyError, AttributeError, TypeError, ValueError):
        return 1


# ===================================================== HLO collective parse
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\(.*\))?\s*(?:->.*)?\{")
_SHAPE_RE = re.compile(r"\b(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64)\[([0-9,]*)\]")
_OP_LINE_RE = re.compile(
    r"=\s*(\([^=]*?\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_WHILE_RE = re.compile(
    r"=.*\bwhile\(.*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CALL_RE = re.compile(
    r"\b(?:fusion|call|conditional)\(.*?to_apply=%?([\w.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")

_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
          "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
          "f64": 8}

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")


def _split_computations(hlo: str) -> Dict[str, str]:
    """Computation headers sit at column 0 (``%name (...) -> ... {`` or
    ``ENTRY %name ...``); bodies are indented and close with a column-0
    ``}``.  Indented lines that merely *look* like headers must not split."""
    comps: Dict[str, list] = {}
    cur = None
    entry = None
    for line in hlo.splitlines():
        at_root = bool(line) and not line[0].isspace()
        if at_root and "{" in line and not line.startswith("HloModule"):
            m = _COMP_RE.match(line)
            if m:
                cur = m.group(1)
                comps[cur] = []
                if line.startswith("ENTRY"):
                    entry = cur
                continue
        if cur is not None:
            if line.startswith("}"):
                cur = None
            else:
                comps[cur].append(line)
    comps["__entry__"] = entry or ""
    return {k: ("\n".join(v) if isinstance(v, list) else v)
            for k, v in comps.items()}


def _direct_collectives(body: str) -> Dict[str, Dict[str, float]]:
    out = {op: {"bytes": 0.0, "count": 0.0, "max_group": 0} for op in
           COLLECTIVE_OPS}
    for line in body.splitlines():
        m = _OP_LINE_RE.search(line)
        if not m:
            continue
        sig, op, start = m.group(1), m.group(2), m.group(3)
        if "-done(" in line:
            continue
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(sig):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _BYTES[dt]
        g = _GROUPS_RE.search(line)
        group = len(g.group(1).split(",")) if g else 0
        out[op]["bytes"] += nbytes
        out[op]["count"] += 1
        out[op]["max_group"] = max(out[op]["max_group"], group)
    return out


def collective_bytes(hlo: str) -> Dict[str, Dict[str, float]]:
    """Collective traffic with while-loop expansion (per device, result
    bytes as the per-device payload proxy)."""
    comps = _split_computations(hlo)
    memo: Dict[str, Dict] = {}

    def trip_count(cond_name: str) -> int:
        cond = comps.get(cond_name, "")
        consts = [int(c) for c in _CONST_RE.findall(cond)]
        return max(consts) if consts else 1

    def expand(name: str) -> Dict[str, Dict[str, float]]:
        if name in memo:
            return memo[name]
        body = comps.get(name, "")
        total = _direct_collectives(body)
        for line in body.splitlines():
            wm = _WHILE_RE.search(line)
            if wm:
                cond_name, body_name = wm.groups()
                trips = trip_count(cond_name)
                sub = expand(body_name)
                for op in COLLECTIVE_OPS:
                    total[op]["bytes"] += sub[op]["bytes"] * trips
                    total[op]["count"] += sub[op]["count"] * trips
                    total[op]["max_group"] = max(total[op]["max_group"],
                                                 sub[op]["max_group"])
                continue
            for cm in _CALL_RE.finditer(line):
                sub = expand(cm.group(1))
                for op in COLLECTIVE_OPS:
                    total[op]["bytes"] += sub[op]["bytes"]
                    total[op]["count"] += sub[op]["count"]
                    total[op]["max_group"] = max(total[op]["max_group"],
                                                 sub[op]["max_group"])
        memo[name] = total
        return total

    entry = comps.pop("__entry__", "")
    if not entry:
        # fall back: treat whole text as one computation (no loop expansion)
        return _direct_collectives(hlo)
    return expand(entry)
