"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches JAX device state — the dry-run must set XLA_FLAGS before any
device query happens.

``compat_make_mesh`` papers over the ``axis_types`` API difference between
JAX releases: newer JAX wants explicit ``AxisType.Auto`` axes, older
releases predate the parameter entirely.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh

try:  # AxisType landed after 0.4.x; gate it so old CPU JAX still imports.
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - version-dependent
    AxisType = None


def compat_make_mesh(shape, axes) -> Mesh:
    """jax.make_mesh with AxisType.Auto on releases that support it."""
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2x16x16 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 4) -> Mesh:
    """Small mesh for CPU multi-device tests."""
    return compat_make_mesh((data, model), ("data", "model"))
