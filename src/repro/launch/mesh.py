"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches JAX device state — the dry-run must set XLA_FLAGS before any
device query happens.
"""

from __future__ import annotations

import jax
from jax.sharding import AxisType, Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2x16x16 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_test_mesh(data: int = 2, model: int = 4) -> Mesh:
    """Small mesh for CPU multi-device tests."""
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(AxisType.Auto, AxisType.Auto))
