"""Serving launcher: batched decode with guided KV-page tiering.

Runs a synthetic multi-session workload against the paged engine and prints
throughput + tiering telemetry.  Policies: gdt (the paper's machinery),
lru, fifo.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch llama3_2_1b --smoke \
      --sessions 8 --rounds 10 --policy gdt
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import numpy as np

from ..configs import ARCHS, get, get_smoke
from ..models import build_model
from ..serve import Engine, ServeConfig


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True, choices=ARCHS)
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--sessions", type=int, default=6)
    p.add_argument("--rounds", type=int, default=8)
    p.add_argument("--prompt-len", type=int, default=12)
    p.add_argument("--max-new", type=int, default=32)
    p.add_argument("--policy", choices=["gdt", "lru", "fifo"], default="gdt")
    p.add_argument("--page-size", type=int, default=16)
    p.add_argument("--hbm-pages", type=int, default=24)
    p.add_argument("--host-pages", type=int, default=256)
    p.add_argument("--max-batch", type=int, default=2)
    args = p.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get(args.arch)
    if cfg.family not in ("dense", "moe"):
        raise SystemExit("paged engine serves decoder LMs (dense/moe)")
    cfg = dataclasses.replace(cfg, remat=False)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, ServeConfig(
        max_batch=args.max_batch, page_size=args.page_size,
        hbm_pages=args.hbm_pages, host_pages=args.host_pages,
        policy=args.policy))

    rng = np.random.default_rng(0)
    for rid in range(args.sessions):
        prompt = list(rng.integers(1, cfg.vocab, args.prompt_len))
        eng.add_request(rid, [int(t) for t in prompt], max_new=args.max_new)
        eng.pause(rid)

    hot = list(range(min(2, args.sessions)))
    t0 = time.time()
    tokens = 0
    for r in range(args.rounds):
        for rid in hot:
            eng.resume(rid)
        if r % 3 == 2:
            eng.resume((r // 3) % args.sessions)
        for _ in range(4):
            tokens += len(eng.step())
        for rid in list(eng.requests):
            if eng.requests[rid].state == "active":
                eng.pause(rid)
    wall = time.time() - t0
    stats = eng.stats()
    stats.update({
        "policy": args.policy,
        "tokens": tokens,
        "tokens_per_second": round(tokens / wall, 2),
        "wall_seconds": round(wall, 2),
    })
    print(json.dumps(stats, indent=1))


if __name__ == "__main__":
    main()
