"""Serving launcher: batched decode with guided KV-page tiering.

Runs a synthetic multi-session workload through the ``LLM`` front door
(``serve.api``) and prints throughput + tiering telemetry, including
per-``finish_reason`` totals.  Policies: gdt (the paper's machinery), lru,
fifo.  ``--temperature/--top-k/--top-p`` switch the sessions from greedy
decode to seeded sampling — the tier machinery underneath is identical.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch llama3_2_1b --smoke \
      --sessions 8 --rounds 10 --policy gdt --temperature 0.8
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import numpy as np

from ..configs import ARCHS, get, get_smoke
from ..models import build_model
from ..serve import LLM, SamplingParams, ServeConfig
from .analysis import serving_summary


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True, choices=ARCHS)
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--sessions", type=int, default=6)
    p.add_argument("--rounds", type=int, default=8)
    p.add_argument("--prompt-len", type=int, default=12)
    p.add_argument("--max-new", type=int, default=32)
    p.add_argument("--policy", choices=["gdt", "lru", "fifo"], default="gdt")
    p.add_argument("--scheduler", choices=["fifo", "priority", "drr"],
                   default="fifo",
                   help="scheduling policy: admission order, preemption "
                        "victims, and the per-step prefill/decode split")
    p.add_argument("--prefill-chunk-tokens", type=int, default=0,
                   help="interleave long prompt ingests at this many "
                        "tokens per engine step (0 = one-shot prefill)")
    p.add_argument("--page-size", type=int, default=16)
    p.add_argument("--hbm-pages", type=int, default=24)
    p.add_argument("--host-pages", type=int, default=256)
    p.add_argument("--max-batch", type=int, default=2)
    p.add_argument("--temperature", type=float, default=0.0)
    p.add_argument("--top-k", type=int, default=0)
    p.add_argument("--top-p", type=float, default=1.0)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--prefix-cache", action="store_true",
                   help="enable the cross-request radix prefix cache")
    p.add_argument("--min-prefix-pages", type=int, default=1,
                   help="pages a prefix must span to enter the cache")
    p.add_argument("--shared-prefix-len", type=int, default=0,
                   help="give every session this many identical leading "
                        "prompt tokens (a synthetic shared system prompt)")
    p.add_argument("--offchip-experts", action="store_true",
                   help="MoE only: keep expert FFN weights host-resident "
                        "and dispatch through a bounded HBM expert cache "
                        "under the guided controller")
    p.add_argument("--expert-cache-size", type=int, default=0,
                   help="HBM expert-cache capacity in blocks, shared "
                        "across layers (0 = every block fits)")
    p.add_argument("--no-expert-double-buffer", action="store_true",
                   help="disable the double-buffered expert prefetch: "
                        "every cache miss becomes a blocking demand fetch")
    p.add_argument("--replicas", type=int, default=1,
                   help="engine replicas behind the router (least-loaded "
                        "dispatch; failures/drains migrate in-flight "
                        "requests bitwise)")
    p.add_argument("--rolling-restart", action="store_true",
                   help="restart every replica in sequence at the run's "
                        "midpoint (requires --replicas >= 2); in-flight "
                        "requests warm-migrate to survivors")
    args = p.parse_args()
    if args.rolling_restart and args.replicas < 2:
        raise SystemExit("--rolling-restart needs --replicas >= 2 "
                         "(a lone replica has no migration target)")

    cfg = get_smoke(args.arch) if args.smoke else get(args.arch)
    if cfg.family not in ("dense", "moe"):
        raise SystemExit("paged engine serves decoder LMs (dense/moe)")
    cfg = dataclasses.replace(cfg, remat=False)
    model = build_model(cfg)
    # --seed governs BOTH param init and the per-session sampling streams
    # (sessions fold their id in below): one flag reproduces a run.
    params = model.init(jax.random.PRNGKey(args.seed))
    llm = LLM(model, params, ServeConfig(
        max_batch=args.max_batch, page_size=args.page_size,
        hbm_pages=args.hbm_pages, host_pages=args.host_pages,
        policy=args.policy, scheduler=args.scheduler,
        prefill_chunk_tokens=args.prefill_chunk_tokens,
        enable_prefix_cache=args.prefix_cache,
        min_prefix_pages=args.min_prefix_pages,
        expert_offchip=args.offchip_experts,
        expert_cache_size=args.expert_cache_size,
        expert_double_buffer=not args.no_expert_double_buffer),
        replicas=args.replicas)

    rng = np.random.default_rng(0)
    shared = [int(t) for t in
              rng.integers(1, cfg.vocab, args.shared_prefix_len)]
    handles = {}
    for rid in range(args.sessions):
        tail_len = max(args.prompt_len - args.shared_prefix_len, 1)
        prompt = shared + [int(t)
                           for t in rng.integers(1, cfg.vocab, tail_len)]
        handles[rid] = llm.submit(prompt, SamplingParams(
            temperature=args.temperature, top_k=args.top_k,
            top_p=args.top_p, seed=args.seed + rid,
            max_tokens=args.max_new), request_id=rid)
        # Chunked submits park in 'prefilling' (no schedulable position to
        # pause yet); they join the pause/resume dance once active.
        if llm.engine.requests[rid].state == "active":
            llm.pause(rid)

    hot = list(range(min(2, args.sessions)))
    t0 = time.time()
    tokens = 0
    # Rolling restart at the midpoint: replace every original replica one
    # at a time while the workload keeps stepping — in-flight requests
    # migrate (warm where pages fit) and no stream drops or changes.
    restart_round = args.rounds // 2 if args.rolling_restart else -1
    for r in range(args.rounds):
        if r == restart_round:
            for rep_id in [rep.replica_id for rep in llm.cluster.replicas]:
                llm.cluster.restart_replica(rep_id)
        for rid in hot:
            if llm.is_live(rid):
                llm.resume(rid)
        extra = (r // 3) % args.sessions
        if r % 3 == 2 and llm.is_live(extra):
            llm.resume(extra)
        for _ in range(4):
            tokens += len(llm.step())
        for rid in list(llm.engine.requests):
            if llm.engine.requests[rid].state == "active":
                llm.pause(rid)
    wall = time.time() - t0
    stats = serving_summary(llm.engine)
    stats.update({
        "policy": args.policy,
        "temperature": args.temperature,
        "tokens": tokens,
        "tokens_per_second": round(tokens / wall, 2),
        "wall_seconds": round(wall, 2),
        "finished_streams": {
            rid: h.finish_reason for rid, h in handles.items() if h.finished},
    })
    print(json.dumps(stats, indent=1))


if __name__ == "__main__":
    main()
