from .pipeline import ShardedLoader, SyntheticLM

__all__ = ["ShardedLoader", "SyntheticLM"]
