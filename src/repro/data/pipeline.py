"""Deterministic synthetic data pipeline.

Generates reproducible token batches (documents of geometric length packed
into fixed-length rows, next-token labels) with per-host sharding on the
production mesh and background prefetch.  No filesystem dependency: the
"dataset" is a seeded PRNG stream, which is what every scale test of the
framework needs; swapping in a real tokenized corpus only changes
``_make_row``.
"""

from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from ..dist.sharding import batch_pspec


class SyntheticLM:
    """Packed-document LM stream: tokens[i+1] is the label of tokens[i]."""

    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 seed: int = 0, doc_mean: int = 512, pad_id: int = 0):
        self.vocab = vocab
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.seed = seed
        self.doc_mean = doc_mean
        self.pad_id = pad_id

    def _make_row(self, rng: np.random.Generator) -> np.ndarray:
        row = np.empty(self.seq_len + 1, np.int32)
        filled = 0
        while filled <= self.seq_len:
            n = min(1 + rng.geometric(1.0 / self.doc_mean),
                    self.seq_len + 1 - filled)
            # Markov-ish tokens: correlated stream so the model can learn.
            start = rng.integers(1, self.vocab)
            toks = (start + np.cumsum(
                rng.integers(0, 17, n))) % (self.vocab - 1) + 1
            row[filled:filled + n] = toks
            filled += n
        return row

    def batch_np(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        rows = np.stack([self._make_row(rng) for _ in range(self.global_batch)])
        return {"tokens": rows[:, :-1].astype(np.int32),
                "labels": rows[:, 1:].astype(np.int32)}

    def iter_host(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_np(step)
            step += 1


class ShardedLoader:
    """Places host batches onto the mesh with background prefetch."""

    def __init__(self, source: SyntheticLM, mesh: Optional[Mesh] = None,
                 prefetch: int = 2):
        self.source = source
        self.mesh = mesh
        self.prefetch = prefetch
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    def _sharding(self, arr: np.ndarray):
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, batch_pspec(self.mesh))

    def _put(self, batch: Dict[str, np.ndarray]):
        out = {}
        for k, v in batch.items():
            sh = self._sharding(v)
            out[k] = jax.device_put(v, sh) if sh is not None else jax.device_put(v)
        return out

    def _worker(self):
        for batch in self.source.iter_host():
            if self._stop.is_set():
                return
            self._q.put(self._put(batch))

    def __iter__(self):
        if self._thread is None:
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()
        while True:
            yield self._q.get()

    def close(self):
        self._stop.set()
