"""Train-step factory.

``make_train_step(model, opt)`` builds the jit-able
``step(params, opt_state, batch) -> (params, opt_state, metrics)`` with:

* optional gradient-accumulation over microbatches (scan, so the HLO stays
  flat in the accumulation factor),
* optional int8 quantize-dequantize on gradients (the lossy channel of the
  compressed DP reduction; see dist/compress.py for the wire-level shard_map
  form),
* remat already applied inside the model's layer scans.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..dist.compress import quantize_roundtrip
from ..models.transformer import Model
from ..optim.adamw import AdamW, AdamWState


@dataclasses.dataclass(frozen=True)
class StepConfig:
    accum: int = 1                    # gradient accumulation microbatches
    compression: Optional[str] = None  # None | "int8"


def _split_batch(batch: Dict[str, jax.Array], n: int):
    def resh(x):
        b = x.shape[0]
        assert b % n == 0, f"global batch {b} not divisible by accum {n}"
        return x.reshape((n, b // n) + x.shape[1:])

    return jax.tree.map(resh, batch)


def make_train_step(model: Model, opt: AdamW,
                    cfg: StepConfig = StepConfig()) -> Callable:
    loss_fn = model.loss

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch)

    def step(params, opt_state: AdamWState, batch):
        if cfg.accum == 1:
            loss, grads = grads_of(params, batch)
        else:
            micro = _split_batch(batch, cfg.accum)

            def body(carry, mb):
                acc_loss, acc_grads = carry
                l, g = grads_of(params, mb)
                return (acc_loss + l,
                        jax.tree.map(jnp.add, acc_grads, g)), None

            zeros = jax.tree.map(jnp.zeros_like, params)
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), zeros), micro)
            loss = loss / cfg.accum
            grads = jax.tree.map(lambda g: g / cfg.accum, grads)

        if cfg.compression == "int8":
            grads = jax.tree.map(quantize_roundtrip, grads)

        params, opt_state, gnorm = opt.update(grads, opt_state, params)
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "step": opt_state.step}
        return params, opt_state, metrics

    return step
