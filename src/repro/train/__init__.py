from .step import StepConfig, make_train_step
from .trainer import Trainer, TrainerConfig

__all__ = ["StepConfig", "Trainer", "TrainerConfig", "make_train_step"]
