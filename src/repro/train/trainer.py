"""Training loop with the paper's online guidance wired in.

The Trainer owns:
  * the jitted train step (params + optimizer state in HBM kind),
  * the guidance runtime: every parameter / moment group is an allocation
    site; the access model charges each group's traffic per step; at the
    decision interval the shared ``GuidanceRuntime`` (over an
    ``ArenaBackend``) may migrate cold groups (in practice: optimizer
    moments of frozen/slow-moving groups, embedding rows) to the host tier
    and hot ones back — under an HBM budget,
  * checkpoint/restart (async) and failure hooks (ft/).

Offload execution model (DESIGN.md Sec. 4): compute always runs on
device-kind arrays.  Slow-tier groups are fetched before the step and
written back after — that per-step transfer *is* the rental cost the
ski-rental controller weighs against migration.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import (
    ArenaBackend,
    ArenaManager,
    GuidanceConfig,
    GuidanceRuntime,
    HardwareModel,
    SiteKind,
    SiteRegistry,
    TPU_V5E,
)
from ..core.placement import JaxArenaPlacer
from ..models.common import is_def
from ..models.transformer import Model
from ..optim.adamw import AdamW, AdamWState
from .step import StepConfig, make_train_step


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 0                 # 0 = off
    ckpt_dir: Optional[str] = None
    gdt: Optional[GuidanceConfig] = None  # None = tiering disabled
    step: StepConfig = dataclasses.field(default_factory=StepConfig)
    # Param-init seed when no explicit rng is passed to the Trainer.
    # Deliberately not defaulted: a silent constant key would make every
    # run share one init stream while looking seeded (rule DET02).
    seed: Optional[int] = None


class Trainer:
    def __init__(self, model: Model, opt: AdamW, cfg: TrainerConfig,
                 hw: HardwareModel = TPU_V5E, rng: Optional[jax.Array] = None):
        self.model = model
        self.opt = opt
        self.cfg = cfg
        self.hw = hw
        if rng is None:
            if cfg.seed is None:
                raise ValueError(
                    "Trainer needs randomness it can attribute: pass "
                    "rng=jax.random.PRNGKey(seed) or set "
                    "TrainerConfig.seed")
            rng = jax.random.PRNGKey(cfg.seed)
        self.params = model.init(rng)
        self.opt_state = opt.init(self.params)
        self.step_fn = jax.jit(make_train_step(model, opt, cfg.step),
                               donate_argnums=(0, 1))
        self.metrics_log: list = []

        # ---- paper integration: sites + arenas + controller ----
        self.registry = SiteRegistry()
        gdt_cfg = cfg.gdt if cfg.gdt is not None else GuidanceConfig(enabled=False)
        self.arenas = ArenaManager(
            self.registry,
            promotion_threshold=gdt_cfg.promotion_threshold,
            fast_capacity_bytes=(gdt_cfg.fast_capacity_bytes or None)
            if gdt_cfg.enabled else None,
        )
        self.placer = JaxArenaPlacer(self.arenas)
        # The shared Algorithm-1 controller over the real-array backend;
        # ``self.gdt`` keeps its historical name (it IS the runtime).
        self.gdt = GuidanceRuntime(
            ArenaBackend(self.arenas, hw, placer=self.placer), hw, gdt_cfg)
        self.runtime = self.gdt
        self._site_groups: Dict[str, Any] = {}
        if gdt_cfg.enabled:
            self._register_state()

    # ------------------------------------------------------------- sites
    def _group_tree(self, tree, kind: SiteKind, prefix: str):
        """Register depth-2 groups of a pytree as sites and bind arrays."""
        leaves, _ = jax.tree_util.tree_flatten_with_path(tree)
        groups: Dict[str, list] = {}
        for path, leaf in leaves:
            parts = [prefix] + [
                str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
            key = "/".join(parts[: self.registry.context_depth])
            groups.setdefault(key, []).append(("/".join(parts), leaf))
        for key, entries in groups.items():
            site = self.registry.register(key.split("/"), kind)
            nbytes = sum(int(a.size * a.dtype.itemsize) for _, a in entries)
            arena = self.arenas.allocate(site, nbytes)
            if arena is not None:
                for name, a in entries:
                    self.placer.bind(arena.arena_id, name, a)
                self._site_groups[key] = (site, arena, [n for n, _ in entries])

    def _register_state(self):
        self._group_tree(self.params, SiteKind.PARAM, "params")
        self._group_tree(self.opt_state.m, SiteKind.OPT_STATE, "adam_m")
        self._group_tree(self.opt_state.v, SiteKind.OPT_STATE, "adam_v")

    def _charge_access_model(self):
        """Static per-step access model: params read fwd+bwd (+written),
        moments read+written once (DESIGN.md Sec. 2)."""
        for key, (site, arena, names) in self._site_groups.items():
            weight = 3 if site.kind == SiteKind.PARAM else 2
            self.arenas.touch(site, weight * arena.resident_bytes)

    # -------------------------------------------------------------- loop
    def run(self, batches: Iterable[Dict[str, jax.Array]]) -> Dict[str, Any]:
        gdt_on = self.gdt.config.enabled
        it = iter(batches)
        t0 = time.perf_counter()
        for i in range(self.cfg.steps):
            batch = next(it)
            if gdt_on:
                self._sync_state_from_placer()
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch)
            if gdt_on:
                self._sync_state_to_placer()
                self._charge_access_model()
                self.gdt.on_step()
            if self.cfg.log_every and (i + 1) % self.cfg.log_every == 0:
                self.metrics_log.append(
                    {k: float(v) for k, v in metrics.items()})
            if (self.cfg.ckpt_every and self.cfg.ckpt_dir
                    and (i + 1) % self.cfg.ckpt_every == 0):
                self.save_checkpoint(int(metrics["step"]))
        wall = time.perf_counter() - t0
        out = {"wall_seconds": wall,
               "final_loss": float(metrics["loss"]),
               "steps": self.cfg.steps}
        if gdt_on:
            out["migrations"] = self.gdt.migration_count
            out["bytes_migrated"] = self.gdt.total_bytes_migrated
            out["transfer_bytes"] = self.placer.transfers_bytes
        return out

    # ------------------------------------------------- placer <-> pytrees
    def _sync_state_from_placer(self):
        """Fetch offloaded groups to device kind for the step (the rental)."""
        trees = {"params": self.params, "adam_m": self.opt_state.m,
                 "adam_v": self.opt_state.v}
        updated = {k: dict() for k in trees}
        for key, (site, arena, names) in self._site_groups.items():
            fetched = self.placer.fetch_fast(arena.arena_id)
            prefix = key.split("/")[0]
            for name, arr in fetched.items():
                updated[prefix][name] = arr
        for prefix, tree in trees.items():
            if updated[prefix]:
                trees[prefix] = _apply_named(tree, updated[prefix], prefix)
        self.params = trees["params"]
        self.opt_state = AdamWState(self.opt_state.step, trees["adam_m"],
                                    trees["adam_v"])

    def _sync_state_to_placer(self):
        """Write the step's outputs back into the placer (slow-tier groups
        are demoted again — the other half of the rental), then point the
        live pytrees at the placer's canonical arrays so tier state carries
        to the next step."""
        trees = {"params": self.params, "adam_m": self.opt_state.m,
                 "adam_v": self.opt_state.v}
        stored: Dict[str, Dict[str, jax.Array]] = {k: {} for k in trees}
        for key, (site, arena, names) in self._site_groups.items():
            prefix = key.split("/")[0]
            values = _collect_named(trees[prefix], names, prefix)
            self.placer.writeback(arena.arena_id, values)
            for e in self.placer.entries(arena.arena_id):
                stored[prefix][e.name] = e.array
        for prefix in trees:
            if stored[prefix]:
                trees[prefix] = _apply_named(trees[prefix], stored[prefix],
                                             prefix)
        self.params = trees["params"]
        self.opt_state = AdamWState(self.opt_state.step, trees["adam_m"],
                                    trees["adam_v"])

    # ------------------------------------------------------- checkpoints
    def save_checkpoint(self, step: int):
        from ..ckpt.checkpoint import save

        save(self.cfg.ckpt_dir, step,
             {"params": self.params, "m": self.opt_state.m,
              "v": self.opt_state.v,
              "opt_step": self.opt_state.step})

    def restore_checkpoint(self, step: Optional[int] = None):
        from ..ckpt.checkpoint import restore

        tree, meta = restore(self.cfg.ckpt_dir, step)
        self.params = tree["params"]
        self.opt_state = AdamWState(tree["opt_step"], tree["m"], tree["v"])
        return meta


# --------------------------------------------------------------- helpers
def _named_leaves(tree, prefix):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = [
        prefix + "/" + "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        for path, _ in leaves
    ]
    return names, [l for _, l in leaves], treedef


def _apply_named(tree, updates: Dict[str, jax.Array], prefix: str):
    names, leaves, treedef = _named_leaves(tree, prefix)
    new_leaves = [updates.get(n, leaf) for n, leaf in zip(names, leaves)]
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


def _collect_named(tree, wanted, prefix: str):
    names, leaves, _ = _named_leaves(tree, prefix)
    wanted = set(wanted)
    return {n: l for n, l in zip(names, leaves) if n in wanted}
