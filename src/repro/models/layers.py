"""Core transformer layers: RMSNorm, rotary embeddings, GQA attention
(training/prefill via chunked online-softmax, decode against a KV cache,
optional sliding window, optional cross-attention), SwiGLU MLP, embeddings.

All functions are pure; parameters are nested dicts produced from the
``ArrayDef`` declarations.  Activation sharding is expressed with logical
axes via ``constrain`` so the same code partitions on any mesh.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..dist.sharding import constrain
from .common import ArrayDef

F32 = jnp.float32
NEG_INF = -1e30


# ------------------------------------------------------------------ rmsnorm
def rmsnorm_defs(d: int):
    return {"scale": ArrayDef((d,), ("embed",), init="ones")}


def rmsnorm(p, x, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(F32)), axis=-1, keepdims=True)
    y = x.astype(F32) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(F32)).astype(x.dtype)


# -------------------------------------------------------------------- rope
def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0):
    """x: (..., S, H, dh); positions: (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = (theta ** (-np.arange(0, half, dtype=np.float32) / half))
    angles = positions[..., :, None].astype(F32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------- attention
@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    window: Optional[int] = None      # sliding-window size (None = full)
    causal: bool = True


def attention_defs(cfg: AttnConfig):
    d, H, K, dh = cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.head_dim
    return {
        "wq": ArrayDef((d, H, dh), ("embed", "heads", None)),
        "wk": ArrayDef((d, K, dh), ("embed", "kv_heads", None)),
        "wv": ArrayDef((d, K, dh), ("embed", "kv_heads", None)),
        "wo": ArrayDef((H, dh, d), ("heads", None, "embed")),
    }


def _qkv(p, x, cfg: AttnConfig, positions, kv_x=None, use_rope=True):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    src = x if kv_x is None else kv_x
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"])
    q = constrain(q, ("batch", "seq", "heads", None))
    k = constrain(k, ("batch", "kv_seq", "kv_heads", None))
    v = constrain(v, ("batch", "kv_seq", "kv_heads", None))
    if use_rope and kv_x is None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _mask(q_pos, k_pos, cfg: AttnConfig, cross: bool):
    """(…, Sq, Sk) boolean mask of *allowed* attention."""
    if cross:
        return None
    allowed = k_pos[..., None, :] <= q_pos[..., :, None]
    if cfg.window is not None:
        allowed &= (q_pos[..., :, None] - k_pos[..., None, :]) < cfg.window
    return allowed


def _attend(q, k, v, mask, cfg: AttnConfig, q_chunk: int = 1024):
    """Grouped-query attention with chunked-q exact softmax.

    q: (B, Sq, H, dh); k/v: (B, Sk, K, dh).  Memory per chunk is
    O(B*H*q_chunk*Sk), never O(Sq*Sk).
    """
    B, Sq, H, dh = q.shape
    K = k.shape[2]
    G = H // K
    scale = 1.0 / np.sqrt(dh)
    qg = q.reshape(B, Sq, K, G, dh)

    n_chunks = max(1, Sq // q_chunk) if Sq % q_chunk == 0 else 1
    qc = Sq // n_chunks

    @jax.checkpoint
    def one_chunk(args):
        # Rematerialized: the (qc, Sk) score block is never stored for the
        # backward pass — flash-style memory behaviour at XLA level.  The
        # Pallas kernel (kernels/flash_attention.py) replaces this on TPU.
        q_blk, mask_blk = args  # (B, qc, K, G, dh), (qc, Sk) | None
        s = jnp.einsum("bqkgd,bskd->bkgqs", q_blk, k,
                       preferred_element_type=F32) * scale
        if mask_blk is not None:
            s = jnp.where(mask_blk[None, None, None], s, NEG_INF)
        w = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(F32))
        return o.astype(v.dtype)

    if n_chunks == 1:
        out = one_chunk((qg, mask))
    else:
        qs = qg.reshape(B, n_chunks, qc, K, G, dh).transpose(1, 0, 2, 3, 4, 5)
        ms = (
            mask.reshape(n_chunks, qc, -1)
            if mask is not None
            else jnp.zeros((n_chunks, 0, 0), bool)
        )
        outs = jax.lax.map(
            lambda a: one_chunk((a[0], a[1] if mask is not None else None)),
            (qs, ms),
        )
        out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, K, G, dh)
    return out.reshape(B, Sq, H, dh)


def attention(p, x, cfg: AttnConfig, positions, kv_x=None,
              kv_positions=None, q_chunk: int = 1024):
    """Full attention for training/prefill (self or cross)."""
    cross = kv_x is not None
    q, k, v = _qkv(p, x, cfg, positions, kv_x=kv_x, use_rope=not cross)
    k_pos = kv_positions if kv_positions is not None else positions
    mask = _mask(positions[0], k_pos[0], cfg, cross)  # same for all batch rows
    out = _attend(q, k, v, mask, cfg, q_chunk=q_chunk)
    out = constrain(out, ("batch", "seq", "heads", None))
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return constrain(y, ("batch", "seq", "embed"))


# ------------------------------------------------------------ decode (1 tok)
def attention_decode(p, x, cache_k, cache_v, pos, cfg: AttnConfig):
    """One-token decode: update the cache at ``pos`` and attend over it.

    x: (B, 1, d); cache_k/v: (B, S_max, K, dh); pos: scalar int32.
    Returns (y, new_cache_k, new_cache_v).
    """
    B, S_max, K, dh = cache_k.shape
    positions = jnp.full((B, 1), pos, jnp.int32)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k1 = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v1 = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q = rope(q, positions, cfg.rope_theta)
    k1 = rope(k1, positions, cfg.rope_theta)
    cache_k = jax.lax.dynamic_update_slice(cache_k, k1.astype(cache_k.dtype),
                                           (0, pos, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v1.astype(cache_v.dtype),
                                           (0, pos, 0, 0))
    cache_k = constrain(cache_k, ("batch", "kv_seq", "kv_heads", "head_dim"))
    cache_v = constrain(cache_v, ("batch", "kv_seq", "kv_heads", "head_dim"))

    H = cfg.n_heads
    G = H // K
    # Match the cache layout (kv_heads replicated when K < model axis,
    # head_dim sharded): without this, SPMD re-shards the whole cache per
    # step ("involuntary full rematerialization" — a per-token all-gather of
    # the KV cache).  Contraction over the sharded head_dim instead costs a
    # small psum of the (B,K,G,S) scores.
    qg = constrain(q.reshape(B, K, G, dh),
                   ("batch", "kv_heads", None, "qdh"))
    s = jnp.einsum("bkgd,bskd->bkgs", qg.astype(F32),
                   cache_k.astype(F32))
    s *= 1.0 / np.sqrt(dh)
    k_pos = jnp.arange(S_max)
    allowed = k_pos <= pos
    if cfg.window is not None:
        allowed &= (pos - k_pos) < cfg.window
    s = jnp.where(allowed[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", w, cache_v.astype(F32))
    o = o.astype(x.dtype)
    y = jnp.einsum("bhk,hkd->bd", o.reshape(B, H, dh), p["wo"])[:, None]
    return constrain(y, ("batch", "seq", "embed")), cache_k, cache_v


def attention_decode_ring(p, x, cache_k, cache_v, pos, cfg: AttnConfig):
    """One-token decode against a *ring-buffer* KV cache of size window
    (sliding-window attention never needs older entries — §Perf climb #3).

    cache_k/v: (B, W, K, dh) where W == cfg.window; slot = pos % W.  Keys are
    stored rope'd at their absolute positions, so ring rotation is free.
    """
    B, W, K, dh = cache_k.shape
    positions = jnp.full((B, 1), pos, jnp.int32)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k1 = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v1 = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q = rope(q, positions, cfg.rope_theta)
    k1 = rope(k1, positions, cfg.rope_theta)
    slot = jax.lax.rem(pos, W)
    cache_k = jax.lax.dynamic_update_slice(cache_k, k1.astype(cache_k.dtype),
                                           (0, slot, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v1.astype(cache_v.dtype),
                                           (0, slot, 0, 0))
    cache_k = constrain(cache_k, ("batch", "kv_seq", "kv_heads", "head_dim"))
    cache_v = constrain(cache_v, ("batch", "kv_seq", "kv_heads", "head_dim"))

    H = cfg.n_heads
    G = H // K
    qg = constrain(q.reshape(B, K, G, dh),
                   ("batch", "kv_heads", None, "qdh"))
    s = jnp.einsum("bkgd,bskd->bkgs", qg.astype(F32), cache_k.astype(F32))
    s *= 1.0 / np.sqrt(dh)
    # Ring validity: during warmup (pos < W-1) only slots <= pos hold data;
    # afterwards every slot is within the window by construction.
    valid = jnp.arange(W) <= pos
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", w, cache_v.astype(F32))
    o = o.astype(x.dtype)
    y = jnp.einsum("bhk,hkd->bd", o.reshape(B, H, dh), p["wo"])[:, None]
    return constrain(y, ("batch", "seq", "embed")), cache_k, cache_v


def cross_attention_decode(p, x, mem_k, mem_v, cfg: AttnConfig):
    """Decode-time cross attention over precomputed encoder memory."""
    B = x.shape[0]
    H, K = cfg.n_heads, cfg.kv_heads
    dh = cfg.head_dim
    G = H // K
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"]).reshape(B, K, G, dh)
    s = jnp.einsum("bkgd,bskd->bkgs", q.astype(F32),
                   mem_k.astype(F32)) / np.sqrt(dh)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", w, mem_v.astype(F32)).astype(x.dtype)
    y = jnp.einsum("bhk,hkd->bd", o.reshape(B, H, dh), p["wo"])[:, None]
    return y


# --------------------------------------------------------------------- mlp
def mlp_defs(d: int, f: int):
    return {
        "w_gate": ArrayDef((d, f), ("embed", "mlp")),
        "w_up": ArrayDef((d, f), ("embed", "mlp")),
        "w_down": ArrayDef((f, d), ("mlp", "embed")),
    }


def mlp(p, x):
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    h = jax.nn.silu(g.astype(F32)).astype(x.dtype) * u
    h = constrain(h, ("batch", "seq", "mlp"))
    y = jnp.einsum("bsf,fd->bsd", h, p["w_down"])
    return constrain(y, ("batch", "seq", "embed"))


# -------------------------------------------------------------- embeddings
def embed_defs(vocab: int, d: int):
    return {"tok": ArrayDef((vocab, d), ("vocab", "embed"), init="embed",
                            scale=0.02)}


def embed(p, tokens):
    out = jnp.take(p["tok"], tokens, axis=0)
    return constrain(out, ("batch", "seq", "embed"))


def lm_head_defs(d: int, vocab: int):
    return {"w": ArrayDef((d, vocab), ("embed", "vocab"))}


def lm_head(p, x):
    logits = jnp.einsum("bsd,dv->bsv", x, p["w"]).astype(F32)
    return constrain(logits, ("batch", "seq", "vocab"))


def cross_entropy(logits, labels, ignore: int = -1):
    """Mean CE over non-ignored positions.  logits f32 (B,S,V)."""
    mask = (labels != ignore)
    safe = jnp.where(mask, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1)


def chunked_lm_loss(head_p, x, labels, ignore: int = -1,
                    chunk: int = 512):
    """lm_head + CE without materializing (B,S,V) f32 logits: the sequence
    is processed in rematerialized chunks (critical for 256k vocabularies).
    Returns (sum_nll, count) reduced over the whole batch."""
    B, S, d = x.shape
    n = max(1, S // chunk) if S % chunk == 0 else 1
    xs = x.reshape(B, n, S // n, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, n, S // n).transpose(1, 0, 2)

    @jax.checkpoint
    def one(args):
        xc, lc = args
        logits = lm_head(head_p, xc)
        mask = (lc != ignore)
        safe = jnp.where(mask, lc, 0)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        return ((logz - gold) * mask).sum(), mask.sum()

    if n == 1:
        nll, cnt = one((xs[0], ls[0]))
    else:
        nlls, cnts = jax.lax.map(one, (xs, ls))
        nll, cnt = nlls.sum(), cnts.sum()
    return nll / jnp.maximum(cnt, 1)
