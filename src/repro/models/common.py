"""Declarative parameter definitions.

Models are pure functions over nested dicts of arrays.  Parameters are
declared as ``ArrayDef`` trees: shape + dtype + per-dimension *logical axis*
names (resolved to mesh axes by ``repro.dist.sharding``) + an initializer.
This gives us, from one declaration:

* ``init_params``      — materialized arrays (deterministic per-path RNG),
* ``abstract_params``  — ShapeDtypeStructs for AOT lowering (no allocation),
* ``param_pspecs``     — PartitionSpecs per leaf for in_shardings,
* ``register_sites``   — paper integration: every parameter subtree becomes
  an allocation site (module path = call-path context, DESIGN.md Sec. 4).

Layer stacks are expressed by ``stack`` (prepends a ``layers`` dimension) and
executed with ``jax.lax.scan`` to keep compile time flat in depth.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from ..dist.sharding import logical_to_pspec

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ArrayDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    dtype: Any = jnp.bfloat16
    init: str = "normal"          # normal | zeros | ones | embed
    scale: Optional[float] = None  # stddev override

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes}")


def is_def(x) -> bool:
    return isinstance(x, ArrayDef)


def stack(defs: PyTree, n: int) -> PyTree:
    """Prepend a ``layers`` dimension of size n to every leaf."""
    return jax.tree.map(
        lambda d: ArrayDef((n,) + d.shape, ("layers",) + d.axes, d.dtype,
                           d.init, d.scale),
        defs,
        is_leaf=is_def,
    )


def _leaf_init(d: ArrayDef, key: jax.Array) -> jax.Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, d.dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, d.dtype)
    fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
    std = d.scale if d.scale is not None else 1.0 / np.sqrt(max(fan_in, 1))
    if d.init == "embed":
        std = d.scale if d.scale is not None else 1.0
    x = jax.random.normal(key, d.shape, jnp.float32) * std
    return x.astype(d.dtype)


def _path_str(path) -> str:
    return "/".join(
        str(getattr(p, "key", getattr(p, "idx", p))) for p in path
    )


def init_params(defs: PyTree, key: jax.Array) -> PyTree:
    """Deterministic init: every leaf's key is folded from a *stable* hash of
    its path (crc32 — Python's ``hash`` is per-process salted and would break
    cross-process reproducibility), so adding/removing parameters never
    reshuffles the others."""
    import zlib

    leaves, treedef = jax.tree_util.tree_flatten_with_path(defs, is_leaf=is_def)
    out = []
    for path, d in leaves:
        h = zlib.crc32(_path_str(path).encode())
        out.append(_leaf_init(d, jax.random.fold_in(key, h)))
    return jax.tree_util.tree_unflatten(treedef, out)


def abstract_params(defs: PyTree) -> PyTree:
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), defs, is_leaf=is_def
    )


def param_pspecs(defs: PyTree, mesh: Mesh, rules=None) -> PyTree:
    return jax.tree.map(
        lambda d: logical_to_pspec(d.axes, d.shape, mesh, rules),
        defs,
        is_leaf=is_def,
    )


def param_shardings(defs: PyTree, mesh: Mesh, rules=None,
                    memory_kind: Optional[str] = None) -> PyTree:
    from jax.sharding import NamedSharding

    def _mk(d: ArrayDef):
        spec = logical_to_pspec(d.axes, d.shape, mesh, rules)
        if memory_kind is None:
            return NamedSharding(mesh, spec)
        return NamedSharding(mesh, spec, memory_kind=memory_kind)

    return jax.tree.map(_mk, defs, is_leaf=is_def)


def tree_bytes(defs: PyTree) -> int:
    total = 0
    for d in jax.tree.leaves(defs, is_leaf=is_def):
        total += int(np.prod(d.shape)) * jnp.dtype(d.dtype).itemsize
    return total


def count_params(defs: PyTree) -> int:
    return sum(int(np.prod(d.shape)) for d in jax.tree.leaves(defs, is_leaf=is_def))


# ------------------------------------------------------------ site binding
def register_sites(defs: PyTree, registry, kind, arenas,
                   prefix: str = "params"):
    """Register every top-level parameter group as an allocation site and
    report its bytes to the hybrid arena manager (paper Sec. 4.1).

    Grouping at depth <= context_depth keeps the number of shared arenas
    bounded exactly the way the paper's call-path truncation does.
    """
    leaves, _ = jax.tree_util.tree_flatten_with_path(defs, is_leaf=is_def)
    groups: Dict[str, int] = {}
    for path, d in leaves:
        parts = [prefix] + [
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        ]
        site_path = tuple(parts[: registry.context_depth])
        nbytes = int(np.prod(d.shape)) * jnp.dtype(d.dtype).itemsize
        groups["/".join(site_path)] = groups.get("/".join(site_path), 0) + nbytes
    out = {}
    for site_path, nbytes in groups.items():
        site = registry.register(site_path.split("/"), kind)
        out[site_path] = arenas.allocate(site, nbytes)
    return out
