"""Mamba2 (state-space duality) block — pure JAX reference implementation.

Training/prefill uses the chunked SSD algorithm (quadratic within chunks,
linear recurrence across chunks, ``lax.scan`` over chunk states); decode is
the O(1)-per-token recurrence.  The intra-chunk einsums are the compute hot
spot that ``kernels/ssd_scan.py`` implements as a Pallas TPU kernel.

Head/state conventions follow Mamba2 defaults: head dim P, state dim N,
one B/C group shared by all heads (n_groups=1).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..dist.sharding import constrain
from .common import ArrayDef

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_model: int
    d_inner: int          # expand * d_model
    head_dim: int = 64    # P
    state_dim: int = 64   # N
    conv_width: int = 4
    chunk: int = 128
    dt_min: float = 0.001
    dt_max: float = 0.1

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim


def ssm_defs(cfg: SSMConfig):
    d, di, H, N = cfg.d_model, cfg.d_inner, cfg.n_heads, cfg.state_dim
    W = cfg.conv_width
    return {
        "w_z": ArrayDef((d, di), ("embed", "d_inner")),
        "w_x": ArrayDef((d, di), ("embed", "d_inner")),
        "w_B": ArrayDef((d, N), ("embed", None)),
        "w_C": ArrayDef((d, N), ("embed", None)),
        "w_dt": ArrayDef((d, H), ("embed", "ssm_heads")),
        "dt_bias": ArrayDef((H,), ("ssm_heads",), dtype=F32, init="zeros"),
        "a_log": ArrayDef((H,), ("ssm_heads",), dtype=F32, init="zeros"),
        "D": ArrayDef((H,), ("ssm_heads",), dtype=F32, init="ones"),
        "conv_x": ArrayDef((W, di), ("conv", "d_inner"), init="normal",
                           scale=0.5),
        "norm": ArrayDef((di,), ("d_inner",), init="ones"),
        "w_out": ArrayDef((di, d), ("d_inner", "embed")),
    }


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv via shifted adds.  x: (B,S,C); w: (W,C)."""
    W = w.shape[0]
    out = x * w[W - 1]
    for i in range(1, W):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, : x.shape[1]]
        out = out + shifted * w[W - 1 - i]
    return out


def _inputs(p, u, cfg: SSMConfig):
    """Common projections.  u: (B,S,d)."""
    z = jnp.einsum("bsd,de->bse", u, p["w_z"])
    x = jnp.einsum("bsd,de->bse", u, p["w_x"])
    Bm = jnp.einsum("bsd,dn->bsn", u, p["w_B"])
    Cm = jnp.einsum("bsd,dn->bsn", u, p["w_C"])
    dt = jnp.einsum("bsd,dh->bsh", u, p["w_dt"]).astype(F32)
    dt = jax.nn.softplus(dt + p["dt_bias"])
    dt = jnp.clip(dt, cfg.dt_min, cfg.dt_max * 100)
    return z, x, Bm, Cm, dt


def ssd_chunked(x, dt, A, Bm, Cm, cfg: SSMConfig,
                init_state=None) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    x: (B,S,H,P) already conv'd/activated; dt: (B,S,H) f32;
    A: (H,) f32 negative; Bm/Cm: (B,S,N).
    Returns y (B,S,H,P) and final state (B,H,N,P).
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(cfg.chunk, S)
    nc = S // Q
    assert S % Q == 0, "sequence must be divisible by the SSD chunk"

    # One scan over chunks: each step computes the intra-chunk causal block
    # (quadratic in Q only) plus the carried-state contribution, then updates
    # the running state.  Live intermediates stay O(B*Q*Q*H) for one chunk.
    xs = x.reshape(Bsz, nc, Q, H, P).transpose(1, 0, 2, 3, 4)
    dts = dt.reshape(Bsz, nc, Q, H).transpose(1, 0, 2, 3)
    Bs = Bm.reshape(Bsz, nc, Q, N).astype(F32).transpose(1, 0, 2, 3)
    Cs = Cm.reshape(Bsz, nc, Q, N).astype(F32).transpose(1, 0, 2, 3)
    causal = jnp.tril(jnp.ones((Q, Q), bool))

    if init_state is None:
        init_state = jnp.zeros((Bsz, H, N, P), F32)

    @jax.checkpoint
    def step(s_prev, inp):
        xc, dtc, Bc, Cc = inp              # (B,Q,H,P) (B,Q,H) (B,Q,N) (B,Q,N)
        a = dtc * A                         # (B,Q,H) log decay, negative
        a_cum = jnp.cumsum(a, axis=1)
        a_tot = a_cum[:, -1]                # (B,H)
        xdt = xc.astype(F32) * dtc[..., None]
        # decay(i,j) = exp(a_cum[i]-a_cum[j]) masked to j<=i.  Mask the
        # exponent (not the result): exp of the unmasked upper triangle
        # overflows and would poison gradients through the where.
        diff = a_cum[:, :, None, :] - a_cum[:, None, :, :]   # (B,Q,Q,H)
        diff = jnp.where(causal[None, :, :, None], diff, -1e30)
        L = jnp.exp(diff)
        scores = jnp.einsum("bin,bjn->bij", Cc, Bc)          # (B,Q,Q)
        y_diag = jnp.einsum("bij,bijh,bjhp->bihp", scores, L, xdt)
        # carried state contribution
        y_off = jnp.einsum("bin,bih,bhnp->bihp", Cc, jnp.exp(a_cum), s_prev)
        # state update
        decay_to_end = jnp.exp(a_tot[:, None, :] - a_cum)    # (B,Q,H)
        s_chunk = jnp.einsum("bjn,bjh,bjhp->bhnp", Bc, decay_to_end, xdt)
        s_new = s_chunk + jnp.exp(a_tot)[..., None, None] * s_prev
        return s_new, y_diag + y_off

    final, ys = jax.lax.scan(step, init_state, (xs, dts, Bs, Cs))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(Bsz, S, H, P)
    return y, final


def ssm_forward(p, u, cfg: SSMConfig, return_state: bool = False):
    """Full Mamba2 block for training/prefill.  u: (B,S,d).

    With ``return_state`` also returns (conv_state, ssm_state) so a decode
    loop can continue exactly where the prefill left off."""
    B, S, d = u.shape
    H, P, N = cfg.n_heads, cfg.head_dim, cfg.state_dim
    z, x, Bm, Cm, dt = _inputs(p, u, cfg)
    x_pre = x                                   # pre-conv projections
    x = _causal_conv(x, p["conv_x"])
    x = jax.nn.silu(x.astype(F32)).astype(u.dtype)
    xh = x.reshape(B, S, H, P)
    A = -jnp.exp(p["a_log"])
    y, final_state = ssd_chunked(xh, dt, A, Bm, Cm, cfg)
    y = y + xh.astype(F32) * p["D"][None, None, :, None]
    y = y.reshape(B, S, cfg.d_inner).astype(u.dtype)
    y = y * jax.nn.silu(z.astype(F32)).astype(u.dtype)
    # grouped RMSNorm (per d_inner)
    var = jnp.mean(jnp.square(y.astype(F32)), axis=-1, keepdims=True)
    y = (y.astype(F32) * jax.lax.rsqrt(var + 1e-6) * p["norm"]).astype(u.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    if return_state:
        W = cfg.conv_width
        conv_state = x_pre[:, S - (W - 1):]      # (B, W-1, d_inner)
        return out, conv_state, final_state
    return out


def ssm_decode(p, u, conv_state, ssm_state, cfg: SSMConfig):
    """One-token decode.  u: (B,1,d); conv_state: (B, W-1, d_inner);
    ssm_state: (B,H,N,P) f32.  Returns (y, conv_state, ssm_state)."""
    B = u.shape[0]
    H, P, N, W = cfg.n_heads, cfg.head_dim, cfg.state_dim, cfg.conv_width
    z, x, Bm, Cm, dt = _inputs(p, u, cfg)         # all (B,1,*)
    x1 = x[:, 0]                                   # (B, d_inner)
    window = jnp.concatenate([conv_state, x1[:, None]], axis=1)  # (B,W,di)
    xc = jnp.einsum("bwc,wc->bc", window, p["conv_x"])
    new_conv = window[:, 1:]
    xc = jax.nn.silu(xc.astype(F32)).astype(u.dtype)
    xh = xc.reshape(B, H, P).astype(F32)

    A = -jnp.exp(p["a_log"])                      # (H,)
    dt1 = dt[:, 0]                                 # (B,H)
    decay = jnp.exp(dt1 * A)                       # (B,H)
    Bn = Bm[:, 0].astype(F32)                      # (B,N)
    Cn = Cm[:, 0].astype(F32)
    upd = jnp.einsum("bn,bhp->bhnp", Bn, xh * dt1[..., None])
    new_state = decay[..., None, None] * ssm_state + upd
    y = jnp.einsum("bn,bhnp->bhp", Cn, new_state)  # (B,H,P)
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(B, 1, cfg.d_inner).astype(u.dtype)
    y = y * jax.nn.silu(z.astype(F32)).astype(u.dtype)
    var = jnp.mean(jnp.square(y.astype(F32)), axis=-1, keepdims=True)
    y = (y.astype(F32) * jax.lax.rsqrt(var + 1e-6) * p["norm"]).astype(u.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    return out, new_conv, new_state
