"""ModelConfig — one dataclass describing every supported architecture
family (dense / MoE / SSM / hybrid / xLSTM / enc-dec / VLM / audio)."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch: str
    family: str                 # dense | moe | hybrid | xlstm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    rope_theta: float = 10000.0
    window: Optional[int] = None          # sliding-window attention (Mixtral)
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_parallelism: str = "tp"           # "tp" | "ep"
    moe_dispatch: str = "dropless"        # "dropless" | "capacity"
    moe_ep_axis_size: int = 16            # ep expert-pad target; must be a
                                          # multiple of the mesh model axis
    capacity_factor: float = 1.0          # capacity path only
    # SSM / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    attn_every: int = 0                   # hybrid: shared attn every k layers
    # xLSTM
    slstm_every: int = 0                  # one sLSTM per k layers
    # enc-dec
    enc_layers: int = 0
    # modality frontend stubs
    frontend: Optional[str] = None        # "audio" | "vision"
    frontend_tokens: int = 0              # patches / frames in the prefix
    dtype: object = jnp.bfloat16
    # training
    remat: bool = True

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def validate(self) -> "ModelConfig":
        assert self.n_heads % max(self.kv_heads, 1) == 0, "GQA grouping"
        if self.family == "moe":
            assert self.n_experts > 0 and self.top_k > 0
            assert self.moe_dispatch in ("dropless", "capacity"), \
                self.moe_dispatch
            if self.moe_parallelism == "ep":
                assert self.moe_ep_axis_size > 0, self.moe_ep_axis_size
        if self.family == "hybrid":
            assert self.ssm_state > 0 and self.attn_every > 0
        if self.family == "encdec":
            assert self.enc_layers > 0
        return self


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # "train" | "prefill" | "decode"


# The assigned input-shape set (identical for every LM arch).
SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# Smoke-scale shapes for CPU tests.
SMOKE_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 128, 4, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 128, 2, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 128, 4, "decode"),
    "long_500k": ShapeConfig("long_500k", 256, 1, "decode"),
}
