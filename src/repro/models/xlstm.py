"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, strictly recurrent), pure JAX.

mLSTM uses a chunkwise-parallel form for training/prefill (decay-weighted
attention within chunks + recurrent (C, n) state across chunks, mirroring the
paper's linear-attention duality) and an O(1) recurrence for decode.  sLSTM
is a time scan with exponential gating and the max-stabilizer state.

Simplifications vs. the released code (documented in DESIGN.md): a single
block family per layer (no conv frontends), gate exponents clipped for
stability in bf16, group norm folded into a single RMS norm per block.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..dist.sharding import constrain
from .common import ArrayDef

F32 = jnp.float32
ICLIP = 5.0  # igate exponent clip


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    d_model: int
    n_heads: int = 4
    proj_factor: float = 2.0      # mLSTM up-projection
    slstm_ff_factor: float = 4.0 / 3.0
    chunk: int = 128

    @property
    def d_inner(self) -> int:
        return int(self.d_model * self.proj_factor)

    @property
    def head_dim(self) -> int:
        return self.d_inner // self.n_heads


# ------------------------------------------------------------------- mLSTM
def mlstm_defs(cfg: XLSTMConfig):
    d, di, H = cfg.d_model, cfg.d_inner, cfg.n_heads
    return {
        "w_up": ArrayDef((d, di), ("embed", "d_inner")),
        "w_z": ArrayDef((d, di), ("embed", "d_inner")),
        "w_q": ArrayDef((di, di), ("d_inner", None)),
        "w_k": ArrayDef((di, di), ("d_inner", None)),
        "w_v": ArrayDef((di, di), ("d_inner", None)),
        "w_i": ArrayDef((di, H), ("d_inner", None), dtype=F32),
        "w_f": ArrayDef((di, H), ("d_inner", None), dtype=F32),
        "b_i": ArrayDef((H,), (None,), dtype=F32, init="zeros"),
        "b_f": ArrayDef((H,), (None,), dtype=F32, init="ones"),
        "norm": ArrayDef((di,), ("d_inner",), init="ones"),
        "w_down": ArrayDef((di, d), ("d_inner", "embed")),
    }


def _mlstm_inputs(p, x, cfg: XLSTMConfig):
    B, S, _ = x.shape
    H, dh = cfg.n_heads, cfg.head_dim
    up = jnp.einsum("bsd,de->bse", x, p["w_up"])
    up = constrain(up, ("batch", "seq", "d_inner"))
    z = jnp.einsum("bsd,de->bse", x, p["w_z"])
    q = jnp.einsum("bse,ef->bsf", up, p["w_q"]).reshape(B, S, H, dh)
    k = jnp.einsum("bse,ef->bsf", up, p["w_k"]).reshape(B, S, H, dh)
    v = jnp.einsum("bse,ef->bsf", up, p["w_v"]).reshape(B, S, H, dh)
    ig = jnp.einsum("bse,eh->bsh", up.astype(F32), p["w_i"]) + p["b_i"]
    fg = jnp.einsum("bse,eh->bsh", up.astype(F32), p["w_f"]) + p["b_f"]
    ig = jnp.clip(ig, -ICLIP, ICLIP)
    flog = jax.nn.log_sigmoid(fg)
    return z, q, k, v, ig, flog


def mlstm_parallel(p, x, cfg: XLSTMConfig, return_state: bool = False):
    """Chunkwise-parallel mLSTM.  x: (B,S,d) -> (B,S,d); optionally also
    the final (C, n) state for decode continuation."""
    B, S, d = x.shape
    H, dh = cfg.n_heads, cfg.head_dim
    Q = min(cfg.chunk, S)
    assert S % Q == 0
    nc = S // Q
    z, q, k, v, ig, flog = _mlstm_inputs(p, x, cfg)
    scale = 1.0 / np.sqrt(dh)

    def resh(t, tail):
        return t.reshape((B, nc, Q) + tail).transpose(1, 0, 2, *range(3, 3 + len(tail)))

    qs, ks, vs = (resh(t, (H, dh)) for t in (q, k, v))
    igs, fls = (resh(t, (H,)) for t in (ig, flog))
    causal = jnp.tril(jnp.ones((Q, Q), bool))

    C0 = jnp.zeros((B, H, dh, dh), F32)
    n0 = jnp.zeros((B, H, dh), F32)

    @jax.checkpoint
    def step(carry, inp):
        C, n = carry
        qc, kc, vc, ic, fc = inp
        fcum = jnp.cumsum(fc, axis=1)                   # (B,Q,H)
        ftot = fcum[:, -1]
        # intra-chunk decay: D[i,j] = exp(fcum_i - fcum_j + i_j), j<=i.
        # Mask the exponent, not the result (grad-through-where safety).
        dmat = fcum[:, :, None, :] - fcum[:, None, :, :] + ic[:, None, :, :]
        dmat = jnp.where(causal[None, :, :, None], dmat, -1e30)
        D = jnp.exp(dmat)
        s = jnp.einsum("bihd,bjhd->bijh", qc.astype(F32), kc.astype(F32))
        s = s * scale * D                                # (B,Q,Q,H)
        y_intra = jnp.einsum("bijh,bjhd->bihd", s, vc.astype(F32))
        # carried state: y_off = exp(fcum_i) * q_i C ; normalizer likewise
        decay_i = jnp.exp(fcum)                          # (B,Q,H)
        y_off = jnp.einsum("bihd,bhde,bih->bihe", qc.astype(F32) * scale, C,
                           decay_i)
        n_off = jnp.einsum("bihd,bhd,bih->bih", qc.astype(F32) * scale, n,
                           decay_i)[..., None]
        y = y_intra + y_off
        nvec = jnp.einsum("bijh->bih", s)[..., None] + n_off
        y = y / jnp.maximum(jnp.abs(nvec), 1.0)
        # state update
        dte = jnp.exp(ftot[:, None, :] - fcum + ic)      # (B,Q,H)
        C_new = jnp.exp(ftot)[..., None, None] * C + jnp.einsum(
            "bjhd,bjh,bjhe->bhde", kc.astype(F32), dte, vc.astype(F32))
        n_new = jnp.exp(ftot)[..., None] * n + jnp.einsum(
            "bjhd,bjh->bhd", kc.astype(F32), dte)
        return (C_new, n_new), y

    (C_f, n_f), ys = jax.lax.scan(step, (C0, n0), (qs, ks, vs, igs, fls))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, cfg.d_inner)
    y = y.astype(x.dtype) * jax.nn.silu(z.astype(F32)).astype(x.dtype)
    var = jnp.mean(jnp.square(y.astype(F32)), axis=-1, keepdims=True)
    y = (y.astype(F32) * jax.lax.rsqrt(var + 1e-6) * p["norm"]).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["w_down"])
    out = constrain(out, ("batch", "seq", "embed"))
    if return_state:
        return out, C_f, n_f
    return out


def mlstm_decode(p, x, C, n, cfg: XLSTMConfig):
    """One-token mLSTM step.  x: (B,1,d); C: (B,H,dh,dh) f32; n: (B,H,dh)."""
    B = x.shape[0]
    H, dh = cfg.n_heads, cfg.head_dim
    z, q, k, v, ig, flog = _mlstm_inputs(p, x, cfg)
    q1 = q[:, 0].astype(F32) / np.sqrt(dh)
    k1, v1 = k[:, 0].astype(F32), v[:, 0].astype(F32)
    f1, i1 = jnp.exp(flog[:, 0]), jnp.exp(ig[:, 0])      # (B,H)
    C_new = f1[..., None, None] * C + i1[..., None, None] * jnp.einsum(
        "bhd,bhe->bhde", k1, v1)
    n_new = f1[..., None] * n + i1[..., None] * k1
    y = jnp.einsum("bhd,bhde->bhe", q1, C_new)
    denom = jnp.abs(jnp.einsum("bhd,bhd->bh", q1, n_new))[..., None]
    y = y / jnp.maximum(denom, 1.0)
    y = y.reshape(B, 1, cfg.d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(F32)).astype(x.dtype)
    var = jnp.mean(jnp.square(y.astype(F32)), axis=-1, keepdims=True)
    y = (y.astype(F32) * jax.lax.rsqrt(var + 1e-6) * p["norm"]).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["w_down"])
    return out, C_new, n_new


# ------------------------------------------------------------------- sLSTM
def slstm_defs(cfg: XLSTMConfig):
    d, H = cfg.d_model, cfg.n_heads
    dh = d // H
    ff = int(d * cfg.slstm_ff_factor)
    gates = {}
    for g in ("i", "f", "z", "o"):
        gates[f"w_{g}"] = ArrayDef((d, H, dh), ("embed", None, None), dtype=F32)
        gates[f"r_{g}"] = ArrayDef((H, dh, dh), (None, None, None), dtype=F32,
                                   scale=0.3)
        gates[f"b_{g}"] = ArrayDef((H, dh), (None, None), dtype=F32,
                                   init="zeros")
    gates.update({
        "norm": ArrayDef((d,), ("embed",), init="ones"),
        "w_ff1": ArrayDef((d, ff), ("embed", "mlp")),
        "w_ff2": ArrayDef((ff, d), ("mlp", "embed")),
    })
    return gates


def _slstm_cell(p, xt, state):
    """xt: (B,H,dh) f32 gate preactivations computed outside per gate."""
    (c, n, h, m) = state
    pre = {}
    for g in ("i", "f", "z", "o"):
        pre[g] = xt[g] + jnp.einsum("bhd,hde->bhe", h, p[f"r_{g}"]) + p[f"b_{g}"]
    ilog = jnp.clip(pre["i"], -ICLIP, ICLIP)
    flog = jax.nn.log_sigmoid(pre["f"])
    m_new = jnp.maximum(flog + m, ilog)
    i_s = jnp.exp(ilog - m_new)
    f_s = jnp.exp(flog + m - m_new)
    z_t = jnp.tanh(pre["z"])
    o_t = jax.nn.sigmoid(pre["o"])
    c_new = f_s * c + i_s * z_t
    n_new = f_s * n + i_s
    h_new = o_t * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, h_new, m_new)


def slstm_forward(p, x, cfg: XLSTMConfig, state=None):
    """Recurrent sLSTM over the sequence.  x: (B,S,d)."""
    B, S, d = x.shape
    H = cfg.n_heads
    dh = d // H
    xf = x.astype(F32)
    pre = {g: jnp.einsum("bsd,dhe->bshe", xf, p[f"w_{g}"])
           for g in ("i", "f", "z", "o")}
    if state is None:
        # m starts at 0 to match the zero-initialized decode cache: the
        # max(n,1) output clamp makes trajectories stabilizer-dependent, so
        # prefill and decode must agree on the initial m exactly.
        zero = jnp.zeros((B, H, dh), F32)
        state = (zero, zero, zero, zero)

    def step(carry, inp):
        new = _slstm_cell(p, inp, carry)
        return new, new[2]

    xs = {g: pre[g].transpose(1, 0, 2, 3) for g in pre}
    final, hs = jax.lax.scan(step, state, xs)
    y = hs.transpose(1, 0, 2, 3).reshape(B, S, d).astype(x.dtype)
    # small gated FFN (proj factor 4/3)
    hff = jnp.einsum("bsd,df->bsf", y, p["w_ff1"])
    hff = jax.nn.gelu(hff.astype(F32)).astype(x.dtype)
    out = jnp.einsum("bsf,fd->bsd", hff, p["w_ff2"])
    return constrain(out, ("batch", "seq", "embed")), final


def slstm_decode(p, x, state, cfg: XLSTMConfig):
    out, new_state = slstm_forward(p, x, cfg, state=state)
    return out, new_state
