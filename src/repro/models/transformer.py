"""Architecture assembly: builds every supported family from ModelConfig.

One ``Model`` object per architecture exposes:
  * ``param_defs()`` / ``init(key)``       — declarations / materialization
  * ``loss(params, batch)``                — training forward (+ CE loss)
  * ``prefill(params, batch, cache)``      — prompt ingestion, fills cache
  * ``decode(params, cache, tokens, pos)`` — one-token serve step
  * ``cache_defs(batch, seq)``             — KV/state cache declarations
  * ``input_specs(shape)``                 — ShapeDtypeStructs for AOT lowering

Layer stacks run under ``jax.lax.scan`` (stacked params) with optional remat;
heterogeneous stacks (zamba2 hybrid, xLSTM) are segmented: uniform segments
scan, the interleaved special blocks (shared attention / sLSTM) unroll.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..dist.sharding import constrain
from .common import ArrayDef, abstract_params, init_params, stack
from .config import ModelConfig, ShapeConfig
from .layers import (
    AttnConfig,
    chunked_lm_loss,
    attention,
    attention_decode,
    attention_decode_ring,
    attention_defs,
    cross_attention_decode,
    cross_entropy,
    embed,
    embed_defs,
    lm_head,
    lm_head_defs,
    mlp,
    mlp_defs,
    rmsnorm,
    rmsnorm_defs,
    rope,
)
from .moe import MoEConfig, moe, moe_decode, moe_defs
from .ssm import SSMConfig, ssm_decode, ssm_defs, ssm_forward
from .xlstm import (
    XLSTMConfig,
    mlstm_decode,
    mlstm_defs,
    mlstm_parallel,
    slstm_decode,
    slstm_defs,
    slstm_forward,
)

PyTree = Any
F32 = jnp.float32


def build_model(cfg: ModelConfig) -> "Model":
    cfg.validate()
    return Model(cfg)


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.attn_cfg = AttnConfig(
            d_model=cfg.d_model,
            n_heads=cfg.n_heads,
            kv_heads=cfg.kv_heads,
            head_dim=cfg.resolved_head_dim,
            rope_theta=cfg.rope_theta,
            window=cfg.window,
        )
        if cfg.family == "moe":
            self.moe_cfg = MoEConfig(
                d_model=cfg.d_model, d_ff=cfg.d_ff, n_experts=cfg.n_experts,
                top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
                dispatch=cfg.moe_dispatch,
                parallelism=cfg.moe_parallelism,
                ep_axis_size=cfg.moe_ep_axis_size,
            )
        if cfg.family == "hybrid":
            self.ssm_cfg = SSMConfig(
                d_model=cfg.d_model, d_inner=cfg.d_inner,
                head_dim=cfg.ssm_head_dim, state_dim=cfg.ssm_state,
            )
        if cfg.family == "xlstm":
            self.xl_cfg = XLSTMConfig(d_model=cfg.d_model, n_heads=cfg.n_heads)

    # ================================================================ defs
    def _dense_layer_defs(self):
        d = {"ln1": rmsnorm_defs(self.cfg.d_model),
             "attn": attention_defs(self.attn_cfg),
             "ln2": rmsnorm_defs(self.cfg.d_model)}
        if self.cfg.family == "moe":
            d["moe"] = moe_defs(self.moe_cfg)
        else:
            d["mlp"] = mlp_defs(self.cfg.d_model, self.cfg.d_ff)
        return d

    def _shared_attn_defs(self):
        return {"ln1": rmsnorm_defs(self.cfg.d_model),
                "attn": attention_defs(self.attn_cfg),
                "ln2": rmsnorm_defs(self.cfg.d_model),
                "mlp": mlp_defs(self.cfg.d_model, self.cfg.d_ff)}

    def param_defs(self) -> PyTree:
        cfg = self.cfg
        out: Dict[str, PyTree] = {
            "embed": embed_defs(cfg.vocab, cfg.d_model),
            "final_ln": rmsnorm_defs(cfg.d_model),
            "head": lm_head_defs(cfg.d_model, cfg.vocab),
        }
        if cfg.family in ("dense", "moe", "vlm"):
            out["layers"] = stack(self._dense_layer_defs(), cfg.n_layers)
            if cfg.family == "vlm":
                out["vision_adapter"] = {
                    "w": ArrayDef((cfg.d_model, cfg.d_model),
                                  ("embed", "embed"))}
        elif cfg.family == "hybrid":
            out["ssm_layers"] = stack(
                {"ln": rmsnorm_defs(cfg.d_model),
                 "ssm": ssm_defs(self.ssm_cfg)}, cfg.n_layers)
            out["shared_attn"] = self._shared_attn_defs()
        elif cfg.family == "xlstm":
            n_m, n_s = self._xlstm_counts()
            out["mlstm_layers"] = stack(
                {"ln": rmsnorm_defs(cfg.d_model),
                 "mlstm": mlstm_defs(self.xl_cfg)}, n_m)
            if n_s:
                out["slstm_layers"] = stack(
                    {"ln": rmsnorm_defs(cfg.d_model),
                     "slstm": slstm_defs(self.xl_cfg)}, n_s)
        elif cfg.family == "encdec":
            out["audio_adapter"] = {
                "w": ArrayDef((cfg.d_model, cfg.d_model), ("embed", "embed"))}
            out["enc_layers"] = stack(
                {"ln1": rmsnorm_defs(cfg.d_model),
                 "attn": attention_defs(self.attn_cfg),
                 "ln2": rmsnorm_defs(cfg.d_model),
                 "mlp": mlp_defs(cfg.d_model, cfg.d_ff)}, cfg.enc_layers)
            out["dec_layers"] = stack(
                {"ln1": rmsnorm_defs(cfg.d_model),
                 "attn": attention_defs(self.attn_cfg),
                 "lnx": rmsnorm_defs(cfg.d_model),
                 "xattn": attention_defs(self.attn_cfg),
                 "ln2": rmsnorm_defs(cfg.d_model),
                 "mlp": mlp_defs(cfg.d_model, cfg.d_ff)}, cfg.n_layers)
        else:
            raise ValueError(f"unknown family {cfg.family}")
        return out

    def _xlstm_counts(self) -> Tuple[int, int]:
        L, k = self.cfg.n_layers, self.cfg.slstm_every
        n_s = L // k if k else 0
        return L - n_s, n_s

    def init(self, key) -> PyTree:
        return init_params(self.param_defs(), key)

    def abstract(self) -> PyTree:
        return abstract_params(self.param_defs())

    # =========================================================== forward
    def _maybe_remat(self, fn):
        return jax.checkpoint(fn) if self.cfg.remat else fn

    def _dense_body(self, x, lp, positions):
        h = x + attention(lp["attn"], rmsnorm(lp["ln1"], x), self.attn_cfg,
                          positions)
        if self.cfg.family == "moe":
            return h + moe(lp["moe"], rmsnorm(lp["ln2"], h), self.moe_cfg)
        return h + mlp(lp["mlp"], rmsnorm(lp["ln2"], h))

    def _run_dense_stack(self, params, x, positions):
        body = self._maybe_remat(
            lambda x, lp: (self._dense_body(x, lp, positions), None))
        x, _ = jax.lax.scan(body, x, params["layers"])
        return x

    def _shared_attn_apply(self, sp, x, positions):
        h = x + attention(sp["attn"], rmsnorm(sp["ln1"], x), self.attn_cfg,
                          positions)
        return h + mlp(sp["mlp"], rmsnorm(sp["ln2"], h))

    def _run_hybrid_stack(self, params, x, positions):
        cfg = self.cfg
        k = cfg.attn_every
        n_seg, rem = divmod(cfg.n_layers, k)

        def ssm_body(x, lp):
            return x + ssm_forward(lp["ssm"], rmsnorm(lp["ln"], x),
                                   self.ssm_cfg), None

        body = self._maybe_remat(ssm_body)
        lp_all = params["ssm_layers"]
        for j in range(n_seg):
            seg = jax.tree.map(lambda a: a[j * k:(j + 1) * k], lp_all)
            x, _ = jax.lax.scan(body, x, seg)
            x = self._shared_attn_apply(params["shared_attn"], x, positions)
        if rem:
            seg = jax.tree.map(lambda a: a[n_seg * k:], lp_all)
            x, _ = jax.lax.scan(body, x, seg)
        return x

    def _run_xlstm_stack(self, params, x, positions):
        n_m, n_s = self._xlstm_counts()
        per_seg = self.cfg.slstm_every - 1 if n_s else n_m

        def m_body(x, lp):
            return x + mlstm_parallel(lp["mlstm"], rmsnorm(lp["ln"], x),
                                      self.xl_cfg), None

        body = self._maybe_remat(m_body)
        mp = params["mlstm_layers"]
        consumed = 0
        for j in range(n_s):
            seg = jax.tree.map(lambda a: a[consumed:consumed + per_seg], mp)
            x, _ = jax.lax.scan(body, x, seg)
            consumed += per_seg
            sp = jax.tree.map(lambda a: a[j], params["slstm_layers"])
            y, _ = slstm_forward(sp["slstm"], rmsnorm(sp["ln"], x),
                                 self.xl_cfg)
            x = x + y
        if consumed < n_m:
            seg = jax.tree.map(lambda a: a[consumed:], mp)
            x, _ = jax.lax.scan(body, x, seg)
        return x

    def _encode(self, params, frames, enc_positions):
        """Encoder over stub frame embeddings (audio frontend)."""
        x = jnp.einsum("bsd,de->bse", frames, params["audio_adapter"]["w"])
        x = constrain(x, ("batch", "seq", "embed"))
        enc_attn = dataclasses.replace(self.attn_cfg, causal=False)

        def body(x, lp):
            h = x + attention(lp["attn"], rmsnorm(lp["ln1"], x), enc_attn,
                              enc_positions)
            return h + mlp(lp["mlp"], rmsnorm(lp["ln2"], h)), None

        x, _ = jax.lax.scan(self._maybe_remat(body), x, params["enc_layers"])
        return x

    def _run_decoder_encdec(self, params, x, positions, enc_out,
                            enc_positions):
        def body(x, lp):
            h = x + attention(lp["attn"], rmsnorm(lp["ln1"], x),
                              self.attn_cfg, positions)
            h = h + attention(lp["xattn"], rmsnorm(lp["lnx"], h),
                              self.attn_cfg, positions, kv_x=enc_out,
                              kv_positions=enc_positions)
            return h + mlp(lp["mlp"], rmsnorm(lp["ln2"], h)), None

        x, _ = jax.lax.scan(self._maybe_remat(body), x, params["dec_layers"])
        return x

    def _trunk(self, params, batch) -> Tuple[jax.Array, jax.Array]:
        """Returns (hidden, positions) for the decoder token stream."""
        cfg = self.cfg
        if cfg.family in ("dense", "moe"):
            tokens = batch["tokens"]
            B, S = tokens.shape
            positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32),
                                         (B, S))
            x = embed(params["embed"], tokens)
            x = self._run_dense_stack(params, x, positions)
        elif cfg.family == "vlm":
            patches, tokens = batch["patches"], batch["tokens"]
            B, P, _ = patches.shape
            S = P + tokens.shape[1]
            positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32),
                                         (B, S))
            xt = embed(params["embed"], tokens)
            xp = jnp.einsum("bpd,de->bpe", patches,
                            params["vision_adapter"]["w"]).astype(xt.dtype)
            x = jnp.concatenate([xp, xt], axis=1)
            x = constrain(x, ("batch", "seq", "embed"))
            x = self._run_dense_stack(params, x, positions)
        elif cfg.family == "hybrid":
            tokens = batch["tokens"]
            B, S = tokens.shape
            positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32),
                                         (B, S))
            x = embed(params["embed"], tokens)
            x = self._run_hybrid_stack(params, x, positions)
        elif cfg.family == "xlstm":
            tokens = batch["tokens"]
            B, S = tokens.shape
            positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32),
                                         (B, S))
            x = embed(params["embed"], tokens)
            x = self._run_xlstm_stack(params, x, positions)
        elif cfg.family == "encdec":
            frames, tokens = batch["frames"], batch["tokens"]
            B, Se, _ = frames.shape
            S = tokens.shape[1]
            enc_positions = jnp.broadcast_to(
                jnp.arange(Se, dtype=jnp.int32), (B, Se))
            positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32),
                                         (B, S))
            enc_out = self._encode(params, frames, enc_positions)
            x = embed(params["embed"], tokens)
            x = self._run_decoder_encdec(params, x, positions, enc_out,
                                         enc_positions)
        else:
            raise ValueError(cfg.family)
        return x, positions

    def loss(self, params, batch) -> jax.Array:
        x, _ = self._trunk(params, batch)
        x = rmsnorm(params["final_ln"], x)
        labels = batch["labels"]
        if self.cfg.family == "vlm":
            # image prefix carries no LM loss
            P = batch["patches"].shape[1]
            pad = jnp.full(
                (labels.shape[0], P), -1, labels.dtype)
            labels = jnp.concatenate([pad, labels], axis=1)
        return chunked_lm_loss(params["head"], x, labels)

    # ============================================================ serving
    def cache_defs(self, batch: int, seq: int) -> PyTree:
        cfg = self.cfg
        K, dh = cfg.kv_heads, self.attn_cfg.head_dim
        kv_axes = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
        # Sliding-window archs keep a ring buffer of window size — a 500k
        # context costs the same KV memory as a 4k one (§Perf climb #3).
        if cfg.window is not None:
            seq = min(seq, cfg.window)

        def kv(n_layers):
            shape = (n_layers, batch, seq, K, dh)
            return {"k": ArrayDef(shape, kv_axes, cfg.dtype, init="zeros"),
                    "v": ArrayDef(shape, kv_axes, cfg.dtype, init="zeros")}

        if cfg.family in ("dense", "moe", "vlm"):
            return {"kv": kv(cfg.n_layers)}
        if cfg.family == "hybrid":
            n_attn = cfg.n_layers // cfg.attn_every
            H = self.ssm_cfg.n_heads
            return {
                "kv": kv(n_attn),
                "conv": ArrayDef(
                    (cfg.n_layers, batch, self.ssm_cfg.conv_width - 1,
                     cfg.d_inner),
                    ("layers", "batch", None, "d_inner"), cfg.dtype,
                    init="zeros"),
                "ssm": ArrayDef(
                    (cfg.n_layers, batch, H, cfg.ssm_state, cfg.ssm_head_dim),
                    ("layers", "batch", "ssm_heads", None, None), F32,
                    init="zeros"),
            }
        if cfg.family == "xlstm":
            n_m, n_s = self._xlstm_counts()
            H, dhx = self.xl_cfg.n_heads, self.xl_cfg.head_dim
            dhs = cfg.d_model // H
            out = {
                "C": ArrayDef((n_m, batch, H, dhx, dhx),
                              ("layers", "batch", None, "d_inner", None), F32,
                              init="zeros"),
                "n": ArrayDef((n_m, batch, H, dhx),
                              ("layers", "batch", None, "d_inner"), F32,
                              init="zeros"),
            }
            if n_s:
                for nm in ("sc", "sn", "sh", "sm"):
                    out[nm] = ArrayDef((n_s, batch, H, dhs),
                                       ("layers", "batch", None, None), F32,
                                       init="zeros")
            return out
        if cfg.family == "encdec":
            enc_seq = seq  # encoder memory length == prompt frames
            return {
                "kv": kv(cfg.n_layers),
                "xk": ArrayDef((cfg.n_layers, batch, enc_seq, K, dh),
                               kv_axes, cfg.dtype, init="zeros"),
                "xv": ArrayDef((cfg.n_layers, batch, enc_seq, K, dh),
                               kv_axes, cfg.dtype, init="zeros"),
            }
        raise ValueError(cfg.family)

    def init_cache(self, batch: int, seq: int) -> PyTree:
        return jax.tree.map(
            lambda d: jnp.zeros(d.shape, d.dtype),
            self.cache_defs(batch, seq),
            is_leaf=lambda x: isinstance(x, ArrayDef),
        )

    # one-token decode ------------------------------------------------------
    def decode(self, params, cache, tokens, pos):
        """tokens: (B,1) int32; pos: scalar int32 (same for all rows).
        Returns (logits (B, vocab), new cache)."""
        cfg = self.cfg
        x = embed(params["embed"], tokens)
        if cfg.family in ("dense", "moe", "vlm"):
            ring = (cfg.window is not None
                    and cache["kv"]["k"].shape[2] <= cfg.window)

            def body(x, xs):
                lp, ck, cv = xs
                h = rmsnorm(lp["ln1"], x)
                dec = attention_decode_ring if ring else attention_decode
                y, ck, cv = dec(lp["attn"], h, ck, cv, pos, self.attn_cfg)
                x = x + y
                h2 = rmsnorm(lp["ln2"], x)
                if cfg.family == "moe":
                    # moe_decode == moe: decode shares the routing function
                    # and grouped GEMM with prefill, so a token's expert
                    # assignment never depends on how the stream is chunked.
                    x = x + moe_decode(lp["moe"], h2, self.moe_cfg)
                else:
                    x = x + mlp(lp["mlp"], h2)
                return x, (ck, cv)

            x, (nk, nv) = jax.lax.scan(
                body, x, (params["layers"], cache["kv"]["k"],
                          cache["kv"]["v"]))
            cache = {**cache, "kv": {"k": nk, "v": nv}}
        elif cfg.family == "hybrid":
            x, cache = self._decode_hybrid(params, cache, x, pos)
        elif cfg.family == "xlstm":
            x, cache = self._decode_xlstm(params, cache, x)
        elif cfg.family == "encdec":
            def body(x, xs):
                lp, ck, cv, xk, xv = xs
                h = rmsnorm(lp["ln1"], x)
                y, ck, cv = attention_decode(lp["attn"], h, ck, cv, pos,
                                             self.attn_cfg)
                x = x + y
                hx = rmsnorm(lp["lnx"], x)
                x = x + cross_attention_decode(lp["xattn"], hx, xk, xv,
                                               self.attn_cfg)
                x = x + mlp(lp["mlp"], rmsnorm(lp["ln2"], x))
                return x, (ck, cv)

            x, (nk, nv) = jax.lax.scan(
                body, x,
                (params["dec_layers"], cache["kv"]["k"], cache["kv"]["v"],
                 cache["xk"], cache["xv"]))
            cache = {**cache, "kv": {"k": nk, "v": nv}}
        else:
            raise ValueError(cfg.family)
        x = rmsnorm(params["final_ln"], x)
        logits = lm_head(params["head"], x)[:, 0]
        return logits, cache

    def _decode_hybrid(self, params, cache, x, pos):
        cfg = self.cfg
        k = cfg.attn_every
        n_seg, rem = divmod(cfg.n_layers, k)

        def seg_scan(x, lp_seg, conv_seg, ssm_seg):
            def body(x, xs):
                lp, cs, ss = xs
                y, cs, ss = ssm_decode(lp["ssm"], rmsnorm(lp["ln"], x), cs,
                                       ss, self.ssm_cfg)
                return x + y, (cs, ss)

            x, (nc, ns) = jax.lax.scan(body, x, (lp_seg, conv_seg, ssm_seg))
            return x, nc, ns

        lp_all = params["ssm_layers"]
        conv_all, ssm_all = cache["conv"], cache["ssm"]
        new_conv, new_ssm = [], []
        kcache, vcache = cache["kv"]["k"], cache["kv"]["v"]
        new_k, new_v = [], []
        sp = params["shared_attn"]
        for j in range(n_seg):
            sl = slice(j * k, (j + 1) * k)
            lp_seg = jax.tree.map(lambda a: a[sl], lp_all)
            x, nc, ns = seg_scan(x, lp_seg, conv_all[sl], ssm_all[sl])
            new_conv.append(nc)
            new_ssm.append(ns)
            h = rmsnorm(sp["ln1"], x)
            y, ck, cv = attention_decode(sp["attn"], h, kcache[j], vcache[j],
                                         pos, self.attn_cfg)
            x = x + y
            x = x + mlp(sp["mlp"], rmsnorm(sp["ln2"], x))
            new_k.append(ck)
            new_v.append(cv)
        if rem:
            sl = slice(n_seg * k, cfg.n_layers)
            lp_seg = jax.tree.map(lambda a: a[sl], lp_all)
            x, nc, ns = seg_scan(x, lp_seg, conv_all[sl], ssm_all[sl])
            new_conv.append(nc)
            new_ssm.append(ns)
        cache = {
            "kv": {"k": jnp.stack(new_k), "v": jnp.stack(new_v)},
            "conv": jnp.concatenate(new_conv, axis=0),
            "ssm": jnp.concatenate(new_ssm, axis=0),
        }
        return x, cache

    def _decode_xlstm(self, params, cache, x):
        n_m, n_s = self._xlstm_counts()
        per_seg = self.cfg.slstm_every - 1 if n_s else n_m
        mp = params["mlstm_layers"]

        def seg_scan(x, lp_seg, C_seg, n_seg_state):
            def body(x, xs):
                lp, C, n = xs
                y, C, n = mlstm_decode(lp["mlstm"], rmsnorm(lp["ln"], x), C,
                                       n, self.xl_cfg)
                return x + y, (C, n)

            x, (nC, nn) = jax.lax.scan(body, x, (lp_seg, C_seg, n_seg_state))
            return x, nC, nn

        new_C, new_n = [], []
        new_s = {nm: [] for nm in ("sc", "sn", "sh", "sm")}
        consumed = 0
        for j in range(n_s):
            sl = slice(consumed, consumed + per_seg)
            lp_seg = jax.tree.map(lambda a: a[sl], mp)
            x, nC, nn = seg_scan(x, lp_seg, cache["C"][sl], cache["n"][sl])
            new_C.append(nC)
            new_n.append(nn)
            consumed += per_seg
            sp = jax.tree.map(lambda a: a[j], params["slstm_layers"])
            state = tuple(cache[nm][j] for nm in ("sc", "sn", "sh", "sm"))
            y, state = slstm_decode(sp["slstm"], rmsnorm(sp["ln"], x), state,
                                    self.xl_cfg)
            x = x + y
            for nm, s in zip(("sc", "sn", "sh", "sm"), state):
                new_s[nm].append(s)
        if consumed < n_m:
            sl = slice(consumed, n_m)
            lp_seg = jax.tree.map(lambda a: a[sl], mp)
            x, nC, nn = seg_scan(x, lp_seg, cache["C"][sl], cache["n"][sl])
            new_C.append(nC)
            new_n.append(nn)
        cache = {"C": jnp.concatenate(new_C, 0),
                 "n": jnp.concatenate(new_n, 0)}
        if n_s:
            for nm in ("sc", "sn", "sh", "sm"):
                cache[nm] = jnp.stack(new_s[nm])
        return x, cache

    # prefill ---------------------------------------------------------------
    def prefill(self, params, batch, cache):
        """Consume the prompt, fill the cache, return last-position logits.

        For recurrent families the cache holds the final state (the parallel
        forms return their final recurrence states); for attention families
        the prompt's K/V land in the cache (ring-ified for windowed archs).
        """
        cfg = self.cfg
        if cfg.family in ("dense", "moe", "vlm", "encdec"):
            return self._prefill_attn(params, batch, cache)
        if cfg.family == "hybrid":
            return self._prefill_hybrid(params, batch, cache)
        return self._prefill_xlstm(params, batch, cache)

    def _prefill_hybrid(self, params, batch, cache):
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        x = embed(params["embed"], tokens)
        k = cfg.attn_every
        n_seg, rem = divmod(cfg.n_layers, k)
        acfg = self.attn_cfg

        def body(x, lp):
            out, conv_s, ssm_s = ssm_forward(
                lp["ssm"], rmsnorm(lp["ln"], x), self.ssm_cfg,
                return_state=True)
            return x + out, (conv_s, ssm_s)

        body = self._maybe_remat(body)
        lp_all = params["ssm_layers"]
        sp = params["shared_attn"]
        convs, ssms, att_k, att_v = [], [], [], []
        S_cache = cache["kv"]["k"].shape[2]
        for j in range(n_seg):
            seg = jax.tree.map(lambda a: a[j * k:(j + 1) * k], lp_all)
            x, (cs, ss) = jax.lax.scan(body, x, seg)
            convs.append(cs)
            ssms.append(ss)
            h = rmsnorm(sp["ln1"], x)
            kk = jnp.einsum("bsd,dhk->bshk", h, sp["attn"]["wk"])
            vv = jnp.einsum("bsd,dhk->bshk", h, sp["attn"]["wv"])
            kk = rope(kk, positions, acfg.rope_theta)
            # place prompt K/V at the head of the cache-length buffer
            pad = S_cache - S
            if pad > 0:
                kk = jnp.pad(kk, ((0, 0), (0, pad), (0, 0), (0, 0)))
                vv = jnp.pad(vv, ((0, 0), (0, pad), (0, 0), (0, 0)))
            att_k.append(kk.astype(cache["kv"]["k"].dtype))
            att_v.append(vv.astype(cache["kv"]["v"].dtype))
            x = x + attention(sp["attn"], h, acfg, positions)
            x = x + mlp(sp["mlp"], rmsnorm(sp["ln2"], x))
        if rem:
            seg = jax.tree.map(lambda a: a[n_seg * k:], lp_all)
            x, (cs, ss) = jax.lax.scan(body, x, seg)
            convs.append(cs)
            ssms.append(ss)
        new_cache = {
            "kv": {"k": jnp.stack(att_k), "v": jnp.stack(att_v)},
            "conv": jnp.concatenate(convs, 0).astype(cache["conv"].dtype),
            "ssm": jnp.concatenate(ssms, 0),
        }
        x = rmsnorm(params["final_ln"], x)
        logits = lm_head(params["head"], x[:, -1:])[:, 0]
        return logits, new_cache

    def _prefill_xlstm(self, params, batch, cache):
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = embed(params["embed"], tokens)
        n_m, n_s = self._xlstm_counts()
        per_seg = cfg.slstm_every - 1 if n_s else n_m

        def m_body(x, lp):
            out, C_f, n_f = mlstm_parallel(
                lp["mlstm"], rmsnorm(lp["ln"], x), self.xl_cfg,
                return_state=True)
            return x + out, (C_f, n_f)

        m_body = self._maybe_remat(m_body)
        mp = params["mlstm_layers"]
        Cs, ns = [], []
        s_states = {nm: [] for nm in ("sc", "sn", "sh", "sm")}
        consumed = 0
        for j in range(n_s):
            seg = jax.tree.map(lambda a: a[consumed:consumed + per_seg], mp)
            x, (C_f, n_f) = jax.lax.scan(m_body, x, seg)
            Cs.append(C_f)
            ns.append(n_f)
            consumed += per_seg
            spj = jax.tree.map(lambda a: a[j], params["slstm_layers"])
            y, state = slstm_forward(spj["slstm"], rmsnorm(spj["ln"], x),
                                     self.xl_cfg)
            x = x + y
            for nm, st in zip(("sc", "sn", "sh", "sm"), state):
                s_states[nm].append(st)
        if consumed < n_m:
            seg = jax.tree.map(lambda a: a[consumed:], mp)
            x, (C_f, n_f) = jax.lax.scan(m_body, x, seg)
            Cs.append(C_f)
            ns.append(n_f)
        new_cache = {"C": jnp.concatenate(Cs, 0), "n": jnp.concatenate(ns, 0)}
        if n_s:
            for nm in ("sc", "sn", "sh", "sm"):
                new_cache[nm] = jnp.stack(s_states[nm])
        x = rmsnorm(params["final_ln"], x)
        logits = lm_head(params["head"], x[:, -1:])[:, 0]
        return logits, new_cache

    def _prefill_attn(self, params, batch, cache):
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        x = embed(params["embed"], tokens)
        acfg = self.attn_cfg

        def kv_of(lp, h, pos_b):
            k = jnp.einsum("bsd,dhk->bshk", h, lp["wk"])
            v = jnp.einsum("bsd,dhk->bshk", h, lp["wv"])
            k = rope(k, pos_b, acfg.rope_theta)
            return k, v

        if cfg.family == "encdec":
            frames = batch["frames"]
            Se = frames.shape[1]
            enc_positions = jnp.broadcast_to(
                jnp.arange(Se, dtype=jnp.int32), (B, Se))
            enc_out = self._encode(params, frames, enc_positions)

            def body(x, lp):
                h = rmsnorm(lp["ln1"], x)
                k, v = kv_of(lp["attn"], h, positions)
                x = x + attention(lp["attn"], h, acfg, positions)
                hx = rmsnorm(lp["lnx"], x)
                xk = jnp.einsum("bsd,dhk->bshk", enc_out, lp["xattn"]["wk"])
                xv = jnp.einsum("bsd,dhk->bshk", enc_out, lp["xattn"]["wv"])
                x = x + attention(lp["xattn"], hx, acfg, positions,
                                  kv_x=enc_out, kv_positions=enc_positions)
                x = x + mlp(lp["mlp"], rmsnorm(lp["ln2"], x))
                return x, (k, v, xk, xv)

            x, (ks, vs, xks, xvs) = jax.lax.scan(
                self._maybe_remat(body), x, params["dec_layers"])
            cache = {"kv": {"k": ks, "v": vs}, "xk": xks, "xv": xvs}
        else:
            def body(x, lp):
                h = rmsnorm(lp["ln1"], x)
                k, v = kv_of(lp["attn"], h, positions)
                x = x + attention(lp["attn"], h, acfg, positions)
                h2 = rmsnorm(lp["ln2"], x)
                if cfg.family == "moe":
                    x = x + moe(lp["moe"], h2, self.moe_cfg)
                else:
                    x = x + mlp(lp["mlp"], h2)
                return x, (k, v)

            x, (ks, vs) = jax.lax.scan(
                self._maybe_remat(body), x, params["layers"])
            if cfg.window is not None and S > cfg.window:
                # Ring-ify: only the last `window` tokens matter; place each
                # at its ring slot (pos % W) so decode continues seamlessly.
                W = cfg.window
                slots = jnp.mod(jnp.arange(S - W, S), W)
                ks = jnp.zeros_like(ks[:, :, :W]).at[:, :, slots].set(
                    ks[:, :, S - W:])
                vs = jnp.zeros_like(vs[:, :, :W]).at[:, :, slots].set(
                    vs[:, :, S - W:])
            cache = {**cache, "kv": {"k": ks, "v": vs}}
        x = rmsnorm(params["final_ln"], x)
        logits = lm_head(params["head"], x[:, -1:])[:, 0]
        return logits, cache

    # ======================================================== input specs
    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every model input of the step the
        shape exercises (train/prefill -> batch dict; decode -> tokens/pos +
        cache)."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        if shape.kind in ("train", "prefill"):
            batch: Dict[str, Any] = {}
            if cfg.family == "encdec":
                batch["frames"] = jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                       cfg.dtype)
                batch["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
            elif cfg.family == "vlm":
                P = cfg.frontend_tokens
                batch["patches"] = jax.ShapeDtypeStruct((B, P, cfg.d_model),
                                                        cfg.dtype)
                batch["tokens"] = jax.ShapeDtypeStruct((B, S - P), i32)
            else:
                batch["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
            if shape.kind == "train":
                lbl_len = S if cfg.family != "vlm" else S - cfg.frontend_tokens
                batch["labels"] = jax.ShapeDtypeStruct((B, lbl_len), i32)
            return batch
        # decode
        cache = jax.tree.map(
            lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype),
            self.cache_defs(B, S),
            is_leaf=lambda x: isinstance(x, ArrayDef),
        )
        return {
            "tokens": jax.ShapeDtypeStruct((B, 1), i32),
            "pos": jax.ShapeDtypeStruct((), i32),
            "cache": cache,
        }
