"""Mixture-of-Experts layer (Mixtral / granite-MoE style).

Two dispatch modes (``MoEConfig.dispatch``):

* ``dropless`` (default): sorted ragged routing.  Tokens are argsorted by
  expert id into contiguous per-expert segments and the expert SwiGLU runs
  as a grouped GEMM over the ragged segments (``kernels/moe_gemm.py`` on
  TPU, a masked-einsum oracle elsewhere).  No token is ever dropped, so the
  layer computes the *same function* for batched prefill, chunked prefill
  and single-token decode — routing is per-token and chunking-invariant.

* ``capacity``: GShard-style capacity-bounded scatter dispatch (tokens over
  capacity are dropped).  Retained for ``parallelism="ep"``, whose
  all-to-all dispatch/combine are expressed over the fixed-shape
  ``(E, C, d)`` buffers; the dropless port of the ep collectives is an open
  item (see DESIGN.md §MoE dispatch).

Parallelism modes:
* ``tp`` (default): expert FFN hidden dim sharded over the model axis; the
  dispatch buffers shard over data via the token dim.  Works for any expert
  count (40 experts on a 16-way axis included).
* ``ep``: experts sharded over the model axis; expert count is padded up to
  a multiple of the axis with *dead* experts that the router masks to zero
  probability (semantics preserved exactly).  Dispatch/combine become
  all-to-alls on the model axis.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..dist.sharding import constrain
from ..kernels import ops
from .common import ArrayDef

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff: int
    n_experts: int
    top_k: int
    capacity_factor: float = 1.0
    dispatch: str = "dropless"       # "dropless" | "capacity"
    parallelism: str = "tp"          # "tp" | "ep"
    ep_axis_size: int = 16           # pad target for ep mode

    @property
    def padded_experts(self) -> int:
        if self.parallelism != "ep":
            return self.n_experts
        m = self.ep_axis_size
        return ((self.n_experts + m - 1) // m) * m

    @property
    def effective_dispatch(self) -> str:
        # ep's all-to-alls are written over fixed-shape capacity buffers;
        # until the ragged all-to-all is ported, ep implies capacity.
        if self.parallelism == "ep":
            return "capacity"
        return self.dispatch


def moe_defs(cfg: MoEConfig):
    E = cfg.padded_experts
    expert_axis = "experts" if cfg.parallelism == "ep" else None
    mlp_axis = None if cfg.parallelism == "ep" else "mlp"
    return {
        "router": ArrayDef((cfg.d_model, E), ("embed", None), dtype=F32),
        "w_gate": ArrayDef((E, cfg.d_model, cfg.d_ff),
                           (expert_axis, "embed", mlp_axis)),
        "w_up": ArrayDef((E, cfg.d_model, cfg.d_ff),
                         (expert_axis, "embed", mlp_axis)),
        "w_down": ArrayDef((E, cfg.d_ff, cfg.d_model),
                           (expert_axis, mlp_axis, "embed")),
    }


# ================================================================= routing
def route_tokens(router, x2d, cfg: MoEConfig) -> Tuple[jax.Array, jax.Array]:
    """Per-token top-k routing: (T, d) -> (gates (T, k) f32, experts (T, k)).

    This is THE routing function — prefill, chunked prefill and decode all
    call it on their flattened token sets.  It looks at one token at a time
    (softmax over experts, top-k, renormalize), so the token->expert
    assignment is bitwise-identical no matter how the token stream is
    chunked into batches.
    """
    E = cfg.padded_experts
    logits = jnp.einsum("td,de->te", x2d.astype(F32), router)
    if E != cfg.n_experts:  # mask dead padding experts (ep mode)
        pad_mask = jnp.arange(E) >= cfg.n_experts
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(probs, cfg.top_k)            # (T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, experts.astype(jnp.int32)


# ========================================================= dropless dispatch
def _moe_dropless(p, x, cfg: MoEConfig):
    """Sorted ragged dispatch: no capacity, no drops.

    argsort tokens by expert id -> contiguous per-expert segments -> grouped
    SwiGLU GEMM over the ragged segments -> gate-weighted scatter-add back
    to token order.  The argsort is stable, so within an expert's segment
    tokens keep stream order and each token's k contributions combine in
    ascending-expert order — both independent of batch chunking.
    """
    B, S, d = x.shape
    E = cfg.padded_experts
    k = cfg.top_k
    T = B * S

    xt = x.reshape(T, d)
    gates, experts = route_tokens(p["router"], xt, cfg)         # (T, k)

    flat_e = experts.reshape(T * k)
    order = jnp.argsort(flat_e, stable=True)                    # (T*k,)
    tok_idx = order // k                # source token of each sorted row
    xs = jnp.take(xt, tok_idx, axis=0)                          # (T*k, d)
    group_sizes = jnp.bincount(flat_e, length=E).astype(jnp.int32)

    ys = ops.moe_grouped_ffn(xs, p["w_gate"], p["w_up"], p["w_down"],
                             group_sizes)                       # (T*k, d)

    gs = gates.reshape(T * k)[order]                            # f32
    y = jnp.zeros((T, d), F32).at[tok_idx].add(ys.astype(F32) * gs[:, None])
    y = y.astype(x.dtype).reshape(B, S, d)
    return constrain(y, ("batch", "seq", "embed"))


# ========================================================= capacity dispatch
def _capacity(tokens: int, cfg: MoEConfig) -> int:
    """True per-row expert capacity: ceil(S*k/E * capacity_factor),
    floored at ``top_k``.

    The floor is the explicit, documented minimum (a row can always place
    one full token's worth of picks) that replaces the old magic
    ``max(8, ...)``, which silently overrode ``capacity_factor`` at small
    S.  Above the floor, ``capacity_factor`` is honored exactly; buffer
    padding is layout-only (see ``_padded_capacity``)."""
    assert cfg.capacity_factor > 0, cfg.capacity_factor
    cap = int(np.ceil(tokens * cfg.top_k / cfg.padded_experts
                      * cfg.capacity_factor))
    return max(cap, cfg.top_k)


def _padded_capacity(cap: int) -> int:
    """Buffer-layout padding: round the slot dim up to a multiple of 8
    (TPU sublane alignment).  Padding slots are *dead* — the drop decision
    (``slot < cap``) uses the true capacity, so padding never silently
    admits tokens beyond what ``capacity_factor`` allows."""
    return -(-cap // 8) * 8


def _moe_capacity(p, x, cfg: MoEConfig):
    """GShard-style capacity-bounded dispatch; dropped tokens pass through
    (residual).

    Dispatch is *per batch row* (GShard's per-group capacity): slot
    assignment (cumsum), scatter and gather all happen within a row, so on a
    batch-sharded mesh every dispatch structure stays shard-local — no
    collective is needed beyond the expert matmuls' own sharding.  (A global
    dispatch here costs TiBs of all-reduce per step; see EXPERIMENTS.md
    §Perf climb #2.)
    """
    B, S, d = x.shape
    E = cfg.padded_experts
    cap = _capacity(S, cfg)                                     # per row
    C = _padded_capacity(cap)                                   # buffer slots
    Tk = S * cfg.top_k

    gate_vals, expert_ids = route_tokens(
        p["router"], x.reshape(B * S, d), cfg)
    gate_vals = gate_vals.reshape(B, S, cfg.top_k)
    expert_ids = expert_ids.reshape(B, S, cfg.top_k)

    # Slot assignment within each row: running count of earlier picks of the
    # same expert.  int16 is enough (C < 32768 at these shapes) and halves
    # the cumsum buffer.
    flat_e = expert_ids.reshape(B, Tk)                           # (B, Tk)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int16)          # (B, Tk, E)
    pos_in_e = jnp.cumsum(onehot, axis=1) - onehot
    slot = jnp.take_along_axis(
        pos_in_e, flat_e[..., None].astype(jnp.int32), axis=2)[..., 0]
    slot = slot.astype(jnp.int32)
    in_cap = slot < cap                # drop rule: true capacity, not padded

    # Scatter tokens into per-row (E, C, d) buffers.  vmap over rows keeps
    # the batch dim a *batching* dim of the scatter (GSPMD partitions it);
    # indexing it with an arange would make it a scattered dim and force the
    # partitioner to replicate + all-reduce the whole buffer.
    xk = jnp.repeat(x, cfg.top_k, axis=1)                        # (B, Tk, d)
    upd = jnp.where(in_cap[..., None], xk, 0).astype(x.dtype)
    safe_slot = jnp.where(in_cap, slot, C - 1)

    def row_scatter(e_row, s_row, u_row):
        return jnp.zeros((E, C, d), x.dtype).at[e_row, s_row].add(u_row)

    buf = jax.vmap(row_scatter)(flat_e, safe_slot, upd)
    buf = constrain(buf, ("batch", "experts", None, "embed"))

    # Expert FFN (SwiGLU), batched over (row, expert).
    g = jnp.einsum("becd,edf->becf", buf, p["w_gate"])
    u = jnp.einsum("becd,edf->becf", buf, p["w_up"])
    h = jax.nn.silu(g.astype(F32)).astype(x.dtype) * u
    h = constrain(h, ("batch", "experts", None, "mlp"))
    out_buf = jnp.einsum("becf,efd->becd", h, p["w_down"])
    # NOTE: no sharding constraint here — out_buf is a partial sum over the
    # model-sharded ffn dim, and gather/combine are linear, so the psum can
    # ride through to the (B,S,d) output: 12.5x fewer all-reduce bytes than
    # reducing the capacity-inflated buffer (§Perf climb #2, change 3).

    # Gather back and combine with gates (vmapped for the same reason).
    def row_gather(o_row, e_row, s_row):
        return o_row[e_row, s_row]

    gathered = jax.vmap(row_gather)(
        out_buf, flat_e, jnp.where(in_cap, slot, 0))              # (B,Tk,d)
    gathered = jnp.where(in_cap[..., None], gathered, 0)
    gathered = gathered.reshape(B, S, cfg.top_k, d)
    y = jnp.einsum("bskd,bsk->bsd", gathered.astype(F32),
                   gate_vals).astype(x.dtype)
    return constrain(y, ("batch", "seq", "embed"))


# ================================================================== facade
def moe(p, x, cfg: MoEConfig, dispatch: Optional[str] = None):
    """x: (B, S, d) -> (B, S, d).

    ``dispatch`` overrides ``cfg.effective_dispatch`` (tests / benchmarks);
    production callers leave it None and get dropless unless the config pins
    the capacity path (ep mode).
    """
    mode = dispatch if dispatch is not None else cfg.effective_dispatch
    if mode == "dropless":
        return _moe_dropless(p, x, cfg)
    assert mode == "capacity", mode
    return _moe_capacity(p, x, cfg)


def moe_decode(p, x, cfg: MoEConfig, dispatch: Optional[str] = None):
    """Decode-time MoE: x is (B, 1, d), one new token per sequence.

    Not a separate code path: decode flows through ``moe`` and therefore
    ``route_tokens`` + the same grouped GEMM as prefill, which is the
    guarantee that ring-decode logits match prefill logits (the two compute
    the same mathematical function of each token's hidden state, and the
    assignment is bitwise-identical regardless of chunking)."""
    return moe(p, x, cfg, dispatch=dispatch)
