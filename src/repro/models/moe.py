"""Mixture-of-Experts layer (Mixtral / granite-MoE style).

Top-k routing with capacity-bounded scatter dispatch (tokens over capacity
are dropped, GShard-style) — no (B,S,E,C) one-hot tensors, so the dispatch
buffers stay O(E*C*d).

Parallelism modes:
* ``tp`` (default): expert FFN hidden dim sharded over the model axis; the
  dispatch buffers shard over data via the token dim.  Works for any expert
  count (40 experts on a 16-way axis included).
* ``ep``: experts sharded over the model axis; expert count is padded up to
  a multiple of the axis with *dead* experts that the router masks to zero
  probability (semantics preserved exactly).  Dispatch/combine become
  all-to-alls on the model axis.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..dist.sharding import constrain
from .common import ArrayDef

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff: int
    n_experts: int
    top_k: int
    capacity_factor: float = 1.0
    parallelism: str = "tp"          # "tp" | "ep"
    ep_axis_size: int = 16           # pad target for ep mode

    @property
    def padded_experts(self) -> int:
        if self.parallelism != "ep":
            return self.n_experts
        m = self.ep_axis_size
        return ((self.n_experts + m - 1) // m) * m


def moe_defs(cfg: MoEConfig):
    E = cfg.padded_experts
    expert_axis = "experts" if cfg.parallelism == "ep" else None
    mlp_axis = None if cfg.parallelism == "ep" else "mlp"
    return {
        "router": ArrayDef((cfg.d_model, E), ("embed", None), dtype=F32),
        "w_gate": ArrayDef((E, cfg.d_model, cfg.d_ff),
                           (expert_axis, "embed", mlp_axis)),
        "w_up": ArrayDef((E, cfg.d_model, cfg.d_ff),
                         (expert_axis, "embed", mlp_axis)),
        "w_down": ArrayDef((E, cfg.d_ff, cfg.d_model),
                           (expert_axis, mlp_axis, "embed")),
    }


def _capacity(tokens: int, cfg: MoEConfig) -> int:
    cap = int(np.ceil(tokens * cfg.top_k / cfg.padded_experts
                      * cfg.capacity_factor))
    return max(8, -(-cap // 8) * 8)  # pad to a multiple of 8


def moe(p, x, cfg: MoEConfig):
    """x: (B, S, d) -> (B, S, d).  Dropped tokens pass through (residual).

    Dispatch is *per batch row* (GShard's per-group capacity): slot
    assignment (cumsum), scatter and gather all happen within a row, so on a
    batch-sharded mesh every dispatch structure stays shard-local — no
    collective is needed beyond the expert matmuls' own sharding.  (A global
    dispatch here costs TiBs of all-reduce per step; see EXPERIMENTS.md
    §Perf climb #2.)
    """
    B, S, d = x.shape
    E = cfg.padded_experts
    C = _capacity(S, cfg)                                       # per row
    Tk = S * cfg.top_k

    logits = jnp.einsum("bsd,de->bse", x.astype(F32), p["router"])
    if E != cfg.n_experts:  # mask dead padding experts
        pad_mask = jnp.arange(E) >= cfg.n_experts
        logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, cfg.top_k)     # (B, S, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)                  # renormalize

    # Slot assignment within each row: running count of earlier picks of the
    # same expert.  int16 is enough (C < 32768 at these shapes) and halves
    # the cumsum buffer.
    flat_e = expert_ids.reshape(B, Tk)                           # (B, Tk)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int16)          # (B, Tk, E)
    pos_in_e = jnp.cumsum(onehot, axis=1) - onehot
    slot = jnp.take_along_axis(
        pos_in_e, flat_e[..., None].astype(jnp.int32), axis=2)[..., 0]
    slot = slot.astype(jnp.int32)
    in_cap = slot < C

    # Scatter tokens into per-row (E, C, d) buffers.  vmap over rows keeps
    # the batch dim a *batching* dim of the scatter (GSPMD partitions it);
    # indexing it with an arange would make it a scattered dim and force the
    # partitioner to replicate + all-reduce the whole buffer.
    xk = jnp.repeat(x, cfg.top_k, axis=1)                        # (B, Tk, d)
    upd = jnp.where(in_cap[..., None], xk, 0).astype(x.dtype)
    safe_slot = jnp.where(in_cap, slot, C - 1)

    def row_scatter(e_row, s_row, u_row):
        return jnp.zeros((E, C, d), x.dtype).at[e_row, s_row].add(u_row)

    buf = jax.vmap(row_scatter)(flat_e, safe_slot, upd)
    buf = constrain(buf, ("batch", "experts", None, "embed"))

    # Expert FFN (SwiGLU), batched over (row, expert).
    g = jnp.einsum("becd,edf->becf", buf, p["w_gate"])
    u = jnp.einsum("becd,edf->becf", buf, p["w_up"])
    h = jax.nn.silu(g.astype(F32)).astype(x.dtype) * u
    h = constrain(h, ("batch", "experts", None, "mlp"))
    out_buf = jnp.einsum("becf,efd->becd", h, p["w_down"])
    # NOTE: no sharding constraint here — out_buf is a partial sum over the
    # model-sharded ffn dim, and gather/combine are linear, so the psum can
    # ride through to the (B,S,d) output: 12.5x fewer all-reduce bytes than
    # reducing the capacity-inflated buffer (§Perf climb #2, change 3).

    # Gather back and combine with gates (vmapped for the same reason).
    def row_gather(o_row, e_row, s_row):
        return o_row[e_row, s_row]

    gathered = jax.vmap(row_gather)(
        out_buf, flat_e, jnp.where(in_cap, slot, 0))              # (B,Tk,d)
    gathered = jnp.where(in_cap[..., None], gathered, 0)
    gathered = gathered.reshape(B, S, cfg.top_k, d)
    y = jnp.einsum("bskd,bsk->bsd", gathered.astype(F32),
                   gate_vals).astype(x.dtype)
    return constrain(y, ("batch", "seq", "embed"))


def moe_decode(p, x, cfg: MoEConfig):
    """Decode-time MoE for a single token per sequence: dense top-k gather of
    expert weights would be ragged; with one token the capacity path is
    overkill, so route through the same code with T=B tokens."""
    return moe(p, x, cfg)
