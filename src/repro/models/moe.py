"""Mixture-of-Experts layer (Mixtral / granite-MoE style).

Two dispatch modes (``MoEConfig.dispatch``):

* ``dropless`` (default, both parallelism modes): sorted ragged routing.
  Tokens are argsorted by expert id into contiguous per-expert segments and
  the expert SwiGLU runs as a grouped GEMM over the ragged segments
  (``kernels/moe_gemm.py`` on TPU, a masked-einsum oracle elsewhere).  No
  token is ever dropped, so the layer computes the *same function* for
  batched prefill, chunked prefill and single-token decode — routing is
  per-token and chunking-invariant.  The argsort is *per batch row* (one
  ragged segment per (row, expert) pair), so on a batch-sharded mesh every
  dispatch structure stays shard-local — no cross-data-shard gather.

* ``capacity``: GShard-style capacity-bounded scatter dispatch (tokens over
  capacity are dropped).  Retained as an explicit opt-in for comparison
  benchmarks; nothing pins it anymore.

Parallelism modes:
* ``tp`` (default): expert FFN hidden dim sharded over the model axis; the
  dispatch buffers shard over data via the token dim.  Works for any expert
  count (40 experts on a 16-way axis included).
* ``ep``: experts sharded over the model axis; expert count is padded up to
  a multiple of the axis with *dead* experts that the router masks to zero
  probability (semantics preserved exactly).  Under an active multi-device
  mesh, dropless ep dispatch/combine run as ragged (dropless) all-to-alls
  inside an explicit shard_map: per-shard ``group_sizes`` metadata is
  exchanged with one tiny all-gather, row payloads move over
  ``ring_ragged_all_to_all`` (ppermute hops), and each shard runs the
  grouped GEMM over its local (source shard x local expert) ragged
  segments.  Routing flows through the same ``route_tokens`` as every
  other path, so ep prefill, chunked prefill and decode agree exactly.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..dist.collectives import ring_ragged_all_to_all, shard_map_compat
from ..dist.sharding import active_mesh, batch_data_axes, constrain
from ..kernels import ops
from .common import ArrayDef

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff: int
    n_experts: int
    top_k: int
    capacity_factor: float = 1.0
    dispatch: str = "dropless"       # "dropless" | "capacity"
    parallelism: str = "tp"          # "tp" | "ep"
    ep_axis_size: int = 16           # ep pad target; validated vs the mesh

    @property
    def padded_experts(self) -> int:
        if self.parallelism != "ep":
            return self.n_experts
        m = self.ep_axis_size
        return ((self.n_experts + m - 1) // m) * m

    def validate_ep_axis(self, axis_size: int) -> None:
        """``ep_axis_size`` is a config constant decoupled from the mesh it
        eventually runs on (it fixes parameter shapes at init time), so call
        sites that see the real mesh must check the two agree: the model
        axis has to divide the padded expert count evenly or some shards
        would own a different number of experts than others."""
        if self.parallelism != "ep":
            return
        if axis_size <= 0 or self.padded_experts % axis_size != 0:
            raise ValueError(
                f"ep mesh mismatch: padded_experts={self.padded_experts} "
                f"(n_experts={self.n_experts} padded to ep_axis_size="
                f"{self.ep_axis_size}) does not divide evenly over a "
                f"{axis_size}-way model axis; set ep_axis_size to a "
                f"multiple of the mesh's model-axis size")


def moe_defs(cfg: MoEConfig):
    E = cfg.padded_experts
    expert_axis = "experts" if cfg.parallelism == "ep" else None
    mlp_axis = None if cfg.parallelism == "ep" else "mlp"
    return {
        "router": ArrayDef((cfg.d_model, E), ("embed", None), dtype=F32),
        "w_gate": ArrayDef((E, cfg.d_model, cfg.d_ff),
                           (expert_axis, "embed", mlp_axis)),
        "w_up": ArrayDef((E, cfg.d_model, cfg.d_ff),
                         (expert_axis, "embed", mlp_axis)),
        "w_down": ArrayDef((E, cfg.d_ff, cfg.d_model),
                           (expert_axis, mlp_axis, "embed")),
    }


# ================================================================= routing
def route_tokens(router, x2d, cfg: MoEConfig) -> Tuple[jax.Array, jax.Array]:
    """Per-token top-k routing: (T, d) -> (gates (T, k) f32, experts (T, k)).

    This is THE routing function — prefill, chunked prefill and decode all
    call it on their flattened token sets (the ep shard_map path included).
    It looks at one token at a time (softmax over experts, top-k,
    renormalize), so the token->expert assignment is bitwise-identical no
    matter how the token stream is chunked into batches.
    """
    E = cfg.padded_experts
    logits = jnp.einsum("td,de->te", x2d.astype(F32), router)
    if E != cfg.n_experts:  # mask dead padding experts (ep mode)
        pad_mask = jnp.arange(E) >= cfg.n_experts
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    # Top-k as k iterative argmaxes — selection and order identical to
    # jax.lax.top_k (descending value, ties to the lowest index), but it
    # lowers to plain reduces the SPMD partitioner keeps shard-local,
    # where the TopK custom-call all-gathers the (T, E) probs on a
    # token-sharded mesh.  k and E are small; the passes are noise next
    # to the expert FFN.
    remaining = probs
    gate_cols, expert_cols = [], []
    for _ in range(cfg.top_k):
        e = jnp.argmax(remaining, axis=-1)
        gate_cols.append(
            jnp.take_along_axis(remaining, e[:, None], axis=-1)[:, 0])
        expert_cols.append(e.astype(jnp.int32))
        remaining = jnp.where(
            jnp.arange(E)[None, :] == e[:, None], -jnp.inf, remaining)
    gates = jnp.stack(gate_cols, axis=-1)                       # (T, k)
    experts = jnp.stack(expert_cols, axis=-1)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, experts


# ========================================================= dropless dispatch
def _sort_picks_by_expert(experts, k: int):
    """Stable per-set argsort of the flattened (n*k,) expert picks.

    Returns (order, tok_idx): ``order`` permutes pick-rows into contiguous
    ascending-expert segments, ``tok_idx`` is each sorted row's source
    token.  Stability keeps stream order within an expert's segment and
    each token's k contributions combining in ascending-expert order —
    both independent of batch chunking."""
    order = jnp.argsort(experts, stable=True)
    return order, order // k


def _data_sharded() -> bool:
    """True when the ambient mesh splits the batch over data axes — the
    regime where per-row dispatch structures pay for themselves."""
    mesh = active_mesh()
    if mesh is None:
        return False
    return any(int(mesh.shape[a]) > 1 for a in batch_data_axes(mesh))


def apply_dropless_flat(gates, experts, x, w_gate, w_up, w_down,
                        cfg: MoEConfig, expert_slots=None):
    """Flat dropless dispatch AFTER routing: sort the (B*S*k,) picks into
    contiguous per-expert ragged segments, run the grouped SwiGLU GEMM,
    combine gate-weighted in ascending-expert order.

    Factored out of ``_moe_dropless`` so the serving engine's tiered
    expert path (``serve/expert_store.py``) executes the *exact same op
    sequence* as the resident path — the bitwise-equality bar for expert
    tiering rests on this sharing.  ``expert_slots`` is an (E,) int32 map
    from logical expert id to the weight row holding its block; it rides
    the grouped GEMM's existing ``group_experts`` remap, so the weight
    arrays may carry more (or differently ordered) rows than ``cfg`` has
    experts — a bounded HBM cache.  Expert FFNs are row-independent, so
    the result is bitwise-identical to the dense layout whenever the
    referenced rows hold the same bytes.  ``expert_slots=None`` preserves
    the classic dense layout (row i == expert i) unchanged.
    """
    B, S, d = x.shape
    E = cfg.padded_experts
    k = cfg.top_k
    Sk = S * k
    flat_e = experts.reshape(B * Sk)
    order, tok_idx = _sort_picks_by_expert(flat_e, k)
    xs = jnp.take(x.reshape(B * S, d), tok_idx, axis=0)         # (B*Sk, d)
    group_sizes = jnp.bincount(flat_e, length=E).astype(jnp.int32)
    slots = None if expert_slots is None else expert_slots.astype(jnp.int32)
    ys = ops.moe_grouped_ffn(xs, w_gate, w_up, w_down, group_sizes, slots)
    gs = gates.reshape(B * Sk)[order]                           # f32
    y = jnp.zeros((B * S, d), F32).at[tok_idx].add(
        ys.astype(F32) * gs[:, None])
    return constrain(y.astype(x.dtype).reshape(B, S, d),
                     ("batch", "seq", "embed"))


def _moe_dropless(p, x, cfg: MoEConfig, per_row: Optional[bool] = None):
    """Sorted ragged dispatch: no capacity, no drops.

    Two segment layouts computing the identical per-token function (expert
    FFNs are row-independent and each token's k contributions combine in
    ascending-expert order under both):

    * **per-row** (picked when a data-sharded mesh is active): each batch
      row argsorts its own S*k picks, giving one contiguous ragged segment
      per (row, expert) pair; the grouped SwiGLU GEMM runs over all B*E
      segments at once (``group_experts`` maps segment -> expert weights)
      and a vmapped gate-weighted scatter-add restores token order.
      Keeping the sort, bincount and scatter *inside* the row makes the
      batch dim a pure batching dim for GSPMD — every dispatch structure
      stays shard-local, where a flat B*S*k sort gathers the whole token
      stream across data shards (prefill_32k dry-run collective bytes).

    * **flat** (meshless / undivided batch): one stable argsort over the
      flat B*S*k picks into E per-expert segments.  Same math, but the
      grouped GEMM's static logical-tile grid is row_tiles + E - 1 instead
      of row_tiles + B*E - 1 — decode at B=64, E=48 would otherwise pay
      ~60x the grid steps for shard-locality no single device needs.
    """
    B, S, d = x.shape
    E = cfg.padded_experts
    k = cfg.top_k
    Sk = S * k

    gates, experts = route_tokens(p["router"], x.reshape(B * S, d), cfg)
    if per_row is None:
        per_row = _data_sharded()

    if not per_row:
        return apply_dropless_flat(gates, experts, x, p["w_gate"],
                                   p["w_up"], p["w_down"], cfg)

    experts_r = experts.reshape(B, Sk)
    gates_r = gates.reshape(B, Sk)

    order, tok_in_row = jax.vmap(
        lambda e: _sort_picks_by_expert(e, k))(experts_r)       # (B, Sk)
    xs = jnp.take_along_axis(x, tok_in_row[..., None], axis=1)  # (B, Sk, d)
    group_sizes = jax.vmap(
        lambda e: jnp.bincount(e, length=E))(experts_r)         # (B, E)

    ys = ops.moe_grouped_ffn(
        xs.reshape(B * Sk, d), p["w_gate"], p["w_up"], p["w_down"],
        group_sizes.reshape(B * E).astype(jnp.int32),
        jnp.tile(jnp.arange(E, dtype=jnp.int32), B))            # (B*Sk, d)

    gs = jnp.take_along_axis(gates_r, order, axis=1)            # f32

    def row_combine(ys_row, tok_row, g_row):
        return jnp.zeros((S, d), F32).at[tok_row].add(
            ys_row.astype(F32) * g_row[:, None])

    y = jax.vmap(row_combine)(ys.reshape(B, Sk, d), tok_in_row, gs)
    y = y.astype(x.dtype)
    return constrain(y, ("batch", "seq", "embed"))


# ==================================================== ragged ep dispatch
def _ep_mesh(cfg: MoEConfig):
    """The active mesh when the explicit ragged-ep shard_map path applies
    (ep parallelism on a real multi-device model axis), else None."""
    if cfg.parallelism != "ep":
        return None
    mesh = active_mesh()
    if mesh is None or "model" not in mesh.shape:
        return None
    if int(mesh.shape["model"]) <= 1:
        return None
    return mesh


def _moe_dropless_ep(p, x, cfg: MoEConfig, mesh):
    """Ragged (dropless) expert-parallel dispatch: all-to-alls carry exactly
    the routed rows, no capacity buffers, no drops.

    Inside one shard_map over the mesh (batch over the data axes, experts
    over ``model``), each model shard:

      1. takes its static slice of the local token stream and routes it
         through ``route_tokens`` (identical assignment to every other
         path; slice-padding rows get gate 0 and contribute nothing),
      2. argsorts its picks by global expert id — segments are contiguous
         per destination shard because each shard owns a contiguous expert
         range — and bincounts per-expert ``group_sizes``,
      3. exchanges the (E,) count vectors with one tiny all-gather (the
         metadata exchange), from which both sides of every ragged
         transfer size are known,
      4. moves row payloads with ``ring_ragged_all_to_all`` (ppermute
         hops), runs the grouped GEMM over its local (source shard x local
         expert) ragged segments via ``group_experts``, and sends results
         back over the reverse ragged all-to-all (same function, sizes
         swapped),
      5. combines with gates in ascending-expert order per token (the same
         order as the tp path) and all-gathers the token slices.

    Per-token math is identical to ``_moe_dropless``: expert FFNs are
    row-independent and combine order is fixed, so ep prefill, chunked
    prefill and decode agree with each other and with tp-dropless.
    """
    B, S, d = x.shape
    E = cfg.padded_experts
    k = cfg.top_k
    m = int(mesh.shape["model"])
    cfg.validate_ep_axis(m)
    E_loc = E // m

    # Batch shards over the data axes when it divides evenly (the shared
    # shed-until-divisible rule); everything else is replicated in.
    dp = batch_data_axes(mesh, B)
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    B_loc = B // dp_size
    batch_entry = tuple(dp) if len(dp) > 1 else (dp[0] if dp else None)
    x_spec = P(batch_entry, None, None)

    T = B_loc * S                 # tokens per data shard
    Tm = -(-T // m)               # static per-model-shard token slice
    Rm = Tm * k                   # ragged-a2a chunk capacity: one slice's picks

    def body(xb, router, wg, wu, wd):
        e_idx = jax.lax.axis_index("model")
        xt = jnp.pad(xb.reshape(T, d), ((0, m * Tm - T), (0, 0)))
        my = jax.lax.dynamic_slice(xt, (e_idx * Tm, 0), (Tm, d))
        live = (e_idx * Tm + jnp.arange(Tm)) < T      # slice-padding rows
        gates, experts = route_tokens(router, my, cfg)          # (Tm, k)
        gates = gates * live[:, None].astype(gates.dtype)

        flat_e = experts.reshape(Rm)
        order, tok_idx = _sort_picks_by_expert(flat_e, k)
        xs = jnp.take(my, tok_idx, axis=0)                      # (Rm, d)
        counts = jnp.bincount(flat_e, length=E).astype(jnp.int32)
        send_sizes = counts.reshape(m, E_loc).sum(axis=1)       # (m,)

        # Metadata exchange: every shard learns every peer's per-expert
        # counts, so both directions of the ragged transfers are sized
        # locally — no per-row size handshake.
        all_counts = jax.lax.all_gather(counts, "model", axis=0)  # (m, E)
        my_counts = jax.lax.dynamic_slice(
            all_counts, (0, e_idx * E_loc), (m, E_loc))
        recv_sizes = my_counts.sum(axis=1)                      # (m,)

        recv = ring_ragged_all_to_all(
            xs, send_sizes, recv_sizes, "model",
            chunk_rows=Rm, out_rows=m * Rm)                     # (m*Rm, d)

        # Shard-local grouped GEMM over (source shard, local expert) ragged
        # segments; group_experts folds the m-fold segment layout onto the
        # shard's E_loc expert weights.
        ys = ops.moe_grouped_ffn(
            recv, wg, wu, wd, my_counts.reshape(m * E_loc),
            jnp.tile(jnp.arange(E_loc, dtype=jnp.int32), m))

        # Combine leg: the receive layout (grouped by source) is exactly
        # the send layout of the reverse transfer, so rows come back in
        # the order this shard sent them (ascending expert id).
        back = ring_ragged_all_to_all(
            ys, recv_sizes, send_sizes, "model",
            chunk_rows=Rm, out_rows=Rm)                         # (Rm, d)

        gs = gates.reshape(Rm)[order]
        y_my = jnp.zeros((Tm, d), F32).at[tok_idx].add(
            back.astype(F32) * gs[:, None])
        y = jax.lax.all_gather(y_my, "model", axis=0, tiled=True)
        return y[:T].reshape(B_loc, S, d).astype(x.dtype)

    out = shard_map_compat(
        body, mesh,
        in_specs=(x_spec, P(None, None), P("model", None, None),
                  P("model", None, None), P("model", None, None)),
        out_specs=x_spec,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    return constrain(out, ("batch", "seq", "embed"))


# ========================================================= capacity dispatch
def _capacity(tokens: int, cfg: MoEConfig) -> int:
    """True per-row expert capacity: ceil(S*k/E_live * capacity_factor),
    floored at ``top_k``.

    The divisor is the *live* expert count: ep padding experts are masked
    to zero routing probability, so budgeting capacity over
    ``padded_experts`` silently shrank every live expert's slots to
    ~n_experts/padded_experts of what ``capacity_factor`` promises (40
    experts padded to 48 lost 17%).  The floor is the explicit, documented
    minimum (a row can always place one full token's worth of picks) that
    replaces the old magic ``max(8, ...)``; above it ``capacity_factor``
    is honored exactly, and buffer padding is layout-only (see
    ``_padded_capacity``)."""
    assert cfg.capacity_factor > 0, cfg.capacity_factor
    cap = int(np.ceil(tokens * cfg.top_k / cfg.n_experts
                      * cfg.capacity_factor))
    return max(cap, cfg.top_k)


def _padded_capacity(cap: int) -> int:
    """Buffer-layout padding: round the slot dim up to a multiple of 8
    (TPU sublane alignment).  Padding slots are *dead* — the drop decision
    (``slot < cap``) uses the true capacity, so padding never silently
    admits tokens beyond what ``capacity_factor`` allows."""
    return -(-cap // 8) * 8


def _moe_capacity(p, x, cfg: MoEConfig):
    """GShard-style capacity-bounded dispatch; dropped tokens pass through
    (residual).

    Dispatch is *per batch row* (GShard's per-group capacity): slot
    assignment (cumsum), scatter and gather all happen within a row, so on a
    batch-sharded mesh every dispatch structure stays shard-local — no
    collective is needed beyond the expert matmuls' own sharding.  (A global
    dispatch here costs TiBs of all-reduce per step; see EXPERIMENTS.md
    §Perf climb #2.)
    """
    B, S, d = x.shape
    E = cfg.padded_experts
    cap = _capacity(S, cfg)                                     # per row
    C = _padded_capacity(cap)                                   # buffer slots
    Tk = S * cfg.top_k

    gate_vals, expert_ids = route_tokens(
        p["router"], x.reshape(B * S, d), cfg)
    gate_vals = gate_vals.reshape(B, S, cfg.top_k)
    expert_ids = expert_ids.reshape(B, S, cfg.top_k)

    # Slot assignment within each row: running count of earlier picks of the
    # same expert.  int16 is enough (C < 32768 at these shapes) and halves
    # the cumsum buffer.
    flat_e = expert_ids.reshape(B, Tk)                           # (B, Tk)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int16)          # (B, Tk, E)
    pos_in_e = jnp.cumsum(onehot, axis=1) - onehot
    slot = jnp.take_along_axis(
        pos_in_e, flat_e[..., None].astype(jnp.int32), axis=2)[..., 0]
    slot = slot.astype(jnp.int32)
    in_cap = slot < cap                # drop rule: true capacity, not padded

    # Scatter tokens into per-row (E, C, d) buffers.  vmap over rows keeps
    # the batch dim a *batching* dim of the scatter (GSPMD partitions it);
    # indexing it with an arange would make it a scattered dim and force the
    # partitioner to replicate + all-reduce the whole buffer.
    xk = jnp.repeat(x, cfg.top_k, axis=1)                        # (B, Tk, d)
    upd = jnp.where(in_cap[..., None], xk, 0).astype(x.dtype)
    safe_slot = jnp.where(in_cap, slot, C - 1)

    def row_scatter(e_row, s_row, u_row):
        return jnp.zeros((E, C, d), x.dtype).at[e_row, s_row].add(u_row)

    buf = jax.vmap(row_scatter)(flat_e, safe_slot, upd)
    buf = constrain(buf, ("batch", "experts", None, "embed"))

    # Expert FFN (SwiGLU), batched over (row, expert).
    g = jnp.einsum("becd,edf->becf", buf, p["w_gate"])
    u = jnp.einsum("becd,edf->becf", buf, p["w_up"])
    h = jax.nn.silu(g.astype(F32)).astype(x.dtype) * u
    h = constrain(h, ("batch", "experts", None, "mlp"))
    out_buf = jnp.einsum("becf,efd->becd", h, p["w_down"])
    # NOTE: no sharding constraint here — out_buf is a partial sum over the
    # model-sharded ffn dim, and gather/combine are linear, so the psum can
    # ride through to the (B,S,d) output: 12.5x fewer all-reduce bytes than
    # reducing the capacity-inflated buffer (§Perf climb #2, change 3).

    # Gather back and combine with gates (vmapped for the same reason).
    def row_gather(o_row, e_row, s_row):
        return o_row[e_row, s_row]

    gathered = jax.vmap(row_gather)(
        out_buf, flat_e, jnp.where(in_cap, slot, 0))              # (B,Tk,d)
    gathered = jnp.where(in_cap[..., None], gathered, 0)
    gathered = gathered.reshape(B, S, cfg.top_k, d)
    y = jnp.einsum("bskd,bsk->bsd", gathered.astype(F32),
                   gate_vals).astype(x.dtype)
    return constrain(y, ("batch", "seq", "embed"))


# ================================================================== facade
def moe(p, x, cfg: MoEConfig, dispatch: Optional[str] = None):
    """x: (B, S, d) -> (B, S, d).

    ``dispatch`` overrides ``cfg.dispatch`` (tests / benchmarks);
    production callers leave it None and get dropless.  ep parallelism on
    an active multi-device mesh takes the ragged all-to-all shard_map
    path; without one (single device, CPU smoke tests) the flat dropless
    layout already computes the identical padded-expert function (dead
    experts receive no rows), so the two agree exactly.
    """
    mode = dispatch if dispatch is not None else cfg.dispatch
    if mode == "dropless":
        mesh = _ep_mesh(cfg)
        if mesh is not None:
            return _moe_dropless_ep(p, x, cfg, mesh)
        return _moe_dropless(p, x, cfg)
    assert mode == "capacity", mode
    return _moe_capacity(p, x, cfg)


def moe_decode(p, x, cfg: MoEConfig, dispatch: Optional[str] = None):
    """Decode-time MoE: x is (B, 1, d), one new token per sequence.

    Not a separate code path: decode flows through ``moe`` and therefore
    ``route_tokens`` + the same grouped GEMM as prefill, which is the
    guarantee that ring-decode logits match prefill logits (the two compute
    the same mathematical function of each token's hidden state, and the
    assignment is bitwise-identical regardless of chunking)."""
    return moe(p, x, cfg, dispatch=dispatch)
