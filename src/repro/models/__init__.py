from .config import ModelConfig, SHAPES, SMOKE_SHAPES, ShapeConfig
from .transformer import Model, build_model

__all__ = ["Model", "ModelConfig", "SHAPES", "SMOKE_SHAPES", "ShapeConfig",
           "build_model"]
