"""Paged attention decode — Pallas TPU kernel.

The serving hot spot for guided KV tiering: one new query token per sequence
attends over KV pages scattered through the HBM pool according to a page
table.  Grid = (B, MP): the page dimension is innermost, so the per-sequence
online-softmax state (m, l, acc) lives in VMEM scratch and the output is
finalized on the last page.

The page table drives *block-index gathering*: each grid step's k/v
BlockSpec index map reads the physical pool slot for (sequence b, logical
page p) from a scalar-prefetch operand — pages never move, the kernel's
tiles jump through the pool.  Invalid / out-of-range pages contribute
nothing (masked by length).

TPU notes: pool pages are (P, K*dh) VMEM tiles (P aligned to 8 sublanes,
K*dh padded to 128 lanes by the wrapper); the query block is (H, dh).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG_INF = -1e30


def _kernel(table_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
            m_scr, l_scr, acc_scr, *,
            page_size: int, kv_heads: int, q_heads: int, dh: int,
            window: Optional[int]):
    b = pl.program_id(0)
    p = pl.program_id(1)
    np_ = pl.num_programs(1)

    @pl.when(p == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    G = q_heads // kv_heads
    length = len_ref[b]
    slot = table_ref[b, p]
    valid_page = (slot >= 0) & (p * page_size < length)

    @pl.when(valid_page)
    def _attend():
        q = q_ref[0][:, :dh].astype(F32)               # (H, dh), un-padded
        k = k_ref[0].astype(F32)                       # (P, K*dh) padded
        v = v_ref[0].astype(F32)
        k = k[:, : kv_heads * dh].reshape(page_size, kv_heads, dh)
        v = v[:, : kv_heads * dh].reshape(page_size, kv_heads, dh)
        qg = q.reshape(kv_heads, G, dh)
        s = jnp.einsum("kgd,pkd->kgp", qg, k,
                       preferred_element_type=F32)     # (K,G,P)
        s = s * (1.0 / np.sqrt(dh))
        pos = p * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (kv_heads, G, page_size), 2)
        ok = pos < length
        if window is not None:
            ok &= (length - 1 - pos) < window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_scr[...]                            # (K, G)
        m_cur = jnp.max(s, axis=2)
        m_new = jnp.maximum(m_prev, m_cur)
        pexp = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(pexp, axis=2)
        acc_scr[...] = (acc_scr[...] * alpha[..., None]
                        + jnp.einsum("kgp,pkd->kgd", pexp, v,
                                     preferred_element_type=F32))
        m_scr[...] = m_new

    @pl.when(p == np_ - 1)
    def _finalize():
        l = l_scr[...]
        safe = jnp.where(l > 0, l, 1.0)
        out = (acc_scr[...] / safe[..., None]).reshape(q_heads, dh)
        pad = o_ref.shape[-1] - dh
        if pad:
            out = jnp.pad(out, ((0, 0), (0, pad)))
        o_ref[0] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "interpret"))
def paged_prefill_pallas(q, k_pool, v_pool, page_table, lengths,
                         window: Optional[int] = None,
                         interpret: bool = False):
    """One-shot prompt attention: S query rows of one sequence over a
    shared page table, causality via per-row ``lengths``.  Reuses the
    decode kernel with the table broadcast across rows — grid (S, MP) —
    so the accumulation order per row is identical to decode's and the
    result is bitwise-equal to chunked per-token ingestion.

    q: (S,H,dh); page_table: (MP,) int32; lengths: (S,) int32."""
    S = q.shape[0]
    table = jnp.broadcast_to(page_table[None, :], (S, page_table.shape[0]))
    return paged_attention_pallas(q, k_pool, v_pool, table, lengths,
                                  window=window, interpret=interpret)


@functools.partial(
    jax.jit, static_argnames=("window", "interpret"))
def paged_attention_pallas(q, k_pool, v_pool, page_table, lengths,
                           window: Optional[int] = None,
                           interpret: bool = False):
    """q: (B,H,dh); k_pool/v_pool: (N,P,K,dh); page_table: (B,MP) int32
    (-1 = unused); lengths: (B,).  Returns (B,H,dh)."""
    B, H, dh = q.shape
    N, P, K, _ = k_pool.shape
    MP = page_table.shape[1]

    # Pools flattened to (N, P, K*dh) lanes-padded tiles.
    kd = K * dh
    kd_p = ((kd + 127) // 128) * 128
    kp = jnp.pad(k_pool.reshape(N, P, kd), ((0, 0), (0, 0), (0, kd_p - kd)))
    vp = jnp.pad(v_pool.reshape(N, P, kd), ((0, 0), (0, 0), (0, kd_p - kd)))
    dh_p = ((dh + 127) // 128) * 128
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, dh_p - dh)))

    grid = (B, MP)

    def k_index(table, b, p):
        return (jnp.maximum(table[b, p], 0), 0, 0)

    out = pl.pallas_call(
        functools.partial(
            _kernel, page_size=P, kv_heads=K, q_heads=H, dh=dh,
            window=window),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,      # page_table, lengths
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, H, dh_p), lambda b, p, table, lens: (b, 0, 0)),
                pl.BlockSpec((1, P, kd_p),
                             lambda b, p, table, lens: k_index(table, b, p)),
                pl.BlockSpec((1, P, kd_p),
                             lambda b, p, table, lens: k_index(table, b, p)),
            ],
            out_specs=pl.BlockSpec((1, H, dh_p),
                                   lambda b, p, table, lens: (b, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((K, H // K), F32),
                pltpu.VMEM((K, H // K), F32),
                pltpu.VMEM((K, H // K, dh), F32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, dh_p), q.dtype),
        interpret=interpret,
    )(page_table, lengths, qp, kp, vp)
    return out[:, :, :dh]
