"""Batched token sampling over logits rows — the decode epilogue.

One fused formulation serves every backend: temperature scaling, top-k and
top-p (nucleus) filtering over a single descending sort, and Gumbel-max
selection, vectorized over the batch rows of the decode dispatch's logits.
Unlike the attention/GEMM kernels there is no separate Pallas lowering —
the whole epilogue is a sort + cumsum + argmax over (B, vocab) that XLA
already fuses into the logits matmul's consumer; a hand-tiled kernel would
buy nothing.  ``kernels/ref.py`` carries an independent numpy oracle
(``sample_tokens_reference``) that the test sweeps assert against.

Determinism contract (what serving correctness rests on):

* Per-row randomness is ``fold_in(PRNGKey(seed), position)`` where
  ``position`` is the token's absolute index in the request's stream
  (prompt + generated).  A request that is preempted and recomputed, or
  prefilled one-shot instead of chunked, re-samples every position with the
  identical key — so replay produces the identical token sequence.
* ``temperature <= 0`` rows take the plain ``argmax(logits)`` path,
  bitwise-equal to greedy decoding (the pre-sampling engine behaviour).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32
NEG_INF = -1e30
# Floor for the temperature divide on sampled rows; greedy rows never take
# the sampled path, so this only guards against user temperatures denormal
# enough to overflow the scale.
_MIN_TEMP = 1e-6


def gumbel_noise(seed, position, vocab: int) -> jax.Array:
    """(vocab,) Gumbel(0,1) noise for one stream position of one request.

    This construction IS the replay contract: kernel and numpy oracle both
    draw their noise from here, so the oracle independently re-verifies the
    sampling *math* (scaling, filtering, argmax) against shared bits.
    """
    key = jax.random.fold_in(jax.random.PRNGKey(seed), position)
    return jax.random.gumbel(key, (vocab,), F32)


def sample_tokens(logits, seeds, positions, temperature, top_k, top_p):
    """Sample one token per logits row, inside the jitted decode dispatch.

    logits: (B, V) — decode-step logits (inactive rows masked to zeros).
    seeds/positions: (B,) int32 — per-request PRNG seed and the absolute
      stream position of the token being sampled.
    temperature: (B,) f32 — ``<= 0`` selects bitwise-greedy argmax.
    top_k: (B,) int32 — keep the k highest-probability tokens (``<= 0`` or
      ``>= V`` disables the filter).
    top_p: (B,) f32 — nucleus filter: keep the smallest prefix of the
      descending distribution whose cumulative probability reaches top_p
      (``>= 1.0`` disables; the argmax token is always kept).

    Returns (B,) int32 sampled token ids.
    """
    B, V = logits.shape
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    scaled = logits.astype(F32) / jnp.maximum(
        temperature.astype(F32), _MIN_TEMP)[:, None]
    # One descending sort feeds both filters.  Stable order so rank ties
    # resolve to the lowest token id, matching the numpy oracle.
    order = jnp.argsort(-scaled, axis=-1, stable=True)
    ranked = jnp.take_along_axis(scaled, order, axis=-1)

    ranks = jnp.arange(V, dtype=jnp.int32)[None, :]
    k = jnp.where(top_k <= 0, V, top_k).astype(jnp.int32)[:, None]
    keep_k = ranks < k
    probs = jax.nn.softmax(ranked, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    # Exclusive cumsum < top_p keeps the smallest covering prefix and always
    # keeps rank 0, so the filter can never empty a row.
    keep_p = (cum - probs) < top_p.astype(F32)[:, None]

    keep = jnp.zeros((B, V), bool).at[
        jnp.arange(B, dtype=jnp.int32)[:, None], order
    ].set(keep_k & keep_p)
    masked = jnp.where(keep, scaled, NEG_INF)

    noise = jax.vmap(gumbel_noise, in_axes=(0, 0, None))(
        seeds, positions, V)
    sampled = jnp.argmax(masked + noise, axis=-1).astype(jnp.int32)
    return jnp.where(temperature > 0.0, sampled, greedy)
