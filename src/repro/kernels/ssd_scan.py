"""Mamba2 SSD intra-chunk block — Pallas TPU kernel.

Computes the quadratic-within-chunk part of the state-space duality
algorithm for one chunk:

    y[i,h,p] = sum_{j<=i} (C_i . B_j) * exp(a_cum[i,h] - a_cum[j,h])
                          * dt[j,h] * x[j,h,p]

Grid = (B, H/block_h): one (chunk Q x chunk Q) decay-weighted attention
block per (batch row, head block).  VMEM tiles: x (Q, block_h*P), dt/a_cum
(Q, block_h), B/C (Q, N).  The (Q, Q) score matrix (shared across heads) is
recomputed per head block — cheaper than staging it through HBM for the
model sizes assigned here (Q<=256, N=64).

The inter-chunk recurrence (linear, O(S)) stays in jnp (models/ssm.py); it
is bandwidth-trivial compared to this block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, o_ref, *,
            Q: int, block_h: int, P: int, N: int):
    x = x_ref[0].astype(F32)          # (Q, block_h*P)
    dt = dt_ref[0].astype(F32)        # (Q, block_h)
    a = a_ref[0].astype(F32)          # (Q, block_h)  per-step log decay
    Bm = b_ref[0].astype(F32)         # (Q, N)
    Cm = c_ref[0].astype(F32)         # (Q, N)

    a_cum = jnp.cumsum(a, axis=0)     # (Q, block_h)
    scores = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())), preferred_element_type=F32)

    xh = x.reshape(Q, block_h, P)
    xdt = xh * dt[..., None]

    row = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    causal = col <= row

    out = jnp.zeros((Q, block_h, P), F32)
    for h in range(block_h):          # static unroll over the head block
        diff = a_cum[:, None, h] - a_cum[None, :, h]      # (Q, Q)
        diff = jnp.where(causal, diff, -1e30)
        w = scores * jnp.exp(diff)                        # (Q, Q)
        yh = jax.lax.dot_general(
            w, xdt[:, h], (((1,), (0,)), ((), ())),
            preferred_element_type=F32)                   # (Q, P)
        out = out.at[:, h].set(yh)

    o_ref[0] = out.reshape(Q, block_h * P).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_h", "interpret"))
def ssd_scan_pallas(x, dt, A, Bm, Cm, block_h: int = 4,
                    interpret: bool = False):
    """One-chunk SSD.  x: (B,Q,H,P); dt: (B,Q,H) f32; A: (H,) f32;
    Bm/Cm: (B,Q,N).  Returns y (B,Q,H,P) f32 (no initial state)."""
    B, Q, H, P = x.shape
    N = Bm.shape[-1]
    bh = min(block_h, H)
    assert H % bh == 0
    a = dt * A                                    # (B,Q,H)

    xt = x.reshape(B, Q, H * P)
    grid = (B, H // bh)

    out = pl.pallas_call(
        functools.partial(_kernel, Q=Q, block_h=bh, P=P, N=N),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Q, bh * P), lambda b, h, bh=bh, P=P: (b, 0, h)),
            pl.BlockSpec((1, Q, bh), lambda b, h: (b, 0, h)),
            pl.BlockSpec((1, Q, bh), lambda b, h: (b, 0, h)),
            pl.BlockSpec((1, Q, N), lambda b, h: (b, 0, 0)),
            pl.BlockSpec((1, Q, N), lambda b, h: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, Q, bh * P), lambda b, h: (b, 0, h)),
        out_shape=jax.ShapeDtypeStruct((B, Q, H * P), F32),
        interpret=interpret,
    )(xt, dt, a, Bm, Cm)
    return out.reshape(B, Q, H, P)
