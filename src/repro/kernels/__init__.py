# Pallas TPU kernels for the serving/training hot spots, each with a
# pure-jnp oracle in ref.py and backend dispatch in ops.py:
#   flash_attention.py — blockwise online-softmax attention (GQA/SWA)
#   paged_attention.py — decode attention over the paged two-tier KV pool
#   ssd_scan.py        — intra-chunk SSD (Mamba2) block
#   moe_gemm.py        — grouped-expert SwiGLU GEMM over sorted ragged
#                        segments (dropless MoE dispatch)
#   sampling.py        — batched Gumbel/top-k/top-p decode epilogue (one
#                        fused jnp lowering; numpy oracle in ref.py)
