"""jit'd kernel wrappers with backend dispatch.

Modes:
  * "pallas"    — pl.pallas_call TPU kernels (kernels/<name>.py),
  * "interpret" — same kernels, Pallas interpret mode (CPU validation),
  * "reference" — pure-jnp oracles (kernels/ref.py).

Default: pallas on TPU, reference elsewhere — so dry-run cost analysis on
the CPU backend reflects honest XLA HLO, while TPU runs get the tiled
kernels.  Override per-call or globally via ``set_mode``.
"""

from __future__ import annotations

from typing import Optional

import jax

from . import ref

_MODE: Optional[str] = None  # None = auto


def set_mode(mode: Optional[str]):
    global _MODE
    assert mode in (None, "pallas", "interpret", "reference")
    _MODE = mode


def current_mode() -> str:
    if _MODE is not None:
        return _MODE
    platform = jax.default_backend()
    return "pallas" if platform == "tpu" else "reference"


def flash_attention(q, k, v, causal: bool = True,
                    window: Optional[int] = None):
    mode = current_mode()
    if mode == "reference":
        return ref.mha_reference(q, k, v, causal=causal, window=window)
    from .flash_attention import flash_attention_pallas

    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  interpret=(mode == "interpret"))


def paged_attention(q, k_pool, v_pool, page_table, lengths,
                    window: Optional[int] = None):
    mode = current_mode()
    if mode == "reference":
        return ref.paged_attention_reference(q, k_pool, v_pool, page_table,
                                             lengths, window=window)
    from .paged_attention import paged_attention_pallas

    return paged_attention_pallas(q, k_pool, v_pool, page_table, lengths,
                                  window=window,
                                  interpret=(mode == "interpret"))


def paged_prefill(q, k_pool, v_pool, page_table, lengths,
                  window: Optional[int] = None):
    """One-shot prompt attention over paged KV: the S prompt tokens of one
    sequence attend as S query rows over a shared page table, with row t's
    causal visibility carried by ``lengths[t]`` (0 disables a padded row).
    Both lowerings reuse the decode paged-attention math, so a whole-prompt
    prefill is bitwise-equal to stepping its tokens through decode.

    q: (S,H,dh); k_pool/v_pool: (N,P,K,dh); page_table: (MP,) int32;
    lengths: (S,) int32.  Returns (S,H,dh)."""
    mode = current_mode()
    if mode == "reference":
        return ref.paged_prefill_reference(q, k_pool, v_pool, page_table,
                                           lengths, window=window)
    from .paged_attention import paged_prefill_pallas

    return paged_prefill_pallas(q, k_pool, v_pool, page_table, lengths,
                                window=window,
                                interpret=(mode == "interpret"))


def moe_grouped_ffn(x, w_gate, w_up, w_down, group_sizes,
                    group_experts=None):
    """Grouped-expert SwiGLU over sorted ragged segments (dropless MoE
    dispatch).  x: (T, d) argsorted by group; group_sizes: (G,) int32;
    group_experts: optional (G,) int32 group->expert weight map (None means
    G == E and groups are experts)."""
    mode = current_mode()
    if mode == "reference":
        return ref.moe_grouped_ffn_reference(x, w_gate, w_up, w_down,
                                             group_sizes, group_experts)
    from .moe_gemm import moe_grouped_ffn_pallas

    return moe_grouped_ffn_pallas(x, w_gate, w_up, w_down, group_sizes,
                                  group_experts,
                                  interpret=(mode == "interpret"))


def sample_tokens(logits, seeds, positions, temperature, top_k, top_p):
    """Batched in-dispatch token sampling (decode epilogue): temperature /
    top-k / top-p filtering + Gumbel-max over (B, vocab) logits rows, with
    per-row keys ``fold_in(PRNGKey(seed), position)`` so preempted or
    re-prefilled requests replay identical streams.  ``temperature <= 0``
    rows are bitwise-equal to ``argmax(logits)``.

    Single lowering on every backend: the epilogue is a sort + cumsum +
    argmax that XLA fuses into the logits consumer, so there is no separate
    Pallas kernel to dispatch to — the numpy oracle for the test sweeps
    lives in ``ref.sample_tokens_reference``.
    """
    from .sampling import sample_tokens as _sample_tokens

    return _sample_tokens(logits, seeds, positions, temperature, top_k,
                          top_p)


def ssd_scan(x, dt, A, Bm, Cm):
    """Intra-chunk SSD block (one chunk).  Cross-chunk recurrence stays in
    models/ssm.py regardless of backend."""
    mode = current_mode()
    if mode == "reference":
        return ref.ssd_reference(x, dt, A, Bm, Cm)
    from .ssd_scan import ssd_scan_pallas

    return ssd_scan_pallas(x, dt, A, Bm, Cm,
                           interpret=(mode == "interpret"))
