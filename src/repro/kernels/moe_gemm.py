"""Grouped-expert SwiGLU GEMM over sorted ragged segments — Pallas TPU
kernel (MegaBlocks-style).

Input tokens arrive argsorted by group id, so each group owns one
contiguous ragged segment of rows; ``group_sizes`` gives the segment
lengths (empty segments allowed).  A *group* is usually an expert, but the
kernel decouples the two: ``group_experts`` maps each of the G groups to
the expert whose weights it multiplies, so the same kernel executes

* the classic per-expert layout (G == E, ``group_experts == arange(E)``),
* the per-batch-row dropless layout (G == B·E, expert ``g % E`` — keeps the
  dropless argsort shard-local over the data axis), and
* the ragged ep layout (G == m·E_local, one segment per (source shard,
  local expert) pair after the ragged all-to-all).

The kernel tiles the row dim into ``block_t`` physical tiles and walks a
sequence of *logical* tiles — one per (group, physical tile) pair the
group's segment overlaps.  A physical tile whose rows straddle a segment
boundary is visited once per overlapping group with a row-masked store, so
ragged boundaries need no padding of the token stream itself.

Grid = (logical_tiles, ff_tiles); the ff dim is innermost so the SwiGLU
partial products accumulate in a VMEM f32 scratch and the output tile is
written once, on the last ff step.  Per-logical-tile group ids, expert
(weight) ids, physical tile ids and segment offsets are scalar-prefetched
(SMEM) so the BlockSpec index maps can steer the expert-weight DMAs.

The logical-tile count depends on the (traced) group sizes, so the grid is
the static worst case ``row_tiles + G - 1``; surplus steps replay the last
tile with a row mask drawn from their own segment offsets, which makes them
idempotent rewrites or no-ops — never double-accumulation.

Backward: custom VJP recomputes through the jnp oracle (exact), mirroring
flash_attention.py — the fwd kernel is the serving hot spot.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import ref

F32 = jnp.float32
DEFAULT_BLOCK_T = 128
DEFAULT_BLOCK_F = 512


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


def _pad_axis(x, size: int, axis: int):
    pad = size - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def make_group_metadata(group_sizes, rows: int, block_t: int):
    """Logical-tile schedule for a ragged row partition into G groups.

    Returns (group_ids, m_tile_ids, group_offsets):
      * group_ids[i]   — group handled by logical tile i,
      * m_tile_ids[i]  — physical row tile it reads/writes (non-decreasing),
      * group_offsets  — (G+1,) row offsets of the segments.
    Arrays are padded to the static worst-case length ``row_tiles + G - 1``;
    padded entries replay the last physical tile (idempotent, see module
    docstring).
    """
    G = group_sizes.shape[0]
    tiles_m = _round_up(rows, block_t) // block_t
    L = tiles_m + G - 1

    ends = jnp.cumsum(group_sizes)
    starts = ends - group_sizes
    group_offsets = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), ends.astype(jnp.int32)])
    first_tile = (starts // block_t).astype(jnp.int32)
    # Tiles overlapped by each segment; empty segments get none.
    spanned = (-(-ends // block_t)).astype(jnp.int32) - first_tile
    group_tiles = jnp.where(group_sizes > 0, spanned, 0)

    group_ids = jnp.repeat(jnp.arange(G, dtype=jnp.int32), group_tiles,
                           total_repeat_length=L)
    tile_base = jnp.cumsum(group_tiles) - group_tiles   # exclusive cumsum
    m_tile_ids = (first_tile[group_ids]
                  + (jnp.arange(L, dtype=jnp.int32) - tile_base[group_ids]))
    m_tile_ids = jnp.clip(m_tile_ids, 0, tiles_m - 1)
    return group_ids, m_tile_ids, group_offsets


def _kernel(gids_ref, mids_ref, offs_ref, wids_ref, x_ref, wg_ref, wu_ref,
            wd_ref, o_ref, acc_ref, *, block_t: int, n_ff: int):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _reset():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(F32)                        # (block_t, d)
    g = jax.lax.dot_general(x, wg_ref[0].astype(F32),
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=F32)
    u = jax.lax.dot_general(x, wu_ref[0].astype(F32),
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=F32)
    h = jax.nn.silu(g) * u                            # (block_t, block_f)
    acc_ref[...] += jax.lax.dot_general(h, wd_ref[0].astype(F32),
                                        (((1,), (0,)), ((), ())),
                                        preferred_element_type=F32)

    @pl.when(j == n_ff - 1)
    def _store():
        gid = gids_ref[i]
        seg_start = offs_ref[gid]
        seg_end = offs_ref[gid + 1]
        row = mids_ref[i] * block_t + jax.lax.broadcasted_iota(
            jnp.int32, acc_ref.shape, 0)
        mask = (row >= seg_start) & (row < seg_end)
        # First visit of a physical tile initializes it; later visits (other
        # groups sharing the tile) only overwrite their own rows.
        first = jnp.logical_or(
            i == 0, mids_ref[jnp.maximum(i - 1, 0)] != mids_ref[i])
        prev = jnp.where(first, jnp.zeros_like(acc_ref[...]), o_ref[...])
        o_ref[...] = jnp.where(mask, acc_ref[...], prev).astype(o_ref.dtype)


def _grouped_ffn_fwd(x, w_gate, w_up, w_down, group_sizes, group_experts, *,
                     block_t: int, block_f: int, interpret: bool):
    T, d = x.shape
    E, _, f = w_gate.shape
    G = group_sizes.shape[0]
    d_p = _round_up(d, 128)
    bf = min(block_f, _round_up(f, 128))
    f_p = _round_up(f, bf)
    T_p = _round_up(T, block_t)
    tiles_m = T_p // block_t
    L = tiles_m + G - 1
    n_ff = f_p // bf

    xp = _pad_axis(_pad_axis(x, T_p, 0), d_p, 1)
    wg = _pad_axis(_pad_axis(w_gate, d_p, 1), f_p, 2)
    wu = _pad_axis(_pad_axis(w_up, d_p, 1), f_p, 2)
    wd = _pad_axis(_pad_axis(w_down, f_p, 1), d_p, 2)

    gids, mids, offs = make_group_metadata(group_sizes, T_p, block_t)
    wids = group_experts.astype(jnp.int32)[gids]      # expert weights per tile

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(L, n_ff),
        in_specs=[
            pl.BlockSpec((block_t, d_p),
                         lambda i, j, gids, mids, offs, wids: (mids[i], 0)),
            pl.BlockSpec((1, d_p, bf),
                         lambda i, j, gids, mids, offs, wids:
                         (wids[i], 0, j)),
            pl.BlockSpec((1, d_p, bf),
                         lambda i, j, gids, mids, offs, wids:
                         (wids[i], 0, j)),
            pl.BlockSpec((1, bf, d_p),
                         lambda i, j, gids, mids, offs, wids:
                         (wids[i], j, 0)),
        ],
        out_specs=pl.BlockSpec(
            (block_t, d_p),
            lambda i, j, gids, mids, offs, wids: (mids[i], 0)),
        scratch_shapes=[pltpu.VMEM((block_t, d_p), F32)],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, block_t=block_t, n_ff=n_ff),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T_p, d_p), x.dtype),
        interpret=interpret,
    )(gids, mids, offs, wids, xp, wg, wu, wd)
    return out[:T, :d]


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8))
def _grouped_ffn(x, w_gate, w_up, w_down, group_sizes, group_experts,
                 block_t, block_f, interpret):
    return _grouped_ffn_fwd(x, w_gate, w_up, w_down, group_sizes,
                            group_experts, block_t=block_t, block_f=block_f,
                            interpret=interpret)


def _ffn_fwd(x, w_gate, w_up, w_down, group_sizes, group_experts, block_t,
             block_f, interpret):
    out = _grouped_ffn(x, w_gate, w_up, w_down, group_sizes, group_experts,
                       block_t, block_f, interpret)
    return out, (x, w_gate, w_up, w_down, group_sizes, group_experts)


def _ffn_bwd(block_t, block_f, interpret, res, g):
    # Exact recompute backward via the jnp oracle (the fwd kernel is the
    # serving hot spot; numerics stay bit-comparable to the reference).
    x, w_gate, w_up, w_down, group_sizes, group_experts = res
    _, vjp = jax.vjp(
        lambda a, b, c, d: ref.moe_grouped_ffn_reference(
            a, b, c, d, group_sizes, group_experts),
        x, w_gate, w_up, w_down)
    dgs = np.zeros(group_sizes.shape, dtype=jax.dtypes.float0)
    dge = np.zeros(group_experts.shape, dtype=jax.dtypes.float0)
    return (*vjp(g), dgs, dge)


_grouped_ffn.defvjp(_ffn_fwd, _ffn_bwd)


@functools.partial(
    jax.jit, static_argnames=("block_t", "block_f", "interpret"))
def moe_grouped_ffn_pallas(x, w_gate, w_up, w_down, group_sizes,
                           group_experts=None,
                           block_t: int = DEFAULT_BLOCK_T,
                           block_f: int = DEFAULT_BLOCK_F,
                           interpret: bool = False):
    """x: (T, d) sorted by group; w_gate/w_up: (E, d, f); w_down: (E, f, d);
    group_sizes: (G,) int32 summing to T; group_experts: (G,) int32 mapping
    each group to its expert weights (default arange — G == E).
    Returns (T, d)."""
    if group_experts is None:
        group_experts = jnp.arange(w_gate.shape[0], dtype=jnp.int32)
    return _grouped_ffn(x, w_gate, w_up, w_down,
                        group_sizes.astype(jnp.int32),
                        group_experts.astype(jnp.int32), block_t, block_f,
                        interpret)
