"""Flash attention forward — Pallas TPU kernel.

Blockwise online-softmax attention with GQA, causal and sliding-window
masking.  Grid = (batch*q_heads, q_blocks, kv_blocks); the kv dimension is
innermost so the (m, l, acc) running state lives in VMEM scratch across kv
steps and the output block is written once on the last step.

Tiling: q/k/v blocks are (block_q, head_dim) / (block_k, head_dim) VMEM
tiles; head_dim is padded to a multiple of 128 by the wrapper (MXU lane
alignment), and scores accumulate in f32 regardless of input dtype.

Backward: ``flash_attention_pallas`` carries a custom VJP whose backward
pass recomputes attention with the pure-jnp oracle (exact, O(S^2/blocks)
memory via the same chunking) — the fwd kernel is the serving/prefill hot
spot; a fused Pallas backward is an optimization left on the table and
noted in EXPERIMENTS.md.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import ref

F32 = jnp.float32
NEG_INF = -1e30
DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: Optional[int],
            block_q: int, block_k: int, seq_k: int, q_offset: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(F32)            # (block_q, dh)
    k = k_ref[0].astype(F32)            # (block_k, dh)
    v = v_ref[0].astype(F32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=F32) * scale        # (block_q, block_k)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0) + q_offset
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = k_pos < seq_k                 # padding guard
    if causal:
        mask &= k_pos <= q_pos
        if window is not None:
            mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_cur = jnp.max(s, axis=1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=F32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_scr[...]
        safe = jnp.where(l > 0, l, 1.0)
        o_ref[0] = (acc_scr[...] / safe[:, None]).astype(o_ref.dtype)


def _pad_to(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"))
def flash_attention_fwd(q, k, v, causal: bool = True,
                        window: Optional[int] = None,
                        block_q: int = DEFAULT_BLOCK_Q,
                        block_k: int = DEFAULT_BLOCK_K,
                        interpret: bool = False):
    """q: (B,Sq,H,dh); k/v: (B,Sk,K,dh) with H = G*K.  Returns (B,Sq,H,dh)."""
    B, Sq, H, dh = q.shape
    _, Sk, K, _ = k.shape
    G = H // K
    scale = 1.0 / np.sqrt(dh)

    # Layout: fold heads into the grid's leading dim; pad dh to lanes.
    dh_p = ((dh + 127) // 128) * 128
    qt = _pad_to(q.transpose(0, 2, 1, 3).reshape(B * H, Sq, dh), 128, 2)
    kt = _pad_to(k.transpose(0, 2, 1, 3).reshape(B * K, Sk, dh), 128, 2)
    vt = _pad_to(v.transpose(0, 2, 1, 3).reshape(B * K, Sk, dh), 128, 2)
    Sq_p = ((Sq + block_q - 1) // block_q) * block_q
    Sk_p = ((Sk + block_k - 1) // block_k) * block_k
    qt = _pad_to(qt, block_q, 1)
    kt = _pad_to(kt, block_k, 1)
    vt = _pad_to(vt, block_k, 1)

    grid = (B * H, Sq_p // block_q, Sk_p // block_k)

    out = pl.pallas_call(
        functools.partial(
            _kernel, scale=scale, causal=causal, window=window,
            block_q=block_q, block_k=block_k, seq_k=Sk,
            q_offset=Sk - Sq),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, dh_p), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, dh_p),
                         lambda bh, qi, ki, G=G: (bh // G, ki, 0)),
            pl.BlockSpec((1, block_k, dh_p),
                         lambda bh, qi, ki, G=G: (bh // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh_p),
                               lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq_p, dh_p), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), F32),      # running max m
            pltpu.VMEM((block_q,), F32),      # running denom l
            pltpu.VMEM((block_q, dh_p), F32),  # output accumulator
        ],
        interpret=interpret,
    )(qt, kt, vt)
    out = out[:, :Sq, :dh].reshape(B, H, Sq, dh).transpose(0, 2, 1, 3)
    return out


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention_pallas(q, k, v, causal: bool = True,
                           window: Optional[int] = None,
                           interpret: bool = False):
    return flash_attention_fwd(q, k, v, causal=causal, window=window,
                               interpret=interpret)


def _fa_fwd(q, k, v, causal, window, interpret):
    out = flash_attention_fwd(q, k, v, causal=causal, window=window,
                              interpret=interpret)
    return out, (q, k, v)


def _fa_bwd(causal, window, interpret, res, g):
    q, k, v = res
    # Exact recompute backward via the jnp oracle (block-sparse Pallas
    # backward is future work; this keeps numerics bit-comparable).
    def f(q, k, v):
        return ref.mha_reference(q, k, v, causal=causal, window=window)

    _, vjp = jax.vjp(f, q, k, v)
    return vjp(g)


flash_attention_pallas.defvjp(_fa_fwd, _fa_bwd)
