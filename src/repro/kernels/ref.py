"""Pure-jnp oracles for every Pallas kernel (the correctness references the
per-kernel test sweeps assert against, and the lowering used on non-TPU
backends / in the dry-run, where cost analysis must reflect real XLA HLO)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

F32 = jnp.float32
NEG_INF = -1e30


# ------------------------------------------------------- flash attention
def mha_reference(q, k, v, causal: bool = True,
                  window: Optional[int] = None,
                  scale: Optional[float] = None) -> jax.Array:
    """Exact softmax attention.  q: (B,Sq,H,dh); k/v: (B,Sk,K,dh), GQA."""
    B, Sq, H, dh = q.shape
    K = k.shape[2]
    G = H // K
    scale = scale if scale is not None else 1.0 / np.sqrt(dh)
    qg = q.reshape(B, Sq, K, G, dh)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(F32),
                   k.astype(F32)) * scale
    q_pos = jnp.arange(Sq)[:, None] + (k.shape[1] - Sq)
    k_pos = jnp.arange(k.shape[1])[None, :]
    if causal:
        mask = k_pos <= q_pos
        if window is not None:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(F32))
    return o.reshape(B, Sq, H, dh).astype(q.dtype)


# ------------------------------------------------------- paged attention
def paged_attention_reference(q, k_pool, v_pool, page_table, lengths,
                              window: Optional[int] = None) -> jax.Array:
    """Decode-time attention over paged KV.

    q: (B, H, dh) — one new token per sequence.
    k_pool/v_pool: (N_pages, P, K, dh) — one layer's HBM page pool.
    page_table: (B, MP) int32 — pool slot per logical page, -1 = unused.
    lengths: (B,) int32 — tokens so far (including the new one).
    """
    B, H, dh = q.shape
    N, P, K, _ = k_pool.shape
    MP = page_table.shape[1]
    G = H // K
    scale = 1.0 / np.sqrt(dh)

    safe = jnp.maximum(page_table, 0)
    k = k_pool[safe]            # (B, MP, P, K, dh)
    v = v_pool[safe]
    k = k.reshape(B, MP * P, K, dh)
    v = v.reshape(B, MP * P, K, dh)
    pos = jnp.arange(MP * P)[None, :]
    valid = (pos < lengths[:, None]) & jnp.repeat(
        page_table >= 0, P, axis=1)
    if window is not None:
        valid &= (lengths[:, None] - 1 - pos) < window

    qg = q.reshape(B, K, G, dh)
    s = jnp.einsum("bkgd,bskd->bkgs", qg.astype(F32),
                   k.astype(F32)) * scale
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", w, v.astype(F32))
    return o.reshape(B, H, dh).astype(q.dtype)


def paged_prefill_reference(q, k_pool, v_pool, page_table, lengths,
                            window: Optional[int] = None) -> jax.Array:
    """One-shot prompt attention over paged KV — masked-einsum oracle.

    The S prompt tokens of ONE sequence are presented as S independent
    query rows over the same page table; row t's causal visibility is
    expressed through ``lengths[t]`` (= t+1 for real tokens, 0 for padded
    rows).  Delegates to the decode oracle with the table broadcast across
    rows, so a one-shot prefill computes bitwise the same function as
    stepping the tokens through decode one at a time — the property the
    serving engine's chunked-prefill equality tests pin down.

    q: (S, H, dh); k_pool/v_pool: (N, P, K, dh); page_table: (MP,) int32
    (-1 = unused); lengths: (S,) int32.  Returns (S, H, dh).
    """
    S = q.shape[0]
    table = jnp.broadcast_to(page_table[None, :], (S, page_table.shape[0]))
    return paged_attention_reference(q, k_pool, v_pool, table, lengths,
                                     window=window)


# ---------------------------------------------------- grouped-expert GEMM
def moe_grouped_ffn_reference(x, w_gate, w_up, w_down, group_sizes,
                              group_experts=None):
    """Grouped-expert SwiGLU over sorted ragged segments — jnp oracle.

    x: (T, d) tokens sorted by group id (contiguous ragged segments);
    w_gate/w_up: (E, d, f); w_down: (E, f, d);
    group_sizes: (G,) int32 summing to T (empty groups allowed);
    group_experts: (G,) int32 mapping each group to its expert weights
    (None means G == E, the classic per-expert layout).

    Every expert's FFN is applied densely to all T rows, and the final
    einsum against the row->expert one-hot performs the segment-select (a
    segment_sum over the expert axis).  O(E) times the flops of the ragged
    kernel — it's the correctness oracle and the non-TPU lowering, where
    smoke-scale shapes make the overhead irrelevant.
    """
    T, d = x.shape
    E = w_gate.shape[0]
    G = group_sizes.shape[0]
    seg = jnp.repeat(jnp.arange(G), group_sizes, total_repeat_length=T)
    if group_experts is not None:
        seg = group_experts.astype(jnp.int32)[seg]
    xf = x.astype(F32)
    g = jnp.einsum("td,edf->etf", xf, w_gate.astype(F32))
    u = jnp.einsum("td,edf->etf", xf, w_up.astype(F32))
    h = jax.nn.silu(g) * u
    y = jnp.einsum("etf,efd->etd", h, w_down.astype(F32))       # (E, T, d)
    sel = jax.nn.one_hot(seg, E, dtype=F32)                     # (T, E)
    out = jnp.einsum("etd,te->td", y, sel)
    return out.astype(x.dtype)


# ------------------------------------------------------------ sampling
def sample_tokens_reference(logits, seeds, positions, temperature, top_k,
                            top_p) -> np.ndarray:
    """Numpy oracle for ``kernels.sampling.sample_tokens``.

    Reimplements the sampling math — temperature scaling, top-k rank
    filter, top-p nucleus filter over the descending distribution,
    Gumbel-max selection, greedy short-circuit at ``temperature <= 0`` —
    independently in numpy, row by row.  Only the raw Gumbel bits are
    shared (``kernels.sampling.gumbel_noise``): they are the PRNG's replay
    contract, not sampling logic, and sharing them is what lets the sweep
    tests demand *exact* token equality rather than a distribution test.
    """
    from .sampling import gumbel_noise

    logits = np.asarray(logits)
    B, V = logits.shape
    seeds = np.asarray(seeds)
    positions = np.asarray(positions)
    temperature = np.asarray(temperature, np.float32)
    top_k = np.asarray(top_k)
    top_p = np.asarray(top_p, np.float32)
    out = np.zeros((B,), np.int32)
    for i in range(B):
        row = logits[i].astype(np.float32)
        if temperature[i] <= 0.0:
            out[i] = int(np.argmax(row))
            continue
        scaled = row / max(float(temperature[i]), 1e-6)
        order = np.argsort(-scaled, kind="stable")
        ranked = scaled[order]
        keep = np.ones((V,), bool)
        if 0 < top_k[i] < V:
            keep[int(top_k[i]):] = False
        shifted = (ranked - ranked.max()).astype(np.float32)
        probs = np.exp(shifted) / np.exp(shifted).sum(dtype=np.float32)
        cum = np.cumsum(probs, dtype=np.float32)
        keep &= (cum - probs) < float(top_p[i])     # rank 0 always kept
        masked = np.where(keep, ranked, NEG_INF)
        noise = np.asarray(
            gumbel_noise(int(seeds[i]), int(positions[i]), V))
        out[i] = int(order[np.argmax(masked + noise[order])])
    return out


# ------------------------------------------------------------- SSD scan
def ssd_reference(x, dt, A, Bm, Cm) -> jax.Array:
    """Naive O(S^2) SSD (Mamba2) reference.

    x: (B,S,H,P); dt: (B,S,H) f32; A: (H,) f32 negative; Bm/Cm: (B,S,N).
    y[t] = sum_{j<=t} C_t . B_j * exp(sum_{j<i<=t} dt_i A) * dt_j x_j.
    """
    Bsz, S, H, P = x.shape
    a = dt * A                                  # (B,S,H)
    a_cum = jnp.cumsum(a, axis=1)
    diff = a_cum[:, :, None, :] - a_cum[:, None, :, :]   # (B,S,S,H)
    causal = jnp.tril(jnp.ones((S, S), bool))
    diff = jnp.where(causal[None, :, :, None], diff, -1e30)
    L = jnp.exp(diff)
    scores = jnp.einsum("bin,bjn->bij", Cm.astype(F32), Bm.astype(F32))
    xdt = x.astype(F32) * dt[..., None]
    y = jnp.einsum("bij,bijh,bjhp->bihp", scores, L, xdt)
    return y
