"""repro — online application guidance for heterogeneous memory systems,
as a production-grade JAX training/serving framework (see DESIGN.md)."""

__version__ = "1.0.0"
