"""Tier enforcement for real JAX arrays via memory-kind shardings.

An arena is a named group of ``jax.Array``s (e.g. one layer's optimizer
moments).  Enforcement remaps the group between the fast tier
(``memory_kind="device"`` — HBM on TPU) and the slow tier
(``memory_kind="pinned_host"`` — host DRAM) with ``jax.device_put``; the
partition spec is never changed, only the memory kind, so migration composes
with any DP/TP/EP sharding.

Fractional assignments are realized at array granularity: the hottest-first
stable order of the arena's entries keeps a prefix on the fast tier whose
byte count best matches the recommended fraction.  (Paged pools — KV caches —
do better: they migrate at page granularity inside ``serve/kvcache.py``.)

The trainer-facing helpers ``fetch_fast``/``current`` implement the offload
execution model: compute always runs on device-kind arrays; slow-tier arenas
pay an explicit per-step transfer, which is precisely the recurring "rental"
cost in the ski-rental model.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax

from .arenas import ArenaManager
from .runtime import FractionPlacer


def _with_memory_kind(x: jax.Array, kind: str) -> jax.Array:
    sharding = x.sharding
    if getattr(sharding, "memory_kind", None) == kind:
        return x
    try:
        target = sharding.with_memory_kind(kind)
    except ValueError:
        # Backend without this memory kind (e.g. CPU jaxlibs lacking
        # pinned_host): tier state stays logical, the array stays put.
        return x
    return jax.device_put(x, target)


def memory_kind_of(x: jax.Array) -> Optional[str]:
    return getattr(x.sharding, "memory_kind", None)


@dataclasses.dataclass
class ArrayEntry:
    name: str
    array: jax.Array

    @property
    def nbytes(self) -> int:
        return int(self.array.size * self.array.dtype.itemsize)


class JaxArenaPlacer(FractionPlacer):
    """FractionPlacer whose ``_apply`` migrates real arrays between tiers."""

    def __init__(
        self,
        arenas: ArenaManager,
        fast_kind: str = "device",
        slow_kind: str = "pinned_host",
    ):
        super().__init__(arenas)
        self.fast_kind = fast_kind
        self.slow_kind = slow_kind
        self._store: Dict[int, List[ArrayEntry]] = {}
        self.transfers_bytes: int = 0  # telemetry: total bytes device_put moved

    # ----------------------------------------------------------------- store
    def bind(self, arena_id: int, name: str, array: jax.Array) -> None:
        """Register an array; it is immediately placed according to the
        arena's current fast fraction (first-touch placement happens in the
        ArenaManager, the placer realizes it physically)."""
        entries = self._store.setdefault(arena_id, [])
        for e in entries:
            if e.name == name:
                e.array = array
                break
        else:
            entries.append(ArrayEntry(name=name, array=array))
        arena = self.arenas.arena_by_id(arena_id)
        if arena is not None and arena.fast_fraction < 1.0:
            self._apply(arena_id, arena.fast_fraction)

    def bind_tree(self, arena_id: int, tree: Any, prefix: str = "") -> None:
        leaves, _ = jax.tree_util.tree_flatten_with_path(tree)
        for path, leaf in leaves:
            self.bind(arena_id, prefix + jax.tree_util.keystr(path), leaf)

    def entries(self, arena_id: int) -> List[ArrayEntry]:
        return self._store.get(arena_id, [])

    def get(self, arena_id: int, name: str) -> jax.Array:
        for e in self._store.get(arena_id, []):
            if e.name == name:
                return e.array
        raise KeyError(f"arena {arena_id} has no array {name!r}")

    # ----------------------------------------------------------- enforcement
    def _apply(self, arena_id: int, new_fraction: float) -> None:
        entries = self._store.get(arena_id)
        if not entries:
            return
        total = sum(e.nbytes for e in entries)
        budget = int(round(new_fraction * total))
        for e in entries:  # stable order: prefix goes fast
            target = self.fast_kind if budget >= e.nbytes else self.slow_kind
            if budget >= e.nbytes:
                budget -= e.nbytes
            if memory_kind_of(e.array) != target:
                self.transfers_bytes += e.nbytes
                e.array = _with_memory_kind(e.array, target)

    # --------------------------------------------------------- step interface
    def fetch_fast(self, arena_id: int) -> Dict[str, jax.Array]:
        """Device-kind copies of the arena for compute.  Slow-tier entries pay
        a transfer (the rental); fast-tier entries are returned as-is."""
        out: Dict[str, jax.Array] = {}
        for e in self._store.get(arena_id, []):
            if memory_kind_of(e.array) == self.fast_kind:
                out[e.name] = e.array
            else:
                self.transfers_bytes += e.nbytes
                out[e.name] = _with_memory_kind(e.array, self.fast_kind)
        return out

    def writeback(self, arena_id: int, values: Dict[str, jax.Array]) -> None:
        """Store updated values, preserving each entry's current tier."""
        for e in self._store.get(arena_id, []):
            if e.name not in values:
                continue
            new = values[e.name]
            kind = memory_kind_of(e.array)
            if kind == self.slow_kind:
                self.transfers_bytes += e.nbytes
                new = _with_memory_kind(new, self.slow_kind)
            e.array = new

    def fast_bytes(self) -> int:
        return sum(
            e.nbytes
            for entries in self._store.values()
            for e in entries
            if memory_kind_of(e.array) == self.fast_kind
        )

    def slow_bytes(self) -> int:
        return sum(
            e.nbytes
            for entries in self._store.values()
            for e in entries
            if memory_kind_of(e.array) != self.fast_kind
        )
