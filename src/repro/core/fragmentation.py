"""Site fragmentation (beyond-paper; the paper's Sec. 6.3 / Sec. 7 future work).

The paper's QMCPACK pathology: one allocation site owns 60-63% of resident
data and is the hottest site *on average*, so site-granularity guidance pins
all of it to the fast tier even when much of it is momentarily cold — and
hardware caching wins.  The authors propose "fragmenting large sets of data
created from the same site into separate sets based on different data
features, such as the age of the data".

This module implements exactly that: an arena with per-chunk telemetry
(chunk = KV page, array, or simulated page run) is *exploded* into
age-quantile sub-arenas that the recommendation engines see as independent
rows, then the resulting fractions are *collapsed* back into per-chunk
placements (hottest chunks first within each fragment).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

from .profiler import ArenaProfile, IntervalProfile

# Synthetic arena-id space for fragments; real arena ids stay well below this.
FRAGMENT_ID_BASE = 1 << 30


@dataclasses.dataclass
class ChunkStats:
    """Telemetry for one migratable chunk of a big arena."""

    chunk_id: int
    nbytes: int
    accesses: int
    age: int            # intervals since allocation (larger = older)
    fast: bool = True   # current placement


@dataclasses.dataclass
class Fragment:
    fragment_id: int
    parent_arena_id: int
    chunks: List[ChunkStats]

    @property
    def nbytes(self) -> int:
        return sum(c.nbytes for c in self.chunks)

    @property
    def accesses(self) -> int:
        return sum(c.accesses for c in self.chunks)

    @property
    def fast_fraction(self) -> float:
        total = self.nbytes
        if total == 0:
            return 1.0
        return sum(c.nbytes for c in self.chunks if c.fast) / total

    def to_row(self) -> ArenaProfile:
        return ArenaProfile(
            arena_id=self.fragment_id,
            site_id=-1,
            label=f"frag:{self.parent_arena_id}:{self.fragment_id - FRAGMENT_ID_BASE}",
            accesses=self.accesses,
            resident_bytes=self.nbytes,
            fast_fraction=self.fast_fraction,
        )


def fragment_by_age(
    parent_arena_id: int,
    chunks: Sequence[ChunkStats],
    num_fragments: int,
    id_offset: int = 0,
) -> List[Fragment]:
    """Split chunks into up to ``num_fragments`` age-quantile groups."""
    if num_fragments < 1:
        raise ValueError("num_fragments must be >= 1")
    ordered = sorted(chunks, key=lambda c: (c.age, c.chunk_id))
    n = len(ordered)
    k = min(num_fragments, n) if n else 0
    fragments: List[Fragment] = []
    for j in range(k):
        lo = (n * j) // k
        hi = (n * (j + 1)) // k
        fragments.append(
            Fragment(
                fragment_id=FRAGMENT_ID_BASE + id_offset + j,
                parent_arena_id=parent_arena_id,
                chunks=list(ordered[lo:hi]),
            )
        )
    return fragments


def explode_profile(
    profile: IntervalProfile,
    telemetry: Dict[int, Sequence[ChunkStats]],
    num_fragments: int = 4,
    min_bytes_to_fragment: int = 0,
) -> Tuple[IntervalProfile, List[Fragment]]:
    """Replace rows that have chunk telemetry with their fragments."""
    rows: List[ArenaProfile] = []
    fragments: List[Fragment] = []
    offset = 0
    for row in profile.rows:
        chunks = telemetry.get(row.arena_id)
        if not chunks or row.resident_bytes < min_bytes_to_fragment:
            rows.append(row)
            continue
        frags = fragment_by_age(row.arena_id, chunks, num_fragments, id_offset=offset)
        offset += len(frags)
        fragments.extend(frags)
        rows.extend(f.to_row() for f in frags)
    exploded = IntervalProfile(
        interval_index=profile.interval_index,
        rows=rows,
        private_pool_bytes=profile.private_pool_bytes,
        collection_seconds=profile.collection_seconds,
    )
    return exploded, fragments


def collapse_to_chunks(
    fragments: Sequence[Fragment],
    fractions: Dict[int, float],
) -> Dict[int, bool]:
    """Map fragment fast-fractions back to per-chunk placement.

    Within a fragment the hottest chunks claim the fast bytes first.  Returns
    chunk_id -> should-be-fast.
    """
    placement: Dict[int, bool] = {}
    for frag in fragments:
        frac = fractions.get(frag.fragment_id, 0.0)
        budget = int(frac * frag.nbytes)
        for c in sorted(
            frag.chunks,
            key=lambda c: (-(c.accesses / c.nbytes if c.nbytes else 0.0), c.chunk_id),
        ):
            if budget >= c.nbytes and c.nbytes > 0:
                placement[c.chunk_id] = True
                budget -= c.nbytes
            else:
                placement[c.chunk_id] = False
    return placement


def parent_fractions(
    fragments: Sequence[Fragment], placement: Dict[int, bool]
) -> Dict[int, float]:
    """Aggregate chunk placement back to per-parent-arena fast fractions."""
    by_parent: Dict[int, Tuple[int, int]] = {}
    for frag in fragments:
        fast_b, tot_b = by_parent.get(frag.parent_arena_id, (0, 0))
        for c in frag.chunks:
            tot_b += c.nbytes
            if placement.get(c.chunk_id, c.fast):
                fast_b += c.nbytes
        by_parent[frag.parent_arena_id] = (fast_b, tot_b)
    return {
        pid: (fast / tot if tot else 1.0) for pid, (fast, tot) in by_parent.items()
    }
