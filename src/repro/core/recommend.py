"""Tier-recommendation engines (paper Sec. 3.2.1, from MemBrain).

Three strategies convert an interval profile into per-arena tier
recommendations for the fast tier of capacity ``C``:

* ``knapsack`` — 0/1 knapsack: value = access count, weight = resident bytes,
  capacity = C.  Exact DP when the scaled problem is small enough, otherwise
  the classical greedy-by-density approximation (which is also what makes
  knapsack's known weakness — rejecting a huge, hot site outright — visible).

* ``hotset``  — sort by accesses-per-byte, select until the aggregate size
  *first exceeds* C (intentional over-prescription).

* ``thermos`` — hotset that admits a capacity-crossing site only when the
  value it contributes exceeds the aggregate value of the hottest bytes it
  may displace; big high-bandwidth sites may keep a *portion* of their data
  in the fast tier.

Recommendations are expressed as ``TierAssignment``: arena_id -> fraction of
that arena's bytes recommended for the fast tier.  ``raw`` keeps the
un-clipped (possibly over-prescribed) 0/1 selection for analysis; ``fractions``
is clipped so that recommended fast bytes never exceed C — that is what
enforcement and the ski-rental cost model consume.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Sequence

from .profiler import ArenaProfile, IntervalProfile

Fraction = float


@dataclasses.dataclass(frozen=True)
class TierAssignment:
    capacity_bytes: int
    fractions: Dict[int, Fraction]   # arena_id -> fraction on fast tier
    raw: Dict[int, Fraction]         # pre-clip selection (may over-prescribe)
    strategy: str

    def fast_fraction(self, arena_id: int) -> Fraction:
        return self.fractions.get(arena_id, 0.0)

    def fast_bytes(self, profile_rows: Sequence[ArenaProfile]) -> int:
        return int(
            sum(r.resident_bytes * self.fast_fraction(r.arena_id) for r in profile_rows)
        )


def _sorted_by_density(rows: Sequence[ArenaProfile]) -> List[ArenaProfile]:
    # Hot first; break ties toward smaller sites (cheaper to keep fast).
    return sorted(rows, key=lambda r: (-r.density(), r.resident_bytes, r.arena_id))


def _clip_to_capacity(
    rows: Sequence[ArenaProfile],
    selection: Dict[int, Fraction],
    capacity: int,
) -> Dict[int, Fraction]:
    """Turn a possibly over-prescribed 0/1 selection into fractions whose
    fast-tier bytes fit in ``capacity``: hottest sites keep full residency,
    the site that crosses the boundary keeps the remaining portion."""
    out: Dict[int, Fraction] = {}
    free = capacity
    for r in _sorted_by_density(rows):
        frac = selection.get(r.arena_id, 0.0)
        if frac <= 0.0 or r.resident_bytes == 0:
            continue
        want = int(r.resident_bytes * frac)
        take = min(want, max(free, 0))
        if take > 0:
            out[r.arena_id] = take / r.resident_bytes
            free -= take
        if free <= 0:
            break
    return out


# ----------------------------------------------------------------- knapsack
_DP_MAX_CELLS = 4_000_000


def knapsack(profile: IntervalProfile, capacity_bytes: int) -> TierAssignment:
    rows = [r for r in profile.rows if r.resident_bytes > 0]
    raw: Dict[int, Fraction] = {}
    if rows and capacity_bytes > 0 and sum(r.resident_bytes for r in rows) <= capacity_bytes:
        raw = {r.arena_id: 1.0 for r in rows}   # everything fits
    elif rows and capacity_bytes > 0:
        # Scale weights so an exact DP stays tractable; fall back to greedy.
        unit = max(1, -(-capacity_bytes // max(1, _DP_MAX_CELLS // max(1, len(rows)))))
        cap_units = capacity_bytes // unit
        if cap_units >= 1 and len(rows) * (cap_units + 1) <= _DP_MAX_CELLS:
            raw = _knapsack_dp(rows, unit, cap_units)
        else:
            raw = _knapsack_greedy(rows, capacity_bytes)
    return TierAssignment(
        capacity_bytes=capacity_bytes,
        fractions=_clip_to_capacity(profile.rows, raw, capacity_bytes),
        raw=raw,
        strategy="knapsack",
    )


def _knapsack_dp(
    rows: Sequence[ArenaProfile], unit: int, cap_units: int
) -> Dict[int, Fraction]:
    n = len(rows)
    weights = [-(-r.resident_bytes // unit) for r in rows]  # ceil: never overfill
    values = [r.accesses for r in rows]
    # dp[c] = best value with capacity c; keep[i][c] via parent pointers.
    dp = [0] * (cap_units + 1)
    keep = [[False] * (cap_units + 1) for _ in range(n)]
    for i in range(n):
        w, v = weights[i], values[i]
        if w > cap_units:
            continue
        for c in range(cap_units, w - 1, -1):
            cand = dp[c - w] + v
            if cand > dp[c]:
                dp[c] = cand
                keep[i][c] = True
    out: Dict[int, Fraction] = {}
    c = cap_units
    for i in range(n - 1, -1, -1):
        if keep[i][c]:
            out[rows[i].arena_id] = 1.0
            c -= weights[i]
    return out


def _knapsack_greedy(
    rows: Sequence[ArenaProfile], capacity_bytes: int
) -> Dict[int, Fraction]:
    out: Dict[int, Fraction] = {}
    free = capacity_bytes
    for r in _sorted_by_density(rows):
        if r.resident_bytes <= free:   # 0/1: whole site or nothing
            out[r.arena_id] = 1.0
            free -= r.resident_bytes
    return out


# ------------------------------------------------------------------- hotset
def hotset(profile: IntervalProfile, capacity_bytes: int) -> TierAssignment:
    rows = [r for r in profile.rows if r.resident_bytes > 0]
    raw: Dict[int, Fraction] = {}
    used = 0
    for r in _sorted_by_density(rows):
        if used > capacity_bytes:
            break                       # stop after first crossing (Sec. 3.2.1)
        raw[r.arena_id] = 1.0
        used += r.resident_bytes
    return TierAssignment(
        capacity_bytes=capacity_bytes,
        fractions=_clip_to_capacity(profile.rows, raw, capacity_bytes),
        raw=raw,
        strategy="hotset",
    )


# ------------------------------------------------------------------ thermos
def thermos(profile: IntervalProfile, capacity_bytes: int) -> TierAssignment:
    rows = [r for r in profile.rows if r.resident_bytes > 0]
    raw: Dict[int, Fraction] = {}
    used = 0
    selected: List[ArenaProfile] = []
    for r in _sorted_by_density(rows):
        free = capacity_bytes - used
        if r.resident_bytes <= free:
            raw[r.arena_id] = 1.0
            selected.append(r)
            used += r.resident_bytes
            continue
        # Crossing the cap: admitting r may displace up to ``overflow`` bytes
        # of already-selected (hotter) data.  Admit only if r's contribution
        # beats the hottest bytes it could crowd out.
        overflow = r.resident_bytes - max(free, 0)
        displaced_value = _hottest_bytes_value(selected, overflow)
        if r.accesses > displaced_value:
            raw[r.arena_id] = 1.0
            selected.append(r)
            used += r.resident_bytes
        # else: skip r; colder-but-smaller sites may still fit the free space.
    return TierAssignment(
        capacity_bytes=capacity_bytes,
        fractions=_clip_to_capacity(profile.rows, raw, capacity_bytes),
        raw=raw,
        strategy="thermos",
    )


def _hottest_bytes_value(selected: Sequence[ArenaProfile], nbytes: int) -> float:
    """Aggregate access-value of the hottest ``nbytes`` among selected rows."""
    if nbytes <= 0:
        return 0.0
    total = 0.0
    remaining = nbytes
    for r in sorted(selected, key=lambda r: -r.density()):
        take = min(remaining, r.resident_bytes)
        total += take * r.density()
        remaining -= take
        if remaining <= 0:
            break
    return total


STRATEGIES: Dict[str, Callable[[IntervalProfile, int], TierAssignment]] = {
    "knapsack": knapsack,
    "hotset": hotset,
    "thermos": thermos,
}


def recommend(
    profile: IntervalProfile, capacity_bytes: int, strategy: str = "thermos"
) -> TierAssignment:
    try:
        fn = STRATEGIES[strategy]
    except KeyError:
        raise ValueError(
            f"unknown strategy {strategy!r}; expected one of {sorted(STRATEGIES)}"
        ) from None
    return fn(profile, capacity_bytes)
