"""Deprecated compatibility shim — the Algorithm-1 loop now lives in
``repro.core.runtime`` (``GuidanceRuntime`` + ``TierBackend``).

``OnlineGDT`` was the original controller class; it survives as a thin
alias that wires a ``GuidanceRuntime`` to an ``ArenaBackend`` so existing
examples and callers keep running unchanged:

    gdt = OnlineGDT(arenas, hw, GDTConfig(...), placer=...)
    gdt.on_step()          # same hooks
    gdt.history            # same telemetry (now IntervalEvent objects)

New code should construct ``GuidanceRuntime`` directly — see DESIGN.md
("Migrating from OnlineGDT") for the mapping.
"""

from __future__ import annotations

from typing import Optional

from .arenas import ArenaManager
from .hwmodel import HardwareModel
from .runtime import (
    ArenaBackend,
    FractionPlacer,
    GuidanceConfig,
    GuidanceRuntime,
    IntervalEvent,
    MoveStats,
    TierPlacer,
)

# Deprecated names, kept importable from their original home.
GDTConfig = GuidanceConfig
IntervalRecord = IntervalEvent

__all__ = [
    "FractionPlacer",
    "GDTConfig",
    "IntervalRecord",
    "MoveStats",
    "OnlineGDT",
    "TierPlacer",
]


class OnlineGDT(GuidanceRuntime):
    """Deprecated: ``GuidanceRuntime`` over an ``ArenaBackend``.

    Kept so the original constructor signature — manager, hardware model,
    config, optional placer — keeps working.  All behaviour (interval
    gating, ski-rental, enforcement order, telemetry) is the shared
    ``GuidanceRuntime`` loop.
    """

    def __init__(
        self,
        arenas: ArenaManager,
        hw: HardwareModel,
        config: GuidanceConfig,
        placer: Optional[TierPlacer] = None,
    ):
        super().__init__(ArenaBackend(arenas, hw, placer=placer), hw, config)

    # Original attribute surface, now delegating to the backend.
    @property
    def arenas(self) -> ArenaManager:
        return self.backend.arenas

    @property
    def placer(self) -> TierPlacer:
        return self.backend.placer

    @property
    def profiler(self):
        return self.backend.profiler
