"""OnlineGDT — the online guided-data-tiering controller (paper Sec. 4.2-4.3).

Ties together the hybrid arena manager, the online profiler, a recommendation
strategy, and the ski-rental break-even rule.  The controller is host-side
Python that runs *between* steps (the analogue of the paper's separate runtime
thread waking at IntervalTime); enforcement is delegated to a ``TierPlacer``
so the same controller drives

* the calibrated memory simulator (``mem/``) for the paper-faithful
  reproduction experiments,
* real JAX arrays via memory-kind shardings (``placement.JaxArenaPlacer``),
* the paged KV cache of the serving engine (``serve/kvcache.py``).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Protocol

from .arenas import ArenaManager
from .hwmodel import HardwareModel
from .profiler import IntervalProfile, OnlineProfiler
from .recommend import TierAssignment, recommend
from .skirental import MigrationDecision, decide


class TierPlacer(Protocol):
    """Enforcement backend: remap arenas to match a tier assignment."""

    def enforce(
        self, profile: IntervalProfile, recs: TierAssignment
    ) -> "MoveStats":  # pragma: no cover - protocol
        ...


@dataclasses.dataclass
class MoveStats:
    bytes_demoted: int = 0   # fast -> slow
    bytes_promoted: int = 0  # slow -> fast

    @property
    def bytes_moved(self) -> int:
        return self.bytes_demoted + self.bytes_promoted


@dataclasses.dataclass
class GDTConfig:
    strategy: str = "thermos"           # paper default (Sec. 5.3)
    fast_capacity_bytes: int = 0        # budget for the fast tier
    interval_steps: int = 10            # decision interval, in runtime steps
    decay: float = 1.0                  # profile reweighting (1.0 = paper)
    min_move_bytes: int = 0             # ignore micro-migrations
    promotion_threshold: int = 4 * 2**20  # hybrid-arena threshold (Sec. 5.3)
    enabled: bool = True


@dataclasses.dataclass
class IntervalRecord:
    """Telemetry for one MaybeMigrate invocation (feeds Fig.7-style plots)."""

    interval_index: int
    decision: MigrationDecision
    migrated: bool
    bytes_moved: int
    fast_bytes_after: int
    profile_seconds: float


class FractionPlacer:
    """Bookkeeping-only placer: updates arena fast fractions.

    Used by the simulator (which charges migration time itself) and as the
    base class for real placers.  Enforcement order follows the paper:
    demotions (fast->slow) first to free space, then promotions.
    """

    def __init__(self, arenas: ArenaManager):
        self.arenas = arenas

    def _apply(self, arena_id: int, new_fraction: float) -> None:
        # Subclasses move real data here.
        pass

    def enforce(self, profile: IntervalProfile, recs: TierAssignment) -> MoveStats:
        stats = MoveStats()
        by_id = {a.arena_id: a for a in self.arenas}
        demotions = []
        promotions = []
        for row in profile.rows:
            arena = by_id.get(row.arena_id)
            if arena is None:
                continue
            target = recs.fast_fraction(row.arena_id)
            delta = target - arena.fast_fraction
            moved = abs(int(delta * arena.resident_bytes))
            if moved == 0:
                continue
            (demotions if delta < 0 else promotions).append((arena, target, moved))
        for arena, target, moved in demotions:     # free space first
            self._apply(arena.arena_id, target)
            arena.fast_fraction = target
            stats.bytes_demoted += moved
        for arena, target, moved in promotions:
            self._apply(arena.arena_id, target)
            arena.fast_fraction = target
            stats.bytes_promoted += moved
        return stats


class OnlineGDT:
    """The OnlineGDT loop of Algorithm 1, driven by runtime step hooks."""

    def __init__(
        self,
        arenas: ArenaManager,
        hw: HardwareModel,
        config: GDTConfig,
        placer: Optional[TierPlacer] = None,
    ):
        self.arenas = arenas
        self.hw = hw
        self.config = config
        self.placer: TierPlacer = placer if placer is not None else FractionPlacer(arenas)
        self.profiler = OnlineProfiler(arenas, hw, decay=config.decay)
        self.history: List[IntervalRecord] = []
        self._steps_since_interval = 0
        self.side_table: Dict[int, float] = {}  # arena_id -> enforced fraction

    # ------------------------------------------------------------------ hooks
    def on_step(self) -> Optional[IntervalRecord]:
        """Call once per runtime step; fires MaybeMigrate at the interval."""
        if not self.config.enabled:
            return None
        self._steps_since_interval += 1
        if self._steps_since_interval < self.config.interval_steps:
            return None
        self._steps_since_interval = 0
        return self.maybe_migrate()

    # ------------------------------------------------------------ MaybeMigrate
    def maybe_migrate(self) -> IntervalRecord:
        profile = self.profiler.snapshot()
        recs = recommend(profile, self.config.fast_capacity_bytes, self.config.strategy)
        decision = decide(profile, recs, self.hw, self.config.min_move_bytes)
        bytes_moved = 0
        if decision.migrate:
            stats = self.placer.enforce(profile, recs)
            bytes_moved = stats.bytes_moved
            for arena_id, frac in recs.fractions.items():
                self.side_table[arena_id] = frac
        record = IntervalRecord(
            interval_index=profile.interval_index,
            decision=decision,
            migrated=decision.migrate,
            bytes_moved=bytes_moved,
            fast_bytes_after=self.arenas.fast_tier_bytes(),
            profile_seconds=profile.collection_seconds,
        )
        self.history.append(record)
        return record

    # ------------------------------------------------------------- telemetry
    @property
    def total_bytes_migrated(self) -> int:
        return sum(r.bytes_moved for r in self.history)

    @property
    def migration_count(self) -> int:
        return sum(1 for r in self.history if r.migrated)
