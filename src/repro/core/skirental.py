"""Ski-rental migration decision (paper Sec. 4.2, Algorithm 1).

At each interval the runtime compares:

* **rental cost** — the recurring cost of keeping the current placement:
  ``(a - b) * EXTRA_NS_PER_SLOWER_ACCESS`` where ``a`` counts accesses served
  by the slow tier that the recommended placement would serve from the fast
  tier, and ``b`` the converse.  Because access counters accumulate from the
  start of execution (no reweighting by default), this *is* the cumulative
  rental cost the break-even algorithm requires.

* **purchase cost** — the one-time cost of enforcing the recommendation:
  pages that would move (either direction) times ``NS_PER_PAGE_MOVED``.

Migration happens iff rental > purchase — the deterministic break-even rule,
which is 2-competitive for ski rental.

Fractional residency generalizes the paper's 0/1 tiers: an arena with
``fast_fraction`` f serves accesses from the fast tier with probability f
(accesses are assumed uniform over the arena's bytes, which is exactly the
assumption site-granularity management makes in the paper).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from .hwmodel import HardwareModel
from .profiler import IntervalProfile
from .recommend import TierAssignment


@dataclasses.dataclass(frozen=True)
class MigrationDecision:
    rental_cost_ns: float
    purchase_cost_ns: float
    bytes_to_move: int
    pages_to_move: int
    migrate: bool

    @property
    def ratio(self) -> float:
        return (
            self.rental_cost_ns / self.purchase_cost_ns
            if self.purchase_cost_ns > 0
            else float("inf")
        )


def get_rental_cost(
    profile: IntervalProfile, recs: TierAssignment, hw: HardwareModel
) -> float:
    a = 0.0  # slow-tier accesses that recs would serve from fast
    b = 0.0  # fast-tier accesses that recs would push to slow
    for r in profile.rows:
        rec = recs.fast_fraction(r.arena_id)
        cur = r.fast_fraction
        if rec > cur:
            a += r.accesses * (rec - cur)
        elif cur > rec:
            b += r.accesses * (cur - rec)
    if a > b:
        return (a - b) * hw.extra_ns_per_slow_access
    return 0.0


def get_purchase_cost(
    profile: IntervalProfile, recs: TierAssignment, hw: HardwareModel
) -> float:
    return _move_cost_ns(profile, recs, hw)


def _move_cost_ns(
    profile: IntervalProfile, recs: TierAssignment, hw: HardwareModel
) -> float:
    total_pages = 0
    for r in profile.rows:
        delta = abs(recs.fast_fraction(r.arena_id) - r.fast_fraction)
        nbytes = int(delta * r.resident_bytes)
        if nbytes:
            total_pages += hw.pages(nbytes)
    return total_pages * hw.ns_per_page_moved


def get_move_bytes(profile: IntervalProfile, recs: TierAssignment) -> int:
    total = 0
    for r in profile.rows:
        delta = abs(recs.fast_fraction(r.arena_id) - r.fast_fraction)
        total += int(delta * r.resident_bytes)
    return total


def decide(
    profile: IntervalProfile,
    recs: TierAssignment,
    hw: HardwareModel,
    min_move_bytes: int = 0,
) -> MigrationDecision:
    """Algorithm 1's MaybeMigrate comparison (without the enforcement)."""
    rental = get_rental_cost(profile, recs, hw)
    bytes_to_move = get_move_bytes(profile, recs)
    purchase = _move_cost_ns(profile, recs, hw)
    migrate = rental > purchase and bytes_to_move > min_move_bytes
    return MigrationDecision(
        rental_cost_ns=rental,
        purchase_cost_ns=purchase,
        bytes_to_move=bytes_to_move,
        pages_to_move=hw.pages(bytes_to_move) if bytes_to_move else 0,
        migrate=migrate,
    )
