"""Allocation sites.

In the paper a *site* is an allocation instruction plus up to three levels of
call-path context, annotated by a compiler pass.  In this framework the
analogue is the *module-tree path* of a tensor group: every parameter,
optimizer-state leaf, KV page pool, or activation group is registered under a
path like ``("layers", "block", "attn", "wq")``.  Paths are truncated to a
configurable context depth (default 3, matching the paper's clone depth) so
that, exactly as in the paper, distinct deep contexts can intentionally share
a site.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Dict, Iterable, Iterator, Optional, Tuple


class SiteKind(enum.Enum):
    PARAM = "param"
    OPT_STATE = "opt_state"
    KV_CACHE = "kv_cache"
    ACTIVATION = "activation"
    BUFFER = "buffer"
    OTHER = "other"


@dataclasses.dataclass(frozen=True)
class Site:
    """An allocation context.  Immutable; identity is the (truncated) path."""

    site_id: int
    path: Tuple[str, ...]
    kind: SiteKind = SiteKind.OTHER

    @property
    def label(self) -> str:
        return "/".join(self.path)

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        return f"Site({self.site_id}, {self.label}, {self.kind.value})"


class SiteRegistry:
    """Interns sites by truncated path, like the paper's annotation pass.

    ``context_depth`` mirrors the paper's "up to three layers of call path
    context": only the last ``context_depth`` path components participate in
    site identity.  Deeper paths therefore coalesce, keeping the number of
    sites bounded the way the paper's cloning bound does.
    """

    def __init__(self, context_depth: int = 3):
        if context_depth < 1:
            raise ValueError("context_depth must be >= 1")
        self.context_depth = context_depth
        self._by_key: Dict[Tuple[Tuple[str, ...], SiteKind], Site] = {}
        self._by_id: Dict[int, Site] = {}

    def _truncate(self, path: Iterable[str]) -> Tuple[str, ...]:
        tup = tuple(str(p) for p in path)
        if not tup:
            raise ValueError("site path must be non-empty")
        return tup[-self.context_depth:]

    def register(self, path: Iterable[str], kind: SiteKind = SiteKind.OTHER) -> Site:
        key = (self._truncate(path), kind)
        site = self._by_key.get(key)
        if site is None:
            site = Site(site_id=len(self._by_id), path=key[0], kind=kind)
            self._by_key[key] = site
            self._by_id[site.site_id] = site
        return site

    def get(self, site_id: int) -> Site:
        return self._by_id[site_id]

    def find(self, path: Iterable[str], kind: SiteKind = SiteKind.OTHER) -> Optional[Site]:
        return self._by_key.get((self._truncate(path), kind))

    def __len__(self) -> int:
        return len(self._by_id)

    def __iter__(self) -> Iterator[Site]:
        return iter(self._by_id.values())
