"""repro.core — the paper's contribution: online application guidance for
heterogeneous memory, adapted to JAX/TPU (see DESIGN.md)."""

from .arenas import Arena, ArenaManager, DEFAULT_PROMOTION_THRESHOLD
from .fragmentation import (
    ChunkStats,
    Fragment,
    collapse_to_chunks,
    explode_profile,
    fragment_by_age,
    parent_fractions,
)
from .hwmodel import CLX, TPU_V5E, HardwareModel, TierSpec
from .profiler import ArenaProfile, IntervalProfile, OnlineProfiler
from .recommend import TierAssignment, hotset, knapsack, recommend, thermos
from .sites import Site, SiteKind, SiteRegistry
from .skirental import MigrationDecision, decide, get_purchase_cost, get_rental_cost
from .tiering import FractionPlacer, GDTConfig, IntervalRecord, MoveStats, OnlineGDT

__all__ = [
    "Arena",
    "ArenaManager",
    "ArenaProfile",
    "CLX",
    "ChunkStats",
    "DEFAULT_PROMOTION_THRESHOLD",
    "FractionPlacer",
    "Fragment",
    "GDTConfig",
    "HardwareModel",
    "IntervalProfile",
    "IntervalRecord",
    "MigrationDecision",
    "MoveStats",
    "OnlineGDT",
    "OnlineProfiler",
    "Site",
    "SiteKind",
    "SiteRegistry",
    "TPU_V5E",
    "TierAssignment",
    "TierSpec",
    "collapse_to_chunks",
    "decide",
    "explode_profile",
    "fragment_by_age",
    "get_purchase_cost",
    "get_rental_cost",
    "hotset",
    "knapsack",
    "parent_fractions",
    "recommend",
    "thermos",
]
