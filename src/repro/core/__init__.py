"""repro.core — the paper's contribution: online application guidance for
heterogeneous memory, adapted to JAX/TPU.

The online loop (Algorithm 1) is owned by a single controller,
``runtime.GuidanceRuntime``, which drives pluggable ``TierBackend``
implementations — arenas of JAX arrays, paged KV pools, the calibrated
simulator.  See DESIGN.md at the repository root for the architecture and
the backend contract.
"""

from .arenas import Arena, ArenaManager, DEFAULT_PROMOTION_THRESHOLD
from .fragmentation import (
    ChunkStats,
    Fragment,
    collapse_to_chunks,
    explode_profile,
    fragment_by_age,
    parent_fractions,
)
from .hwmodel import CLX, TPU_V5E, HardwareModel, TierSpec
from .profiler import ArenaProfile, IntervalProfile, OnlineProfiler
from .recommend import TierAssignment, hotset, knapsack, recommend, thermos
from .runtime import (
    ArenaBackend,
    FractionPlacer,
    GuidanceConfig,
    GuidanceRuntime,
    IntervalEvent,
    MigrationPlan,
    MoveStats,
    RentalEvent,
    TierBackend,
    TierPlacer,
    static_plan,
)
from .sites import Site, SiteKind, SiteRegistry
from .skirental import MigrationDecision, decide, get_purchase_cost, get_rental_cost

__all__ = [
    "Arena",
    "ArenaBackend",
    "ArenaManager",
    "ArenaProfile",
    "CLX",
    "ChunkStats",
    "DEFAULT_PROMOTION_THRESHOLD",
    "FractionPlacer",
    "Fragment",
    "GuidanceConfig",
    "GuidanceRuntime",
    "HardwareModel",
    "IntervalEvent",
    "IntervalProfile",
    "MigrationDecision",
    "MigrationPlan",
    "MoveStats",
    "OnlineProfiler",
    "RentalEvent",
    "Site",
    "SiteKind",
    "SiteRegistry",
    "TPU_V5E",
    "TierAssignment",
    "TierBackend",
    "TierPlacer",
    "TierSpec",
    "collapse_to_chunks",
    "decide",
    "explode_profile",
    "fragment_by_age",
    "get_purchase_cost",
    "get_rental_cost",
    "hotset",
    "knapsack",
    "parent_fractions",
    "recommend",
    "static_plan",
    "thermos",
]
