"""GuidanceRuntime — the single owner of Algorithm 1 (paper Sec. 4.2-4.3).

One online loop drives every workload in the framework:

    profile -> (optional) fragment -> recommend -> ski-rental decide
            -> enforce -> record

Consumers plug in through the ``TierBackend`` protocol instead of
re-implementing the loop:

* ``snapshot() -> IntervalProfile`` — per-arena access/residency rows,
* ``telemetry() -> {arena_id: [ChunkStats]}`` — *optional* per-chunk stats;
  when present, the runtime explodes big arenas into age-quantile fragments
  (Sec. 6.3 fix) and collapses the recommendation back to chunk placement —
  fragmentation lives in the core loop, not in callers,
* ``enforce(plan) -> MoveStats`` — realize a ``MigrationPlan`` physically,
* ``reweight(decay)`` — Algorithm 1's optional ReweightProfile step.

Three backends ship with the framework: ``ArenaBackend`` (trainer path:
``FractionPlacer``/``JaxArenaPlacer`` over an ``ArenaManager``),
``serve.engine.PagedKVBackend`` (KV pages of the serving engine) and
``mem.simulator.SimArenaBackend`` (the calibrated reproduction rig).

All telemetry that used to be scattered across consumers (per-interval
record history, ``Engine.decisions``, swap-in counters) flows into one
structured event stream (``events``: ``IntervalEvent`` / ``RentalEvent``)
consumed by ``launch.analysis.guidance_summary`` and the benchmarks.

The seed controller's deprecated alias (DESIGN.md §8) is gone: construct
``GuidanceRuntime`` over the backend you need.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Mapping, Optional, Protocol, Sequence

from .arenas import ArenaManager
from .fragmentation import (
    FRAGMENT_ID_BASE,
    ChunkStats,
    Fragment,
    collapse_to_chunks,
    explode_profile,
    parent_fractions,
)
from .hwmodel import HardwareModel
from .profiler import IntervalProfile, OnlineProfiler
from .recommend import TierAssignment, recommend
from .skirental import MigrationDecision, decide


# ------------------------------------------------------------------ config
@dataclasses.dataclass
class GuidanceConfig:
    """Knobs of Algorithm 1."""

    strategy: str = "thermos"           # paper default (Sec. 5.3)
    fast_capacity_bytes: int = 0        # budget for the fast tier
    interval_steps: int = 10            # decision interval, in runtime steps
    decay: float = 1.0                  # ReweightProfile factor (1.0 = paper)
    min_move_bytes: int = 0             # ignore micro-migrations
    promotion_threshold: int = 4 * 2**20  # hybrid-arena threshold (Sec. 5.3)
    enabled: bool = True
    num_fragments: int = 4              # age quantiles when telemetry exists
    skip_empty_intervals: bool = False  # no event when the profile is empty

    def __post_init__(self):
        if not (0.0 <= self.decay <= 1.0):
            raise ValueError("decay must be in [0, 1]")


# ------------------------------------------------------------------- stats
@dataclasses.dataclass
class MoveStats:
    """What one enforcement actually moved."""

    bytes_demoted: int = 0       # fast -> slow
    bytes_promoted: int = 0      # slow -> fast
    dropped_promotions: int = 0  # planned promotions refused for capacity

    @property
    def bytes_moved(self) -> int:
        return self.bytes_demoted + self.bytes_promoted


# -------------------------------------------------------------------- plan
@dataclasses.dataclass
class MigrationPlan:
    """Everything a backend needs to realize one interval's decision.

    ``fractions`` is the per-(parent-)arena fast-fraction target; for
    backends with chunk telemetry, ``chunk_placement`` maps each chunk id to
    its recommended tier (hottest chunks claim the fast bytes first).
    """

    profile: IntervalProfile            # the raw (unexploded) snapshot
    exploded: IntervalProfile           # post-fragmentation view
    fragments: List[Fragment]
    assignment: TierAssignment          # recommendation over ``exploded``
    decision: MigrationDecision
    fractions: Dict[int, float]         # arena_id -> target fast fraction
    chunk_placement: Dict[int, bool]    # chunk_id -> should-be-fast
    capacity_bytes: int
    strategy: str

    def fast_fraction(self, arena_id: int) -> float:
        """Target fraction for one arena (0.0 when not recommended) — the
        same accessor ``TierAssignment`` offers, so placers accept either."""
        return self.fractions.get(arena_id, 0.0)


# ------------------------------------------------------------------ events
@dataclasses.dataclass
class IntervalEvent:
    """One MaybeMigrate invocation of the controller loop."""

    interval_index: int
    decision: MigrationDecision
    migrated: bool
    bytes_moved: int
    fast_bytes_after: int
    profile_seconds: float
    step: int = -1                      # backend step clock, if provided
    backend: str = ""
    dropped_promotions: int = 0
    # The full plan (profiles, fragments, chunk placement) is retained only
    # on the MOST RECENT interval event; the runtime strips it from older
    # events so a long-lived stream stays scalar-sized.
    plan: Optional[MigrationPlan] = None
    kind: str = "interval"


@dataclasses.dataclass
class RentalEvent:
    """A between-intervals rental payment (e.g. a demand swap-in)."""

    step: int
    nbytes: int
    source: str = "swap_in"
    kind: str = "rental"


GuidanceEvent = object  # IntervalEvent | RentalEvent (discriminated by .kind)


# ---------------------------------------------------------------- protocol
class TierBackend(Protocol):
    """What a consumer implements to be driven by ``GuidanceRuntime``."""

    def snapshot(self) -> IntervalProfile:  # pragma: no cover - protocol
        ...

    def telemetry(self) -> Mapping[int, Sequence[ChunkStats]]:  # pragma: no cover
        """Per-arena chunk stats; empty mapping disables fragmentation."""
        ...

    def enforce(self, plan: MigrationPlan) -> MoveStats:  # pragma: no cover
        ...

    def reweight(self, decay: float) -> None:  # pragma: no cover - protocol
        ...


class TierPlacer(Protocol):
    """Arena-granularity enforcement primitive (``FractionPlacer`` family)."""

    def enforce(self, profile: IntervalProfile, recs) -> MoveStats:  # pragma: no cover
        ...


# ---------------------------------------------------------------- placers
class FractionPlacer:
    """Bookkeeping-only placer: updates arena fast fractions.

    Used by the simulator (which charges migration time itself) and as the
    base class for real placers.  Enforcement order follows the paper:
    demotions (fast->slow) first to free space, then promotions.  ``recs``
    may be a ``TierAssignment`` or a ``MigrationPlan`` — anything with a
    ``fast_fraction(arena_id)`` accessor.
    """

    def __init__(self, arenas: ArenaManager):
        self.arenas = arenas

    def _apply(self, arena_id: int, new_fraction: float) -> None:
        # Subclasses move real data here.
        pass

    def enforce(self, profile: IntervalProfile, recs) -> MoveStats:
        stats = MoveStats()
        by_id = {a.arena_id: a for a in self.arenas}
        demotions = []
        promotions = []
        for row in profile.rows:
            arena = by_id.get(row.arena_id)
            if arena is None:
                continue
            target = recs.fast_fraction(row.arena_id)
            delta = target - arena.fast_fraction
            moved = abs(int(delta * arena.resident_bytes))
            if moved == 0:
                continue
            (demotions if delta < 0 else promotions).append((arena, target, moved))
        for arena, target, moved in demotions:     # free space first
            self._apply(arena.arena_id, target)
            arena.fast_fraction = target
            stats.bytes_demoted += moved
        for arena, target, moved in promotions:
            self._apply(arena.arena_id, target)
            arena.fast_fraction = target
            stats.bytes_promoted += moved
        return stats


# ---------------------------------------------------------------- backends
class ArenaBackend:
    """TierBackend over an ``ArenaManager`` + ``TierPlacer`` (trainer path).

    ``FractionPlacer`` keeps it bookkeeping-only; ``placement.JaxArenaPlacer``
    moves real JAX arrays between memory kinds.
    """

    name = "arena"

    def __init__(
        self,
        arenas: ArenaManager,
        hw: HardwareModel,
        placer: Optional[TierPlacer] = None,
    ):
        self.arenas = arenas
        self.placer: TierPlacer = placer if placer is not None else FractionPlacer(arenas)
        # Decay is owned by the runtime (reweight); the profiler never decays.
        self.profiler = OnlineProfiler(arenas, hw, decay=1.0)

    def snapshot(self) -> IntervalProfile:
        return self.profiler.snapshot()

    def telemetry(self) -> Mapping[int, Sequence[ChunkStats]]:
        return {}

    def enforce(self, plan: MigrationPlan) -> MoveStats:
        return self.placer.enforce(plan.profile, plan)

    def reweight(self, decay: float) -> None:
        self.arenas.scale_access_counters(decay)

    def fast_bytes(self) -> int:
        return self.arenas.fast_tier_bytes()


# ----------------------------------------------------------------- runtime
class GuidanceRuntime:
    """The online loop of Algorithm 1, driven by runtime step hooks.

    Host-side Python that runs *between* steps (the analogue of the paper's
    runtime thread waking at IntervalTime).  Owns interval gating, profile
    fragmentation, recommendation, the ski-rental break-even rule, the
    enforcement dispatch and the telemetry stream; the backend owns only
    mechanism (how to observe and how to move bytes).
    """

    def __init__(
        self,
        backend: TierBackend,
        hw: HardwareModel,
        config: GuidanceConfig,
        clock: Optional[Callable[[], int]] = None,
    ):
        self.backend = backend
        self.hw = hw
        self.config = config
        self.clock = clock
        self.events: List[object] = []
        self.side_table: Dict[int, float] = {}  # arena_id -> enforced fraction
        self.last_plan: Optional[MigrationPlan] = None
        self._steps_since_interval = 0

    # ------------------------------------------------------------------ hooks
    def on_step(self) -> Optional[IntervalEvent]:
        """Call once per runtime step; fires MaybeMigrate at the interval."""
        if not self.config.enabled:
            return None
        self._steps_since_interval += 1
        if self._steps_since_interval < self.config.interval_steps:
            return None
        self._steps_since_interval = 0
        return self.maybe_migrate()

    # ------------------------------------------------------------ MaybeMigrate
    def maybe_migrate(self) -> Optional[IntervalEvent]:
        profile = self.backend.snapshot()
        if not profile.rows and self.config.skip_empty_intervals:
            return None
        telemetry = self._collect_telemetry()
        if telemetry:
            exploded, fragments = explode_profile(
                profile, telemetry, num_fragments=self.config.num_fragments)
        else:
            exploded, fragments = profile, []
        if self.config.decay < 1.0:       # ReweightProfile (Sec. 4.2)
            self.backend.reweight(self.config.decay)
        recs = recommend(exploded, self.config.fast_capacity_bytes,
                         self.config.strategy)
        decision = decide(exploded, recs, self.hw, self.config.min_move_bytes)
        plan = self._build_plan(profile, exploded, fragments, recs, decision)
        self.last_plan = plan
        on_plan = getattr(self.backend, "on_plan", None)
        if on_plan is not None:           # optional backend hook (every interval)
            on_plan(plan)
        stats = MoveStats()
        if decision.migrate:
            stats = self.backend.enforce(plan)
            self.side_table.update(plan.fractions)
        event = IntervalEvent(
            interval_index=profile.interval_index,
            decision=decision,
            migrated=decision.migrate,
            bytes_moved=stats.bytes_moved,
            fast_bytes_after=self._fast_bytes(),
            profile_seconds=profile.collection_seconds,
            step=self.clock() if self.clock is not None else -1,
            backend=getattr(self.backend, "name", type(self.backend).__name__),
            dropped_promotions=stats.dropped_promotions,
            plan=plan,
        )
        # Keep the heavy plan payload only on the newest event: an engine
        # firing every interval for hours must not accumulate per-chunk
        # telemetry in the history (scalar fields are kept forever).
        for prior in reversed(self.events):
            if getattr(prior, "kind", "") == "interval":
                prior.plan = None
                break
        self.events.append(event)
        return event

    def _collect_telemetry(self) -> Mapping[int, Sequence[ChunkStats]]:
        fn = getattr(self.backend, "telemetry", None)
        if fn is None or self.config.num_fragments < 1:
            return {}
        return fn() or {}

    def _build_plan(self, profile, exploded, fragments, recs, decision) -> MigrationPlan:
        if fragments:
            chunk_placement = collapse_to_chunks(fragments, recs.fractions)
            fractions = {aid: f for aid, f in recs.fractions.items()
                         if aid < FRAGMENT_ID_BASE}
            fractions.update(parent_fractions(fragments, chunk_placement))
        else:
            chunk_placement = {}
            fractions = dict(recs.fractions)
        return MigrationPlan(
            profile=profile, exploded=exploded, fragments=list(fragments),
            assignment=recs, decision=decision, fractions=fractions,
            chunk_placement=chunk_placement,
            capacity_bytes=self.config.fast_capacity_bytes,
            strategy=self.config.strategy,
        )

    def _fast_bytes(self) -> int:
        fn = getattr(self.backend, "fast_bytes", None)
        return int(fn()) if fn is not None else 0

    # ------------------------------------------------------------- telemetry
    def record_rental(self, nbytes: int, source: str = "swap_in",
                      step: Optional[int] = None) -> None:
        """Log a between-intervals rental payment (demand swap-in etc.)."""
        if step is None:
            step = self.clock() if self.clock is not None else -1
        self.events.append(RentalEvent(step=step, nbytes=nbytes, source=source))

    @property
    def history(self) -> List[IntervalEvent]:
        return [e for e in self.events if getattr(e, "kind", "") == "interval"]

    @property
    def decisions(self) -> List[MigrationDecision]:
        return [e.decision for e in self.history]

    @property
    def rentals(self) -> List[RentalEvent]:
        return [e for e in self.events if getattr(e, "kind", "") == "rental"]

    @property
    def total_bytes_migrated(self) -> int:
        return sum(e.bytes_moved for e in self.history)

    @property
    def migration_count(self) -> int:
        return sum(1 for e in self.history if e.migrated)


# ------------------------------------------------------------ offline path
def static_plan(
    profile: IntervalProfile, capacity_bytes: int, strategy: str = "thermos"
) -> TierAssignment:
    """Offline MemBrain: one-shot recommendation over a whole-run profile.

    No ski-rental gate and no enforcement — callers (the simulator's offline
    oracle, dry-run planners) apply the returned fractions statically.  This
    is the only sanctioned entry to the recommendation engines outside the
    online loop.
    """
    return recommend(profile, capacity_bytes, strategy)
