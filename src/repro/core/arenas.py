"""Hybrid arena allocation (paper Sec. 4.1.1).

Two arena classes:

* **Private pool** — one aggregate arena that absorbs every site whose
  cumulative allocated bytes stay below ``promotion_threshold`` (paper: 4 MB).
  It is always pinned to the fast tier and is *not* profiled, exactly like the
  paper's thread-private arenas: small, hot-or-unknown data is cheap to keep
  fast and expensive to track.

* **Shared arenas** — one per promoted site.  These are the units of
  profiling and of tier migration.  A shared arena knows its resident bytes
  (exact — the runtime is the allocator, the analogue of the paper's VMA
  fault/release instrumentation) and its current tier, possibly fractional:
  ``fast_fraction`` of its pages on the fast tier.  Fractional residency is
  what thermos' "place a portion of a big hot site in the upper tier" needs,
  and what paged arenas (KV pools) implement natively.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterator, List, Optional

from .sites import Site, SiteKind, SiteRegistry

PRIVATE_POOL_ID = -1
DEFAULT_PROMOTION_THRESHOLD = 4 * 2**20  # 4 MB, paper Sec. 5.3


@dataclasses.dataclass
class Arena:
    """A profiled, migratable group of data belonging to one site."""

    arena_id: int
    site: Site
    resident_bytes: int = 0
    # Fraction of resident bytes currently on the fast tier, in [0, 1].
    fast_fraction: float = 1.0
    # Cumulative access counter for the current profile epoch.
    accesses: int = 0

    @property
    def fast_bytes(self) -> int:
        return int(round(self.resident_bytes * self.fast_fraction))

    @property
    def slow_bytes(self) -> int:
        return self.resident_bytes - self.fast_bytes


class ArenaManager:
    """Implements the hybrid allocation policy over logical allocations.

    The runtime reports logical allocation events (``allocate``), frees
    (``release``) and access traffic (``touch``).  Small sites live in the
    private pool until their *cumulative* allocated bytes cross the promotion
    threshold; from then on their data belongs to a dedicated shared arena.
    (The already-pooled prefix stays in the pool, as in the paper — only *new*
    data from the promoted context flows to the shared arena.  For tensor
    arenas, where an "allocation" is one array, this means the array that
    crosses the threshold is the first one placed in the shared arena.)
    """

    def __init__(
        self,
        registry: Optional[SiteRegistry] = None,
        promotion_threshold: int = DEFAULT_PROMOTION_THRESHOLD,
        on_promote: Optional[Callable[[Arena], None]] = None,
        fast_capacity_bytes: Optional[int] = None,
    ):
        """``fast_capacity_bytes``: physical size of the fast tier.  When set,
        new allocations follow *first-touch* semantics — they land on the fast
        tier while it has room and spill to the slow tier once full (the
        paper's unguided baseline, and the placement every guided run starts
        from).  When None, everything starts fast (unconstrained)."""
        self.registry = registry if registry is not None else SiteRegistry()
        self.promotion_threshold = promotion_threshold
        self.fast_capacity_bytes = fast_capacity_bytes
        self._cumulative: Dict[int, int] = {}           # site_id -> bytes ever
        self._arenas: Dict[int, Arena] = {}             # site_id -> shared arena
        self._private_bytes: Dict[int, int] = {}        # site_id -> pooled bytes
        self._on_promote = on_promote
        self._next_arena_id = 0

    # ------------------------------------------------------------------ alloc
    def allocate(self, site: Site, nbytes: int) -> Optional[Arena]:
        """Record an allocation; returns the shared arena it landed in, or
        None if it went to the private pool."""
        if nbytes < 0:
            raise ValueError("negative allocation")
        cum = self._cumulative.get(site.site_id, 0) + nbytes
        self._cumulative[site.site_id] = cum
        arena = self._arenas.get(site.site_id)
        if arena is None:
            if cum <= self.promotion_threshold:
                self._private_bytes[site.site_id] = (
                    self._private_bytes.get(site.site_id, 0) + nbytes
                )
                return None
            arena = Arena(arena_id=self._next_arena_id, site=site, resident_bytes=0)
            self._next_arena_id += 1
            self._arenas[site.site_id] = arena
            if self._on_promote is not None:
                self._on_promote(arena)
        if self.fast_capacity_bytes is None:
            arena.resident_bytes += nbytes
            return arena
        # First-touch: the new bytes take whatever fast-tier room remains.
        free = max(0, self.fast_capacity_bytes - self.fast_tier_bytes())
        fast_take = min(nbytes, free)
        old_fast = arena.fast_bytes
        arena.resident_bytes += nbytes
        arena.fast_fraction = (
            (old_fast + fast_take) / arena.resident_bytes
            if arena.resident_bytes
            else 1.0
        )
        return arena

    def release(self, site: Site, nbytes: int) -> None:
        arena = self._arenas.get(site.site_id)
        if arena is not None:
            arena.resident_bytes = max(0, arena.resident_bytes - nbytes)
        else:
            cur = self._private_bytes.get(site.site_id, 0)
            self._private_bytes[site.site_id] = max(0, cur - nbytes)

    # ------------------------------------------------------------------ touch
    def touch(self, site: Site, accesses: int = 1) -> None:
        """Record access traffic.  Private-pool sites are not profiled."""
        arena = self._arenas.get(site.site_id)
        if arena is not None:
            arena.accesses += accesses

    # ---------------------------------------------------------------- queries
    def arena_for(self, site: Site) -> Optional[Arena]:
        return self._arenas.get(site.site_id)

    def arena_by_id(self, arena_id: int) -> Optional[Arena]:
        for a in self._arenas.values():
            if a.arena_id == arena_id:
                return a
        return None

    def arenas(self) -> List[Arena]:
        return list(self._arenas.values())

    def __iter__(self) -> Iterator[Arena]:
        return iter(self._arenas.values())

    @property
    def private_pool_bytes(self) -> int:
        return sum(self._private_bytes.values())

    @property
    def shared_bytes(self) -> int:
        return sum(a.resident_bytes for a in self._arenas.values())

    def fast_tier_bytes(self) -> int:
        """Bytes currently on the fast tier (private pool is always fast)."""
        return self.private_pool_bytes + sum(a.fast_bytes for a in self._arenas.values())

    def reset_access_counters(self) -> None:
        for a in self._arenas.values():
            a.accesses = 0

    def scale_access_counters(self, factor: float) -> None:
        """Profile reweighting hook (Algorithm 1's optional ReweightProfile)."""
        for a in self._arenas.values():
            a.accesses = int(a.accesses * factor)
